// RSS growth probe: Literal-execute vs buffer-execute paths
// (needs --features xla + `make artifacts`; the stub backend errors out)
use seedflood::model::{Manifest, ParamStore};
use seedflood::runtime::{loss_args, Runtime};
use seedflood::xla;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    for l in s.lines() {
        if let Some(v) = l.strip_prefix("VmRSS:") {
            return v.trim().trim_end_matches(" kB").trim().parse::<f64>().unwrap() / 1024.0;
        }
    }
    0.0
}

fn main() -> anyhow::Result<()> {
    let m = Manifest::load("artifacts/tiny_manifest.json")?;
    let rt = Runtime::cpu("artifacts")?;
    let exe = rt.load(&m, "loss")?;
    let params = ParamStore::init(&m, 0);
    let ids: Vec<i32> = (0..m.config.batch * m.config.seq).map(|i| (i % 200 + 4) as i32).collect();
    let labels: Vec<i32> = (0..m.config.batch).map(|i| (i % 2) as i32).collect();
    let ct = vec![2, 3];

    println!("start RSS {:.0} MB", rss_mb());
    for it in 0..400 {
        let args = loss_args(&params, &ids, vec![m.config.batch, m.config.seq], &labels, &ct);
        let _ = exe.run(&args)?;
        if it % 100 == 99 { println!("literal path it {}: RSS {:.0} MB", it + 1, rss_mb()); }
    }
    // buffer path
    for it in 0..400 {
        let mut bufs = vec![];
        for t in &params.tensors { bufs.push(rt.upload_f32(&t.data, &t.shape)?); }
        bufs.push(rt.upload_i32(&ids, &[m.config.batch, m.config.seq])?);
        bufs.push(rt.upload_i32(&labels, &[m.config.batch])?);
        bufs.push(rt.upload_i32(&ct, &[2])?);
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let _ = exe.run_b(&refs)?;
        if it % 100 == 99 { println!("buffer path it {}: RSS {:.0} MB", it + 1, rss_mb()); }
    }
    Ok(())
}
