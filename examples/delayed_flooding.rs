//! Delayed flooding (paper §4.5 / Fig 7): sweep the per-iteration flooding
//! budget k and show that moderate k matches full flooding while extreme
//! truncation (k = 1) degrades — the bounded-staleness behaviour.
//!
//!   cargo run --release --example delayed_flooding -- [--clients 16] [--steps 400]

use seedflood::config::{ExperimentConfig, Method};
use seedflood::sim;
use seedflood::topology::{Kind, Topology};
use seedflood::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let clients: usize = args.get_parse("clients", 16)?;
    let steps: usize = args.get_parse("steps", 400)?;
    let diameter = Topology::ring(clients).diameter();
    println!("ring of {clients} clients, diameter D = {diameter}");

    let base = ExperimentConfig {
        method: Method::SeedFlood,
        model: "tiny".into(),
        task: "sst2".into(),
        clients,
        topology: Kind::Ring,
        steps,
        lr: 1e-3,
        init_from: if std::path::Path::new("checkpoints/tiny_pretrained.sfck").exists() {
            "checkpoints/tiny_pretrained.sfck".into()
        } else {
            String::new()
        },
        ..Default::default()
    };

    println!("\n{:>10} {:>10} {:>8} {:>16}", "k (hops)", "staleness", "GMP%", "bytes/edge");
    for k in [1usize, 2, 4, diameter] {
        let cfg = ExperimentConfig { flood_steps: k, ..base.clone() };
        let r = sim::run_experiment(cfg)?;
        let staleness = diameter.div_ceil(k);
        println!("{k:>10} {staleness:>9}i {:>8.2} {:>16.0}", 100.0 * r.gmp, r.per_edge_bytes);
    }
    println!("\n(k = D ≡ full flooding; staleness = ⌈D/k⌉ iterations, paper §4.5)");
    Ok(())
}
