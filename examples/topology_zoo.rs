//! Topology zoo: the graph quantities that drive consensus behaviour —
//! diameter (flooding rounds needed), spectral gap (gossip mixing rate) —
//! across every topology the library ships, plus a flooding-coverage
//! demonstration on each (the paper's "topology-invariant consensus").
//!
//!   cargo run --release --example topology_zoo -- [--clients 32]

use seedflood::flood::{flood_rounds, FloodState};
use seedflood::net::{MsgId, Network, SeedUpdate};
use seedflood::topology::{Kind, Topology};
use seedflood::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n: usize = args.get_parse("clients", 32)?;

    println!(
        "{:<14} {:>6} {:>8} {:>10} {:>12} {:>14} {:>12}",
        "topology", "edges", "diam D", "spec gap", "cover@D?", "msgs flooded", "dup ratio"
    );
    for kind in [Kind::Ring, Kind::Meshgrid, Kind::Torus, Kind::SmallWorld,
                 Kind::ErdosRenyi, Kind::Star, Kind::Complete] {
        let topo = Topology::build(kind, n, 7);
        let (edges, d, gap) = (topo.num_edges(), topo.diameter(), topo.spectral_gap());
        let kindname = topo.kind.clone();

        // flood one message from every client; check full coverage at D
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(SeedUpdate {
                id: MsgId { origin: i as u32, step: 0 },
                seed: i as u64,
                coeff: 1.0,
            });
        }
        flood_rounds(&mut states, &mut net, d, |_, _| {});
        let covered = states.iter().all(|s| s.seen.len() == n);
        let dups: u64 = states.iter().map(|s| s.duplicates).sum();
        let total = net.acct.total_messages;
        println!(
            "{:<14} {:>6} {:>8} {:>10.4} {:>12} {:>14} {:>11.2}x",
            kindname, edges, d, gap,
            if covered { "yes" } else { "NO" },
            total,
            dups as f64 / (n * (n - 1)) as f64
        );
    }
    println!("\nperfect coverage after D rounds on every graph = the paper's");
    println!("topology-invariant consensus; gossip's mixing rate (spectral gap)");
    println!("varies by orders of magnitude across the same graphs.");
    Ok(())
}
