//! Quickstart: the SeedFlood public API in ~40 lines.
//!
//! Loads the AOT artifacts, builds a 8-client ring, runs a short SeedFlood
//! fine-tune on the sst2 analogue and prints GMP + communication cost.
//!
//!   make artifacts && cargo run --release --example quickstart

use seedflood::config::{ExperimentConfig, Method};
use seedflood::sim;
use seedflood::topology::Kind;
use seedflood::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig {
        method: Method::SeedFlood,
        model: "tiny".into(),
        task: "sst2".into(),
        clients: 8,
        topology: Kind::Ring,
        steps: 120,
        lr: 1e-3,
        eval_every: 40,
        // shared pretrained θ⁰ if available (see `seedflood pretrain`)
        init_from: if std::path::Path::new("checkpoints/tiny_pretrained.sfck").exists() {
            "checkpoints/tiny_pretrained.sfck".into()
        } else {
            String::new()
        },
        ..Default::default()
    };

    let record = sim::run_experiment(cfg)?;

    println!("\n== quickstart result ==");
    println!("method      {}", record.method);
    println!("GMP         {:.2}% (test accuracy of the averaged model)", 100.0 * record.gmp);
    println!("final loss  {:.4}", record.final_loss);
    println!("comm total  {}", human_bytes(record.total_bytes));
    println!("comm / edge {}", human_bytes(record.per_edge_bytes as u64));
    println!("wall        {:.1}s", record.wall_secs);
    for e in &record.evals {
        println!("  step {:>4}: loss {:.4} acc {:.3} consensus_err {:.2e}",
                 e.step, e.loss, e.accuracy, e.consensus_error);
    }
    Ok(())
}
