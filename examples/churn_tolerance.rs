//! Churn tolerance (ISSUE 2): the four-method comparison — DSGD, ChocoSGD,
//! DZSGD, SeedFlood — under the unreliable-network & churn scenario
//! presets, next to the reliable baseline. This is the regime the paper's
//! robustness claim (§3.3) targets and where related work says
//! decentralized training lives or dies (Go With The Flow,
//! arXiv:2509.21221; Graph-based Gossiping, arXiv:2506.10607).
//!
//! The grid is produced by the same harness as `seedflood experiment
//! churn` ([`seedflood::experiments::churn`]), so the two surfaces always
//! agree: every method runs the same number of iterations, because fault
//! windows live on the iteration clock (only the FO learning rate keeps
//! its Table 5 scale).
//!
//! Runs entirely on the synthetic backend — no artifacts needed:
//!
//!   cargo run --release --example churn_tolerance -- [--clients 16] [--steps 120]

use seedflood::config::ExperimentConfig;
use seedflood::experiments;
use seedflood::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let clients: usize = args.get_parse("clients", 16)?;
    let steps: usize = args.get_parse("steps", 120)?;
    println!(
        "{clients} clients, {steps} iterations per run (equal for every method; \
         reliable baseline runs on a ring), synthetic backend"
    );

    let base = ExperimentConfig {
        model: "synthetic".into(),
        task: "sst2".into(),
        clients,
        steps,
        lr: 1e-3,
        ..Default::default()
    };
    let scenarios: Vec<String> = ["", "lossy-ring", "flaky-torus", "churn-er"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let records = experiments::churn(&base, &scenarios)?;
    experiments::print_churn(&records);

    println!(
        "\n(SeedFlood answers loss and churn with gap-request repair: a recovering\n\
         client broadcasts O(n) high-water marks and neighbors return only the\n\
         missing ranges — the repairB column. Delivery degrades to bounded\n\
         staleness instead of silent loss, while dense gossip pays O(d) per edge\n\
         to achieve less. Compare --repair-mode reflood for the legacy full-log\n\
         re-flood cost.)"
    );
    Ok(())
}
