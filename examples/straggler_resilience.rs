//! Straggler resilience (ISSUE 4): SeedFlood vs the gossip baselines on
//! the event-driven virtual-time engine, under heterogeneous client
//! speeds — the regime the lockstep clock cannot even express, and where
//! related work argues decentralized training is actually decided (From
//! Promise to Practice, arXiv:2410.11998; Unifying Local Communications
//! and Local Updates, arXiv:2606.11081).
//!
//! Gossip methods mix simultaneous snapshots of every neighbor, so under
//! `--time-model event` they run through the barrier adapter: results
//! match lockstep exactly, but every iteration costs the cohort maximum
//! and the fast clients' waiting shows up as idle fraction. SeedFlood is
//! fully asynchronous: a client floods its seed the moment its local step
//! finishes, nobody waits, and slow clients surface as a *staleness
//! distribution* instead of wasted time.
//!
//! Runs entirely on the synthetic backend — no artifacts needed:
//!
//!   cargo run --release --example straggler_resilience -- \
//!       [--clients 16] [--steps 60] [--rates lognormal:0.7]
//!
//! Try `--rates stragglers:0.25,4` (a quarter of the fleet 4× slower) or
//! `--rates jitter:0.6` (per-step stalls — the worst case for barriers:
//! they pay Σ_t max_i while SeedFlood pays max_i Σ_t).

use seedflood::config::{ExperimentConfig, Method};
use seedflood::experiments::run_one;
use seedflood::sched::TimeModel;
use seedflood::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let clients: usize = args.get_parse("clients", 16)?;
    let steps: usize = args.get_parse("steps", 60)?;
    let rates = args.get_or("rates", "lognormal:0.7").to_string();
    println!(
        "{clients} clients, {steps} local steps each, event-driven virtual time, \
         rates {rates} (synthetic backend)"
    );

    let base = ExperimentConfig {
        model: "synthetic".into(),
        task: "sst2".into(),
        clients,
        steps,
        lr: 1e-3,
        time_model: TimeModel::Event,
        rates,
        ..Default::default()
    };

    println!(
        "\n{:<12} {:>8} {:>10} {:>12} {:>8} {:>18}",
        "method", "GMP%", "makespan", "idle%", "policy", "staleness p50/99"
    );
    for method in [Method::Dsgd, Method::ChocoSgd, Method::Dzsgd, Method::SeedFlood] {
        let mut cfg = base.clone();
        cfg.method = method;
        if !method.is_zeroth_order() {
            cfg.lr = base.lr * 10.0; // FO tolerates larger steps (Table 5)
        }
        let r = run_one(cfg)?;
        let policy = if method == Method::SeedFlood { "async" } else { "barrier" };
        println!(
            "{:<12} {:>8.2} {:>10.1} {:>12.1} {:>8} {:>15}/{}",
            r.method,
            100.0 * r.gmp,
            r.virtual_makespan,
            100.0 * r.idle_frac,
            policy,
            r.staleness_p50,
            r.staleness_p99,
        );
    }

    println!(
        "\n(makespan is virtual time in nominal-step units. Barrier methods wait\n\
         for the slowest client every iteration — identical results to lockstep,\n\
         paid for in idle time; SeedFlood floods each seed the moment its local\n\
         step finishes, so heterogeneity becomes bounded staleness instead of\n\
         waiting. Compare --rates jitter:0.6, where the per-step cohort maximum\n\
         makes the barrier tax strict.)"
    );
    Ok(())
}
