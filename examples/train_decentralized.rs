//! End-to-end driver (the repro brief's required example): pretrain a
//! transformer from scratch with the FO oracle, then fine-tune it
//! *decentralized* with SeedFlood across a ring of clients, logging the
//! full loss curve, GMP trajectory, communication cost, and the Table-4
//! style GE/MA phase breakdown. Also runs the DSGD reference for the
//! FO-vs-ZO comparison (paper Fig 3's trade-off).
//!
//!   cargo run --release --example train_decentralized -- \
//!       [--model tiny] [--clients 8] [--steps 600] [--task sst2]
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use seedflood::config::{ExperimentConfig, Method};
use seedflood::experiments;
use seedflood::sim;
use seedflood::topology::Kind;
use seedflood::util::cli::Args;
use seedflood::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let model = args.get_or("model", "tiny").to_string();
    let clients: usize = args.get_parse("clients", 8)?;
    let steps: usize = args.get_parse("steps", 600)?;
    let task = args.get_or("task", "sst2").to_string();
    let ckpt = format!("checkpoints/{model}_e2e.sfck");

    // Phase 1: pretrain the shared θ⁰ (the substitute for OPT's pretrained
    // weights — stopped inside the paper's zero-shot band; see DESIGN.md)
    println!("== phase 1: pretraining shared θ⁰ ({model}) ==");
    experiments::pretrain(&model, "artifacts", &ckpt, 0, 2000, 1e-2, 0, 0.66)?;

    // Phase 2: decentralized ZO fine-tuning with SeedFlood
    println!("\n== phase 2: SeedFlood across {clients} clients (ring) ==");
    let cfg = ExperimentConfig {
        method: Method::SeedFlood,
        model: model.clone(),
        task: task.clone(),
        clients,
        topology: Kind::Ring,
        steps,
        lr: 1e-3,
        eval_every: (steps / 8).max(1),
        init_from: ckpt.clone(),
        ..Default::default()
    };
    let sf = sim::run_experiment(cfg.clone())?;
    println!("\nloss curve (every {} steps):", (steps / 20).max(1));
    for (i, chunk) in sf.train_losses.chunks((steps / 20).max(1)).enumerate() {
        let mean: f64 = chunk.iter().sum::<f64>() / chunk.len() as f64;
        println!("  step {:>5}: train loss {mean:.4}", i * (steps / 20).max(1));
    }
    for e in &sf.evals {
        println!("  eval @ {:>5}: loss {:.4} acc {:.3} bytes {}", e.step, e.loss,
                 e.accuracy, human_bytes(e.total_bytes));
    }

    // Phase 3: the DSGD reference (FO upper line of Fig 3, 10x fewer steps)
    println!("\n== phase 3: DSGD reference ==");
    let dsgd = sim::run_experiment(ExperimentConfig {
        method: Method::Dsgd,
        steps: (steps / 10).max(1),
        lr: 1e-2,
        eval_every: 0,
        ..cfg
    })?;

    println!("\n== e2e summary ({task}, {clients} clients) ==");
    println!("{:<12} {:>8} {:>12} {:>14} {:>8}", "method", "GMP%", "loss", "cost/edge", "wall s");
    for r in [&sf, &dsgd] {
        println!(
            "{:<12} {:>8.2} {:>12.4} {:>14} {:>8.1}",
            r.method, 100.0 * r.gmp, r.final_loss,
            human_bytes(r.per_edge_bytes as u64), r.wall_secs
        );
    }
    for (phase, ms) in &sf.phase_ms {
        println!("SeedFlood phase {phase}: {:.0} ms total", ms);
    }
    let ratio = dsgd.per_edge_bytes / sf.per_edge_bytes.max(1.0);
    println!("\nSeedFlood used {ratio:.0}x less communication per edge than DSGD");
    sf.save("results/e2e_seedflood.json")?;
    dsgd.save("results/e2e_dsgd.json")?;
    println!("records saved to results/e2e_*.json");
    Ok(())
}
