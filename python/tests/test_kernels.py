"""L1 kernel correctness: pallas vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shapes/dtypes per the repro brief; deadline disabled
because interpret-mode pallas first-call compilation is slow.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, matmul_ref, subcge_apply, subcge_apply_ref


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (8, 8, 8), (16, 32, 16), (128, 64, 128), (256, 128, 512),
        (1, 64, 64), (7, 13, 5), (130, 70, 34),  # non-power-of-two / ragged
    ])
    def test_matches_ref(self, m, k, n):
        x, y = rand(0, m, k), rand(1, k, n)
        np.testing.assert_allclose(
            np.asarray(matmul(x, y)), np.asarray(matmul_ref(x, y)),
            rtol=1e-5, atol=1e-5)

    def test_block_adaptation(self):
        # bm/bn larger than dims must adapt down to divisors
        x, y = rand(2, 3, 5), rand(3, 5, 9)
        np.testing.assert_allclose(
            np.asarray(matmul(x, y, bm=512, bn=512)),
            np.asarray(matmul_ref(x, y)), rtol=1e-5, atol=1e-5)

    def test_identity(self):
        x = rand(4, 32, 32)
        eye = jnp.eye(32, dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, eye)),
                                   np.asarray(x), rtol=1e-6, atol=1e-6)

    @settings(deadline=None, max_examples=20)
    @given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shapes(self, m, k, n, seed):
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k), jnp.float32)
        y = jax.random.normal(ky, (k, n), jnp.float32)
        np.testing.assert_allclose(np.asarray(matmul(x, y)),
                                   np.asarray(matmul_ref(x, y)),
                                   rtol=1e-4, atol=1e-4)


class TestSubCGE:
    @pytest.mark.parametrize("m,n,r", [
        (16, 16, 4), (64, 128, 32), (256, 64, 64), (33, 17, 8),
    ])
    def test_matches_ref(self, m, n, r):
        theta, u, v = rand(0, m, n), rand(1, m, r), rand(2, n, r)
        a = rand(3, r, r)
        np.testing.assert_allclose(
            np.asarray(subcge_apply(theta, u, a, v)),
            np.asarray(subcge_apply_ref(theta, u, a, v)),
            rtol=1e-4, atol=1e-4)

    def test_zero_coefficients_noop(self):
        theta, u, v = rand(0, 32, 48), rand(1, 32, 8), rand(2, 48, 8)
        a = jnp.zeros((8, 8), jnp.float32)
        np.testing.assert_allclose(np.asarray(subcge_apply(theta, u, a, v)),
                                   np.asarray(theta), rtol=0, atol=0)

    def test_single_coordinate_is_rank1_axpy(self):
        """A with one entry == the paper's single seed-scalar message:
        theta - c * U[:,i] V[:,j]^T (Eq. 9/10 consistency)."""
        m, n, r, i, j, c = 24, 40, 16, 3, 11, 0.37
        theta, u, v = rand(0, m, n), rand(1, m, r), rand(2, n, r)
        a = jnp.zeros((r, r), jnp.float32).at[i, j].set(c)
        want = theta - c * jnp.outer(u[:, i], v[:, j])
        np.testing.assert_allclose(np.asarray(subcge_apply(theta, u, a, v)),
                                   np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_additivity(self):
        """Aggregating k messages at once == applying them one by one —
        the invariant that lets SeedFlood batch flooded updates."""
        m, n, r = 32, 32, 8
        theta, u, v = rand(0, m, n), rand(1, m, r), rand(2, n, r)
        msgs = [(0, 1, 0.5), (3, 3, -0.2), (0, 1, 0.1), (7, 2, 1.5)]
        a = jnp.zeros((r, r), jnp.float32)
        seq = theta
        for i, j, c in msgs:
            a = a.at[i, j].add(c)
            one = jnp.zeros((r, r), jnp.float32).at[i, j].set(c)
            seq = subcge_apply(seq, u, one, v)
        batched = subcge_apply(theta, u, a, v)
        np.testing.assert_allclose(np.asarray(batched), np.asarray(seq),
                                   rtol=1e-4, atol=1e-5)

    @settings(deadline=None, max_examples=15)
    @given(m=st.integers(2, 80), n=st.integers(2, 80), r=st.integers(1, 32),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shapes(self, m, n, r, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        theta = jax.random.normal(ks[0], (m, n), jnp.float32)
        u = jax.random.normal(ks[1], (m, r), jnp.float32)
        v = jax.random.normal(ks[2], (n, r), jnp.float32)
        a = jax.random.normal(ks[3], (r, r), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(subcge_apply(theta, u, a, v)),
            np.asarray(subcge_apply_ref(theta, u, a, v)),
            rtol=1e-3, atol=1e-3)
