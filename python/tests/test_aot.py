"""AOT pipeline tests: HLO-text lowering, manifest consistency, and the
artifact signature contract that the rust runtime relies on."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, model


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:50]
    assert "ROOT" in text
    # text (not serialized proto) is the interchange format — must be ASCII
    text.encode("ascii")


def test_pallas_kernel_lowers_into_hlo_text():
    from compile.kernels import matmul

    lowered = jax.jit(lambda x, y: (matmul(x, y),)).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "dot(" in text or "dot " in text  # interpret-mode lowers to HLO dots


def test_param_specs_cover_all_artifact_inputs():
    cfg = configs.get("tiny")
    specs = model.param_specs(cfg)
    names = [n for n, _ in specs]
    assert len(names) == len(set(names)), "duplicate param names"
    # 2D params (SubCGE scope) must include every weight matrix
    two_d = [n for n, s in specs if len(s) == 2]
    assert "embed.tok" in two_d
    assert all(f"block{l}.attn.wq" in two_d for l in range(cfg.layers))


def test_lora_specs_shapes(for_rank=4):
    cfg = configs.get("tiny")
    specs = model.lora_specs(cfg, for_rank)
    assert len(specs) == 4 * cfg.layers  # A+B for wq and wv per layer
    for name, shape in specs:
        if name.endswith("lora_a"):
            assert shape == (cfg.dim, for_rank)
        else:
            assert shape == (for_rank, cfg.dim)


@pytest.mark.skipif(
    not os.path.exists("../artifacts/tiny_manifest.json"),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open("../artifacts/tiny_manifest.json") as f:
            return json.load(f)

    def test_params_match_model(self, manifest):
        cfg = configs.get("tiny")
        specs = model.param_specs(cfg)
        assert [p["name"] for p in manifest["params"]] == [n for n, _ in specs]
        assert [tuple(p["shape"]) for p in manifest["params"]] == [s for _, s in specs]

    def test_num_params_correct(self, manifest):
        cfg = configs.get("tiny")
        assert manifest["config"]["num_params"] == model.num_params(cfg)

    def test_artifact_files_exist_and_are_hlo(self, manifest):
        for tag, art in manifest["artifacts"].items():
            path = os.path.join("../artifacts", art["file"])
            assert os.path.exists(path), f"{tag}: {path} missing"
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), f"{tag} is not HLO text"

    def test_loss_signature(self, manifest):
        cfg = configs.get("tiny")
        loss = manifest["artifacts"]["loss"]
        n_params = len(manifest["params"])
        assert len(loss["inputs"]) == n_params + 3
        assert loss["inputs"][-3]["shape"] == [cfg.batch, cfg.seq]
        assert loss["inputs"][-1]["shape"] == [aot.NUM_CLASSES]
        assert [o["name"] for o in loss["outputs"]] == ["loss", "correct"]

    def test_grad_outputs_mirror_params(self, manifest):
        grad = manifest["artifacts"]["grad"]
        n_params = len(manifest["params"])
        assert len(grad["outputs"]) == 1 + n_params
        for o, p in zip(grad["outputs"][1:], manifest["params"]):
            assert o["shape"] == p["shape"], o["name"]

    def test_subcge_signature(self, manifest):
        sub = manifest["artifacts"]["subcge"]
        n2d = len(manifest["params2d"])
        r = manifest["config"]["subcge_rank"]
        assert len(sub["inputs"]) == 4 * n2d
        assert len(sub["outputs"]) == n2d
        # A matrices are the last n2d inputs, all (r, r)
        for a in sub["inputs"][3 * n2d:]:
            assert a["shape"] == [r, r]
