"""L2 model tests: shapes, determinism, learnability, pallas-path parity,
FO-grad sanity, LoRA wiring, SubCGE whole-model apply."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

CFG = configs.get("tiny")


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    ids = jax.random.randint(k1, (CFG.batch, CFG.seq), 0, CFG.vocab)
    label = jax.random.randint(k2, (CFG.batch,), 0, 2)
    cls = jnp.array([5, 6], jnp.int32)
    return ids, label, cls


def test_param_specs_order_stable(params):
    specs = model.param_specs(CFG)
    assert specs[0][0] == "embed.tok"
    assert specs[-1][0] == "final.ln.bias"
    assert len(specs) == 2 + 16 * CFG.layers + 2
    for (name, shape), arr in zip(specs, params):
        assert arr.shape == shape, name


def test_num_params_matches(params):
    total = sum(int(np.prod(p.shape)) for p in params)
    assert total == model.num_params(CFG)


def test_logits_shape(params, batch):
    ids, _, _ = batch
    logits = model.forward_logits(CFG, params, ids)
    assert logits.shape == (CFG.batch, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_deterministic(params, batch):
    l1, c1 = model.loss_fn(CFG, params, *batch)
    l2, c2 = model.loss_fn(CFG, params, *batch)
    assert float(l1) == float(l2) and float(c1) == float(c2)


def test_loss_near_log2_at_init(params, batch):
    # random init + 2-way candidate scoring => loss ~ ln 2
    loss, correct = model.loss_fn(CFG, params, *batch)
    assert 0.1 < float(loss) < 3.0
    assert 0 <= float(correct) <= CFG.batch


def test_pallas_path_matches_native(params, batch):
    """The L1-kernel-in-L2 composition: identical numerics to native dots."""
    l_native, c_native = model.loss_fn(CFG, params, *batch, use_pallas=False)
    l_pallas, c_pallas = model.loss_fn(CFG, params, *batch, use_pallas=True)
    np.testing.assert_allclose(float(l_native), float(l_pallas),
                               rtol=1e-4, atol=1e-5)
    assert float(c_native) == float(c_pallas)


def test_grad_descends(params, batch):
    ids, label, cls = batch

    def scalar(ps):
        return model.loss_fn(CFG, ps, ids, label, cls)[0]

    loss0, grads = jax.value_and_grad(scalar)(params)
    stepped = [p - 0.05 * g for p, g in zip(params, grads)]
    loss1 = scalar(stepped)
    assert float(loss1) < float(loss0)


def test_grad_matches_finite_difference(params, batch):
    ids, label, cls = batch

    def scalar(ps):
        return model.loss_fn(CFG, ps, idx_ids, label, cls)[0] if False else \
            model.loss_fn(CFG, ps, ids, label, cls)[0]

    grads = jax.grad(scalar)(params)
    # probe one direction with central differences
    z = [jax.random.normal(jax.random.PRNGKey(7 + i), p.shape, jnp.float32)
         for i, p in enumerate(params)]
    eps = 1e-3
    plus = [p + eps * zi for p, zi in zip(params, z)]
    minus = [p - eps * zi for p, zi in zip(params, z)]
    fd = (float(scalar(plus)) - float(scalar(minus))) / (2 * eps)
    analytic = float(sum(jnp.vdot(g, zi) for g, zi in zip(grads, z)))
    np.testing.assert_allclose(fd, analytic, rtol=5e-2, atol=5e-3)


def test_lora_zero_b_is_identity(params, batch):
    """LoRA with B=0 must not change the loss (standard LoRA init)."""
    lspecs = model.lora_specs(CFG, 4)
    lora = []
    for name, shape in lspecs:
        if name.endswith("lora_a"):
            lora.append(0.1 * jax.random.normal(
                jax.random.PRNGKey(len(lora)), shape, jnp.float32))
        else:
            lora.append(jnp.zeros(shape, jnp.float32))
    l0, _ = model.loss_fn(CFG, params, *batch)
    l1, _ = model.loss_fn(CFG, params, *batch, lora=lora)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)


def test_lora_nonzero_changes_loss(params, batch):
    lspecs = model.lora_specs(CFG, 4)
    lora = [0.3 * jax.random.normal(jax.random.PRNGKey(i), s, jnp.float32)
            for i, (_, s) in enumerate(lspecs)]
    l0, _ = model.loss_fn(CFG, params, *batch)
    l1, _ = model.loss_fn(CFG, params, *batch, lora=lora)
    assert float(l0) != float(l1)


def test_subcge_apply_all_matches_ref(params):
    p2d = [p for p in params if p.ndim == 2]
    r = 16
    keys = jax.random.split(jax.random.PRNGKey(3), 3 * len(p2d))
    us = [jax.random.normal(keys[3 * i], (p.shape[0], r)) for i, p in enumerate(p2d)]
    vs = [jax.random.normal(keys[3 * i + 1], (p.shape[1], r)) for i, p in enumerate(p2d)]
    amats = [0.01 * jax.random.normal(keys[3 * i + 2], (r, r)) for i, p in enumerate(p2d)]
    out = model.subcge_apply_all(p2d, us, vs, amats)
    for o, t, u, v, a in zip(out, p2d, us, vs, amats):
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(t - u @ a @ v.T),
                                   rtol=1e-4, atol=1e-4)


def test_zo_spsa_estimator_descends(params, batch):
    """A few SPSA steps reduce loss in expectation — the L2 contract the
    rust zo/ module relies on."""
    ids, label, cls = batch

    def scalar(ps):
        return float(model.loss_fn(CFG, ps, ids, label, cls)[0])

    ps = list(params)
    eps, lr = 1e-3, 1e-2
    loss_start = scalar(ps)
    key = jax.random.PRNGKey(42)
    for t in range(8):
        key, sub = jax.random.split(key)
        z = [jax.random.normal(jax.random.fold_in(sub, i), p.shape)
             for i, p in enumerate(ps)]
        lp = scalar([p + eps * zi for p, zi in zip(ps, z)])
        lm = scalar([p - eps * zi for p, zi in zip(ps, z)])
        alpha = (lp - lm) / (2 * eps)
        ps = [p - lr * alpha * zi for p, zi in zip(ps, z)]
    assert scalar(ps) < loss_start + 0.5  # no blow-up; usually decreases
