"""Build-time python package: L2 jax model + L1 pallas kernels + AOT export.

Never imported at runtime — the rust coordinator only consumes the HLO text
artifacts and JSON manifest emitted by ``python -m compile.aot``.
"""
