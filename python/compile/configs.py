"""Model size configurations for the SeedFlood reproduction.

Each config describes a decoder-only, pre-LN transformer LM (OPT-style
block layout).  The paper fine-tunes pretrained OPT checkpoints; we train
the same architecture from scratch at configurable scale (see
DESIGN.md#Substitutions).  The ``opt125m`` entry mirrors the real OPT-125M
shape and is used for shape/byte accounting only (too slow to train on the
CPU-PJRT substrate).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    seq: int
    dim: int
    layers: int
    heads: int
    mlp_ratio: int = 4
    batch: int = 8  # fixed batch shape baked into each AOT artifact

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads

    @property
    def mlp_dim(self) -> int:
        return self.dim * self.mlp_ratio


CONFIGS = {
    # test-scale: fast enough for pytest + rust integration tests
    "tiny": ModelConfig("tiny", vocab=256, seq=32, dim=64, layers=2, heads=4, batch=8),
    # default experiment scale (paper tables/figures run at this size)
    "small": ModelConfig("small", vocab=512, seq=64, dim=128, layers=4, heads=8, batch=8),
    # e2e example scale
    "base": ModelConfig("base", vocab=1024, seq=64, dim=256, layers=6, heads=8, batch=8),
    # ~27M params, used by examples/train_decentralized at --model medium
    "medium": ModelConfig("medium", vocab=2048, seq=128, dim=512, layers=8, heads=8, batch=8),
    # shape mirror of OPT-125M (accounting only; never trained here)
    "opt125m": ModelConfig("opt125m", vocab=50272, seq=2048, dim=768, layers=12, heads=12, batch=1),
}


def get(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown model config {name!r}; have {sorted(CONFIGS)}")
    return CONFIGS[name]
