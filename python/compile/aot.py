"""AOT export: lower the L2 jax graphs (containing the L1 pallas kernels)
to HLO *text* artifacts + a JSON manifest the rust runtime consumes.

HLO text — NOT ``lowered.compile()`` / serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published ``xla``
crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Artifacts per model config (shapes are baked in; B = cfg.batch):

  <cfg>_loss          params.., ids(B,S)i32, label(B)i32, cls(C)i32
                      -> (loss f32[], correct f32[])
  <cfg>_grad          same inputs -> (loss, d_param0, d_param1, ...)
  <cfg>_loss_lora     params.., loraA/B.., ids, label, cls -> (loss, correct)
  <cfg>_grad_lora     same -> (loss, d_loraA0, d_loraB0, ...)  [LoRA grads only]
  <cfg>_subcge        params2d.., U.., V.., A..  -> (updated params2d..)
                      [the L1 pallas SubCGE kernel, paper Eq. 10]
  <cfg>_loss_pallas   loss with every linear routed through the L1 pallas
                      matmul kernel (tiny config only: proves composition)

Usage: cd python && python -m compile.aot --config tiny --out-dir ../artifacts
"""

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model

NUM_CLASSES = 2
LORA_RANK = 8
SUBCGE_RANK = 64  # max rank; smaller effective ranks restrict coordinates


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def _batch_specs(cfg):
    return [
        _spec((cfg.batch, cfg.seq), jnp.int32),
        _spec((cfg.batch,), jnp.int32),
        _spec((NUM_CLASSES,), jnp.int32),
    ]


def _batch_io(cfg):
    return [
        _io("input_ids", (cfg.batch, cfg.seq), "i32"),
        _io("label_class", (cfg.batch,), "i32"),
        _io("class_tokens", (NUM_CLASSES,), "i32"),
    ]


def build_artifacts(cfg_name: str, out_dir: str, *, with_pallas_loss: bool):
    cfg = configs.get(cfg_name)
    pspecs = model.param_specs(cfg)
    lspecs = model.lora_specs(cfg, LORA_RANK)
    np_, nl = len(pspecs), len(lspecs)
    p2d = [(n, s) for n, s in pspecs if len(s) == 2]

    param_in = [_spec(s) for _, s in pspecs]
    lora_in = [_spec(s) for _, s in lspecs]

    manifest = {
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "seq": cfg.seq,
            "dim": cfg.dim, "layers": cfg.layers, "heads": cfg.heads,
            "mlp_ratio": cfg.mlp_ratio, "batch": cfg.batch,
            "num_classes": NUM_CLASSES, "lora_rank": LORA_RANK,
            "subcge_rank": SUBCGE_RANK,
            "num_params": int(sum(int(jnp.prod(jnp.array(s))) for _, s in pspecs)),
        },
        "params": [{"name": n, "shape": list(s)} for n, s in pspecs],
        "lora_params": [{"name": n, "shape": list(s)} for n, s in lspecs],
        "params2d": [n for n, _ in p2d],
        "artifacts": {},
    }

    def emit(tag, fn, in_specs, in_io, out_io):
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}_{tag}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][tag] = {
            "file": fname, "inputs": in_io, "outputs": out_io,
        }
        print(f"  {fname}: {len(text)} chars, {len(in_io)} inputs")

    params_io = [_io(n, s, "f32") for n, s in pspecs]
    lora_io = [_io(n, s, "f32") for n, s in lspecs]

    # ---- loss -------------------------------------------------------------
    def loss_flat(*args):
        params = list(args[:np_])
        ids, label, cls = args[np_:]
        return model.loss_fn(cfg, params, ids, label, cls)

    emit("loss", loss_flat, param_in + _batch_specs(cfg),
         params_io + _batch_io(cfg),
         [_io("loss", (), "f32"), _io("correct", (), "f32")])

    # ---- grad (FO baselines: DSGD / ChocoSGD) ------------------------------
    def grad_flat(*args):
        params = list(args[:np_])
        ids, label, cls = args[np_:]

        def scalar_loss(ps):
            return model.loss_fn(cfg, ps, ids, label, cls)[0]

        loss, grads = jax.value_and_grad(scalar_loss)(params)
        return (loss, *grads)

    emit("grad", grad_flat, param_in + _batch_specs(cfg),
         params_io + _batch_io(cfg),
         [_io("loss", (), "f32")] + [_io(f"d_{n}", s, "f32") for n, s in pspecs])

    # ---- loss_lora / grad_lora (LoRA baseline variants) ---------------------
    def loss_lora_flat(*args):
        params = list(args[:np_])
        lora = list(args[np_:np_ + nl])
        ids, label, cls = args[np_ + nl:]
        return model.loss_fn(cfg, params, ids, label, cls, lora=lora)

    emit("loss_lora", loss_lora_flat, param_in + lora_in + _batch_specs(cfg),
         params_io + lora_io + _batch_io(cfg),
         [_io("loss", (), "f32"), _io("correct", (), "f32")])

    def grad_lora_flat(*args):
        params = list(args[:np_])
        lora = list(args[np_:np_ + nl])
        ids, label, cls = args[np_ + nl:]

        def scalar_loss(lo):
            return model.loss_fn(cfg, params, ids, label, cls, lora=lo)[0]

        loss, grads = jax.value_and_grad(scalar_loss)(lora)
        return (loss, *grads)

    emit("grad_lora", grad_lora_flat, param_in + lora_in + _batch_specs(cfg),
         params_io + lora_io + _batch_io(cfg),
         [_io("loss", (), "f32")] + [_io(f"d_{n}", s, "f32") for n, s in lspecs])

    # ---- subcge apply (L1 pallas kernel, paper Eq. 10) ----------------------
    n2d = len(p2d)
    r = SUBCGE_RANK

    def subcge_flat(*args):
        thetas = list(args[:n2d])
        us = list(args[n2d:2 * n2d])
        vs = list(args[2 * n2d:3 * n2d])
        amats = list(args[3 * n2d:4 * n2d])
        return tuple(model.subcge_apply_all(thetas, us, vs, amats))

    sub_in = ([_spec(s) for _, s in p2d]
              + [_spec((s[0], r)) for _, s in p2d]
              + [_spec((s[1], r)) for _, s in p2d]
              + [_spec((r, r)) for _ in p2d])
    sub_io = ([_io(n, s, "f32") for n, s in p2d]
              + [_io(f"U_{n}", (s[0], r), "f32") for n, s in p2d]
              + [_io(f"V_{n}", (s[1], r), "f32") for n, s in p2d]
              + [_io(f"A_{n}", (r, r), "f32") for n, s in p2d])
    emit("subcge", subcge_flat, sub_in, sub_io,
         [_io(f"new_{n}", s, "f32") for n, s in p2d])

    # ---- loss through the pallas matmul kernel (composition proof) ----------
    if with_pallas_loss:
        def loss_pallas_flat(*args):
            params = list(args[:np_])
            ids, label, cls = args[np_:]
            return model.loss_fn(cfg, params, ids, label, cls, use_pallas=True)

        emit("loss_pallas", loss_pallas_flat, param_in + _batch_specs(cfg),
             params_io + _batch_io(cfg),
             [_io("loss", (), "f32"), _io("correct", (), "f32")])

    mpath = os.path.join(out_dir, f"{cfg.name}_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {os.path.basename(mpath)} written")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default="tiny,small",
                    help="comma-separated model config names")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file marker")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name in args.config.split(","):
        print(f"[aot] lowering config {name!r}")
        build_artifacts(name, args.out_dir,
                        with_pallas_loss=(name == "tiny"))
    # marker file so `make` can treat the whole set as one target
    with open(os.path.join(args.out_dir, "STAMP"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
