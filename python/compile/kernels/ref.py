"""Pure-jnp correctness oracles for the pallas kernels (L1).

These are the ground truth the pytest + hypothesis suites check the pallas
implementations against (assert_allclose), and double as the slow-path
implementations inside model.py when ``use_pallas=False``.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Oracle for kernels.matmul.matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def subcge_apply_ref(theta, u, a, v):
    """Oracle for kernels.subcge.subcge_apply: theta - u @ a @ v^T."""
    return theta - (u @ a @ v.T).astype(theta.dtype)
