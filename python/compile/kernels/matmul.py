"""L1 pallas kernel: blocked matmul used by the transformer's linear layers.

TPU mapping (DESIGN.md#Hardware-Adaptation): the BlockSpec grid expresses
the HBM->VMEM schedule — an (bm, K) panel of ``x`` and a (K, bn) panel of
``y`` are staged into VMEM per program instance and contracted on the MXU.
K is kept whole per block because every contraction in our models has
K <= mlp_dim <= 2048, i.e. the K-panels fit VMEM comfortably
(bm*K + K*bn + bm*bn floats < 16 MiB for the default 128x128 blocks).

``interpret=True`` is mandatory on this CPU-PJRT image: real TPU lowering
emits a Mosaic custom-call the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    # One (bm, K) x (K, bn) contraction per program instance; f32 accumulate.
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (>=1)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128) -> jax.Array:
    """Blocked pallas matmul ``x @ y`` for 2D f32 operands.

    Block sizes adapt downward to divide the operand dims so the kernel is
    usable across every layer shape in the model family.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)
