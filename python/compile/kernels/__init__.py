"""L1 pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""

from .matmul import matmul
from .subcge import subcge_apply
from .ref import matmul_ref, subcge_apply_ref

__all__ = ["matmul", "subcge_apply", "matmul_ref", "subcge_apply_ref"]
