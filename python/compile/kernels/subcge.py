"""L1 pallas kernel: the SubCGE aggregation hot path.

Applies a batch of canonical-coordinate zeroth-order updates to one 2D
layer in a single fused pass (paper Eq. 10):

    theta <- theta - U @ A @ V^T

where ``A`` (r x r) accumulates the flooded seed-scalar messages
(A[i_k, j_k] += coeff_k, done by the rust coordinator in O(1) per message)
and U (a x r), V (b x r) are the globally shared subspace factors.

TPU mapping (DESIGN.md#Hardware-Adaptation): grid over row panels of
theta/U; per program instance a (bm, r) panel of U is staged to VMEM,
contracted with the VMEM-resident A (r x r) on the MXU, then contracted
with V^T (r x b, also VMEM-resident since r <= 64), and the subtraction is
fused into the same pass — exactly one HBM read and one HBM write of theta.
This replaces the O(n·d) stream of axpy's of the dense MeZO path with two
MXU-friendly small matmuls, which is the paper's Figure 5 claim.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _subcge_kernel(theta_ref, u_ref, a_ref, v_ref, o_ref):
    # T = U_blk @ A    : (bm, r) @ (r, r)
    t = jnp.dot(u_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    # out = theta_blk - T @ V^T : (bm, r) @ (r, b)
    upd = jnp.dot(t, v_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = theta_ref[...] - upd.astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def subcge_apply(theta: jax.Array, u: jax.Array, a: jax.Array, v: jax.Array,
                 *, bm: int = 256) -> jax.Array:
    """Fused ``theta - u @ a @ v.T`` for one 2D layer.

    theta: (m, n) f32, u: (m, r), a: (r, r), v: (n, r).
    """
    m, n = theta.shape
    r = u.shape[1]
    assert u.shape == (m, r) and v.shape == (n, r) and a.shape == (r, r), (
        theta.shape, u.shape, a.shape, v.shape)
    bm = _pick_block(m, bm)
    grid = (m // bm,)
    return pl.pallas_call(
        _subcge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),   # theta row panel
            pl.BlockSpec((bm, r), lambda i: (i, 0)),   # U row panel
            pl.BlockSpec((r, r), lambda i: (0, 0)),    # A resident
            pl.BlockSpec((n, r), lambda i: (0, 0)),    # V resident
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), theta.dtype),
        interpret=True,
    )(theta, u, a, v)
