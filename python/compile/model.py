"""L2: the jax transformer LM used by every SeedFlood experiment.

Decoder-only, pre-LN (OPT-style block layout), LM head tied to the token
embedding.  Classification follows the MeZO prompt convention: the model
scores the C task verbalizer tokens at the last sequence position and the
loss is cross-entropy over those C candidate scores (Malladi et al. 2023).

Parameters travel as a *flat ordered list* of arrays; ``param_specs``
defines the canonical order which ``aot.py`` records in the artifact
manifest and the rust ``model::ParamStore`` mirrors exactly.

``use_pallas=True`` routes every linear layer through the L1 pallas matmul
kernel so the lowered HLO contains the kernel (the ``loss_pallas``
artifact proves the three-layer composition end to end); the default path
uses XLA-native dots, which is what the training experiments run (see
DESIGN.md#Perf — interpret-mode pallas is a correctness vehicle on this
CPU image, not a speed one).
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.matmul import matmul as pallas_matmul
from .kernels.subcge import subcge_apply as pallas_subcge_apply


# --------------------------------------------------------------------------
# Parameter layout
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list. Order is the ABI between python & rust."""
    d, md = cfg.dim, cfg.mlp_dim
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed.tok", (cfg.vocab, d)),
        ("embed.pos", (cfg.seq, d)),
    ]
    for l in range(cfg.layers):
        p = f"block{l}"
        specs += [
            (f"{p}.ln1.scale", (d,)),
            (f"{p}.ln1.bias", (d,)),
            (f"{p}.attn.wq", (d, d)),
            (f"{p}.attn.bq", (d,)),
            (f"{p}.attn.wk", (d, d)),
            (f"{p}.attn.bk", (d,)),
            (f"{p}.attn.wv", (d, d)),
            (f"{p}.attn.bv", (d,)),
            (f"{p}.attn.wo", (d, d)),
            (f"{p}.attn.bo", (d,)),
            (f"{p}.ln2.scale", (d,)),
            (f"{p}.ln2.bias", (d,)),
            (f"{p}.mlp.fc1", (d, md)),
            (f"{p}.mlp.b1", (md,)),
            (f"{p}.mlp.fc2", (md, d)),
            (f"{p}.mlp.b2", (d,)),
        ]
    specs += [
        ("final.ln.scale", (d,)),
        ("final.ln.bias", (d,)),
    ]
    return specs


def lora_specs(cfg: ModelConfig, rank: int) -> List[Tuple[str, Tuple[int, ...]]]:
    """LoRA adapters on q_proj and v_proj (paper Appendix B.3)."""
    d = cfg.dim
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    for l in range(cfg.layers):
        for proj in ("wq", "wv"):
            specs.append((f"block{l}.attn.{proj}.lora_a", (d, rank)))
            specs.append((f"block{l}.attn.{proj}.lora_b", (rank, d)))
    return specs


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    """Random init matching the canonical order (scaled-normal / zeros)."""
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".scale"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".bias", ".bq", ".bk", ".bv", ".bo", ".b1", ".b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            std = 0.02 if name.startswith("embed") else fan_in ** -0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _linear(x, w, b, use_pallas: bool):
    """x: (..., k) @ w: (k, n) + b."""
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y = pallas_matmul(x2, w) if use_pallas else jnp.dot(
        x2, w, preferred_element_type=jnp.float32)
    return y.reshape(lead + (w.shape[1],)) + b


def forward_logits(cfg: ModelConfig, params: List[jax.Array],
                   input_ids: jax.Array, *, use_pallas: bool = False,
                   lora: List[jax.Array] = None,
                   lora_scale: float = 2.0) -> jax.Array:
    """Return logits at the LAST position only: (B, vocab).

    ``lora``: optional flat list in lora_specs order; adapters on wq/wv.
    """
    p = {name: arr for (name, _), arr in zip(param_specs(cfg), params)}
    la = {}
    if lora is not None:
        la = {name: arr for (name, _), arr in
              zip(lora_specs(cfg, lora[0].shape[1]), lora)}

    B, S = input_ids.shape
    h = p["embed.tok"][input_ids] + p["embed.pos"][None, :S, :]

    mask = jnp.tril(jnp.ones((S, S), jnp.float32))
    neg = jnp.float32(-1e9)

    def attn_proj(x, l, which):
        w = p[f"block{l}.attn.{which}"]
        b = p[f"block{l}.attn.b{which[-1]}"]
        y = _linear(x, w, b, use_pallas)
        ka, kb = f"block{l}.attn.{which}.lora_a", f"block{l}.attn.{which}.lora_b"
        if ka in la:
            y = y + lora_scale * _linear(_linear(x, la[ka], 0.0, use_pallas),
                                         la[kb], 0.0, use_pallas)
        return y

    for l in range(cfg.layers):
        x = _layer_norm(h, p[f"block{l}.ln1.scale"], p[f"block{l}.ln1.bias"])
        q = attn_proj(x, l, "wq").reshape(B, S, cfg.heads, cfg.head_dim)
        k = attn_proj(x, l, "wk").reshape(B, S, cfg.heads, cfg.head_dim)
        v = attn_proj(x, l, "wv").reshape(B, S, cfg.heads, cfg.head_dim)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (cfg.head_dim ** 0.5)
        scores = jnp.where(mask[None, None] > 0, scores, neg)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, S, cfg.dim)
        h = h + _linear(o, p[f"block{l}.attn.wo"], p[f"block{l}.attn.bo"],
                        use_pallas)
        x = _layer_norm(h, p[f"block{l}.ln2.scale"], p[f"block{l}.ln2.bias"])
        x = _linear(x, p[f"block{l}.mlp.fc1"], p[f"block{l}.mlp.b1"], use_pallas)
        x = jax.nn.gelu(x)
        h = h + _linear(x, p[f"block{l}.mlp.fc2"], p[f"block{l}.mlp.b2"],
                        use_pallas)

    h = _layer_norm(h, p["final.ln.scale"], p["final.ln.bias"])
    last = h[:, -1, :]                                   # (B, d)
    # tied LM head
    if use_pallas:
        logits = pallas_matmul(last, p["embed.tok"].T)
    else:
        logits = jnp.dot(last, p["embed.tok"].T,
                         preferred_element_type=jnp.float32)
    return logits                                        # (B, vocab)


# --------------------------------------------------------------------------
# Loss / metrics (MeZO-style candidate scoring)
# --------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: List[jax.Array], input_ids: jax.Array,
            label_class: jax.Array, class_tokens: jax.Array,
            *, use_pallas: bool = False, lora: List[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over the C verbalizer-token scores at the last position.

    Returns (mean loss, #correct as f32) so eval can sum accuracy counts.
    """
    logits = forward_logits(cfg, params, input_ids, use_pallas=use_pallas,
                            lora=lora)
    cls = logits[:, class_tokens]                        # (B, C)
    logp = jax.nn.log_softmax(cls, axis=-1)
    nll = -jnp.take_along_axis(logp, label_class[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(cls, axis=-1) == label_class).astype(jnp.float32)
    return jnp.mean(nll), jnp.sum(correct)


def subcge_apply_all(params2d: List[jax.Array], us: List[jax.Array],
                     vs: List[jax.Array], amats: List[jax.Array]
                     ) -> List[jax.Array]:
    """Apply the SubCGE aggregated update to every 2D parameter.

    Each layer goes through the L1 pallas kernel (paper Eq. 10):
    theta_l <- theta_l - U_l A_l V_l^T.
    """
    return [pallas_subcge_apply(t, u, a, v)
            for t, u, a, v in zip(params2d, us, amats, vs)]
