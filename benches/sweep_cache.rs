//! Env-core cache microbench (ISSUE 5): what the sweep driver saves per
//! cell. `EnvCore::build` pays for backend construction, dataset
//! generation, batchification and the uniform partition; a cell run from
//! a cached core only re-derives the seeded θ⁰ (`Env::from_core`). The
//! ratio between the two rows is the per-cell setup speedup of an
//! N-seed × M-method sweep over one (model, task, clients) group.
//!
//! Run: cargo bench --bench sweep_cache

use std::sync::Arc;

use seedflood::config::ExperimentConfig;
use seedflood::sim::{CoreKey, Env, EnvCore};
use seedflood::util::bench::Bencher;

fn main() {
    let cfg = ExperimentConfig {
        model: "synthetic".into(),
        task: "sst2".into(),
        clients: 16,
        ..Default::default()
    };
    let mut b = Bencher::coarse();
    b.bench("EnvCore::build (synthetic, 16 clients)", || {
        EnvCore::build(CoreKey::of(&cfg)).unwrap()
    });
    let core = Arc::new(EnvCore::build(CoreKey::of(&cfg)).unwrap());
    let mut seed = 0u64;
    b.bench("Env::from_core (cached core, fresh seed)", || {
        seed += 1;
        Env::from_core(core.clone(), ExperimentConfig { seed, ..cfg.clone() }).unwrap()
    });
    let build = b.results[0].median_s();
    let derive = b.results[1].median_s();
    println!(
        "\ncached-core cell setup is {:.1}x cheaper than a from-scratch Env",
        build / derive.max(1e-12)
    );
    print!("{}", b.summary());
}
