//! Table 4: per-iteration wall-clock breakdown of the SeedFlood framework.
//!
//! Two sections:
//!
//! * **Parallel local-step scaling** (always runs, synthetic backend): one
//!   iteration's local steps fanned out over the engine's thread pool at
//!   `clients = 16`, timed for 1/2/4/8 workers — the wall-clock win the
//!   ISSUE 1 engine refactor exists for. Results are identical across
//!   thread counts (see tests/engine.rs); only the clock changes.
//!
//! * **GE/MA artifact breakdown** (needs real PJRT bindings + artifacts):
//!   MeZO-style dense updates vs SubCGE — gradient estimation (GE) and
//!   message applying (MA), the paper's 51x MA claim on our substrate.
//!
//! The headline thread-scaling number is a tracked ledger entry (same
//! convention as benches/scale.rs and benches/event.rs): the full run
//! writes BENCH_table4.json, and `--smoke --check BENCH_table4.json`
//! gates it in CI within a wide multiplicative band.
//!
//! Run: cargo bench --bench table4_breakdown             (writes ledger)
//!      cargo bench --bench table4_breakdown -- --smoke --check BENCH_table4.json

use std::collections::BTreeMap;
use std::time::Instant;

use seedflood::algos;
use seedflood::config::{ExperimentConfig, Method};
use seedflood::model::{Manifest, ParamStore};
use seedflood::net::{MsgId, SeedUpdate};
use seedflood::runtime::Runtime;
use seedflood::sim::Env;
use seedflood::subcge::{CoeffAccum, DeviceBasisCache, SubspaceBasis};
use seedflood::topology::{Kind, Topology};
use seedflood::util::json::Json;
use seedflood::zo;

/// Same wide band as the other ledgers: catches order-of-magnitude
/// drift, tolerates loaded CI runners (a 1x measurement on a busy or
/// small machine stays inside an 8x band around a ~3-4x baseline).
const TOLERANCE: f64 = 8.0;

/// Returns (1-thread wall ms, best speedup over 1 thread) — the
/// headline number the ledger tracks.
fn parallel_local_step_scaling(iters: usize) -> anyhow::Result<(f64, f64)> {
    let clients = 16;
    let cfg = ExperimentConfig {
        method: Method::SeedFlood,
        clients,
        topology: Kind::Ring,
        steps: iters + 1,
        task: "sst2".into(),
        ..Default::default()
    };
    let env = Env::synthetic(cfg)?;
    let topo = Topology::build(Kind::Ring, clients, 0);

    println!("== local-step fan-out: {clients} clients, {iters} iterations, synthetic oracle ==");
    println!("{:>8} {:>12} {:>10}", "threads", "wall (ms)", "speedup");
    let mut base_ms = 0.0f64;
    let mut best = (1usize, f64::INFINITY);
    for &threads in &[1usize, 2, 4, 8] {
        let (mut algo, mut states) = algos::build(&env, &topo)?;
        // warmup iteration (thread spawn paths, caches)
        algo.begin_step(&mut states, 0, &env)?;
        std::hint::black_box(algos::local_step_all(&*algo, &mut states, 0, &env, threads)?);
        let t0 = Instant::now();
        for t in 1..=iters {
            algo.begin_step(&mut states, t, &env)?;
            let losses = algos::local_step_all(&*algo, &mut states, t, &env, threads)?;
            std::hint::black_box(losses);
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        if threads == 1 {
            base_ms = ms;
        }
        if ms < best.1 {
            best = (threads, ms);
        }
        println!("{threads:>8} {ms:>12.1} {:>9.2}x", base_ms / ms);
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let speedup = base_ms / best.1;
    if cores >= 4 && speedup <= 1.15 {
        // a measurement, not an invariant: a loaded runner can swallow the
        // win, so warn instead of aborting before the GE/MA section
        println!(
            "\nWARNING: expected the local-step phase to speed up with threads \
             on a {cores}-core machine; best was {speedup:.2}x at {} threads",
            best.0
        );
    } else {
        println!(
            "\nbest: {speedup:.2}x at {} threads ({cores} cores) — local-step phase scales",
            best.0
        );
    }
    Ok((base_ms, speedup))
}

/// Regression gate against the committed ledger — the benches/scale.rs
/// convention: only metrics present on both sides are compared.
fn run_check(path: &str, metrics: &[(String, f64)]) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read baseline {path}: {e}"));
    let base = Json::parse(&text).unwrap_or_else(|e| panic!("unparseable baseline {path}: {e}"));
    let base_metrics = base
        .get("metrics")
        .and_then(|m| m.as_obj().cloned())
        .unwrap_or_else(|e| panic!("baseline {path} has no metrics object: {e}"));
    println!("\n== regression check vs {path} (tolerance {TOLERANCE}x) ==");
    let mut failures = 0;
    for (name, value) in metrics {
        match base_metrics.get(name.as_str()) {
            Some(b) => {
                let b = b.as_f64().unwrap_or_else(|e| panic!("baseline metric {name}: {e}"));
                let ok = b > 0.0 && *value >= b / TOLERANCE && *value <= b * TOLERANCE;
                println!(
                    "  {:<38} {:>12.4} vs baseline {:>10.4}  [{}]",
                    name,
                    value,
                    b,
                    if ok { "ok" } else { "REGRESSION" }
                );
                if !ok {
                    failures += 1;
                }
            }
            None => println!("  {name:<38} {value:>12.4} (no baseline entry — skipped)"),
        }
    }
    assert_eq!(failures, 0, "{failures} metric(s) left the {TOLERANCE}x tolerance band");
}

fn artifact_ge_ma_breakdown() -> anyhow::Result<()> {
    let dir = if std::path::Path::new("artifacts").exists() { "artifacts" } else { "../artifacts" };
    let name = if Manifest::load(&format!("{dir}/small_manifest.json")).is_ok() {
        "small"
    } else {
        "tiny"
    };
    let m = Manifest::load(&format!("{dir}/{name}_manifest.json"))?;
    let rt = Runtime::cpu(dir)?;
    let exe_loss = rt.load(&m, "loss")?;
    let exe_subcge = rt.load(&m, "subcge")?;

    let b = m.config.batch;
    let seq = m.config.seq;
    let ids: Vec<i32> = (0..b * seq).map(|i| (i % (m.config.vocab - 8) + 4) as i32).collect();
    let labels: Vec<i32> = (0..b).map(|i| (i % 2) as i32).collect();
    let class_tokens = vec![2, 3];
    let loss_of = |p: &seedflood::tensor::ParamVec| -> f32 {
        let args = seedflood::runtime::loss_args(p, &ids, vec![b, seq], &labels, &class_tokens);
        exe_loss.run(&args).unwrap()[0].data[0]
    };

    let n_msgs = 16; // 16 clients => 16 messages per iteration (paper)
    let iters = 5; // paper: averaged over 5 steps
    let basis = SubspaceBasis::new(&m, 32, 1_000_000, 7);

    println!("\n== Table 4: wall-clock per iteration, model={name}, {n_msgs} messages ==");
    let mut report: Vec<(&str, f64, f64, f64)> = vec![];

    for (method, dense, cached) in [("MeZO", true, false),
                                    ("SubCGE", false, false),
                                    ("SubCGE+devcache", false, true)] {
        let mut params = ParamStore::init(&m, 0);
        let mut accum = CoeffAccum::new(&basis);
        let mut dev_cache = if cached {
            Some(DeviceBasisCache::new(&basis, &rt).unwrap())
        } else {
            None
        };
        let (mut ge_ms, mut ma_ms) = (0.0, 0.0);
        for it in 0..iters {
            let seed = 777 + it as u64;
            // GE: two forwards + perturb/unperturb + local update
            let t0 = Instant::now();
            let alpha = if dense {
                let a = zo::spsa_alpha(&mut params, 1e-3, |p| loss_of(p), |p, s| {
                    zo::perturb_dense(p, seed, s)
                });
                zo::apply_dense_update(&mut params, seed, 1e-4 * a);
                a
            } else {
                let a = zo::spsa_alpha(&mut params, 1e-3, |p| loss_of(p), |p, s| {
                    zo::perturb_subcge(p, &basis, seed, s)
                });
                accum.accumulate(&basis, &SeedUpdate {
                    id: MsgId { origin: 0, step: it as u32 },
                    seed,
                    coeff: 1e-4 * a,
                });
                a
            };
            std::hint::black_box(alpha);
            ge_ms += t0.elapsed().as_secs_f64() * 1e3;

            // MA: apply n_msgs received messages
            let t1 = Instant::now();
            if dense {
                for k in 0..n_msgs {
                    zo::apply_dense_update(&mut params, 10_000 + k as u64, 1e-5);
                }
            } else {
                for k in 0..n_msgs {
                    accum.accumulate(&basis, &SeedUpdate {
                        id: MsgId { origin: 1 + k as u32, step: it as u32 },
                        seed: 10_000 + k as u64,
                        coeff: 1e-5,
                    });
                }
                match dev_cache.as_mut() {
                    Some(c) => accum
                        .flush_with_artifact_cached(&basis, c, &mut params, &exe_subcge, &rt)
                        .unwrap(),
                    None => accum
                        .flush_with_artifact(&basis, &mut params, &exe_subcge, &rt)
                        .unwrap(),
                }
            }
            ma_ms += t1.elapsed().as_secs_f64() * 1e3;
        }
        let (ge, ma) = (ge_ms / iters as f64, ma_ms / iters as f64);
        report.push((method, ge, ma, ge + ma));
    }

    println!("\n{:>8} {:>10} {:>10} {:>12}", "method", "GE (ms)", "MA (ms)", "total (ms)");
    for (m_, ge, ma, tot) in &report {
        println!("{m_:>8} {ge:>10.2} {ma:>10.2} {tot:>12.2}");
    }
    let mezo_ma = report[0].2;
    let sub_ma = report[2].2.min(report[1].2);
    println!(
        "\nMA speedup (paper: 1432ms -> 28ms = 51x on OPT-2.7B/A100): {:.1}x here",
        mezo_ma / sub_ma
    );
    assert!(sub_ma < mezo_ma, "SubCGE MA must beat dense MeZO MA");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let check_path = argv.iter().position(|a| a == "--check").map(|i| {
        argv.get(i + 1).unwrap_or_else(|| panic!("--check needs a baseline path")).clone()
    });

    let iters = if smoke { 10 } else { 30 };
    let (base_ms, speedup) = parallel_local_step_scaling(iters)?;
    let metrics: Vec<(String, f64)> = vec![("local_step_speedup_best".into(), speedup)];

    let have_artifacts = ["artifacts/tiny_manifest.json", "../artifacts/tiny_manifest.json"]
        .iter()
        .any(|p| std::path::Path::new(p).exists());
    // Runtime::cpu errors on the in-repo PJRT stub — probe before diving in
    if !smoke && have_artifacts && Runtime::cpu("artifacts").is_ok() {
        artifact_ge_ma_breakdown()?;
    } else {
        println!(
            "\nskipping GE/MA artifact breakdown (needs real PJRT bindings and `make artifacts`)"
        );
    }

    if !smoke {
        let mut tobj = BTreeMap::new();
        tobj.insert("local_step_s_1t".to_string(), Json::Num(base_ms / 1e3));
        let mut mobj = BTreeMap::new();
        for (k, v) in &metrics {
            mobj.insert(k.clone(), Json::Num(*v));
        }
        let doc = Json::obj(vec![
            ("schema", Json::str("seedflood-table4-bench-v1")),
            ("timings", Json::Obj(tobj)),
            ("metrics", Json::Obj(mobj)),
        ]);
        std::fs::write("BENCH_table4.json", doc.to_string_pretty() + "\n")
            .expect("cannot write BENCH_table4.json");
        println!("\nwrote BENCH_table4.json");
    }
    if let Some(path) = check_path {
        run_check(&path, &metrics);
    }
    println!("table4 OK");
    Ok(())
}
