//! Massive-scale benchmark and tracked perf ledger (ISSUE 6): O(m)
//! topology construction at 100k clients, the double-sweep diameter
//! estimator vs the old all-pairs BFS, CSR network build cost, bounded
//! flooding throughput from 1k to 100k clients, the origin-sparse dedup
//! memory comparison (PR 7: sparse filter vs the dense `Vec<StepSet>`
//! projection), a *full* all-origin flood at n = 100k — impossible under
//! the dense representation (~320 GB of dedup tables) — and a short
//! cheap-model SeedFlood segment through the event-driven engine.
//!
//! Headline comparison — "flood-ready construction": everything the
//! simulator does before the first flood round (build the topology, then
//! `Topology::diameter()` for the flood depth). The pre-PR code paths are
//! reproduced verbatim below (`naive_erdos_renyi`, `naive_diameter`) so
//! the speedup rows measure the real before/after, not a strawman.
//!
//! Run: cargo bench --bench scale               (full grid, a few min —
//!                                               the 100k all-origin
//!                                               flood dominates;
//!                                               writes BENCH_scale.json)
//!      cargo bench --bench scale -- --smoke    (CI grid, a few seconds;
//!                                               writes nothing)
//!      cargo bench --bench scale -- --smoke --check BENCH_scale.json
//!                                              (CI regression gate:
//!                                               every measured metric
//!                                               must stay within the
//!                                               tolerance band of the
//!                                               committed ledger)

use std::collections::{BTreeMap, VecDeque};
use std::hint::black_box;
use std::time::Instant;

use seedflood::config::{ExperimentConfig, Method};
use seedflood::flood::{flood_rounds, FloodDedup, FloodState};
use seedflood::net::{MsgId, Network, SeedUpdate};
use seedflood::rng::Rng;
use seedflood::sched::TimeModel;
use seedflood::sim::{self, Env};
use seedflood::topology::{Kind, Topology};
use seedflood::util::json::Json;

/// Multiplicative tolerance band for `--check`: a metric regresses when
/// it leaves `[baseline/8, baseline*8]`. Wide on purpose — the ledger
/// tracks order-of-magnitude drift (an O(m) path quietly becoming
/// O(n^2)), not machine-to-machine noise.
const TOLERANCE: f64 = 8.0;

/// Median wall-clock seconds over `reps` runs of `f`.
fn median_time(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

// ---------------------------------------------------------------------------
// Pre-PR reference implementations, reproduced verbatim from the old
// rust/src/topology/mod.rs (see git history). Do not "improve" these:
// their whole point is to be exactly what shipped before the rewrite.
// ---------------------------------------------------------------------------

/// The old G(n,p) generator: n(n-1)/2 Bernoulli draws per attempt, then
/// an adjacency build that scans `adj[a]` for duplicates on every edge.
fn naive_erdos_renyi(n: usize, seed: u64) -> Vec<Vec<usize>> {
    let p = (2.0 * (n as f64).ln() / n as f64).min(1.0);
    let mut rng = Rng::new(seed);
    loop {
        let mut edges = vec![];
        for a in 0..n {
            for b in a + 1..n {
                if rng.next_f64() < p {
                    edges.push((a, b));
                }
            }
        }
        let mut adj: Vec<Vec<usize>> = vec![vec![]; n];
        for &(a, b) in &edges {
            if !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        }
        for l in &mut adj {
            l.sort_unstable();
        }
        if connected(&adj) {
            return adj;
        }
    }
}

fn bfs_dist(adj: &[Vec<usize>], src: usize) -> Vec<usize> {
    let mut dist = vec![usize::MAX; adj.len()];
    let mut q = VecDeque::new();
    dist[src] = 0;
    q.push_back(src);
    while let Some(u) = q.pop_front() {
        for &v in &adj[u] {
            if dist[v] == usize::MAX {
                dist[v] = dist[u] + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

fn connected(adj: &[Vec<usize>]) -> bool {
    bfs_dist(adj, 0).iter().all(|&d| d != usize::MAX)
}

/// The old flood-depth computation: exact all-pairs BFS diameter,
/// O(n·(n+m)) — what `Topology::diameter()` did at every n.
fn naive_diameter(adj: &[Vec<usize>]) -> usize {
    (0..adj.len()).map(|s| bfs_dist(adj, s).into_iter().max().unwrap()).max().unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Benchmark stages
// ---------------------------------------------------------------------------

/// Before/after on the flood-setup path at one n: old generator + exact
/// diameter vs new generator + `diameter()` (bounds estimator above the
/// exact cutoff). Returns (flood_ready_speedup, generator_speedup).
fn flood_ready_compare(n: usize) -> (f64, f64) {
    let naive_gen_s = median_time(1, || {
        black_box(naive_erdos_renyi(n, 42));
    });
    let adj = naive_erdos_renyi(n, 42);
    let naive_diam_s = median_time(1, || {
        black_box(naive_diameter(&adj));
    });
    let new_gen_s = median_time(3, || {
        black_box(Topology::build(Kind::ErdosRenyi, n, 42));
    });
    let t = Topology::build(Kind::ErdosRenyi, n, 42);
    let new_diam_s = median_time(3, || {
        black_box(t.diameter());
    });
    let flood_ready = (naive_gen_s + naive_diam_s) / (new_gen_s + new_diam_s).max(1e-9);
    let generator = naive_gen_s / new_gen_s.max(1e-9);
    println!(
        "  n={:<6} old {:>9.1} ms (gen {:>7.1} + diam {:>8.1})  \
         new {:>7.2} ms  -> {:>6.1}x flood-ready, {:>5.1}x generator",
        n,
        1e3 * (naive_gen_s + naive_diam_s),
        1e3 * naive_gen_s,
        1e3 * naive_diam_s,
        1e3 * (new_gen_s + new_diam_s),
        flood_ready,
        generator
    );
    (flood_ready, generator)
}

struct FloodRow {
    secs: f64,
    delivered: u64,
    ns_per_delivery: f64,
    /// Largest per-client dedup filter after the flood, in bytes.
    dedup_bytes: usize,
}

/// Bounded SeedFlood segment on a scale-free graph: 64 clients *spread
/// across the id space* inject one update each (origin = their own
/// client id), then `diameter()` synchronous flood rounds carry all 64
/// to every client. Spreading the origins makes every client's dedup
/// filter face origin ids up to ~n — the access pattern that cost the
/// dense `Vec<StepSet>` table O(max origin) per client — while the
/// origin *count* stays bounded, so the per-event machinery (CSR
/// fan-out, pooled FIFOs, windowed dedup) is exercised at full scale
/// without an O(n²) flood.
fn bounded_flood(n: usize, origins: usize) -> FloodRow {
    let topo = Topology::build(Kind::ScaleFree, n, 42);
    let depth = topo.diameter().max(1);
    let mut net = Network::new(topo);
    let mut states: Vec<FloodState> = (0..n)
        .map(|_| {
            let mut st = FloodState::new();
            st.retain = 8;
            st.seen.reserve_origins(n);
            st
        })
        .collect();
    let want = origins.min(n);
    let stride = (n / want).max(1);
    for i in 0..want {
        let client = i * stride;
        states[client].inject(SeedUpdate {
            id: MsgId { origin: client as u32, step: 0 },
            seed: 0x5eed ^ client as u64,
            coeff: 1.0,
        });
    }
    let t0 = Instant::now();
    flood_rounds(&mut states, &mut net, depth, |_, _| {});
    let secs = t0.elapsed().as_secs_f64();
    for (i, st) in states.iter().enumerate() {
        assert_eq!(
            st.seen.len(),
            want,
            "client {i}/{n} missed flood messages after {depth} rounds"
        );
    }
    let delivered = net.acct.delivered_messages;
    assert!(delivered > 0, "flood at n={n} delivered nothing");
    let dedup_bytes = states.iter().map(|s| s.seen.mem_bytes()).max().unwrap_or(0);
    FloodRow { secs, delivered, ns_per_delivery: secs * 1e9 / delivered as f64, dedup_bytes }
}

/// Bytes the historical dense `Vec<StepSet>` dedup table needs for the
/// same per-client knowledge as [`bounded_flood`] leaves behind: replay
/// one covered client's ids into a filter pinned to the dense
/// representation. The dense table is origin-id-indexed, so spread
/// origins cost O(max origin id) — the n²-wall side of the comparison.
fn dense_dedup_projection_bytes(n: usize, origins: usize) -> usize {
    let want = origins.min(n);
    let stride = (n / want).max(1);
    let mut dense = FloodDedup::with_crossover(u32::MAX);
    for i in 0..want {
        dense.insert(MsgId { origin: (i * stride) as u32, step: 0 });
    }
    dense.mem_bytes()
}

struct FullFloodRow {
    rounds: usize,
    secs: f64,
    /// Simulation-wide dedup bytes after full coverage (every floor
    /// advanced: the steady-state footprint).
    end_bytes: u64,
    /// Largest simulation-wide dedup total observed (sampled every 8
    /// rounds — mid-flood, when the per-client bump bitsets are live).
    peak_bytes: u64,
}

/// The PR 7 acceptance segment: a *full* all-origin flood — every client
/// an origin — on the hierarchical topology, one synchronous round at a
/// time until every client has heard every origin. Under the dense
/// representation this was out of reach at n = 100k (O(n) `StepSet`s per
/// client = O(n²) simulation-wide, ~320 GB); the origin-sparse filter
/// peaks at a bitset per client (n/8 bytes, ~1.3 GB total) and collapses
/// to a few hundred bytes per client at the floor advance. The measured
/// round count is certified against `diameter_bounds()`.
fn full_flood(n: usize) -> FullFloodRow {
    let topo = Topology::build(Kind::Hierarchical, n, 42);
    let (lb, ub) = topo.diameter_bounds();
    let mut net = Network::new(topo);
    let mut states: Vec<FloodState> = (0..n)
        .map(|_| {
            let mut st = FloodState::new();
            st.retain = 8;
            st.seen.reserve_origins(n);
            st
        })
        .collect();
    for (i, st) in states.iter_mut().enumerate() {
        st.inject(SeedUpdate {
            id: MsgId { origin: i as u32, step: 0 },
            seed: 0x5eed ^ i as u64,
            coeff: 1.0,
        });
    }
    let dedup_total =
        |states: &[FloodState]| states.iter().map(|s| s.seen.mem_bytes() as u64).sum::<u64>();
    let t0 = Instant::now();
    let mut rounds = 0usize;
    let mut peak_bytes = dedup_total(&states);
    while !states.iter().all(|s| s.seen.len() == n) {
        assert!(rounds < ub, "full flood at n={n} not covered after ub={ub} rounds");
        flood_rounds(&mut states, &mut net, 1, |_, _| {});
        rounds += 1;
        if rounds % 8 == 0 {
            peak_bytes = peak_bytes.max(dedup_total(&states));
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    assert!(
        lb <= rounds && rounds <= ub,
        "full flood rounds {rounds} outside certified bounds [{lb},{ub}] at n={n}"
    );
    let end_bytes = dedup_total(&states);
    FullFloodRow { rounds, secs, end_bytes, peak_bytes: peak_bytes.max(end_bytes) }
}

/// Short cheap-model SeedFlood run through the event-driven engine: the
/// end-to-end "massive-scale segment" of the acceptance criteria. The
/// shrunk synthetic oracle keeps per-client step cost trivial, so this
/// measures the simulator — scheduler, flooding, CSR network — not the
/// model.
fn event_segment(clients: usize) -> f64 {
    let cfg = ExperimentConfig {
        method: Method::SeedFlood,
        model: "cheap".into(),
        task: "sst2".into(),
        clients,
        topology: Kind::Hierarchical,
        steps: 2,
        local_steps: 1,
        flood_steps: 1,
        flood_retain: 64,
        eval_every: 0,
        time_model: TimeModel::Event,
        threads: 1,
        ..Default::default()
    };
    let t0 = Instant::now();
    let env = Env::new(cfg).expect("cheap-model env");
    let record = sim::run_with_env(&env).expect("event-driven cheap segment");
    let secs = t0.elapsed().as_secs_f64();
    assert!(record.final_loss.is_finite(), "cheap segment diverged");
    println!(
        "  {} clients, 2 steps: {:.2} s  (GMP {:.1}%, loss {:.4}, {} B on the wire)",
        clients,
        secs,
        100.0 * record.gmp,
        record.final_loss,
        record.total_bytes
    );
    secs
}

/// Regression gate: every metric measured this run that also exists in
/// the committed ledger must lie within the tolerance band. Metrics
/// present on only one side are reported but never fail the check (the
/// smoke grid measures a subset of the full grid).
fn run_check(path: &str, metrics: &[(String, f64)]) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read baseline {path}: {e}"));
    let base = Json::parse(&text).unwrap_or_else(|e| panic!("unparseable baseline {path}: {e}"));
    let base_metrics = base
        .get("metrics")
        .and_then(|m| m.as_obj().cloned())
        .unwrap_or_else(|e| panic!("baseline {path} has no metrics object: {e}"));
    println!("\n== regression check vs {path} (tolerance {TOLERANCE}x) ==");
    let mut failures = 0;
    for (name, value) in metrics {
        match base_metrics.get(name.as_str()) {
            Some(b) => {
                let b = b.as_f64().unwrap_or_else(|e| panic!("baseline metric {name}: {e}"));
                let ok = b > 0.0 && *value >= b / TOLERANCE && *value <= b * TOLERANCE;
                println!(
                    "  {:<38} {:>12.4} vs baseline {:>10.4}  [{}]",
                    name,
                    value,
                    b,
                    if ok { "ok" } else { "REGRESSION" }
                );
                if !ok {
                    failures += 1;
                }
            }
            None => println!("  {name:<38} {value:>12.4} (no baseline entry — skipped)"),
        }
    }
    for name in base_metrics.keys() {
        if !metrics.iter().any(|(k, _)| k == name) {
            println!("  {name:<38} (baseline-only — not measured in this mode)");
        }
    }
    assert_eq!(failures, 0, "{failures} metric(s) left the {TOLERANCE}x tolerance band");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let check_path = argv.iter().position(|a| a == "--check").map(|i| {
        argv.get(i + 1).unwrap_or_else(|| panic!("--check needs a baseline path")).clone()
    });

    let mut timings: Vec<(String, f64)> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // -- 1. construction sweep: O(m) generators across four kinds ----------
    println!(
        "== construction sweep ({}) ==",
        if smoke { "smoke: n <= 10k" } else { "full: n <= 100k" }
    );
    let kinds = [Kind::Ring, Kind::SmallWorld, Kind::ScaleFree, Kind::Hierarchical];
    let sweep_ns: &[usize] = if smoke { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    for &kind in &kinds {
        for &n in sweep_ns {
            let reps = if n >= 100_000 { 1 } else { 3 };
            let secs = median_time(reps, || {
                black_box(Topology::build(kind, n, 42));
            });
            println!("  {:<12} n={:<7} {:>10.2} ms", kind.name(), n, 1e3 * secs);
            timings.push((format!("construct_s_{}_{}", kind.name(), n), secs));
        }
    }

    // -- 2. flood-ready construction: old code path vs new -----------------
    println!("\n== flood-ready construction (generator + flood depth), old vs new ==");
    let cmp_ns: &[usize] = if smoke { &[2_000] } else { &[2_000, 10_000] };
    for &n in cmp_ns {
        let (flood_ready, generator) = flood_ready_compare(n);
        metrics.push((format!("construct_speedup_flood_ready_{}k", n / 1000), flood_ready));
        metrics.push((format!("er_generator_speedup_{}k", n / 1000), generator));
    }

    // -- 3. diameter bounds + CSR network build at the largest scale -------
    let nd = if smoke { 10_000 } else { 100_000 };
    println!("\n== diameter bounds and network build at n = {nd} ==");
    for kind in [Kind::ScaleFree, Kind::Hierarchical] {
        let t = Topology::build(kind, nd, 7);
        let t0 = Instant::now();
        let (lb, ub) = t.diameter_bounds();
        let secs = t0.elapsed().as_secs_f64();
        assert!(
            (1..=nd).contains(&lb) && lb <= ub && ub <= nd,
            "degenerate bounds ({lb}, {ub}) on {} n={nd}",
            kind.name()
        );
        println!("  {:<12} bounds ({lb}, {ub}) in {:>8.2} ms", kind.name(), 1e3 * secs);
        timings.push((format!("diameter_bounds_s_{}_{}", kind.name(), nd), secs));
    }
    let t = Topology::build(Kind::ScaleFree, nd, 7);
    let net_secs = median_time(1, || {
        black_box(Network::new(t.clone()));
    });
    println!("  CSR Network::new on scale-free n={nd}: {:.2} ms", 1e3 * net_secs);
    timings.push((format!("network_build_s_scale-free_{nd}"), net_secs));

    // -- 4. bounded flooding throughput + dedup memory ---------------------
    println!("\n== bounded flood (64 spread origins, scale-free, coverage asserted) ==");
    let flood_ns: &[usize] = if smoke { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    let mut per_delivery: Vec<(usize, f64)> = Vec::new();
    for &n in flood_ns {
        let row = bounded_flood(n, 64);
        println!(
            "  n={:<7} {:>8.1} ms, {:>9} deliveries, {:>7.1} ns/delivery",
            n,
            1e3 * row.secs,
            row.delivered,
            row.ns_per_delivery
        );
        metrics.push((format!("per_delivery_ns_{}k", n / 1000), row.ns_per_delivery));
        per_delivery.push((n, row.ns_per_delivery));
        if n > 1_024 {
            // above the dense/sparse crossover: compare the sparse filter
            // against the dense Vec<StepSet> projection of the same state
            let dense = dense_dedup_projection_bytes(n, 64) as f64;
            let ratio = dense / row.dedup_bytes.max(1) as f64;
            println!(
                "  n={:<7} dedup {:>7.1} KB sparse vs {:>9.1} KB dense projection \
                 -> {:>6.0}x smaller",
                n,
                row.dedup_bytes as f64 / 1024.0,
                dense / 1024.0,
                ratio
            );
            metrics.push((format!("dedup_sparse_vs_dense_ratio_{}k", n / 1000), ratio));
        }
    }
    let base_ns = per_delivery[0].1;
    for &(n, ns) in per_delivery.iter().skip(1) {
        metrics.push((format!("per_delivery_growth_{}k_vs_1k", n / 1000), ns / base_ns));
    }

    // -- 5. full all-origin flood: the n² dedup wall, removed --------------
    let mb = |b: u64| b as f64 / (1024.0 * 1024.0);
    println!("\n== full all-origin flood (hierarchical, every client an origin) ==");
    let full_ns: &[usize] = if smoke { &[4_096] } else { &[4_096, 100_000] };
    for &n in full_ns {
        let row = full_flood(n);
        println!(
            "  n={:<7} {:>4} rounds in {:>7.1} s, dedup {:>7.2} MB end / {:>8.2} MB peak",
            n,
            row.rounds,
            row.secs,
            mb(row.end_bytes),
            mb(row.peak_bytes)
        );
        metrics.push((format!("full_flood_rounds_{n}"), row.rounds as f64));
        metrics.push((format!("full_flood_end_dedup_mb_{n}"), mb(row.end_bytes)));
        metrics.push((format!("full_flood_peak_dedup_mb_{n}"), mb(row.peak_bytes)));
        timings.push((format!("full_flood_s_{n}"), row.secs));
    }

    // -- 6. event-driven cheap-model segment (full grid only) --------------
    if !smoke {
        println!("\n== event-driven SeedFlood segment, cheap oracle ==");
        metrics.push(("event_segment_s".into(), event_segment(2048)));
    }

    // -- hard floors: the acceptance criteria, independent of any ledger ---
    let get = |name: &str| -> f64 {
        metrics
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("metric {name} was not measured"))
    };
    if smoke {
        assert!(
            get("construct_speedup_flood_ready_2k") >= 8.0,
            "flood-ready construction fell below 8x at n=2k"
        );
        assert!(
            get("per_delivery_growth_10k_vs_1k") <= 8.0,
            "per-delivery flood work grew super-linearly from 1k to 10k clients"
        );
        assert!(
            get("dedup_sparse_vs_dense_ratio_10k") >= 20.0,
            "sparse dedup no longer beats the dense projection by 20x at n=10k"
        );
        assert!(
            get("full_flood_end_dedup_mb_4096") <= 50.0,
            "all-origin flood left more than 50 MB of dedup state at n=4096"
        );
    } else {
        assert!(
            get("construct_speedup_flood_ready_10k") >= 10.0,
            "flood-ready construction fell below the 10x acceptance floor at n=10k"
        );
        assert!(
            get("per_delivery_growth_100k_vs_1k") <= 8.0,
            "per-delivery flood work grew super-linearly from 1k to 100k clients"
        );
        assert!(
            get("dedup_sparse_vs_dense_ratio_100k") >= 50.0,
            "sparse dedup fell below the 50x acceptance floor vs dense at n=100k"
        );
        assert!(
            get("full_flood_end_dedup_mb_100000") <= 1000.0,
            "the 100k all-origin flood no longer settles under 1 GB of dedup state"
        );
        assert!(get("event_segment_s") <= 60.0, "cheap event segment no longer runs in seconds");
    }

    // -- ledger + regression gate ------------------------------------------
    if !smoke {
        let mut tobj = BTreeMap::new();
        for (k, v) in &timings {
            tobj.insert(k.clone(), Json::Num(*v));
        }
        let mut mobj = BTreeMap::new();
        for (k, v) in &metrics {
            mobj.insert(k.clone(), Json::Num(*v));
        }
        let doc = Json::obj(vec![
            ("schema", Json::str("seedflood-scale-bench-v1")),
            ("timings", Json::Obj(tobj)),
            ("metrics", Json::Obj(mobj)),
        ]);
        std::fs::write("BENCH_scale.json", doc.to_string_pretty() + "\n")
            .expect("cannot write BENCH_scale.json");
        let (nt, nm) = (timings.len(), metrics.len());
        println!("\nwrote BENCH_scale.json ({nt} timings, {nm} metrics)");
    }
    if let Some(path) = check_path {
        run_check(&path, &metrics);
    }
    println!("\nscale bench OK ({})", if smoke { "smoke grid" } else { "full grid" });
}
