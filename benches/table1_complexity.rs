//! Table 1: measured communication bytes + apply-computation scaling per
//! approach, validated against the paper's complexity columns:
//!
//!   Traditional gossip     bytes O(d)      apply O(d)
//!   Gossip w/ shared rand  bytes O(t·n)    apply O(t·n·d)
//!   SeedFlood              bytes O(n)      apply O(n + r·d)   perfect ✓
//!
//! We measure actual on-wire bytes per communication round via the network
//! accounting (varying d and n independently) and assert the scaling signs:
//! gossip grows with d and not n (per edge); SeedFlood grows with n and not
//! d. Run: cargo bench --bench table1_complexity

use std::sync::Arc;

use seedflood::flood::{flood_rounds, FloodState};
use seedflood::net::{MsgId, Network, Payload, SeedUpdate};
use seedflood::tensor::{ParamVec, Tensor};
use seedflood::topology::Topology;

fn dense_round_bytes(n: usize, d: usize) -> f64 {
    let topo = Topology::ring(n);
    let mut net = Network::new(topo);
    let p = Arc::new(ParamVec::new(vec!["w".into()], vec![Tensor::zeros(&[d])]));
    for i in 0..n {
        net.broadcast(i, &Payload::Dense(p.clone()));
    }
    net.per_edge_bytes()
}

fn seedflood_round_bytes(n: usize) -> f64 {
    let topo = Topology::ring(n);
    let diam = topo.diameter();
    let mut net = Network::new(topo);
    let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
    for (i, st) in states.iter_mut().enumerate() {
        st.inject(SeedUpdate {
            id: MsgId { origin: i as u32, step: 0 },
            seed: i as u64,
            coeff: 1.0,
        });
    }
    flood_rounds(&mut states, &mut net, diam + 1, |_, _| {});
    net.per_edge_bytes()
}

fn main() {
    println!("== Table 1: measured per-edge bytes per communication round ==\n");

    println!(
        "{:>12} {:>12} {:>16} {:>16}",
        "d (params)", "n (clients)", "gossip B/edge", "seedflood B/edge"
    );
    let mut gossip_by_d = vec![];
    let mut flood_by_d = vec![];
    for d in [10_000usize, 100_000, 1_000_000] {
        let g = dense_round_bytes(16, d);
        let f = seedflood_round_bytes(16);
        println!("{d:>12} {:>12} {g:>16.0} {f:>16.0}", 16);
        gossip_by_d.push(g);
        flood_by_d.push(f);
    }
    println!();
    let mut flood_by_n = vec![];
    for n in [8usize, 16, 32, 64] {
        let g = dense_round_bytes(n, 100_000);
        let f = seedflood_round_bytes(n);
        println!("{:>12} {n:>12} {g:>16.0} {f:>16.0}", 100_000);
        flood_by_n.push((n, f));
    }

    // scaling assertions — the paper's complexity table, measured
    assert!(gossip_by_d[2] / gossip_by_d[0] > 50.0, "gossip must scale with d");
    assert!(
        (flood_by_d[2] - flood_by_d[0]).abs() < 1.0,
        "seedflood bytes must be independent of d"
    );
    let (n0, f0) = flood_by_n[0];
    let (n3, f3) = flood_by_n[3];
    let growth = f3 / f0;
    let expected = n3 as f64 / n0 as f64;
    assert!(
        growth > 0.5 * expected && growth < 2.0 * expected,
        "seedflood per-edge bytes must scale ~O(n): got {growth:.2}x for {expected:.0}x n"
    );
    println!("\ntable1 OK: gossip bytes ∝ d, SeedFlood bytes ∝ n and independent of d");
}
