//! Event-engine hot-path benchmark and tracked perf ledger (ISSUE 9):
//! raw queue throughput (sequential pops vs the cohort drain), the
//! per-delivery flood scan cost behind the engine's `Round` events,
//! end-to-end events/sec of the cheap-model SeedFlood run at 2048–10k
//! clients, the cohort-parallel speedup over `--threads 1` (uniform
//! rates, where every instant holds a full step cohort), and the
//! seed-reconstruction fast path (fill throughput; multi-seed one-sweep
//! and chunk-parallel apply vs the historical k-pass loop at k = 16).
//!
//! Every speedup pair is asserted bit-identical before it is timed — the
//! fast paths are only interesting because they change *nothing* about
//! the results.
//!
//! Run: cargo bench --bench event               (full grid, ~a minute;
//!                                               writes BENCH_event.json)
//!      cargo bench --bench event -- --smoke    (CI grid, seconds;
//!                                               writes nothing)
//!      cargo bench --bench event -- --smoke --check BENCH_event.json
//!                                              (CI regression gate)
//!
//! The ≥ 2× floors (cohort parallelism at 8 threads, multi-seed parallel
//! apply at k = 16) are asserted only when the machine has ≥ 8 cores —
//! on smaller CI boxes they degrade to a WARN, and the wide `--check`
//! band against the committed ledger still catches order-of-magnitude
//! regressions (the same convention as table4's thread-scaling number).

use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

use seedflood::config::{ExperimentConfig, Method};
use seedflood::flood::{flood_rounds, FloodState};
use seedflood::metrics::RunRecord;
use seedflood::net::{MsgId, Network, SeedUpdate};
use seedflood::rng::Rng;
use seedflood::sched::{EventQueue, TimeModel};
use seedflood::sim::{self, Env};
use seedflood::tensor::{ParamVec, Tensor};
use seedflood::topology::{Kind, Topology};
use seedflood::util::json::Json;
use seedflood::zo;

/// Multiplicative tolerance band for `--check`: a metric regresses when
/// it leaves `[baseline/8, baseline*8]`. Wide on purpose — the ledger
/// tracks order-of-magnitude drift, not machine-to-machine noise.
const TOLERANCE: f64 = 8.0;

/// Median wall-clock seconds over `reps` runs of `f`.
fn median_time(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

fn cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------------
// 1. queue ops: sequential pops vs the cohort drain
// ---------------------------------------------------------------------------

/// Ops/sec (each push and each pop counts as one op) through the engine's
/// priority queue on a clustered-time workload: many events share an
/// instant, as step cohorts do.
fn queue_ops_sequential(events: usize) -> f64 {
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    for i in 0..events {
        q.push(rng.next_below(events as u64 / 16), (i % 3) as u8, i as u64);
    }
    let mut sink = 0u64;
    while let Some(e) = q.pop() {
        sink ^= e.payload;
    }
    let secs = t0.elapsed().as_secs_f64();
    black_box(sink);
    (2 * events) as f64 / secs
}

/// Same workload drained through [`EventQueue::pop_cohort`] — the cohort
/// API must not cost queue throughput.
fn queue_ops_cohort(events: usize) -> f64 {
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Rng::new(7);
    let t0 = Instant::now();
    for i in 0..events {
        q.push(rng.next_below(events as u64 / 16), (i % 3) as u8, i as u64);
    }
    let mut cohort = Vec::new();
    let mut sink = 0u64;
    while q.pop_cohort(&mut cohort) > 0 {
        for e in &cohort {
            sink ^= e.payload;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    black_box(sink);
    (2 * events) as f64 / secs
}

// ---------------------------------------------------------------------------
// 2. per-delivery flood scan — the Round-event workload
// ---------------------------------------------------------------------------

/// ns per delivered message of a bounded flood on the hierarchical
/// topology: the exact send/collect scan the engine's `Round` events run,
/// without the rest of the simulator around it.
fn round_scan_ns_per_delivery(n: usize, origins: usize) -> f64 {
    let topo = Topology::build(Kind::Hierarchical, n, 42);
    let depth = topo.diameter().max(1);
    let mut net = Network::new(topo);
    let mut states: Vec<FloodState> = (0..n)
        .map(|_| {
            let mut st = FloodState::new();
            st.retain = 8;
            st.seen.reserve_origins(n);
            st
        })
        .collect();
    let want = origins.min(n);
    let stride = (n / want).max(1);
    for i in 0..want {
        let client = i * stride;
        states[client].inject(SeedUpdate {
            id: MsgId { origin: client as u32, step: 0 },
            seed: 0x5eed ^ client as u64,
            coeff: 1.0,
        });
    }
    let t0 = Instant::now();
    flood_rounds(&mut states, &mut net, depth, |_, _| {});
    let secs = t0.elapsed().as_secs_f64();
    let delivered = net.acct.delivered_messages;
    assert!(delivered > 0, "round scan at n={n} delivered nothing");
    secs * 1e9 / delivered as f64
}

// ---------------------------------------------------------------------------
// 3. end-to-end event engine: events/sec and cohort-parallel speedup
// ---------------------------------------------------------------------------

/// One cheap-model SeedFlood run through the event engine (uniform rates:
/// the bit-for-bit reduction regime). Returns (sim seconds, record) —
/// environment construction is excluded so the number is the engine, not
/// the model build.
fn event_run(clients: usize, steps: usize, threads: usize) -> (f64, RunRecord) {
    let cfg = ExperimentConfig {
        method: Method::SeedFlood,
        model: "cheap".into(),
        task: "sst2".into(),
        clients,
        topology: Kind::Hierarchical,
        steps,
        local_steps: 1,
        flood_steps: 1,
        flood_retain: 64,
        eval_every: 0,
        time_model: TimeModel::Event,
        threads,
        ..Default::default()
    };
    let env = Env::new(cfg).expect("cheap-model env");
    let t0 = Instant::now();
    let record = sim::run_with_env(&env).expect("event-driven cheap run");
    let secs = t0.elapsed().as_secs_f64();
    assert!(record.final_loss.is_finite(), "cheap event run diverged");
    (secs, record)
}

/// Thread-count invariance, asserted bitwise — the cohort fan-out's
/// contract, checked on the very runs being timed.
fn assert_same_trajectory(a: &RunRecord, b: &RunRecord, what: &str) {
    assert_eq!(a.train_losses.len(), b.train_losses.len(), "{what}: train loss count");
    for (i, (x, y)) in a.train_losses.iter().zip(b.train_losses.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: train loss diverged at step {i}");
    }
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{what}: final loss");
    assert_eq!(a.gmp.to_bits(), b.gmp.to_bits(), "{what}: gmp");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: wire bytes");
}

// ---------------------------------------------------------------------------
// 4. seed-reconstruction fast path
// ---------------------------------------------------------------------------

/// ~2M-element parameter vector with an odd 1D tail, so every code path
/// (blocked bulk, scalar tail) is on the clock.
fn bench_params() -> ParamVec {
    ParamVec::new(
        vec!["wq".into(), "wk".into(), "ln".into()],
        vec![
            Tensor::from_vec(&[1024, 1024], vec![0.1; 1 << 20]),
            Tensor::from_vec(&[1024, 1024], vec![-0.1; 1 << 20]),
            Tensor::from_vec(&[4097], vec![0.5; 4097]),
        ],
    )
}

fn assert_params_bits_eq(a: &ParamVec, b: &ParamVec, what: &str) {
    for (ta, tb) in a.tensors.iter().zip(b.tensors.iter()) {
        for (x, y) in ta.data.iter().zip(tb.data.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what} diverged");
        }
    }
}

/// Multi-seed flush at k = 16: one-sweep and chunk-parallel apply vs the
/// historical per-message k-pass loop. Returns
/// (kpass_s, sweep_speedup, par_speedup) — both variants bit-identical
/// to the k-pass reference, asserted before timing.
fn multi_seed_flush(threads: usize) -> (f64, f64, f64) {
    const K: usize = 16;
    let updates: Vec<(u64, f32)> =
        (0..K).map(|i| (0x5eed_f100d + i as u64 * 13, 1e-3 * (i as f32 + 1.0))).collect();
    let base = bench_params();

    let mut reference = base.clone();
    for &(seed, coeff) in &updates {
        zo::apply_dense_update(&mut reference, seed, coeff);
    }
    let mut sweep = base.clone();
    zo::apply_dense_updates(&mut sweep, &updates);
    assert_params_bits_eq(&reference, &sweep, "one-sweep vs k-pass");
    let mut par = base.clone();
    zo::apply_dense_updates_par(&mut par, &updates, threads);
    assert_params_bits_eq(&reference, &par, "parallel vs k-pass");

    let kpass_s = median_time(3, || {
        let mut p = base.clone();
        for &(seed, coeff) in &updates {
            zo::apply_dense_update(&mut p, seed, coeff);
        }
        black_box(&p);
    });
    let sweep_s = median_time(3, || {
        let mut p = base.clone();
        zo::apply_dense_updates(&mut p, &updates);
        black_box(&p);
    });
    let par_s = median_time(3, || {
        let mut p = base.clone();
        zo::apply_dense_updates_par(&mut p, &updates, threads);
        black_box(&p);
    });
    (kpass_s, kpass_s / sweep_s.max(1e-9), kpass_s / par_s.max(1e-9))
}

/// Raw reconstruction throughput: million normals/sec out of the blocked
/// `fill_normal` (the per-message O(d) regeneration cost).
fn reconstruct_melems_per_sec() -> f64 {
    let mut buf = vec![0f32; 1 << 21];
    let mut rng = Rng::new(99);
    let secs = median_time(3, || {
        rng.fill_normal(&mut buf);
        black_box(&buf);
    });
    (buf.len() as f64 / 1e6) / secs.max(1e-9)
}

// ---------------------------------------------------------------------------
// ledger machinery (same shape as benches/scale.rs)
// ---------------------------------------------------------------------------

/// Regression gate: every metric measured this run that also exists in
/// the committed ledger must lie within the tolerance band. Metrics
/// present on only one side are reported but never fail the check (the
/// smoke grid measures a subset of the full grid).
fn run_check(path: &str, metrics: &[(String, f64)]) {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read baseline {path}: {e}"));
    let base = Json::parse(&text).unwrap_or_else(|e| panic!("unparseable baseline {path}: {e}"));
    let base_metrics = base
        .get("metrics")
        .and_then(|m| m.as_obj().cloned())
        .unwrap_or_else(|e| panic!("baseline {path} has no metrics object: {e}"));
    println!("\n== regression check vs {path} (tolerance {TOLERANCE}x) ==");
    let mut failures = 0;
    for (name, value) in metrics {
        match base_metrics.get(name.as_str()) {
            Some(b) => {
                let b = b.as_f64().unwrap_or_else(|e| panic!("baseline metric {name}: {e}"));
                let ok = b > 0.0 && *value >= b / TOLERANCE && *value <= b * TOLERANCE;
                println!(
                    "  {:<38} {:>12.4} vs baseline {:>10.4}  [{}]",
                    name,
                    value,
                    b,
                    if ok { "ok" } else { "REGRESSION" }
                );
                if !ok {
                    failures += 1;
                }
            }
            None => println!("  {name:<38} {value:>12.4} (no baseline entry — skipped)"),
        }
    }
    for name in base_metrics.keys() {
        if !metrics.iter().any(|(k, _)| k == name) {
            println!("  {name:<38} (baseline-only — not measured in this mode)");
        }
    }
    assert_eq!(failures, 0, "{failures} metric(s) left the {TOLERANCE}x tolerance band");
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let check_path = argv.iter().position(|a| a == "--check").map(|i| {
        argv.get(i + 1).unwrap_or_else(|| panic!("--check needs a baseline path")).clone()
    });
    let cores = cores();

    let mut timings: Vec<(String, f64)> = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();

    // -- 1. queue ops ------------------------------------------------------
    let events = if smoke { 200_000 } else { 1_000_000 };
    println!("== event queue ({events} events, clustered instants) ==");
    let seq_ops = queue_ops_sequential(events);
    let coh_ops = queue_ops_cohort(events);
    println!("  sequential pops: {:>10.0} ops/s", seq_ops);
    println!("  cohort drain:    {:>10.0} ops/s", coh_ops);
    metrics.push(("queue_push_pop_ops_per_sec".into(), seq_ops));
    metrics.push(("cohort_drain_ops_per_sec".into(), coh_ops));

    // -- 2. per-delivery flood scan ----------------------------------------
    println!("\n== per-delivery flood scan (hierarchical, 64 spread origins) ==");
    let scan_ns = round_scan_ns_per_delivery(2048, 64);
    println!("  n=2048   {scan_ns:>7.1} ns/delivery");
    metrics.push(("round_scan_ns_per_delivery_2048".into(), scan_ns));

    // -- 3. events/sec through the engine ----------------------------------
    println!("\n== event engine end to end (cheap model, uniform rates) ==");
    let event_ns: &[usize] = if smoke { &[2_048] } else { &[2_048, 10_240] };
    for &n in event_ns {
        let steps = 2;
        let (secs, record) = event_run(n, steps, 1);
        let eps = (n * steps) as f64 / secs.max(1e-9);
        println!(
            "  n={:<6} {} steps in {:>6.2} s -> {:>8.0} step-events/s (loss {:.4})",
            n, steps, secs, eps, record.final_loss
        );
        timings.push((format!("event_run_s_{n}"), secs));
        metrics.push((format!("step_events_per_sec_{n}"), eps));
    }

    // -- 4. cohort-parallel speedup (uniform rates = full cohorts) ---------
    println!("\n== cohort parallelism: --threads 8 vs --threads 1 ==");
    let (nc, ns) = (128, 8);
    let (t1, rec1) = event_run(nc, ns, 1);
    let (t8, rec8) = event_run(nc, ns, 8);
    assert_same_trajectory(&rec1, &rec8, "threads 8 vs 1");
    let cohort_speedup = t1 / t8.max(1e-9);
    println!(
        "  n={nc}, {ns} steps: {:.2} s @1t  {:.2} s @8t  -> {cohort_speedup:.2}x \
         (trajectories bit-identical)",
        t1, t8
    );
    timings.push(("cohort_run_s_1t".into(), t1));
    timings.push(("cohort_run_s_8t".into(), t8));
    metrics.push(("cohort_speedup_8t".into(), cohort_speedup));

    // -- 5. seed-reconstruction fast path ----------------------------------
    println!("\n== seed reconstruction (2M params) ==");
    let fill_rate = reconstruct_melems_per_sec();
    println!("  fill_normal: {fill_rate:>8.1} M normals/s");
    metrics.push(("reconstruct_melems_per_sec".into(), fill_rate));
    let (kpass_s, sweep_speedup, par_speedup) = multi_seed_flush(0);
    println!(
        "  k=16 flush: k-pass {:.0} ms, one-sweep {sweep_speedup:.2}x, \
         parallel {par_speedup:.2}x (all bit-identical)",
        1e3 * kpass_s
    );
    timings.push(("multi_seed_kpass_s_k16".into(), kpass_s));
    metrics.push(("multi_seed_sweep_speedup_k16".into(), sweep_speedup));
    metrics.push(("multi_seed_par_speedup_k16".into(), par_speedup));

    // -- hard floors -------------------------------------------------------
    let get = |name: &str| -> f64 {
        metrics
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("metric {name} was not measured"))
    };
    assert!(
        get("queue_push_pop_ops_per_sec") >= 1e6,
        "event queue fell below 1M ops/s — O(log n) pops regressed"
    );
    assert!(
        get("cohort_drain_ops_per_sec") >= get("queue_push_pop_ops_per_sec") / 4.0,
        "pop_cohort costs more than 4x the sequential pop path"
    );
    if cores >= 8 {
        assert!(
            get("cohort_speedup_8t") >= 2.0,
            "cohort parallelism below the 2x acceptance floor at 8 threads \
             ({} cores available)",
            cores
        );
        assert!(
            get("multi_seed_par_speedup_k16") >= 2.0,
            "parallel multi-seed flush below the 2x acceptance floor at k=16 \
             ({} cores available)",
            cores
        );
    } else {
        println!(
            "\nWARN: only {cores} cores — the 2x cohort/multi-seed floors are not \
             asserted on this machine (the --check band still applies)"
        );
    }

    // -- ledger + regression gate ------------------------------------------
    if !smoke {
        let mut tobj = BTreeMap::new();
        for (k, v) in &timings {
            tobj.insert(k.clone(), Json::Num(*v));
        }
        let mut mobj = BTreeMap::new();
        for (k, v) in &metrics {
            mobj.insert(k.clone(), Json::Num(*v));
        }
        let doc = Json::obj(vec![
            ("schema", Json::str("seedflood-event-bench-v1")),
            ("timings", Json::Obj(tobj)),
            ("metrics", Json::Obj(mobj)),
        ]);
        std::fs::write("BENCH_event.json", doc.to_string_pretty() + "\n")
            .expect("cannot write BENCH_event.json");
        let (nt, nm) = (timings.len(), metrics.len());
        println!("\nwrote BENCH_event.json ({nt} timings, {nm} metrics)");
    }
    if let Some(path) = check_path {
        run_check(&path, &metrics);
    }
    println!("\nevent bench OK ({})", if smoke { "smoke grid" } else { "full grid" });
}
