//! Fig 5: runtime of applying k zeroth-order gradient messages —
//! dense MeZO reconstruct-and-apply (O(k·d)) vs SubCGE coordinate
//! accumulation + one batched flush (O(k + r·d)).
//!
//! The paper measures OPT-2.7B on an A100; we measure the same two code
//! paths on the `small`-shaped parameter vector on CPU. The claim under
//! test is the asymptotic separation (orders of magnitude at large k) and
//! the k-independence of the SubCGE flush — not absolute milliseconds.
//!
//! Run: cargo bench --bench fig5_apply  (harness = false)

use seedflood::model::Manifest;
use seedflood::net::{MsgId, SeedUpdate};
use seedflood::rng::Rng;
use seedflood::subcge::{CoeffAccum, SubspaceBasis};
use seedflood::tensor::{ParamVec, Tensor};
use seedflood::util::bench::Bencher;
use seedflood::zo;

fn manifest() -> Manifest {
    // prefer the real small manifest if artifacts exist; else synthesize
    for dir in ["artifacts", "../artifacts"] {
        if let Ok(m) = Manifest::load(&format!("{dir}/small_manifest.json")) {
            return m;
        }
        if let Ok(m) = Manifest::load(&format!("{dir}/tiny_manifest.json")) {
            return m;
        }
    }
    // artifact-free fallback: same shape conventions, no files needed
    // (both apply paths here are pure-rust, so the comparison is identical)
    seedflood::oracle::synthetic_manifest()
}

fn params_of(m: &Manifest) -> ParamVec {
    ParamVec::new(
        m.params.iter().map(|s| s.name.clone()).collect(),
        m.params
            .iter()
            .map(|s| {
                let mut t = Tensor::zeros(&s.shape);
                Rng::new(1).fill_normal(&mut t.data);
                t
            })
            .collect(),
    )
}

fn main() {
    let m = manifest();
    let d = m.config.num_params;
    println!("== Fig 5: message-apply runtime, model={} (d={d}) ==", m.config.name);
    let mut b = Bencher::coarse();

    let basis = SubspaceBasis::new(&m, m.config.subcge_rank.min(32), 1_000_000, 7);
    let mut rows: Vec<(usize, f64, f64)> = vec![];

    for k in [1usize, 4, 16, 64, 256] {
        let msgs: Vec<SeedUpdate> = (0..k)
            .map(|i| SeedUpdate {
                id: MsgId { origin: 0, step: i as u32 },
                seed: 1000 + i as u64,
                coeff: 1e-4,
            })
            .collect();

        // MeZO path: regenerate z(seed) and axpy, per message
        let mut p_mezo = params_of(&m);
        let r_mezo = b.bench(&format!("mezo_apply k={k}"), || {
            for msg in &msgs {
                zo::apply_dense_update(&mut p_mezo, msg.seed, msg.coeff);
            }
        });
        let mezo_ms = r_mezo.median_s() * 1e3;

        // SubCGE path: O(1) coordinate folds + one batched U A V^T flush
        let mut p_sub = params_of(&m);
        let mut accum = CoeffAccum::new(&basis);
        let r_sub = b.bench(&format!("subcge_apply k={k}"), || {
            for msg in &msgs {
                accum.accumulate(&basis, msg);
            }
            accum.flush_rust(&basis, &mut p_sub);
        });
        let sub_ms = r_sub.median_s() * 1e3;
        rows.push((k, mezo_ms, sub_ms));
    }

    println!("\n{:>6} {:>14} {:>14} {:>10}", "k msgs", "MeZO (ms)", "SubCGE (ms)", "speedup");
    for (k, mezo, sub) in &rows {
        println!("{k:>6} {mezo:>14.3} {sub:>14.3} {:>9.1}x", mezo / sub);
    }
    // paper claim: separation grows with k (MeZO linear in k, SubCGE ~flat)
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    let mezo_growth = last.1 / first.1;
    let sub_growth = last.2 / first.2;
    println!("\nMeZO grows {mezo_growth:.0}x from k=1 to k=256; SubCGE grows {sub_growth:.1}x");
    assert!(
        mezo_growth > 10.0 * sub_growth,
        "expected MeZO to scale linearly in k while SubCGE stays ~flat"
    );
    println!("fig5 OK: SubCGE apply cost is ~independent of message count");
}
