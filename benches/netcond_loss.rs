//! Robustness bench (ISSUE 2/3): per-edge bytes and consensus error for
//! all four methods under increasing packet loss, on the sparsest topology
//! (ring of 8) where loss bites hardest — plus the repair-protocol
//! comparison under the churn-er preset: gap-request repair (summaries +
//! gap-fills, O(gap) on the wire) vs the legacy full-log re-flood.
//! Complements table1: the question here is not how cost scales with d or
//! n, but what *staying robust* costs.
//!
//! Run: cargo bench --bench netcond_loss

use seedflood::config::{ExperimentConfig, Method};
use seedflood::flood::RepairMode;
use seedflood::metrics::RunRecord;
use seedflood::sim;

fn run(method: Method, loss: f64) -> RunRecord {
    let zo = method.is_zeroth_order();
    let netcond = if loss > 0.0 {
        // periodic anti-entropy repair so flooding recovers what loss kills
        format!("loss={loss};repair=5")
    } else {
        String::new()
    };
    let cfg = ExperimentConfig {
        method,
        model: "synthetic".into(),
        task: "sst2".into(),
        clients: 8,
        steps: if zo { 40 } else { 10 },
        lr: if zo { 1e-3 } else { 1e-2 },
        netcond,
        ..Default::default()
    };
    sim::run_experiment(cfg).unwrap()
}

fn run_churn(mode: RepairMode, retain: usize) -> RunRecord {
    let cfg = ExperimentConfig {
        method: Method::SeedFlood,
        model: "synthetic".into(),
        task: "sst2".into(),
        clients: 8,
        steps: 40,
        lr: 1e-3,
        netcond: "churn-er".into(),
        repair_mode: mode,
        flood_retain: retain,
        ..Default::default()
    };
    sim::run_experiment(cfg).unwrap()
}

fn main() {
    println!("== netcond: four-method robustness to packet loss (ring of 8, synthetic) ==\n");
    println!(
        "{:>6} {:<12} {:>8} {:>8} {:>14} {:>14} {:>12}",
        "loss", "method", "GMP%", "deliv%", "consensus", "B/edge", "repair B"
    );
    let mut seedflood_lossy = None;
    let mut dsgd_lossy = None;
    let mut seedflood_reliable = None;
    for loss in [0.0, 0.02, 0.1] {
        for method in [Method::Dsgd, Method::ChocoSgd, Method::Dzsgd, Method::SeedFlood] {
            let r = run(method, loss);
            let consensus = r.evals.last().map(|e| e.consensus_error).unwrap_or(0.0);
            println!(
                "{:>6} {:<12} {:>8.2} {:>8.1} {:>14.2e} {:>14.0} {:>12}",
                loss,
                r.method,
                100.0 * r.gmp,
                100.0 * r.delivery_ratio,
                consensus,
                r.per_edge_bytes,
                r.repair_bytes
            );
            if method == Method::SeedFlood && loss == 0.0 {
                seedflood_reliable = Some(r);
            } else if method == Method::SeedFlood && loss == 0.1 {
                seedflood_lossy = Some(r);
            } else if method == Method::Dsgd && loss == 0.1 {
                dsgd_lossy = Some(r);
            }
        }
        println!();
    }

    let sf0 = seedflood_reliable.unwrap();
    let sf = seedflood_lossy.unwrap();
    let dsgd = dsgd_lossy.unwrap();
    // seed messages stay orders of magnitude below dense gossip even with
    // the repair traffic folded in (the paper's O(n) vs O(d))
    assert!(
        sf.per_edge_bytes * 10.0 < dsgd.per_edge_bytes,
        "seedflood repair overhead ate its cost advantage: {} vs {}",
        sf.per_edge_bytes,
        dsgd.per_edge_bytes
    );
    // the fault layer really dropped traffic, and the reliable run didn't
    assert_eq!(sf0.delivery_ratio, 1.0, "reliable run must deliver everything");
    assert!(sf.delivery_ratio < 1.0, "10% loss must drop messages");
    assert_eq!(sf0.repair_bytes, 0, "no faults, no repair traffic");
    assert!(sf.repair_bytes > 0, "anti-entropy heartbeats must transmit repairs");
    println!(
        "netcond_loss OK: seedflood/dsgd per-edge under 10% loss = {:.1}/{:.1} KB, \
         seedflood delivery {:.1}% with staleness ≤ {} iter\n",
        sf.per_edge_bytes / 1024.0,
        dsgd.per_edge_bytes / 1024.0,
        100.0 * sf.delivery_ratio,
        sf.max_staleness
    );

    println!("== repair-protocol comparison under churn-er (8 clients, 40 steps) ==\n");
    println!(
        "{:<10} {:>8} {:>12} {:>12} {:>10} {:>10}",
        "mode", "GMP%", "repair B", "total B", "staleness", "retained"
    );
    // reflood needs retain=0 (keep everything): it replays the full log
    let reflood = run_churn(RepairMode::Reflood, 0);
    let gap = run_churn(RepairMode::Gap, 4096);
    for (mode, r) in [("reflood", &reflood), ("gap", &gap)] {
        println!(
            "{:<10} {:>8.2} {:>12} {:>12} {:>10} {:>10}",
            mode, 100.0 * r.gmp, r.repair_bytes, r.total_bytes, r.max_staleness,
            r.flood_retained
        );
    }
    assert!(reflood.repair_bytes > 0, "churn recoveries must trigger re-floods");
    assert!(gap.repair_bytes > 0, "churn recoveries must trigger gap repairs");
    // the acceptance criterion: gap-request repair strictly undercuts the
    // full-log re-flood on the wire
    assert!(
        gap.repair_bytes < reflood.repair_bytes,
        "gap repair ({} B) must beat full-log re-flood ({} B)",
        gap.repair_bytes,
        reflood.repair_bytes
    );
    println!(
        "\nnetcond_loss OK: gap repair {} B vs full-log re-flood {} B \
         ({:.1}x fewer repair bytes under churn-er)",
        gap.repair_bytes,
        reflood.repair_bytes,
        reflood.repair_bytes as f64 / gap.repair_bytes.max(1) as f64
    );
}
