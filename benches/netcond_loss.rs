//! Robustness bench (ISSUE 2): per-edge bytes and consensus error for all
//! four methods under increasing packet loss, on the sparsest topology
//! (ring of 8) where loss bites hardest. Complements table1: the question
//! here is not how cost scales with d or n, but what *staying robust*
//! costs — SeedFlood's repair re-floods add duplicate seed traffic, dense
//! gossip silently mixes with fewer neighbors.
//!
//! Run: cargo bench --bench netcond_loss

use seedflood::config::{ExperimentConfig, Method};
use seedflood::metrics::RunRecord;
use seedflood::sim;

fn run(method: Method, loss: f64) -> RunRecord {
    let zo = method.is_zeroth_order();
    let netcond = if loss > 0.0 {
        // periodic anti-entropy repair so flooding recovers what loss kills
        format!("loss={loss};repair=5")
    } else {
        String::new()
    };
    let cfg = ExperimentConfig {
        method,
        model: "synthetic".into(),
        task: "sst2".into(),
        clients: 8,
        steps: if zo { 40 } else { 10 },
        lr: if zo { 1e-3 } else { 1e-2 },
        netcond,
        ..Default::default()
    };
    sim::run_experiment(cfg).unwrap()
}

fn main() {
    println!("== netcond: four-method robustness to packet loss (ring of 8, synthetic) ==\n");
    println!(
        "{:>6} {:<12} {:>8} {:>8} {:>14} {:>14}",
        "loss", "method", "GMP%", "deliv%", "consensus", "B/edge"
    );
    let mut seedflood_lossy = None;
    let mut dsgd_lossy = None;
    let mut seedflood_reliable = None;
    for loss in [0.0, 0.02, 0.1] {
        for method in [Method::Dsgd, Method::ChocoSgd, Method::Dzsgd, Method::SeedFlood] {
            let r = run(method, loss);
            let consensus = r.evals.last().map(|e| e.consensus_error).unwrap_or(0.0);
            println!(
                "{:>6} {:<12} {:>8.2} {:>8.1} {:>14.2e} {:>14.0}",
                loss,
                r.method,
                100.0 * r.gmp,
                100.0 * r.delivery_ratio,
                consensus,
                r.per_edge_bytes
            );
            if method == Method::SeedFlood && loss == 0.0 {
                seedflood_reliable = Some(r);
            } else if method == Method::SeedFlood && loss == 0.1 {
                seedflood_lossy = Some(r);
            } else if method == Method::Dsgd && loss == 0.1 {
                dsgd_lossy = Some(r);
            }
        }
        println!();
    }

    let sf0 = seedflood_reliable.unwrap();
    let sf = seedflood_lossy.unwrap();
    let dsgd = dsgd_lossy.unwrap();
    // seed messages stay orders of magnitude below dense gossip even with
    // the repair re-flood overhead folded in (the paper's O(n) vs O(d))
    assert!(
        sf.per_edge_bytes * 10.0 < dsgd.per_edge_bytes,
        "seedflood repair overhead ate its cost advantage: {} vs {}",
        sf.per_edge_bytes,
        dsgd.per_edge_bytes
    );
    // the fault layer really dropped traffic, and the reliable run didn't
    assert_eq!(sf0.delivery_ratio, 1.0, "reliable run must deliver everything");
    assert!(sf.delivery_ratio < 1.0, "10% loss must drop messages");
    assert!(sf.flood_duplicates > sf0.flood_duplicates, "repairs must re-flood");
    println!(
        "netcond_loss OK: seedflood/dsgd per-edge under 10% loss = {:.1}/{:.1} KB, \
         seedflood delivery {:.1}% with staleness ≤ {} iter",
        sf.per_edge_bytes / 1024.0,
        dsgd.per_edge_bytes / 1024.0,
        100.0 * sf.delivery_ratio,
        sf.max_staleness
    );
}
