//! Offline shim of the `anyhow` crate — the exact subset this repo uses:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros, the
//! [`Context`] trait on `Result`/`Option`, and the typed [`Ok`] helper. Error values
//! are stored as a rendered message chain (outermost first), which matches
//! how the coordinator consumes them (Display/Debug only, no downcasting).

use std::fmt::{self, Debug, Display};

/// A rendered error with a context chain. Unlike `std` errors this type
/// intentionally does NOT implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` below cannot collide with the identity
/// `From<Error>` used by `?` (the same trick the real anyhow plays).
pub struct Error {
    /// message chain, outermost context first
    msgs: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg(msg: impl Display) -> Error {
        Error { msgs: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, ctx: impl Display) -> Error {
        self.msgs.insert(0, ctx.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(|s| s.as_str()).unwrap_or(""))
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // record the std source chain too, so context is not lost
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// Attach context to failures, like anyhow's `Context`.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Typed `Ok` for closures whose error type would otherwise be ambiguous
/// (`anyhow::Ok(value)`).
#[allow(non_snake_case)]
pub fn Ok<T>(t: T) -> Result<T> {
    Result::Ok(t)
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Like anyhow's `ensure!`: bail with the formatted message unless the
/// condition holds (callers always pass a message in this repo, so the
/// real crate's condition-only default form is not implemented).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn bail_and_anyhow() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        let e: Error = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn ensure_bails_with_message() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(-4).unwrap_err().to_string(), "negative: -4");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
