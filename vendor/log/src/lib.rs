//! Offline shim of the `log` facade crate — the subset this repo uses:
//! level/filter enums, the [`Log`] trait, [`set_logger`]/[`set_max_level`],
//! and the `error!`..`trace!` macros. Semantics match the real crate for
//! this surface (levels order Error < Warn < Info < Debug < Trace; records
//! below the max level are dropped before reaching the logger).

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Clone, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger was already set")
    }
}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro backend — not part of the public log API surface.
#[doc(hidden)]
pub fn __log(level: Level, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__log($lvl, ::core::format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error { ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) } }
#[macro_export]
macro_rules! warn { ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) } }
#[macro_export]
macro_rules! info { ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) } }
#[macro_export]
macro_rules! debug { ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) } }
#[macro_export]
macro_rules! trace { ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) } }

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static HITS: AtomicU64 = AtomicU64::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, m: &Metadata) -> bool {
            m.level() <= Level::Info
        }
        fn log(&self, _r: &Record) {
            HITS.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_order_like_real_log() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Info <= Level::Info);
    }

    #[test]
    fn filtered_records_are_dropped() {
        static C: Counter = Counter;
        let _ = set_logger(&C);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(Ordering::Relaxed);
        info!("hello {}", 1);
        debug!("dropped by max level");
        error!("counted");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 2);
    }
}
