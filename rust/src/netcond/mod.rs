//! `NetCond` — deterministic unreliable-network & churn fault injection.
//!
//! The paper's central claim is that flooding near-zero-size seed messages
//! stays robust "across complex network topologies" (§3.3), but the
//! simulator historically exercised only perfectly reliable, static
//! graphs. This module is the declarative fault model that closes that
//! gap: per-edge packet-loss probability, integer delivery delay, scheduled
//! link up/down windows, and node churn (clients offline for `[from,
//! until)` iteration windows, then rejoining). [`crate::net::Network`]
//! compiles a `NetCond` into per-edge tables
//! ([`crate::net::Network::install`]) and consults them on every
//! send/receive; [`crate::flood::FloodState`] answers faults with repair
//! (gap-request summaries by default, legacy full re-floods via
//! [`crate::flood::RepairMode`]) so delivery degrades to *bounded
//! staleness* instead of silent loss.
//!
//! Everything is deterministic: fault draws come from a dedicated RNG
//! stream (`seed`), advanced only on the sequential communication path, so
//! a faulty run is bit-for-bit reproducible and independent of
//! `--threads` (tested in `rust/tests/netcond.rs`).
//!
//! # Spec strings
//!
//! A `NetCond` is described by a compact spec string — the value of the
//! `--netcond` CLI knob and the `netcond` config/TOML key. Clauses are
//! separated by `;` (never `,` — commas separate whole scenarios in list
//! options like `experiment churn --scenarios a,b`):
//!
//! | clause | meaning |
//! |---|---|
//! | `loss=P` | iid per-edge packet-loss probability (both directions) |
//! | `delay=K` | delivery delay of `K` communication rounds on every edge |
//! | `link:A-B@T0..T1` | undirected link A–B down during iterations `[T0, T1)` |
//! | `node:I@T0..T1` | client I offline during iterations `[T0, T1)` |
//! | `eloss:A-B=P` | per-edge loss override for link A–B |
//! | `edelay:A-B=K` | per-edge delay override for link A–B |
//! | `repair=K` | anti-entropy: trigger the repair protocol every K iterations |
//! | `seed=S` | fault RNG stream seed |
//!
//! Alternatively the spec may be one of the scenario [`preset`] names
//! (`lossy-ring`, `flaky-torus`, `churn-er`), which also pin the topology
//! they are named after.
//!
//! ```
//! use seedflood::net::Network;
//! use seedflood::netcond::NetCond;
//! use seedflood::topology::Topology;
//!
//! let cond = NetCond::parse("loss=0.1;delay=1;node:2@1..3;repair=4").unwrap();
//! let mut net = Network::new(Topology::ring(4));
//! net.install(&cond).unwrap();
//! net.set_step(1);
//! assert!(!net.is_online(2)); // inside the churn window
//! net.set_step(3);
//! assert!(net.is_online(2));
//! assert!(net.should_repair(2)); // just recovered → re-flood trigger
//! ```

use anyhow::{bail, ensure, Result};

use crate::topology::{Kind, Topology};

/// Default seed of the dedicated fault RNG stream (spec clause `seed=S`).
pub const DEFAULT_FAULT_SEED: u64 = 0xFA_01_17;

/// One scheduled connectivity event. Windows are half-open iteration
/// ranges `[from, until)` on the simulation's step clock
/// ([`crate::net::Network::set_step`]) — under the event-driven engine
/// (`--time-model event`) that clock is the *nominal* iteration (virtual
/// time in nominal-step units), so the same scenario spec stresses both
/// engines at the same point of training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// Undirected link `a`–`b` drops all traffic during the window.
    Link { a: usize, b: usize, from: usize, until: usize },
    /// Client `id` is offline during the window: it transmits nothing and
    /// receives nothing; in-flight messages addressed to it stay buffered
    /// on its in-edges until it rejoins.
    Node { id: usize, from: usize, until: usize },
}

/// Declarative fault model for the simulated network. Disabled is
/// represented by *absence* (no `NetCond` installed), so the reliable
/// default path carries zero overhead and stays bit-for-bit identical to
/// the pre-netcond simulator.
#[derive(Clone, Debug, PartialEq)]
pub struct NetCond {
    /// fault RNG stream seed (independent of the experiment seed)
    pub seed: u64,
    /// uniform iid per-edge packet-loss probability
    pub loss: f64,
    /// uniform per-edge delivery delay, in communication rounds
    pub delay: u64,
    /// per-link loss overrides (undirected: applied to both directions)
    pub edge_loss: Vec<(usize, usize, f64)>,
    /// per-link delay overrides (undirected)
    pub edge_delay: Vec<(usize, usize, u64)>,
    /// scheduled link/node down windows
    pub events: Vec<Event>,
    /// anti-entropy period: every `repair_every` iterations each client
    /// runs its repair protocol — gap-request summary or legacy re-flood,
    /// see [`crate::flood::RepairMode`] (0 = recovery-triggered only)
    pub repair_every: usize,
}

impl Default for NetCond {
    fn default() -> Self {
        NetCond {
            seed: DEFAULT_FAULT_SEED,
            loss: 0.0,
            delay: 0,
            edge_loss: vec![],
            edge_delay: vec![],
            events: vec![],
            repair_every: 0,
        }
    }
}

impl NetCond {
    /// Parse a spec string (see the module docs for the clause grammar).
    /// Range errors (probabilities outside `[0, 1]`, empty windows) are
    /// rejected here; graph-shape errors (unknown nodes/edges) are caught
    /// by [`Self::validate`] once the topology is known.
    pub fn parse(spec: &str) -> Result<NetCond> {
        let mut c = NetCond::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(rest) = clause.strip_prefix("link:") {
                let (edge, window) = split2(rest, '@', clause)?;
                let (a, b) = parse_edge(edge)?;
                let (from, until) = parse_window(window)?;
                c.events.push(Event::Link { a, b, from, until });
            } else if let Some(rest) = clause.strip_prefix("node:") {
                let (id, window) = split2(rest, '@', clause)?;
                let id = parse_num::<usize>(id, "node id")?;
                let (from, until) = parse_window(window)?;
                c.events.push(Event::Node { id, from, until });
            } else if let Some(rest) = clause.strip_prefix("eloss:") {
                let (edge, p) = split2(rest, '=', clause)?;
                let (a, b) = parse_edge(edge)?;
                let p = parse_prob(p)?;
                c.edge_loss.push((a, b, p));
            } else if let Some(rest) = clause.strip_prefix("edelay:") {
                let (edge, k) = split2(rest, '=', clause)?;
                let (a, b) = parse_edge(edge)?;
                c.edge_delay.push((a, b, parse_num::<u64>(k, "delay")?));
            } else if let Some((k, v)) = clause.split_once('=') {
                match k.trim() {
                    "loss" => c.loss = parse_prob(v)?,
                    "delay" => c.delay = parse_num(v, "delay")?,
                    "seed" => c.seed = parse_num(v, "seed")?,
                    "repair" => c.repair_every = parse_num(v, "repair period")?,
                    other => bail!("unknown netcond key {other:?} in clause {clause:?}"),
                }
            } else {
                bail!("cannot parse netcond clause {clause:?}");
            }
        }
        Ok(c)
    }

    /// Check the model against a concrete graph: every referenced node
    /// must exist and every referenced link must be an edge of `topo`.
    pub fn validate(&self, topo: &Topology) -> Result<()> {
        let check_edge = |a: usize, b: usize| -> Result<()> {
            ensure!(
                a < topo.n && b < topo.n && topo.has_edge(a, b),
                "netcond references {a}-{b}, not an edge of {} (n={})",
                topo.kind,
                topo.n
            );
            Ok(())
        };
        for &(a, b, _) in &self.edge_loss {
            check_edge(a, b)?;
        }
        for &(a, b, _) in &self.edge_delay {
            check_edge(a, b)?;
        }
        for ev in &self.events {
            match *ev {
                Event::Link { a, b, from, until } => {
                    check_edge(a, b)?;
                    ensure!(from < until, "empty link window {from}..{until}");
                }
                Event::Node { id, from, until } => {
                    ensure!(id < topo.n, "netcond node {id} out of range (n={})", topo.n);
                    ensure!(from < until, "empty node window {from}..{until}");
                }
            }
        }
        Ok(())
    }

    /// True if any fault source is active (an all-zero model behaves
    /// identically to no model, just with the bookkeeping installed).
    pub fn is_faulty(&self) -> bool {
        self.loss > 0.0
            || self.delay > 0
            || !self.events.is_empty()
            || self.edge_loss.iter().any(|&(_, _, p)| p > 0.0)
            || self.edge_delay.iter().any(|&(_, _, k)| k > 0)
    }
}

/// A named scenario: a fault model plus the topology it is defined on.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub kind: Kind,
    pub cond: NetCond,
}

/// Scenario presets for the robustness experiments (`seedflood experiment
/// churn`, `examples/churn_tolerance.rs`). Preset names pin the topology
/// they are named after; windows scale with `steps` so the same preset
/// works for short tests and long runs.
pub fn preset(name: &str, n: usize, steps: usize) -> Option<Scenario> {
    // window helper: the [num/den, (num+1)/den) fraction of training
    let w = |num: usize, den: usize| (steps * num / den, steps * (num + 1) / den);
    match name {
        // uniform 5% packet loss on the sparsest paper topology — every
        // message crosses each ring hop exactly twice, so loss bites
        // hardest here; periodic anti-entropy restores delivery
        "lossy-ring" => Some(Scenario {
            kind: Kind::Ring,
            cond: NetCond {
                loss: 0.05,
                repair_every: (steps / 10).max(1),
                ..Default::default()
            },
        }),
        // mild loss plus three scheduled link flaps on a torus: while a
        // link is down the effective diameter exceeds the flood depth, so
        // the persistent outbox has to carry messages across iterations
        "flaky-torus" => {
            let mut cond = NetCond {
                loss: 0.02,
                repair_every: (steps / 10).max(1),
                ..Default::default()
            };
            if n >= 4 && steps >= 6 {
                let topo = Topology::torus(n);
                for (j, node) in [0, n / 3, 2 * n / 3].into_iter().enumerate() {
                    let nbr = topo.neighbors(node)[0];
                    let (from, until) = w(j + 1, 6);
                    cond.events.push(Event::Link { a: node, b: nbr, from, until });
                }
            }
            Some(Scenario { kind: Kind::Torus, cond })
        }
        // staggered node churn on an Erdős–Rényi graph: up to three
        // distinct clients go offline for a fifth of training each and
        // rejoin; repair is purely recovery-triggered
        "churn-er" => {
            let mut cond = NetCond { loss: 0.01, ..Default::default() };
            if n >= 4 && steps >= 5 {
                // candidates are ascending, so adjacent dedup suffices
                // (at n = 4, n/2 == n-2 — don't churn one client twice)
                let mut nodes = vec![1, n / 2, n - 2];
                nodes.dedup();
                for (j, node) in nodes.into_iter().enumerate() {
                    let (from, until) = w(j + 1, 5);
                    cond.events.push(Event::Node { id: node, from, until });
                }
            }
            Some(Scenario { kind: Kind::ErdosRenyi, cond })
        }
        _ => None,
    }
}

/// Resolve a `--netcond` value: a [`preset`] name (which also pins the
/// topology) or a raw spec string (which leaves the topology alone).
pub fn resolve(spec: &str, n: usize, steps: usize) -> Result<(Option<Kind>, NetCond)> {
    if let Some(sc) = preset(spec, n, steps) {
        return Ok((Some(sc.kind), sc.cond));
    }
    Ok((None, NetCond::parse(spec)?))
}

fn split2<'a>(s: &'a str, sep: char, clause: &str) -> Result<(&'a str, &'a str)> {
    s.split_once(sep)
        .ok_or_else(|| anyhow::anyhow!("expected {sep:?} in netcond clause {clause:?}"))
}

fn parse_num<T: std::str::FromStr>(s: &str, what: &str) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    s.trim()
        .parse::<T>()
        .map_err(|e| anyhow::anyhow!("bad {what} {s:?}: {e}"))
}

fn parse_prob(s: &str) -> Result<f64> {
    let p: f64 = parse_num(s, "probability")?;
    ensure!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
    Ok(p)
}

/// `"A-B"` → (A, B)
fn parse_edge(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s
        .split_once('-')
        .ok_or_else(|| anyhow::anyhow!("expected A-B edge, got {s:?}"))?;
    Ok((parse_num(a, "node id")?, parse_num(b, "node id")?))
}

/// `"T0..T1"` → [T0, T1), non-empty
fn parse_window(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s
        .split_once("..")
        .ok_or_else(|| anyhow::anyhow!("expected T0..T1 window, got {s:?}"))?;
    let (from, until) = (parse_num(a, "window start")?, parse_num(b, "window end")?);
    ensure!(from < until, "empty netcond window {from}..{until}");
    Ok((from, until))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_clause_kinds() {
        let c = NetCond::parse(
            "loss=0.1; delay=2; seed=9; repair=5; link:0-1@3..7; node:2@4..6; \
             eloss:1-2=0.5; edelay:2-3=4",
        )
        .unwrap();
        assert_eq!(c.loss, 0.1);
        assert_eq!(c.delay, 2);
        assert_eq!(c.seed, 9);
        assert_eq!(c.repair_every, 5);
        assert_eq!(c.edge_loss, vec![(1, 2, 0.5)]);
        assert_eq!(c.edge_delay, vec![(2, 3, 4)]);
        assert_eq!(
            c.events,
            vec![
                Event::Link { a: 0, b: 1, from: 3, until: 7 },
                Event::Node { id: 2, from: 4, until: 6 },
            ]
        );
        assert!(c.is_faulty());
    }

    #[test]
    fn empty_clauses_ok_but_comma_is_not_a_separator() {
        let c = NetCond::parse("loss=0.05;;delay=1;").unwrap();
        assert_eq!(c.loss, 0.05);
        assert_eq!(c.delay, 1);
        // commas separate whole scenarios in CLI list options, so they
        // must never silently split a single spec
        assert!(NetCond::parse("loss=0.05,delay=1").is_err());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(NetCond::parse("loss=1.5").is_err()); // prob out of range
        assert!(NetCond::parse("node:2@7..3").is_err()); // empty window
        assert!(NetCond::parse("link:0@1..2").is_err()); // missing -B
        assert!(NetCond::parse("gibberish").is_err());
        assert!(NetCond::parse("frob=1").is_err()); // unknown key
    }

    #[test]
    fn zero_spec_is_not_faulty() {
        let c = NetCond::parse("loss=0").unwrap();
        assert!(!c.is_faulty());
        assert_eq!(c, NetCond { loss: 0.0, ..Default::default() });
    }

    #[test]
    fn validate_against_topology() {
        let topo = Topology::ring(6);
        // 0-1 is a ring edge, 0-3 is not
        assert!(NetCond::parse("link:0-1@0..5").unwrap().validate(&topo).is_ok());
        assert!(NetCond::parse("link:0-3@0..5").unwrap().validate(&topo).is_err());
        assert!(NetCond::parse("node:9@0..5").unwrap().validate(&topo).is_err());
        assert!(NetCond::parse("eloss:2-3=0.2").unwrap().validate(&topo).is_ok());
        assert!(NetCond::parse("edelay:2-4=1").unwrap().validate(&topo).is_err());
    }

    #[test]
    fn presets_resolve_and_validate() {
        for (name, kind) in [
            ("lossy-ring", Kind::Ring),
            ("flaky-torus", Kind::Torus),
            ("churn-er", Kind::ErdosRenyi),
        ] {
            let sc = preset(name, 16, 100).expect(name);
            assert_eq!(sc.kind, kind, "{name}");
            assert!(sc.cond.is_faulty(), "{name}");
            let topo = Topology::build(sc.kind, 16, 0);
            sc.cond.validate(&topo).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert!(preset("nope", 16, 100).is_none());
    }

    #[test]
    fn presets_survive_tiny_runs() {
        // short tests use few steps/clients; windows must stay valid
        for name in ["lossy-ring", "flaky-torus", "churn-er"] {
            let sc = preset(name, 8, 10).expect(name);
            let topo = Topology::build(sc.kind, 8, 0);
            sc.cond.validate(&topo).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn churn_er_nodes_distinct_at_minimum_n() {
        // at n = 4, the candidates [1, n/2, n-2] collide — the preset must
        // not churn the same client in back-to-back windows
        let sc = preset("churn-er", 4, 20).unwrap();
        let ids: Vec<usize> = sc
            .cond
            .events
            .iter()
            .map(|ev| match *ev {
                Event::Node { id, .. } => id,
                Event::Link { .. } => panic!("churn-er has no link events"),
            })
            .collect();
        assert_eq!(ids, vec![1, 2]);
    }

    #[test]
    fn resolve_preset_vs_raw_spec() {
        let (kind, cond) = resolve("lossy-ring", 16, 200).unwrap();
        assert_eq!(kind, Some(Kind::Ring));
        assert_eq!(cond.loss, 0.05);
        let (kind, cond) = resolve("loss=0.2;delay=1", 16, 200).unwrap();
        assert_eq!(kind, None);
        assert_eq!(cond.loss, 0.2);
        assert!(resolve("not-a-preset-or-spec", 16, 200).is_err());
    }
}
