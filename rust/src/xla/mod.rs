//! In-repo stub of the `xla` (PJRT) bindings.
//!
//! The offline image cannot resolve or link the real xla-rs crate, so
//! `crate::xla` points here (see lib.rs): every entry point reports a
//! clear "built without the real xla bindings" error instead of failing to
//! resolve at build time. The simulator remains fully usable through the
//! pure-rust synthetic oracle (`model = "synthetic"`, see
//! [`crate::oracle`]); only the AOT-artifact paths need the real crate —
//! add the `xla` dependency and swap lib.rs to `pub use ::xla;`.
//!
//! Types mirror the subset of the xla-rs API the crate consumes:
//! `PjRtClient`, `PjRtLoadedExecutable::execute_b`, `PjRtBuffer`,
//! `HloModuleProto::from_text_file`, `XlaComputation::from_proto`, and
//! `Literal::{to_tuple, to_vec}`.

// the private unit fields exist only to forbid construction outside this
// module; nothing ever reads them
#![allow(dead_code)]

use std::fmt;

/// Error for every stubbed entry point.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: seedflood was built with the in-repo PJRT stub; wire the \
             real xla-rs bindings (see rust/src/xla/mod.rs) and run \
             `make artifacts` to execute AOT HLO graphs, or use \
             `--model synthetic` for the pure-rust oracle"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host value types uploadable as device buffers.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct Literal(());

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
