//! ChocoSGD (Koloskova et al., 2019) and Choco-LoRA — gossip with top-K
//! compressed communication and error feedback through surrogate copies.
//!
//! Per client i the state is the model `x_i`, its own public surrogate
//! `x̂_i`, and surrogates `x̂_j` for every neighbor. A communication round:
//!
//! 1. `q_i = topK(x_i − x̂_i)`               (compression, paper: keep 1%)
//! 2. send `q_i` to all neighbors; everyone updates their copy of `x̂_i`
//! 3. `x_i ← x_i + γ Σ_j w_ij (x̂_j − x̂_i)`   (consensus step, γ = 1)
//!
//! Surrogates are initialized to θ⁰ (paper Appendix B.2: "initialize
//! surrogate model parameters with pretrained weights").

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use super::{Algorithm, Space};
use crate::data::BatchSampler;
use crate::net::{Network, Payload};
use crate::sim::{consensus_error, Env};
use crate::tensor::ParamVec;
use crate::topology::Topology;

pub struct Choco {
    space: Space,
    /// x_i
    clients: Vec<ParamVec>,
    /// x̂_i (own public surrogate)
    hat_self: Vec<ParamVec>,
    /// x̂_j as locally tracked by i: hat_nbr[i][j]
    hat_nbr: Vec<HashMap<usize, ParamVec>>,
    samplers: Vec<BatchSampler>,
    weights: Vec<Vec<(usize, f32)>>,
    local_steps: usize,
    lr: f32,
    gamma: f32,
    topk_ratio: f32,
}

impl Choco {
    pub fn new(env: &Env, topo: &Topology) -> Choco {
        let space = Space::for_method(env);
        let clients: Vec<ParamVec> =
            (0..env.n_clients()).map(|_| space.init_client(env)).collect();
        let hat_self = clients.clone();
        let hat_nbr = (0..env.n_clients())
            .map(|i| {
                topo.neighbors(i)
                    .iter()
                    .map(|&j| (j, clients[j].clone()))
                    .collect()
            })
            .collect();
        Choco {
            space,
            clients,
            hat_self,
            hat_nbr,
            samplers: env.make_samplers(),
            weights: topo.mixing_weights(),
            local_steps: env.cfg.local_steps,
            lr: env.cfg.lr,
            gamma: env.cfg.consensus_lr,
            topk_ratio: env.cfg.topk_ratio,
        }
    }

    /// Global top-K of |x_i − x̂_i| over the whole parameter vector,
    /// returned per-tensor as (index, value) lists.
    fn compress(&self, i: usize) -> Vec<Vec<(u32, f32)>> {
        let x = &self.clients[i];
        let hat = &self.hat_self[i];
        let d: usize = x.num_elements();
        let k = ((self.topk_ratio as f64 * d as f64).ceil() as usize).max(1);
        // collect (|delta|, tensor, idx, val) and select top k globally
        let mut entries: Vec<(f32, u32, u32)> = Vec::with_capacity(d);
        for (ti, (xt, ht)) in x.tensors.iter().zip(hat.tensors.iter()).enumerate() {
            for (ei, (&a, &b)) in xt.data.iter().zip(ht.data.iter()).enumerate() {
                let delta = a - b;
                if delta != 0.0 {
                    entries.push((delta.abs(), ti as u32, ei as u32));
                }
            }
        }
        let k = k.min(entries.len());
        let mut out = vec![vec![]; x.tensors.len()];
        if k == 0 {
            return out;
        }
        entries.select_nth_unstable_by(k - 1, |a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, ti, ei) in entries[..k].iter() {
            let delta = x.tensors[ti as usize].data[ei as usize]
                - hat.tensors[ti as usize].data[ei as usize];
            out[ti as usize].push((ei, delta));
        }
        out
    }
}

/// Apply a sparse delta to a surrogate.
fn apply_sparse(target: &mut ParamVec, q: &[Vec<(u32, f32)>]) {
    for (t, qs) in target.tensors.iter_mut().zip(q.iter()) {
        for &(idx, val) in qs {
            t.data[idx as usize] += val;
        }
    }
}

impl Algorithm for Choco {
    fn local_step(&mut self, client: usize, _step: usize, env: &Env) -> Result<f32> {
        let (b, _) = env.batch_shape();
        let (ids, labels) = self.samplers[client].next_batch(b);
        let (loss, grads) = self.space.grad(env, &self.clients[client], &ids, &labels)?;
        self.clients[client].axpy(-self.lr, &grads);
        Ok(loss)
    }

    fn communicate(&mut self, step: usize, _env: &Env, net: &mut Network) -> Result<()> {
        if (step + 1) % self.local_steps != 0 {
            return Ok(());
        }
        let n = self.clients.len();
        // 1+2: compress, broadcast, update own surrogate
        let qs: Vec<Arc<Vec<Vec<(u32, f32)>>>> =
            (0..n).map(|i| Arc::new(self.compress(i))).collect();
        for i in 0..n {
            net.broadcast(i, &Payload::Sparse(qs[i].clone()));
            apply_sparse(&mut self.hat_self[i], &qs[i]);
        }
        // receive: update tracked neighbor surrogates
        for i in 0..n {
            for m in net.recv_all(i) {
                let Payload::Sparse(q) = m.payload else {
                    panic!("choco received non-sparse payload");
                };
                if let Some(hat) = self.hat_nbr[i].get_mut(&m.from) {
                    apply_sparse(hat, &q);
                }
            }
        }
        // 3: consensus step x_i += γ Σ_j w_ij (x̂_j − x̂_i)
        for i in 0..n {
            let wrow = &self.weights[i];
            let mut delta = self.clients[i].zeros_like();
            for (&j, hat_j) in &self.hat_nbr[i] {
                let w = wrow.iter().find(|&&(k, _)| k == j).map(|&(_, w)| w).unwrap_or(0.0);
                delta.axpy(w, hat_j);
                delta.axpy(-w, &self.hat_self[i]);
            }
            self.clients[i].axpy(self.gamma, &delta);
        }
        Ok(())
    }

    fn eval_gmp(&self, env: &Env, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)> {
        let refs: Vec<&ParamVec> = self.clients.iter().collect();
        let avg = ParamVec::average(&refs);
        self.space.eval(env, &avg, batches)
    }

    fn snapshot(&self) -> Vec<ParamVec> {
        self.clients.clone()
    }

    fn restore(&mut self, snap: Vec<ParamVec>) {
        assert_eq!(snap.len(), self.clients.len());
        self.clients = snap;
    }

    fn consensus_error(&self) -> f64 {
        consensus_error(&self.clients)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn apply_sparse_updates_selected_entries() {
        let mut p = ParamVec::new(
            vec!["w".into()],
            vec![Tensor::from_vec(&[4], vec![0.0; 4])],
        );
        apply_sparse(&mut p, &[vec![(1, 2.0), (3, -1.0)]]);
        assert_eq!(p.tensors[0].data, vec![0.0, 2.0, 0.0, -1.0]);
    }
}
