//! ChocoSGD (Koloskova et al., 2019) and Choco-LoRA — gossip with top-K
//! compressed communication and error feedback through surrogate copies.
//!
//! Per client i the state is the model `x_i`, its own public surrogate
//! `x̂_i`, and surrogates `x̂_j` for every neighbor. A communication round:
//!
//! 1. `q_i = topK(x_i − x̂_i)`               (compression, paper: keep 1%)
//! 2. send `q_i` to all neighbors; everyone updates their copy of `x̂_i`
//! 3. `x_i ← x_i + γ Σ_j w_ij (x̂_j − x̂_i)`   (consensus step, γ = 1)
//!
//! Surrogates are initialized to θ⁰ (paper Appendix B.2: "initialize
//! surrogate model parameters with pretrained weights").
//!
//! Engine shape: x_i and the surrogates are per-client [`ClientState`]
//! scratch; the struct holds only shared read-only state.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use super::{init_states, Algorithm, ClientState, Scratch, Space, TimePolicy};
use crate::net::{Network, Payload};
use crate::sim::Env;
use crate::tensor::ParamVec;
use crate::topology::Topology;

pub struct Choco {
    space: Space,
    weights: Vec<Vec<(usize, f32)>>,
    local_steps: usize,
    lr: f32,
    gamma: f32,
    topk_ratio: f32,
}

impl Choco {
    pub fn build(env: &Env, topo: &Topology) -> (Box<dyn Algorithm>, Vec<ClientState>) {
        let space = Space::for_method(env);
        // every client starts from the same θ⁰, and so do all surrogates
        let theta0 = space.init_client(env);
        let states = init_states(env, &space, |i| Scratch::Choco {
            hat_self: theta0.clone(),
            hat_nbr: topo
                .neighbors(i)
                .iter()
                .map(|&j| (j, theta0.clone()))
                .collect::<BTreeMap<usize, ParamVec>>(),
        });
        let algo = Choco {
            space,
            weights: topo.mixing_weights(),
            local_steps: env.cfg.local_steps,
            lr: env.cfg.lr,
            gamma: env.cfg.consensus_lr,
            topk_ratio: env.cfg.topk_ratio,
        };
        (Box::new(algo), states)
    }

    /// Global top-K of |x − x̂| over the whole parameter vector,
    /// returned per-tensor as (index, value) lists.
    fn compress(&self, x: &ParamVec, hat: &ParamVec) -> Vec<Vec<(u32, f32)>> {
        let d: usize = x.num_elements();
        let k = ((self.topk_ratio as f64 * d as f64).ceil() as usize).max(1);
        // collect (|delta|, tensor, idx) and select top k globally
        let mut entries: Vec<(f32, u32, u32)> = Vec::with_capacity(d);
        for (ti, (xt, ht)) in x.tensors.iter().zip(hat.tensors.iter()).enumerate() {
            for (ei, (&a, &b)) in xt.data.iter().zip(ht.data.iter()).enumerate() {
                let delta = a - b;
                if delta != 0.0 {
                    entries.push((delta.abs(), ti as u32, ei as u32));
                }
            }
        }
        let k = k.min(entries.len());
        let mut out = vec![vec![]; x.tensors.len()];
        if k == 0 {
            return out;
        }
        entries.select_nth_unstable_by(k - 1, |a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, ti, ei) in entries[..k].iter() {
            let delta = x.tensors[ti as usize].data[ei as usize]
                - hat.tensors[ti as usize].data[ei as usize];
            out[ti as usize].push((ei, delta));
        }
        out
    }
}

/// Apply a sparse delta to a surrogate.
fn apply_sparse(target: &mut ParamVec, q: &[Vec<(u32, f32)>]) {
    for (t, qs) in target.tensors.iter_mut().zip(q.iter()) {
        for &(idx, val) in qs {
            t.data[idx as usize] += val;
        }
    }
}

impl Algorithm for Choco {
    fn local_step(
        &self,
        state: &mut ClientState,
        _client: usize,
        _step: usize,
        env: &Env,
    ) -> Result<f32> {
        let (b, _) = env.batch_shape();
        let (ids, labels) = state.sampler.next_batch(b);
        let (loss, grads) = self.space.grad(env, &state.params, &ids, &labels)?;
        state.params.axpy(-self.lr, &grads);
        Ok(loss)
    }

    fn communicate(
        &mut self,
        states: &mut [ClientState],
        step: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        if (step + 1) % self.local_steps != 0 {
            return Ok(());
        }
        let n = states.len();
        net.tick(); // one communication round on the netcond delivery clock
        // 1+2: compress, broadcast, update own surrogate. An offline
        // (churned-out) client skips the whole round — including the
        // O(d log d) top-K, whose result nobody could receive: its
        // surrogate must only advance when neighbors could have seen the
        // same delta — under loss the copies desync anyway, which is
        // exactly the degradation the robustness experiments measure.
        let qs: Vec<Option<Arc<Vec<Vec<(u32, f32)>>>>> = states
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if !net.is_online(i) {
                    return None;
                }
                let (params, hat_self, _) = s.choco_view();
                Some(Arc::new(self.compress(params, hat_self)))
            })
            .collect();
        for (i, q) in qs.iter().enumerate() {
            let Some(q) = q else { continue };
            net.broadcast(i, &Payload::Sparse(q.clone()));
            let (_, hat_self, _) = states[i].choco_parts();
            apply_sparse(hat_self, q);
        }
        // receive: update tracked neighbor surrogates
        for (i, state) in states.iter_mut().enumerate() {
            for m in net.recv_all(i) {
                let Payload::Sparse(q) = m.payload else {
                    panic!("choco received non-sparse payload");
                };
                let (_, _, hat_nbr) = state.choco_parts();
                if let Some(hat) = hat_nbr.get_mut(&m.from) {
                    apply_sparse(hat, &q);
                }
            }
        }
        // 3: consensus step x_i += γ Σ_j w_ij (x̂_j − x̂_i)
        for (i, state) in states.iter_mut().enumerate() {
            let wrow = &self.weights[i];
            let (params, hat_self, hat_nbr) = state.choco_parts();
            let mut delta = params.zeros_like();
            // BTreeMap iteration: ascending neighbor id, same on every run
            for (&j, hat_j) in hat_nbr.iter() {
                let w = wrow.iter().find(|&&(k, _)| k == j).map(|&(_, w)| w).unwrap_or(0.0);
                delta.axpy(w, hat_j);
                delta.axpy(-w, hat_self);
            }
            params.axpy(self.gamma, &delta);
        }
        Ok(())
    }

    /// Virtual-time hook API (ISSUE 4): the surrogate-tracking consensus
    /// step needs every neighbor's delta from the *same* round, so Choco
    /// runs through the lockstep adapter in event mode (identical results
    /// for any `--rates`; stragglers show up as makespan/idle metrics).
    fn time_policy(&self) -> TimePolicy {
        TimePolicy::Barrier
    }

    fn eval_gmp(
        &self,
        states: &[ClientState],
        env: &Env,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        super::eval_gmp_avg(&self.space, states, env, batches)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn apply_sparse_updates_selected_entries() {
        let mut p = ParamVec::new(
            vec!["w".into()],
            vec![Tensor::from_vec(&[4], vec![0.0; 4])],
        );
        apply_sparse(&mut p, &[vec![(1, 2.0), (3, -1.0)]]);
        assert_eq!(p.tensors[0].data, vec![0.0, 2.0, 0.0, -1.0]);
    }
}
