//! Single-client zeroth-order baselines for Table 3: MeZO (dense
//! perturbations, Malladi et al. 2023) vs SubCGE (shared-subspace
//! canonical-coordinate perturbations) — the sanity check that restricting
//! the perturbation pool does not hurt final quality.

use anyhow::Result;

use super::{probe_seed, Algorithm};
use crate::data::BatchSampler;
use crate::net::{MsgId, Network, SeedUpdate};
use crate::sim::Env;
use crate::subcge::{CoeffAccum, SubspaceBasis};
use crate::tensor::ParamVec;
use crate::util::timer::PhaseClock;
use crate::zo;

pub struct SingleZo {
    params: ParamVec,
    basis: Option<SubspaceBasis>,
    accum: Option<CoeffAccum>,
    sampler: BatchSampler,
    lr: f32,
    eps: f32,
    seed: u64,
    clock: PhaseClock,
}

impl SingleZo {
    pub fn new(env: &Env, subcge: bool) -> SingleZo {
        assert_eq!(env.n_clients(), 1, "single-client methods need --clients 1");
        let basis = subcge.then(|| {
            SubspaceBasis::new(&env.manifest, env.cfg.rank, env.cfg.refresh,
                               env.cfg.seed ^ 0x5EED_F100D)
        });
        let accum = basis.as_ref().map(CoeffAccum::new);
        SingleZo {
            params: env.init_params.clone(),
            basis,
            accum,
            sampler: env.make_samplers().remove(0),
            lr: env.cfg.lr,
            eps: env.cfg.eps,
            seed: env.cfg.seed,
            clock: PhaseClock::new(),
        }
    }
}

impl Algorithm for SingleZo {
    fn local_step(&mut self, _client: usize, step: usize, env: &Env) -> Result<f32> {
        if let Some(b) = &mut self.basis {
            if step > 0 {
                b.maybe_refresh(step);
            }
        }
        let (bsz, _) = env.batch_shape();
        let (ids, labels) = self.sampler.next_batch(bsz);
        let seed = probe_seed(self.seed, 0, step);
        let mut probe_err = None;
        let mut first_loss = None;
        let basis = &self.basis;
        let t0 = std::time::Instant::now();
        let alpha = zo::spsa_alpha(
            &mut self.params,
            self.eps,
            |p| match env.loss_acc(p, &ids, &labels) {
                Ok((l, _)) => {
                    first_loss.get_or_insert(l);
                    l
                }
                Err(e) => {
                    probe_err = Some(e);
                    0.0
                }
            },
            |p, s| match basis {
                Some(b) => zo::perturb_subcge(p, b, seed, s),
                None => zo::perturb_dense(p, seed, s),
            },
        );
        self.clock.add("GE", t0.elapsed());
        if let Some(e) = probe_err {
            return Err(e);
        }
        let t1 = std::time::Instant::now();
        match (&self.basis, &mut self.accum) {
            (Some(basis), Some(accum)) => {
                accum.accumulate(
                    basis,
                    &SeedUpdate {
                        id: MsgId { origin: 0, step: step as u32 },
                        seed,
                        coeff: self.lr * alpha,
                    },
                );
                accum.flush_with_artifact(basis, &mut self.params, &env.exe_subcge, &env.rt)?;
            }
            _ => zo::apply_dense_update(&mut self.params, seed, self.lr * alpha),
        }
        self.clock.add("MA", t1.elapsed());
        Ok(first_loss.unwrap_or(0.0))
    }

    fn communicate(&mut self, _step: usize, _env: &Env, _net: &mut Network) -> Result<()> {
        Ok(())
    }

    fn eval_gmp(&self, env: &Env, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)> {
        env.eval_full(&self.params, batches)
    }

    fn snapshot(&self) -> Vec<ParamVec> {
        vec![self.params.clone()]
    }

    fn restore(&mut self, snap: Vec<ParamVec>) {
        self.params = snap.into_iter().next().unwrap();
    }

    fn consensus_error(&self) -> f64 {
        0.0
    }

    fn phase_ms(&self) -> Vec<(String, f64)> {
        vec![
            ("GE".into(), self.clock.total_ms("GE")),
            ("MA".into(), self.clock.total_ms("MA")),
        ]
    }
}
