//! Single-client zeroth-order baselines for Table 3: MeZO (dense
//! perturbations, Malladi et al. 2023) vs SubCGE (shared-subspace
//! canonical-coordinate perturbations) — the sanity check that restricting
//! the perturbation pool does not hurt final quality.
//!
//! Engine shape: n = 1, so the "fan-out" is a single local step; the basis
//! still refreshes in `begin_step` and the params/accumulator live in the
//! one [`ClientState`].

use std::time::Instant;

use anyhow::Result;

use super::{init_states, probe_seed, Algorithm, ClientState, Scratch, Space, TimePolicy};
use crate::net::{MsgId, Network, SeedUpdate};
use crate::sim::Env;
use crate::subcge::{CoeffAccum, SubspaceBasis};
use crate::util::timer::SharedClock;
use crate::zo;

pub struct SingleZo {
    basis: Option<SubspaceBasis>,
    lr: f32,
    eps: f32,
    seed: u64,
    clock: SharedClock,
}

impl SingleZo {
    pub fn build(env: &Env, subcge: bool) -> (Box<dyn Algorithm>, Vec<ClientState>) {
        assert_eq!(env.n_clients(), 1, "single-client methods need --clients 1");
        let basis = subcge.then(|| {
            SubspaceBasis::new(env.manifest(), env.cfg.rank, env.cfg.refresh,
                               env.cfg.seed ^ 0x5EED_F100D)
        });
        let space = Space::Full;
        let states = init_states(env, &space, |_| match &basis {
            Some(b) => Scratch::Accum(CoeffAccum::new(b)),
            None => Scratch::None,
        });
        let algo = SingleZo {
            basis,
            lr: env.cfg.lr,
            eps: env.cfg.eps,
            seed: env.cfg.seed,
            clock: SharedClock::new(),
        };
        (Box::new(algo), states)
    }
}

impl Algorithm for SingleZo {
    /// No pre-refresh settle needed: `local_step` flushes its accumulator
    /// inline, so nothing basis-relative is ever pending between steps.
    fn begin_step(
        &mut self,
        _states: &mut [ClientState],
        step: usize,
        _env: &Env,
    ) -> Result<()> {
        if let Some(b) = &mut self.basis {
            if step > 0 {
                b.maybe_refresh(step);
            }
        }
        Ok(())
    }

    fn local_step(
        &self,
        state: &mut ClientState,
        _client: usize,
        step: usize,
        env: &Env,
    ) -> Result<f32> {
        let (bsz, _) = env.batch_shape();
        let (ids, labels) = state.sampler.next_batch(bsz);
        let seed = probe_seed(self.seed, 0, step);
        let mut probe_err = None;
        let mut first_loss = None;
        let basis = &self.basis;
        // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
        let t0 = Instant::now();
        let alpha = zo::spsa_alpha(
            &mut state.params,
            self.eps,
            |p| match env.loss_acc(p, &ids, &labels) {
                Ok((l, _)) => {
                    first_loss.get_or_insert(l);
                    l
                }
                Err(e) => {
                    probe_err = Some(e);
                    0.0
                }
            },
            |p, s| match basis {
                Some(b) => zo::perturb_subcge(p, b, seed, s),
                None => zo::perturb_dense(p, seed, s),
            },
        );
        self.clock.add("GE", t0.elapsed());
        if let Some(e) = probe_err {
            return Err(e);
        }
        // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
        let t1 = Instant::now();
        match &self.basis {
            Some(basis) => {
                let (params, accum) = state.accum_parts();
                accum.accumulate(
                    basis,
                    &SeedUpdate {
                        id: MsgId { origin: 0, step: step as u32 },
                        seed,
                        coeff: self.lr * alpha,
                    },
                );
                env.subcge_flush(basis, accum, params, None)?;
            }
            None => zo::apply_dense_update(&mut state.params, seed, self.lr * alpha),
        }
        self.clock.add("MA", t1.elapsed());
        Ok(first_loss.unwrap_or(0.0))
    }

    fn communicate(
        &mut self,
        _states: &mut [ClientState],
        _step: usize,
        _env: &Env,
        _net: &mut Network,
    ) -> Result<()> {
        Ok(())
    }

    /// Virtual-time hook API (ISSUE 4): a single client never waits for
    /// anyone — event mode is just the lockstep sequence with timestamps.
    /// All `on_*` hooks keep their no-op defaults (updates are applied
    /// inside `local_step`; there is nothing to flood or flush).
    fn time_policy(&self) -> TimePolicy {
        TimePolicy::Async
    }

    fn eval_gmp(
        &self,
        states: &[ClientState],
        env: &Env,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        env.eval_full(&states[0].params, batches)
    }

    fn consensus_error(&self, _states: &[ClientState]) -> f64 {
        0.0
    }

    fn phase_ms(&self) -> Vec<(String, f64)> {
        vec![
            ("GE".into(), self.clock.total_ms("GE")),
            ("MA".into(), self.clock.total_ms("MA")),
        ]
    }
}
