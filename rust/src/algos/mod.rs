//! Decentralized training algorithms — the paper's full comparison grid
//! behind one trait: DSGD, ChocoSGD, DZSGD, their LoRA variants, SeedFlood,
//! and the single-client MeZO/SubCGE baselines (Table 3).
//!
//! The simulator drives the paper's protocol: `local_step` once per client
//! per iteration, then `communicate` once per iteration — each algorithm
//! decides internally whether to act (gossip methods exchange every
//! `local_steps` iterations; SeedFlood floods every iteration, per Alg. 1).

pub mod choco;
pub mod dsgd;
pub mod dzsgd;
pub mod seedflood;
pub mod single;

use anyhow::Result;

use crate::config::Method;
use crate::model::ParamStore;
use crate::net::Network;
use crate::sim::Env;
use crate::tensor::ParamVec;
use crate::topology::Topology;

/// One decentralized training method.
pub trait Algorithm {
    /// One local optimization step for `client` at iteration `step`;
    /// returns the training loss observed.
    fn local_step(&mut self, client: usize, step: usize, env: &Env) -> Result<f32>;

    /// One communication opportunity after iteration `step` (the algorithm
    /// applies its own schedule).
    fn communicate(&mut self, step: usize, env: &Env, net: &mut Network) -> Result<()>;

    /// Global Model Performance: evaluate the *average* of client models
    /// (paper §4.1 metric) on the given batches → (loss, accuracy).
    fn eval_gmp(&self, env: &Env, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)>;

    /// Mean squared distance of client models from their average.
    fn consensus_error(&self) -> f64;

    /// Optional per-phase wall-clock breakdown (Table 4).
    fn phase_ms(&self) -> Vec<(String, f64)> {
        vec![]
    }

    /// Snapshot of the trainable state (per-client param vectors) for the
    /// paper's best-validation checkpoint selection (Table 5 note).
    fn snapshot(&self) -> Vec<ParamVec>;

    /// Restore a snapshot taken by [`Self::snapshot`].
    fn restore(&mut self, snap: Vec<ParamVec>);
}

/// Whether a method trains the full parameter vector or LoRA adapters over
/// a frozen shared base — unifies the *-LoRA variants.
pub enum Space {
    Full,
    Lora { base: ParamVec },
}

impl Space {
    pub fn for_method(env: &Env) -> Space {
        if env.cfg.method.is_lora() {
            Space::Lora { base: env.init_params.clone() }
        } else {
            Space::Full
        }
    }

    /// θ⁰ for one client — identical across clients (shared pretrained
    /// checkpoint or seeded init; see Env::init_params).
    pub fn init_client(&self, env: &Env) -> ParamVec {
        match self {
            Space::Full => env.init_params.clone(),
            Space::Lora { .. } => ParamStore::init_lora(&env.manifest, env.cfg.seed),
        }
    }

    pub fn loss(&self, env: &Env, p: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, f32)> {
        match self {
            Space::Full => env.loss_acc(p, ids, labels),
            Space::Lora { base } => env.loss_acc_lora(base, p, ids, labels),
        }
    }

    pub fn grad(&self, env: &Env, p: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, ParamVec)> {
        match self {
            Space::Full => env.grad(p, ids, labels),
            Space::Lora { base } => env.grad_lora(base, p, ids, labels),
        }
    }

    pub fn eval(
        &self,
        env: &Env,
        p: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        match self {
            Space::Full => env.eval_full(p, batches),
            Space::Lora { base } => env.eval_lora(base, p, batches),
        }
    }
}

/// Probe seed for client i at step t — unique, deterministic, and shared
/// knowledge once communicated (the `s_{i,t}` of §3.1).
pub fn probe_seed(global: u64, client: usize, step: usize) -> u64 {
    // splitmix-style avalanche over (global, client, step)
    let mut z = global
        ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (step as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Synchronous gossip-averaging round over dense payloads (Eq. 2's mixing
/// step, Metropolis–Hastings weights). Shared by DSGD and DZSGD (+LoRA).
pub fn gossip_mix(
    clients: &mut [ParamVec],
    weights: &[Vec<(usize, f32)>],
    net: &mut Network,
) {
    use std::sync::Arc;

    use crate::net::Payload;

    let n = clients.len();
    let snaps: Vec<Arc<ParamVec>> = clients.iter().map(|c| Arc::new(c.clone())).collect();
    for (i, snap) in snaps.iter().enumerate() {
        net.broadcast(i, &Payload::Dense(snap.clone()));
    }
    for i in 0..n {
        let msgs = net.recv_all(i);
        let wrow = &weights[i];
        let w_of = |j: usize| wrow.iter().find(|&&(k, _)| k == j).map(|&(_, w)| w);
        let mut mixed = clients[i].zeros_like();
        let mut used = 0.0f32;
        for m in msgs {
            if let (Some(w), Payload::Dense(p)) = (w_of(m.from), m.payload) {
                mixed.axpy(w, &p);
                used += w;
            }
        }
        // own weight plus any weight from undelivered neighbors (failure
        // injection) falls back to self — keeps the row stochastic.
        mixed.axpy(1.0 - used, &snaps[i]);
        clients[i] = mixed;
    }
}

/// Construct the configured algorithm.
pub fn build(env: &Env, topo: &Topology) -> Result<Box<dyn Algorithm>> {
    Ok(match env.cfg.method {
        Method::Dsgd | Method::DsgdLora => Box::new(dsgd::Dsgd::new(env, topo)),
        Method::ChocoSgd | Method::ChocoLora => Box::new(choco::Choco::new(env, topo)),
        Method::Dzsgd | Method::DzsgdLora => Box::new(dzsgd::Dzsgd::new(env, topo)),
        Method::SeedFlood => Box::new(seedflood::SeedFlood::new(env, topo)),
        Method::Mezo => Box::new(single::SingleZo::new(env, false)),
        Method::SubCge => Box::new(single::SingleZo::new(env, true)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_seeds_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in 0..64 {
            for t in 0..200 {
                assert!(seen.insert(probe_seed(7, c, t)), "collision at ({c},{t})");
            }
        }
        // deterministic
        assert_eq!(probe_seed(7, 3, 5), probe_seed(7, 3, 5));
        assert_ne!(probe_seed(7, 3, 5), probe_seed(8, 3, 5));
    }
}
