//! Decentralized training algorithms — the paper's full comparison grid
//! behind one trait: DSGD, ChocoSGD, DZSGD, their LoRA variants, SeedFlood,
//! and the single-client MeZO/SubCGE baselines (Table 3).
//!
//! # The parallel client-execution engine (ISSUE 1 tentpole)
//!
//! An [`Algorithm`] is now *shared, read-only state* for the local phase
//! (mixing weights, the SubCGE basis, hyperparameters, the LoRA [`Space`]);
//! everything a single client mutates during a local step lives in an
//! explicit [`ClientState`] (params, mini-batch sampler, a private RNG
//! stream, and algorithm scratch — flooding dedup sets, coefficient
//! accumulators, Choco surrogates). The engine owns the `Vec<ClientState>`
//! and drives one iteration as:
//!
//! 1. [`Algorithm::begin_step`] — sequential hook for shared-state
//!    mutation (e.g. the τ-periodic basis refresh);
//! 2. [`local_step_all`] — fans `local_step` out over a scoped-thread pool
//!    ([`crate::util::par`]), one client per invocation, merging losses in
//!    client order so a parallel run reproduces a sequential run exactly;
//! 3. [`Algorithm::communicate`] — sequential, deterministic network
//!    rounds (each algorithm applies its own schedule).
//!
//! # The virtual-time hook API (ISSUE 4 tentpole)
//!
//! Under `--time-model event` the loop above is replaced by a
//! discrete-event driver ([`crate::sim::EventDriven`]): clients complete
//! local steps at virtual times set by a seeded speed model, and
//! communication is driven off the delivery clock. An algorithm declares
//! its [`TimePolicy`]:
//!
//! * [`TimePolicy::Barrier`] (trait default) — the *lockstep adapter*:
//!   the driver still synchronizes every step (calling the synchronous
//!   [`Algorithm::communicate`] at each barrier), and heterogeneous
//!   speeds only show up as honest timing metrics (virtual makespan, idle
//!   fraction). DSGD/Choco/DZSGD gossip over dense snapshots of *all*
//!   clients, so they cannot run barrier-free — this is the measured cost
//!   of requiring one.
//! * [`TimePolicy::Async`] — the per-client hooks run instead:
//!   [`Algorithm::on_step_begin`] (catch up on deliveries before
//!   probing), [`Algorithm::on_step_complete`] (flood the fresh update
//!   immediately — no barrier), [`Algorithm::on_send`]/
//!   [`Algorithm::on_deliver`] (one communication round on the delivery
//!   clock), [`Algorithm::on_iteration_start`] (nominal schedule clock
//!   advanced — netcond repair triggers), and [`Algorithm::on_barrier`]
//!   (all clients completed a step index: settle state for evaluation).
//!
//! With uniform rates the event interleaving degenerates to the lockstep
//! order, and the async hooks reproduce the lockstep trajectory
//! bit-for-bit (property-tested in rust/tests/properties.rs).

pub mod choco;
pub mod dsgd;
pub mod dzsgd;
pub mod seedflood;
pub mod single;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::Method;
use crate::data::BatchSampler;
use crate::flood::FloodState;
use crate::model::ParamStore;
use crate::net::Network;
use crate::rng::Rng;
use crate::sim::Env;
use crate::subcge::CoeffAccum;
use crate::tensor::ParamVec;
use crate::topology::Topology;
use crate::util::par::par_map_mut;

/// Per-client mutable state, owned by the engine and handed to exactly one
/// worker thread at a time during the local phase.
pub struct ClientState {
    /// this client's trainable parameters (full θ_i or LoRA adapters)
    pub params: ParamVec,
    /// mini-batch iterator over the client's local shard
    pub sampler: BatchSampler,
    /// private RNG stream seeded from `cfg.seed` and the client id —
    /// reserved for client-local randomness (upcoming churn/async work);
    /// today's probe randomness flows through `probe_seed` and the sampler
    pub rng: Rng,
    /// algorithm-specific scratch
    pub scratch: Scratch,
}

/// Algorithm-specific per-client scratch.
pub enum Scratch {
    None,
    /// SeedFlood: coefficient accumulator + flooding protocol state
    Flood { accum: CoeffAccum, flood: FloodState },
    /// single-client SubCGE: coefficient accumulator only
    Accum(CoeffAccum),
    /// ChocoSGD: own public surrogate x̂_i + tracked neighbor surrogates
    /// (BTreeMap, not HashMap: the consensus step iterates this map and
    /// float sums must accumulate in the same order on every run for the
    /// engine's determinism contract)
    Choco { hat_self: ParamVec, hat_nbr: BTreeMap<usize, ParamVec> },
}

impl ClientState {
    /// Split-borrow params + SeedFlood scratch.
    pub fn flood_parts(&mut self) -> (&mut ParamVec, &mut CoeffAccum, &mut FloodState) {
        match &mut self.scratch {
            Scratch::Flood { accum, flood } => (&mut self.params, accum, flood),
            _ => panic!("client state has no flooding scratch"),
        }
    }

    /// Split-borrow params + a coefficient accumulator (SeedFlood or
    /// single-client SubCGE).
    pub fn accum_parts(&mut self) -> (&mut ParamVec, &mut CoeffAccum) {
        match &mut self.scratch {
            Scratch::Flood { accum, .. } => (&mut self.params, accum),
            Scratch::Accum(accum) => (&mut self.params, accum),
            _ => panic!("client state has no coefficient accumulator"),
        }
    }

    /// Split-borrow params + Choco surrogates.
    pub fn choco_parts(
        &mut self,
    ) -> (&mut ParamVec, &mut ParamVec, &mut BTreeMap<usize, ParamVec>) {
        match &mut self.scratch {
            Scratch::Choco { hat_self, hat_nbr } => (&mut self.params, hat_self, hat_nbr),
            _ => panic!("client state has no choco scratch"),
        }
    }

    /// Immutable view of the Choco surrogates.
    pub fn choco_view(&self) -> (&ParamVec, &ParamVec, &BTreeMap<usize, ParamVec>) {
        match &self.scratch {
            Scratch::Choco { hat_self, hat_nbr } => (&self.params, hat_self, hat_nbr),
            _ => panic!("client state has no choco scratch"),
        }
    }
}

/// How an algorithm relates to the virtual-time engine (`--time-model
/// event`): can it act per-client on the delivery clock, or does it need
/// the step barrier the lockstep loop provided implicitly?
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TimePolicy {
    /// Synchronize every local step across clients (the lockstep
    /// adapter): results are identical to `--time-model lockstep` for any
    /// speed model; heterogeneous rates surface only as virtual-time
    /// metrics (makespan, idle fraction). The default — dense/sparse
    /// gossip mixes simultaneous snapshots of all clients and has no
    /// barrier-free formulation here.
    #[default]
    Barrier,
    /// Fully event-driven: local steps complete at per-client virtual
    /// times, communication runs off the delivery clock through the
    /// `on_*` hooks, and no client ever waits for another.
    Async,
}

/// One decentralized training method. Implementations must be
/// `Send + Sync`: during the local phase the same `&self` is shared by all
/// worker threads (interior mutability only for thread-safe telemetry like
/// [`crate::util::timer::SharedClock`]).
pub trait Algorithm: Send + Sync {
    /// Sequential pre-iteration hook — the only place shared state may be
    /// mutated (e.g. SeedFlood's τ-periodic subspace refresh). Receives
    /// the client states because shared-state changes can require settling
    /// per-client pending state first: coefficient accumulators are
    /// basis-relative, and under the event engine stragglers may still
    /// hold coefficients when the fastest client crosses a refresh
    /// boundary (a no-op in lockstep, where every iteration flushes).
    fn begin_step(
        &mut self,
        _states: &mut [ClientState],
        _step: usize,
        _env: &Env,
    ) -> Result<()> {
        Ok(())
    }

    /// One local optimization step for one client at iteration `step`;
    /// returns the training loss observed. Runs concurrently across
    /// clients — it must only touch `state` and read-only shared state.
    fn local_step(
        &self,
        state: &mut ClientState,
        client: usize,
        step: usize,
        env: &Env,
    ) -> Result<f32>;

    /// One communication opportunity after iteration `step` (the algorithm
    /// applies its own schedule). Sequential and deterministic.
    fn communicate(
        &mut self,
        states: &mut [ClientState],
        step: usize,
        env: &Env,
        net: &mut Network,
    ) -> Result<()>;

    // --- virtual-time hooks (ISSUE 4; only called by the event driver) ---

    /// Whether this method runs barrier-free in event mode (see
    /// [`TimePolicy`]). Default: the lockstep adapter.
    fn time_policy(&self) -> TimePolicy {
        TimePolicy::Barrier
    }

    /// Async mode: the nominal schedule clock advanced to iteration
    /// `step` ([`Network::set_step`] was just called) — arm netcond
    /// repair triggers etc. Sequential.
    fn on_iteration_start(
        &mut self,
        _states: &mut [ClientState],
        _step: usize,
        _env: &Env,
        _net: &mut Network,
    ) -> Result<()> {
        Ok(())
    }

    /// Async mode: `client` is about to run local step `step` — catch up
    /// on everything delivered since its last step (e.g. flush a pending
    /// coefficient accumulator so the probe sees current params). Must be
    /// a no-op when nothing was delivered in between. `&self` (like
    /// [`Self::local_step`]): the event driver fans a same-instant cohort
    /// of clients out over worker threads, each running its
    /// `on_step_begin` + `local_step` concurrently — shared mutation only
    /// through thread-safe interior mutability.
    fn on_step_begin(
        &self,
        _state: &mut ClientState,
        _client: usize,
        _step: usize,
        _env: &Env,
    ) -> Result<()> {
        Ok(())
    }

    /// Async mode: `client` just finished local step `step` — transmit
    /// immediately instead of waiting for a barrier (SeedFlood floods the
    /// freshly injected seed here). Only called for online clients.
    fn on_step_complete(
        &mut self,
        _state: &mut ClientState,
        _client: usize,
        _step: usize,
        _env: &Env,
        _net: &mut Network,
    ) -> Result<()> {
        Ok(())
    }

    /// Async mode, send half of one delivery-clock round: forward
    /// anything queued (outbox, armed repair traffic). Only called for
    /// online clients. The driver advances the delivery clock with
    /// virtual time *before* processing any event at an instant, so
    /// sends here and in [`Self::on_step_complete`] stamp the same round
    /// — netcond `delay=K` costs K rounds on every hop, as in lockstep.
    fn on_send(
        &mut self,
        _state: &mut ClientState,
        _client: usize,
        _env: &Env,
        _net: &mut Network,
    ) -> Result<()> {
        Ok(())
    }

    /// Async mode, receive half of one delivery-clock round: drain due
    /// messages for `client` and apply them (`step` is the nominal
    /// iteration, for staleness accounting). Only called for online
    /// clients, after every client's send half.
    fn on_deliver(
        &mut self,
        _state: &mut ClientState,
        _client: usize,
        _step: usize,
        _env: &Env,
        _net: &mut Network,
    ) -> Result<()> {
        Ok(())
    }

    /// Event mode: every client has completed local step `step` — settle
    /// state so evaluation sees comparable models. The default is the
    /// lockstep adapter: run the synchronous [`Self::communicate`]
    /// (barrier methods gossip here); async methods override to flush
    /// per-client accumulators instead.
    fn on_barrier(
        &mut self,
        states: &mut [ClientState],
        step: usize,
        env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        self.communicate(states, step, env, net)
    }

    /// Global Model Performance: evaluate the *average* of client models
    /// (paper §4.1 metric) on the given batches → (loss, accuracy).
    fn eval_gmp(
        &self,
        states: &[ClientState],
        env: &Env,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)>;

    /// Mean squared distance of client models from their average.
    fn consensus_error(&self, states: &[ClientState]) -> f64 {
        let refs: Vec<&ParamVec> = states.iter().map(|s| &s.params).collect();
        crate::sim::consensus_error_refs(&refs)
    }

    /// Optional per-phase wall-clock breakdown (Table 4).
    fn phase_ms(&self) -> Vec<(String, f64)> {
        vec![]
    }

    /// Snapshot of the trainable state (per-client param vectors) for the
    /// paper's best-validation checkpoint selection (Table 5 note).
    fn snapshot(&self, states: &[ClientState]) -> Vec<ParamVec> {
        states.iter().map(|s| s.params.clone()).collect()
    }

    /// Restore a snapshot taken by [`Self::snapshot`].
    fn restore(&self, states: &mut [ClientState], snap: Vec<ParamVec>) {
        assert_eq!(snap.len(), states.len());
        for (s, p) in states.iter_mut().zip(snap) {
            s.params = p;
        }
    }
}

/// Fan one iteration's local steps out across up to `threads` workers
/// (0 = all cores). Losses come back in client order and the first error
/// (lowest client id) wins, so the outcome is identical for every thread
/// count — the engine's determinism contract (tests/engine.rs).
pub fn local_step_all(
    algo: &dyn Algorithm,
    states: &mut [ClientState],
    step: usize,
    env: &Env,
    threads: usize,
) -> Result<Vec<f32>> {
    par_map_mut(states, threads, |i, st| algo.local_step(st, i, step, env))
        .into_iter()
        .collect()
}

/// Build the common per-client states: θ⁰ from the method's [`Space`], the
/// client's shard sampler, a private RNG stream, plus per-algo scratch.
pub fn init_states(
    env: &Env,
    space: &Space,
    mut scratch: impl FnMut(usize) -> Scratch,
) -> Vec<ClientState> {
    env.make_samplers()
        .into_iter()
        .enumerate()
        .map(|(i, sampler)| ClientState {
            params: space.init_client(env),
            sampler,
            rng: Rng::fold_in(env.cfg.seed ^ 0xC11E_57A7E, i as u64),
            scratch: scratch(i),
        })
        .collect()
}

/// GMP (paper §4.1): evaluate the average of the client models in the
/// method's trainable space — the shared `eval_gmp` body of every
/// multi-client algorithm.
pub fn eval_gmp_avg(
    space: &Space,
    states: &[ClientState],
    env: &Env,
    batches: &[(Vec<i32>, Vec<i32>)],
) -> Result<(f64, f64)> {
    let refs: Vec<&ParamVec> = states.iter().map(|s| &s.params).collect();
    let avg = ParamVec::average(&refs);
    space.eval(env, &avg, batches)
}

/// Temporarily assemble the per-client params into one contiguous slice for
/// cross-client mixing ops (gossip), putting them back afterwards.
pub fn with_client_params<R>(
    states: &mut [ClientState],
    f: impl FnOnce(&mut [ParamVec]) -> R,
) -> R {
    let mut ps: Vec<ParamVec> = states
        .iter_mut()
        .map(|s| std::mem::replace(&mut s.params, ParamVec::new(vec![], vec![])))
        .collect();
    let out = f(&mut ps);
    for (s, p) in states.iter_mut().zip(ps) {
        s.params = p;
    }
    out
}

/// Whether a method trains the full parameter vector or LoRA adapters over
/// a frozen shared base — unifies the *-LoRA variants.
pub enum Space {
    Full,
    Lora { base: ParamVec },
}

impl Space {
    pub fn for_method(env: &Env) -> Space {
        if env.cfg.method.is_lora() {
            Space::Lora { base: env.init_params.clone() }
        } else {
            Space::Full
        }
    }

    /// θ⁰ for one client — identical across clients (shared pretrained
    /// checkpoint or seeded init; see Env::init_params).
    pub fn init_client(&self, env: &Env) -> ParamVec {
        match self {
            Space::Full => env.init_params.clone(),
            Space::Lora { .. } => ParamStore::init_lora(env.manifest(), env.cfg.seed),
        }
    }

    pub fn loss(
        &self,
        env: &Env,
        p: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, f32)> {
        match self {
            Space::Full => env.loss_acc(p, ids, labels),
            Space::Lora { base } => env.loss_acc_lora(base, p, ids, labels),
        }
    }

    pub fn grad(
        &self,
        env: &Env,
        p: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, ParamVec)> {
        match self {
            Space::Full => env.grad(p, ids, labels),
            Space::Lora { base } => env.grad_lora(base, p, ids, labels),
        }
    }

    pub fn eval(
        &self,
        env: &Env,
        p: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        match self {
            Space::Full => env.eval_full(p, batches),
            Space::Lora { base } => env.eval_lora(base, p, batches),
        }
    }
}

/// Probe seed for client i at step t — unique, deterministic, and shared
/// knowledge once communicated (the `s_{i,t}` of §3.1).
pub fn probe_seed(global: u64, client: usize, step: usize) -> u64 {
    // splitmix-style avalanche over (global, client, step)
    let mut z = global
        ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (step as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Synchronous gossip-averaging round over dense payloads (Eq. 2's mixing
/// step, Metropolis–Hastings weights). Shared by DSGD and DZSGD (+LoRA).
///
/// Fault injection (netcond) flows through the network layer: an offline
/// client's broadcast costs nothing and it receives nothing (mixing with
/// itself via the stochastic-row fallback below), lost messages simply
/// drop out of the weighted sum, and delayed models arrive — and get
/// mixed — in a later gossip round. Each call is one communication round
/// on the delivery clock.
pub fn gossip_mix(
    clients: &mut [ParamVec],
    weights: &[Vec<(usize, f32)>],
    net: &mut Network,
) {
    use std::sync::Arc;

    use crate::net::Payload;

    let n = clients.len();
    net.tick();
    let snaps: Vec<Arc<ParamVec>> = clients.iter().map(|c| Arc::new(c.clone())).collect();
    for (i, snap) in snaps.iter().enumerate() {
        net.broadcast(i, &Payload::Dense(snap.clone()));
    }
    for i in 0..n {
        // newest model per source wins: a rejoining client can drain
        // several buffered (delayed) snapshots from one neighbor in a
        // single round — mixing them all would double-count that
        // neighbor's weight and push the self-coefficient negative.
        // Per-edge FIFO + ascending-source drain order means the last
        // entry per source is the newest, and BTreeMap iteration keeps
        // the ascending-source float-sum order of the reliable path.
        let mut latest: BTreeMap<usize, Arc<ParamVec>> = BTreeMap::new();
        for m in net.recv_all(i) {
            if let Payload::Dense(p) = m.payload {
                latest.insert(m.from, p);
            }
        }
        let wrow = &weights[i];
        let w_of = |j: usize| wrow.iter().find(|&&(k, _)| k == j).map(|&(_, w)| w);
        let mut mixed = clients[i].zeros_like();
        let mut used = 0.0f32;
        for (src, p) in latest {
            if let Some(w) = w_of(src) {
                mixed.axpy(w, &p);
                used += w;
            }
        }
        // own weight plus any weight from undelivered neighbors (failure
        // injection) falls back to self — keeps the row stochastic.
        mixed.axpy(1.0 - used, &snaps[i]);
        clients[i] = mixed;
    }
}

/// Construct the configured algorithm plus its per-client states.
pub fn build(env: &Env, topo: &Topology) -> Result<(Box<dyn Algorithm>, Vec<ClientState>)> {
    Ok(match env.cfg.method {
        Method::Dsgd | Method::DsgdLora => dsgd::Dsgd::build(env, topo),
        Method::ChocoSgd | Method::ChocoLora => choco::Choco::build(env, topo),
        Method::Dzsgd | Method::DzsgdLora => dzsgd::Dzsgd::build(env, topo),
        Method::SeedFlood => seedflood::SeedFlood::build(env, topo)?,
        Method::Mezo => single::SingleZo::build(env, false),
        Method::SubCge => single::SingleZo::build(env, true),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn probe_seeds_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in 0..64 {
            for t in 0..200 {
                assert!(seen.insert(probe_seed(7, c, t)), "collision at ({c},{t})");
            }
        }
        // deterministic
        assert_eq!(probe_seed(7, 3, 5), probe_seed(7, 3, 5));
        assert_ne!(probe_seed(7, 3, 5), probe_seed(8, 3, 5));
    }

    #[test]
    fn with_client_params_roundtrips() {
        let mk = |v: f32| ClientState {
            params: ParamVec::new(vec!["w".into()], vec![Tensor::from_vec(&[2], vec![v, v])]),
            sampler: BatchSampler::new(
                vec![crate::data::Example { tokens: vec![0, 1], label: 0 }],
                0,
            ),
            rng: Rng::new(0),
            scratch: Scratch::None,
        };
        let mut states = vec![mk(1.0), mk(2.0)];
        let sum = with_client_params(&mut states, |ps| {
            assert_eq!(ps.len(), 2);
            ps[0].scale(10.0);
            ps.iter().map(|p| p.tensors[0].data[0]).sum::<f32>()
        });
        assert_eq!(sum, 12.0);
        // mutation inside the closure is visible after the roundtrip
        assert_eq!(states[0].params.tensors[0].data, vec![10.0, 10.0]);
        assert_eq!(states[1].params.tensors[0].data, vec![2.0, 2.0]);
    }
}
