//! DZSGD (Tang et al., 2020) and DZSGD-LoRA — the zeroth-order gossip
//! baselines: the local first-order step of DSGD is replaced by a dense
//! SPSA estimate (MeZO-style in-place probing), while consensus still uses
//! full-model gossip averaging — which is why its communication cost stays
//! O(d) per round (the paper's 5.26 TB row in Table 8).

use anyhow::Result;

use super::{
    gossip_mix, init_states, probe_seed, with_client_params, Algorithm, ClientState, Scratch,
    Space, TimePolicy,
};
use crate::net::Network;
use crate::sim::Env;
use crate::topology::Topology;
use crate::zo;

pub struct Dzsgd {
    space: Space,
    weights: Vec<Vec<(usize, f32)>>,
    local_steps: usize,
    lr: f32,
    eps: f32,
    seed: u64,
}

impl Dzsgd {
    pub fn build(env: &Env, topo: &Topology) -> (Box<dyn Algorithm>, Vec<ClientState>) {
        let space = Space::for_method(env);
        let states = init_states(env, &space, |_| Scratch::None);
        let algo = Dzsgd {
            space,
            weights: topo.mixing_weights(),
            local_steps: env.cfg.local_steps,
            lr: env.cfg.lr,
            eps: env.cfg.eps,
            seed: env.cfg.seed,
        };
        (Box::new(algo), states)
    }
}

impl Algorithm for Dzsgd {
    fn local_step(
        &self,
        state: &mut ClientState,
        client: usize,
        step: usize,
        env: &Env,
    ) -> Result<f32> {
        let (b, _) = env.batch_shape();
        let (ids, labels) = state.sampler.next_batch(b);
        let seed = probe_seed(self.seed, client, step);
        let space = &self.space;
        let mut probe_err = None;
        let mut first_loss = None;
        let alpha = zo::spsa_alpha(
            &mut state.params,
            self.eps,
            |p| match space.loss(env, p, &ids, &labels) {
                Ok((l, _)) => {
                    first_loss.get_or_insert(l);
                    l
                }
                Err(e) => {
                    probe_err = Some(e);
                    0.0
                }
            },
            |p, s| zo::perturb_dense(p, seed, s),
        );
        if let Some(e) = probe_err {
            return Err(e);
        }
        // ZO-SGD descent along the reconstructed direction (Eq. 4)
        zo::apply_dense_update(&mut state.params, seed, self.lr * alpha);
        Ok(first_loss.unwrap_or(0.0))
    }

    fn communicate(
        &mut self,
        states: &mut [ClientState],
        step: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        if (step + 1) % self.local_steps == 0 {
            with_client_params(states, |ps| gossip_mix(ps, &self.weights, net));
        }
        Ok(())
    }

    /// Virtual-time hook API (ISSUE 4): the local step is zeroth-order
    /// but consensus is still dense gossip over simultaneous snapshots,
    /// so DZSGD barriers like DSGD — exactly the contrast with SeedFlood
    /// ([`TimePolicy::Async`]) the straggler experiments measure.
    fn time_policy(&self) -> TimePolicy {
        TimePolicy::Barrier
    }

    fn eval_gmp(
        &self,
        states: &[ClientState],
        env: &Env,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        super::eval_gmp_avg(&self.space, states, env, batches)
    }
}
