//! DZSGD (Tang et al., 2020) and DZSGD-LoRA — the zeroth-order gossip
//! baselines: the local first-order step of DSGD is replaced by a dense
//! SPSA estimate (MeZO-style in-place probing), while consensus still uses
//! full-model gossip averaging — which is why its communication cost stays
//! O(d) per round (the paper's 5.26 TB row in Table 8).

use anyhow::Result;

use super::{gossip_mix, probe_seed, Algorithm, Space};
use crate::data::BatchSampler;
use crate::net::Network;
use crate::sim::{consensus_error, Env};
use crate::tensor::ParamVec;
use crate::topology::Topology;
use crate::zo;

pub struct Dzsgd {
    space: Space,
    clients: Vec<ParamVec>,
    samplers: Vec<BatchSampler>,
    weights: Vec<Vec<(usize, f32)>>,
    local_steps: usize,
    lr: f32,
    eps: f32,
    seed: u64,
}

impl Dzsgd {
    pub fn new(env: &Env, topo: &Topology) -> Dzsgd {
        let space = Space::for_method(env);
        let clients = (0..env.n_clients()).map(|_| space.init_client(env)).collect();
        Dzsgd {
            space,
            clients,
            samplers: env.make_samplers(),
            weights: topo.mixing_weights(),
            local_steps: env.cfg.local_steps,
            lr: env.cfg.lr,
            eps: env.cfg.eps,
            seed: env.cfg.seed,
        }
    }
}

impl Algorithm for Dzsgd {
    fn local_step(&mut self, client: usize, step: usize, env: &Env) -> Result<f32> {
        let (b, _) = env.batch_shape();
        let (ids, labels) = self.samplers[client].next_batch(b);
        let seed = probe_seed(self.seed, client, step);
        let space = &self.space;
        let mut probe_err = None;
        let mut first_loss = None;
        let alpha = zo::spsa_alpha(
            &mut self.clients[client],
            self.eps,
            |p| match space.loss(env, p, &ids, &labels) {
                Ok((l, _)) => {
                    first_loss.get_or_insert(l);
                    l
                }
                Err(e) => {
                    probe_err = Some(e);
                    0.0
                }
            },
            |p, s| zo::perturb_dense(p, seed, s),
        );
        if let Some(e) = probe_err {
            return Err(e);
        }
        // ZO-SGD descent along the reconstructed direction (Eq. 4)
        zo::apply_dense_update(&mut self.clients[client], seed, self.lr * alpha);
        Ok(first_loss.unwrap_or(0.0))
    }

    fn communicate(&mut self, step: usize, _env: &Env, net: &mut Network) -> Result<()> {
        if (step + 1) % self.local_steps == 0 {
            gossip_mix(&mut self.clients, &self.weights, net);
        }
        Ok(())
    }

    fn eval_gmp(&self, env: &Env, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)> {
        let refs: Vec<&ParamVec> = self.clients.iter().collect();
        let avg = ParamVec::average(&refs);
        self.space.eval(env, &avg, batches)
    }

    fn snapshot(&self) -> Vec<ParamVec> {
        self.clients.clone()
    }

    fn restore(&mut self, snap: Vec<ParamVec>) {
        assert_eq!(snap.len(), self.clients.len());
        self.clients = snap;
    }

    fn consensus_error(&self) -> f64 {
        consensus_error(&self.clients)
    }
}
