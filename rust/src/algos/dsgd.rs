//! DSGD (Lian et al., 2017) and DSGD-LoRA — the first-order gossip
//! baselines (paper Eq. 2): local SGD steps followed by Metropolis–Hastings
//! weighted averaging of neighbor models every `local_steps` iterations.

use anyhow::Result;

use super::{gossip_mix, Algorithm, Space};
use crate::data::BatchSampler;
use crate::net::Network;
use crate::sim::{consensus_error, Env};
use crate::tensor::ParamVec;
use crate::topology::Topology;

pub struct Dsgd {
    space: Space,
    clients: Vec<ParamVec>,
    samplers: Vec<BatchSampler>,
    weights: Vec<Vec<(usize, f32)>>,
    local_steps: usize,
    lr: f32,
}

impl Dsgd {
    pub fn new(env: &Env, topo: &Topology) -> Dsgd {
        let space = Space::for_method(env);
        let clients = (0..env.n_clients()).map(|_| space.init_client(env)).collect();
        Dsgd {
            space,
            clients,
            samplers: env.make_samplers(),
            weights: topo.mixing_weights(),
            local_steps: env.cfg.local_steps,
            lr: env.cfg.lr,
        }
    }
}

impl Algorithm for Dsgd {
    fn local_step(&mut self, client: usize, _step: usize, env: &Env) -> Result<f32> {
        let (b, _) = env.batch_shape();
        let (ids, labels) = self.samplers[client].next_batch(b);
        let (loss, grads) = self.space.grad(env, &self.clients[client], &ids, &labels)?;
        self.clients[client].axpy(-self.lr, &grads);
        Ok(loss)
    }

    fn communicate(&mut self, step: usize, _env: &Env, net: &mut Network) -> Result<()> {
        if (step + 1) % self.local_steps == 0 {
            gossip_mix(&mut self.clients, &self.weights, net);
        }
        Ok(())
    }

    fn eval_gmp(&self, env: &Env, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)> {
        let refs: Vec<&ParamVec> = self.clients.iter().collect();
        let avg = ParamVec::average(&refs);
        self.space.eval(env, &avg, batches)
    }

    fn snapshot(&self) -> Vec<ParamVec> {
        self.clients.clone()
    }

    fn restore(&mut self, snap: Vec<ParamVec>) {
        assert_eq!(snap.len(), self.clients.len());
        self.clients = snap;
    }

    fn consensus_error(&self) -> f64 {
        consensus_error(&self.clients)
    }
}
