//! DSGD (Lian et al., 2017) and DSGD-LoRA — the first-order gossip
//! baselines (paper Eq. 2): local SGD steps followed by Metropolis–Hastings
//! weighted averaging of neighbor models every `local_steps` iterations.
//!
//! Engine shape: the struct is the shared read-only state (space, mixing
//! weights, hyperparameters); params + sampler live in [`ClientState`].

use anyhow::Result;

use super::{
    gossip_mix, init_states, with_client_params, Algorithm, ClientState, Scratch, Space,
    TimePolicy,
};
use crate::net::Network;
use crate::sim::Env;
use crate::topology::Topology;

pub struct Dsgd {
    space: Space,
    weights: Vec<Vec<(usize, f32)>>,
    local_steps: usize,
    lr: f32,
}

impl Dsgd {
    pub fn build(env: &Env, topo: &Topology) -> (Box<dyn Algorithm>, Vec<ClientState>) {
        let space = Space::for_method(env);
        let states = init_states(env, &space, |_| Scratch::None);
        let algo = Dsgd {
            space,
            weights: topo.mixing_weights(),
            local_steps: env.cfg.local_steps,
            lr: env.cfg.lr,
        };
        (Box::new(algo), states)
    }
}

impl Algorithm for Dsgd {
    fn local_step(
        &self,
        state: &mut ClientState,
        _client: usize,
        _step: usize,
        env: &Env,
    ) -> Result<f32> {
        let (b, _) = env.batch_shape();
        let (ids, labels) = state.sampler.next_batch(b);
        let (loss, grads) = self.space.grad(env, &state.params, &ids, &labels)?;
        state.params.axpy(-self.lr, &grads);
        Ok(loss)
    }

    fn communicate(
        &mut self,
        states: &mut [ClientState],
        step: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        if (step + 1) % self.local_steps == 0 {
            with_client_params(states, |ps| gossip_mix(ps, &self.weights, net));
        }
        Ok(())
    }

    /// Virtual-time hook API (ISSUE 4): dense gossip averages simultaneous
    /// snapshots of every neighbor, so DSGD runs through the lockstep
    /// adapter — under `--time-model event` every step still barriers
    /// (results identical to lockstep for any `--rates`), and the cost of
    /// requiring that barrier shows up as virtual makespan + idle
    /// fraction in the `RunRecord`.
    fn time_policy(&self) -> TimePolicy {
        TimePolicy::Barrier
    }

    fn eval_gmp(
        &self,
        states: &[ClientState],
        env: &Env,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        super::eval_gmp_avg(&self.space, states, env, batches)
    }
}
