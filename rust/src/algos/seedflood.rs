//! SeedFlood (paper Alg. 1) — the paper's contribution.
//!
//! Per iteration, each client:
//!  (A) refreshes the globally shared SubCGE basis every τ steps;
//!  (B) estimates a zeroth-order update in the shared subspace (SPSA with
//!      the canonical-coordinate perturbation), packaging it as a
//!      seed–scalar pair with coefficient `η·α/n`;
//!  (C) injects it into the flooding protocol, runs `k` flooding rounds
//!      (k = network diameter by default ⇒ all-gather-equivalent
//!      consensus; k < D is the delayed-flooding ablation of §4.5), folds
//!      every newly received message into the O(1)-per-message coefficient
//!      accumulator, and flushes the batched update `θ − U A Vᵀ` through
//!      the AOT pallas kernel.
//!
//! Engine shape: the basis and hyperparameters are shared read-only state
//! (the basis refresh happens in the sequential [`Algorithm::begin_step`]
//! hook); each client's accumulator and flooding state live in its
//! [`ClientState`], so step (B) runs concurrently across clients while
//! step (C) stays sequential and deterministic.
//!
//! Phase wall-clock is tracked as "GE" (gradient estimation) and "MA"
//! (message applying) to regenerate Table 4.
//!
//! Under a [`crate::netcond::NetCond`] fault model, step (C) additionally
//! honours the network's churn/repair signals: offline clients keep
//! computing locally but skip their flood rounds (outboxes persist), and
//! a recovery or anti-entropy trigger runs the configured repair protocol
//! (`--repair-mode`: gap-request summaries by default, legacy full
//! re-flood otherwise) so every update still reaches every live client
//! with bounded staleness.
//! Caveat: the staleness bound must stay well below the basis-refresh
//! period τ — a message applied after a refresh reconstructs its probe in
//! the *new* basis (documented approximation, same as delayed flooding
//! §4.5). Under the event engine this includes heterogeneous-rate lag:
//! `begin_step` settles every *accumulated* coefficient before a refresh,
//! but a message still in flight (or a straggler lagging the nominal
//! clock by ≳ τ) crosses the boundary and reconstructs in the new basis.
//! The run reports `staleness_p50/p90/p99` exactly so this is checkable:
//! keep τ ≫ `staleness_p99`. Epoch-stamped messages that make the caveat
//! structural are a ROADMAP item.

use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::{init_states, probe_seed, Algorithm, ClientState, Scratch, Space, TimePolicy};
use crate::flood::{self, FloodState, RepairMode, WireFormat};
use crate::net::{MsgId, Network, SeedUpdate};
use crate::sim::Env;
use crate::subcge::{CoeffAccum, DeviceBasisCache, SubspaceBasis};
use crate::topology::Topology;
use crate::util::timer::SharedClock;
use crate::zo;

pub struct SeedFlood {
    /// globally shared subspace factors — mutated only in `begin_step`
    basis: SubspaceBasis,
    flood_steps: usize,
    lr: f32,
    eps: f32,
    seed: u64,
    n: usize,
    clock: SharedClock,
    /// use the AOT pallas artifact for the flush (true on the hot path;
    /// false falls back to the pure-rust kernel — used by tests/benches;
    /// the synthetic backend always takes the pure-rust path)
    pub use_artifact: bool,
    /// device-resident basis factors (rebuilt on subspace refresh).
    /// Mutex, not a plain Option: `on_step_begin` runs concurrently
    /// across a same-instant event cohort (`&self`), and any member may
    /// need the catch-up flush. The lock is only taken when coefficients
    /// are pending — zero in the uniform-rate steady state, so the common
    /// path never contends.
    device_cache: Mutex<Option<DeviceBasisCache>>,
}

impl SeedFlood {
    pub fn build(env: &Env, topo: &Topology) -> Result<(Box<dyn Algorithm>, Vec<ClientState>)> {
        // reflood replays the retention window as the full history; with a
        // bounded window, messages evicted before a repair would be lost
        // for good — reject the combination instead of silently degrading
        anyhow::ensure!(
            env.cfg.repair_mode != RepairMode::Reflood || env.cfg.flood_retain == 0,
            "repair_mode=reflood requires flood_retain=0 (unbounded retention): \
             a bounded window cannot replay the full history"
        );
        let n = env.n_clients();
        let basis = SubspaceBasis::new(
            env.manifest(),
            env.cfg.rank,
            env.cfg.refresh,
            env.cfg.seed ^ 0x5EED_F100D,
        );
        let wire = if env.cfg.quantize_msgs {
            WireFormat::Quantized(env.cfg.lr)
        } else {
            WireFormat::Full
        };
        let space = Space::Full;
        let states = init_states(env, &space, |_| {
            let mut flood = FloodState {
                wire,
                retain: env.cfg.flood_retain,
                repair_mode: env.cfg.repair_mode,
                ..FloodState::new()
            };
            // every client is an origin: sizing the dedup filter's floor
            // universe up front is what lets the origin-sparse
            // representation compress steady-state flooding at large n
            // (a no-op reservation below the dense crossover)
            flood.seen.reserve_origins(n);
            Scratch::Flood { accum: CoeffAccum::new(&basis), flood }
        });
        let flood_steps = if env.cfg.flood_steps == 0 {
            topo.diameter().max(1)
        } else {
            env.cfg.flood_steps
        };
        let algo = SeedFlood {
            basis,
            flood_steps,
            lr: env.cfg.lr,
            eps: env.cfg.eps,
            seed: env.cfg.seed,
            n,
            clock: SharedClock::new(),
            use_artifact: true,
            device_cache: Mutex::new(None),
        };
        Ok((Box::new(algo), states))
    }

    /// Flush one client's accumulated coefficients through the batched
    /// kernel — a strict no-op (not even a device-cache build) when
    /// nothing is pending. The single flush body behind every path that
    /// applies coefficients ([`Self::flush_all`], the event engine's
    /// per-client catch-up in `on_step_begin`, the pre-refresh settle in
    /// `begin_step`), so all of them perform identical float operations.
    fn flush_one(&self, state: &mut ClientState, env: &Env) -> Result<()> {
        let pending = match &state.scratch {
            Scratch::Flood { accum, .. } => accum.pending,
            _ => 0,
        };
        if pending == 0 {
            return Ok(());
        }
        // The cache lock is held across the whole flush, so concurrent
        // cohort members with pending coefficients serialize here — fine:
        // the artifact runtime serializes executions anyway, and with
        // uniform rates pending == 0 and nobody reaches this line.
        let mut cache = self.device_cache.lock().expect("device cache lock poisoned");
        if self.use_artifact && cache.is_none() {
            *cache = env.make_device_cache(&self.basis)?;
        }
        // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
        let t0 = Instant::now();
        let (params, accum) = state.accum_parts();
        if self.use_artifact {
            env.subcge_flush(&self.basis, accum, params, cache.as_mut())?;
        } else {
            accum.flush_rust(&self.basis, params);
        }
        self.clock.add("MA", t0.elapsed());
        Ok(())
    }

    /// [`Self::flush_one`] over every client — the tail of every lockstep
    /// iteration and the event driver's barrier settle
    /// ([`Algorithm::on_barrier`]).
    fn flush_all(&self, states: &mut [ClientState], env: &Env) -> Result<()> {
        for st in states.iter_mut() {
            self.flush_one(st, env)?;
        }
        Ok(())
    }
}

impl Algorithm for SeedFlood {
    fn begin_step(&mut self, states: &mut [ClientState], step: usize, env: &Env) -> Result<()> {
        // (A) subspace refresh — sequential, before the local-step fan-out,
        // so all clients see the same basis this iteration. Accumulated
        // coefficients are basis-relative, so any pending ones must be
        // applied before the basis changes: a strict no-op in lockstep
        // (communicate() flushes every iteration), but under the event
        // engine stragglers can hold deliveries accumulated against the
        // old basis when the fastest client crosses a refresh boundary.
        if step > 0 && self.basis.refresh_due(step) {
            self.flush_all(states, env)?;
            if self.basis.maybe_refresh(step) {
                // device copies are stale; DeviceBasisCache::sync would
                // catch the epoch bump too, dropping keeps the invariant
                // obvious (&mut self here, so get_mut skips the lock)
                *self.device_cache.get_mut().expect("device cache lock poisoned") = None;
            }
        }
        Ok(())
    }

    fn local_step(
        &self,
        state: &mut ClientState,
        client: usize,
        step: usize,
        env: &Env,
    ) -> Result<f32> {
        // (B) local gradient estimation in the shared subspace
        let (b, _) = env.batch_shape();
        let (ids, labels) = state.sampler.next_batch(b);
        let seed = probe_seed(self.seed, client, step);
        let basis = &self.basis;
        let mut probe_err = None;
        let mut first_loss = None;
        // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
        let t0 = Instant::now();
        let alpha = zo::spsa_alpha(
            &mut state.params,
            self.eps,
            |p| match env.loss_acc(p, &ids, &labels) {
                Ok((l, _)) => {
                    first_loss.get_or_insert(l);
                    l
                }
                Err(e) => {
                    probe_err = Some(e);
                    0.0
                }
            },
            |p, s| zo::perturb_subcge(p, basis, seed, s),
        );
        self.clock.add("GE", t0.elapsed());
        if let Some(e) = probe_err {
            return Err(e);
        }

        // package as seed–scalar message with coefficient η·α/n (Alg. 1)
        let msg = SeedUpdate {
            id: MsgId { origin: client as u32, step: step as u32 },
            seed,
            coeff: self.lr * alpha / self.n as f32,
        };
        // inject first: under the quantized wire format the origin must
        // apply the same rounded coefficient every other client will see
        let (_, accum, flood) = state.flood_parts();
        let msg = flood.inject(msg);
        // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
        let t1 = Instant::now();
        accum.accumulate(basis, &msg); // own update
        self.clock.add("MA", t1.elapsed());
        Ok(first_loss.unwrap_or(0.0))
    }

    fn communicate(
        &mut self,
        states: &mut [ClientState],
        step: usize,
        env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        // netcond repair: clients whose connectivity just recovered (or
        // whose anti-entropy period elapsed) run the configured repair
        // protocol — gap-request (summary + gap-fill, O(gap) on the wire)
        // or the legacy full re-flood — so delivery degrades to bounded
        // staleness instead of silent loss
        for (i, st) in states.iter_mut().enumerate() {
            if net.should_repair(i) {
                let (_, _, flood) = st.flood_parts();
                flood.repair();
            }
        }
        // (C) k synchronous flooding rounds via the shared lockstep driver
        // (offline clients skip both halves — outboxes persist until they
        // rejoin); fold fresh messages as they arrive (coordinate update
        // is O(1) per message per layer)
        // fn item, not a closure: the projection returns a borrow of its
        // argument, which needs a late-bound lifetime for the for<'a> bound
        fn flood_of(st: &mut ClientState) -> &mut FloodState {
            st.flood_parts().2
        }
        let basis = &self.basis;
        let clock = &self.clock;
        flood::flood_rounds_by(
            states,
            net,
            self.flood_steps,
            flood_of,
            |st, _i, fresh| {
                let (_, accum, flood) = st.flood_parts();
                flood.note_staleness(step, fresh);
                // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
                let t0 = Instant::now();
                for m in fresh {
                    accum.accumulate(basis, m);
                }
                clock.add("MA", t0.elapsed());
            },
        );
        // apply the batched update through the pallas artifact (Eq. 10)
        self.flush_all(states, env)
    }

    // --- virtual-time hooks (ISSUE 4): flooding is fully asynchronous ---
    //
    // The seed–scalar protocol never needs a step barrier: a client
    // floods the moment its local step finishes, forwards at every
    // delivery-clock round, and folds received messages into its O(1)
    // coefficient accumulator whenever they arrive. With uniform rates
    // the event interleaving degenerates to the lockstep order (inject
    // sends == the first round's sends, barrier flush == the iteration
    // flush), which is why `--time-model event --rates uniform`
    // reproduces the lockstep trajectory bit-for-bit.

    fn time_policy(&self) -> TimePolicy {
        TimePolicy::Async
    }

    fn on_iteration_start(
        &mut self,
        states: &mut [ClientState],
        _step: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        // netcond repair triggers, re-keyed to the nominal iteration
        // clock — the same arming loop communicate() runs in lockstep
        for (i, st) in states.iter_mut().enumerate() {
            if net.should_repair(i) {
                st.flood_parts().2.repair();
            }
        }
        Ok(())
    }

    fn on_step_begin(
        &self,
        state: &mut ClientState,
        _client: usize,
        _step: usize,
        env: &Env,
    ) -> Result<()> {
        // catch-up flush: a straggler (or a fast client racing ahead)
        // applies everything delivered since its last flush, so the SPSA
        // probe sees current params. Pending is zero whenever the last
        // barrier flush already caught up — then this is a strict no-op,
        // preserving the uniform-rate reduction contract. May run
        // concurrently across a cohort (`&self`); the device cache behind
        // its Mutex is the only shared mutable state it can touch.
        self.flush_one(state, env)
    }

    fn on_step_complete(
        &mut self,
        state: &mut ClientState,
        client: usize,
        _step: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        // flood the freshly injected seed now — no barrier; this send is
        // the event-time equivalent of the first lockstep round's send
        state.flood_parts().2.send_round(client, net);
        Ok(())
    }

    fn on_send(
        &mut self,
        state: &mut ClientState,
        client: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        state.flood_parts().2.send_round(client, net);
        Ok(())
    }

    fn on_deliver(
        &mut self,
        state: &mut ClientState,
        client: usize,
        step: usize,
        _env: &Env,
        net: &mut Network,
    ) -> Result<()> {
        let basis = &self.basis;
        let (_, accum, flood) = state.flood_parts();
        let fresh = flood.collect(client, net);
        if fresh.is_empty() {
            return Ok(());
        }
        flood.note_staleness(step, &fresh);
        // sflint: allow(wall-clock, reason = "phase-timing metric (SharedClock -> RunRecord::phase_ms); never feeds training results")
        let t0 = Instant::now();
        for m in &fresh {
            accum.accumulate(basis, m);
        }
        self.clock.add("MA", t0.elapsed());
        Ok(())
    }

    fn on_barrier(
        &mut self,
        states: &mut [ClientState],
        _step: usize,
        env: &Env,
        _net: &mut Network,
    ) -> Result<()> {
        // all clients completed this step index: flush so evaluation sees
        // settled params — the event-time position of the lockstep
        // iteration flush. No communication happens here.
        self.flush_all(states, env)
    }

    fn eval_gmp(
        &self,
        states: &[ClientState],
        env: &Env,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        super::eval_gmp_avg(&Space::Full, states, env, batches)
    }

    fn phase_ms(&self) -> Vec<(String, f64)> {
        vec![
            ("GE".into(), self.clock.total_ms("GE")),
            ("MA".into(), self.clock.total_ms("MA")),
        ]
    }
}
