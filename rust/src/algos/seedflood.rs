//! SeedFlood (paper Alg. 1) — the paper's contribution.
//!
//! Per iteration, each client:
//!  (A) refreshes the globally shared SubCGE basis every τ steps;
//!  (B) estimates a zeroth-order update in the shared subspace (SPSA with
//!      the canonical-coordinate perturbation), packaging it as a
//!      seed–scalar pair with coefficient `η·α/n`;
//!  (C) injects it into the flooding protocol, runs `k` flooding rounds
//!      (k = network diameter by default ⇒ all-gather-equivalent
//!      consensus; k < D is the delayed-flooding ablation of §4.5), folds
//!      every newly received message into the O(1)-per-message coefficient
//!      accumulator, and flushes the batched update `θ − U A Vᵀ` through
//!      the AOT pallas kernel.
//!
//! Phase wall-clock is tracked as "GE" (gradient estimation) and "MA"
//! (message applying) to regenerate Table 4.

use anyhow::Result;

use super::{probe_seed, Algorithm};
use crate::data::BatchSampler;
use crate::flood::{FloodState, WireFormat};
use crate::net::{MsgId, Network, SeedUpdate};
use crate::sim::{consensus_error, Env};
use crate::subcge::{CoeffAccum, DeviceBasisCache, SubspaceBasis};
use crate::tensor::ParamVec;
use crate::topology::Topology;
use crate::util::timer::PhaseClock;
use crate::zo;

pub struct SeedFlood {
    clients: Vec<ParamVec>,
    basis: SubspaceBasis,
    accums: Vec<CoeffAccum>,
    floods: Vec<FloodState>,
    samplers: Vec<BatchSampler>,
    flood_steps: usize,
    lr: f32,
    eps: f32,
    seed: u64,
    n: usize,
    clock: PhaseClock,
    /// use the AOT pallas artifact for the flush (true on the hot path;
    /// false falls back to the pure-rust kernel — used by tests/benches)
    pub use_artifact: bool,
    /// device-resident basis factors (rebuilt on subspace refresh)
    device_cache: Option<DeviceBasisCache>,
}

impl SeedFlood {
    pub fn new(env: &Env, topo: &Topology) -> SeedFlood {
        let n = env.n_clients();
        let basis = SubspaceBasis::new(
            &env.manifest,
            env.cfg.rank,
            env.cfg.refresh,
            env.cfg.seed ^ 0x5EED_F100D,
        );
        let accums = (0..n).map(|_| CoeffAccum::new(&basis)).collect();
        let clients = (0..n).map(|_| env.init_params.clone()).collect();
        let flood_steps = if env.cfg.flood_steps == 0 {
            topo.diameter().max(1)
        } else {
            env.cfg.flood_steps
        };
        SeedFlood {
            clients,
            basis,
            accums,
            floods: (0..n)
                .map(|_| FloodState {
                    wire: if env.cfg.quantize_msgs {
                        WireFormat::Quantized(env.cfg.lr)
                    } else {
                        WireFormat::Full
                    },
                    ..FloodState::new()
                })
                .collect(),
            samplers: env.make_samplers(),
            flood_steps,
            lr: env.cfg.lr,
            eps: env.cfg.eps,
            seed: env.cfg.seed,
            n,
            clock: PhaseClock::new(),
            use_artifact: true,
            device_cache: None,
        }
    }

    fn flush(&mut self, client: usize, env: &Env) -> Result<()> {
        if self.use_artifact {
            if self.device_cache.is_none() {
                self.device_cache = Some(DeviceBasisCache::new(&self.basis, &env.rt)?);
            }
            self.accums[client].flush_with_artifact_cached(
                &self.basis,
                self.device_cache.as_mut().unwrap(),
                &mut self.clients[client],
                &env.exe_subcge,
                &env.rt,
            )
        } else {
            self.accums[client].flush_rust(&self.basis, &mut self.clients[client]);
            Ok(())
        }
    }
}

impl Algorithm for SeedFlood {
    fn local_step(&mut self, client: usize, step: usize, env: &Env) -> Result<f32> {
        // (A) subspace refresh — once per iteration, driven by client 0 so
        // the shared basis flips exactly once (all clients see the same
        // basis because it is stored once; determinism is unit-tested).
        if client == 0 && step > 0 {
            // pending accumulators must be empty across a basis change;
            // they are — communicate() flushes every iteration.
            self.basis.maybe_refresh(step);
        }

        // (B) local gradient estimation in the shared subspace
        let (b, _) = env.batch_shape();
        let (ids, labels) = self.samplers[client].next_batch(b);
        let seed = probe_seed(self.seed, client, step);
        let basis = &self.basis;
        let mut probe_err = None;
        let mut first_loss = None;
        let t0 = std::time::Instant::now();
        let alpha = zo::spsa_alpha(
            &mut self.clients[client],
            self.eps,
            |p| match env.loss_acc(p, &ids, &labels) {
                Ok((l, _)) => {
                    first_loss.get_or_insert(l);
                    l
                }
                Err(e) => {
                    probe_err = Some(e);
                    0.0
                }
            },
            |p, s| zo::perturb_subcge(p, basis, seed, s),
        );
        self.clock.add("GE", t0.elapsed());
        if let Some(e) = probe_err {
            return Err(e);
        }

        // package as seed–scalar message with coefficient η·α/n (Alg. 1)
        let msg = SeedUpdate {
            id: MsgId { origin: client as u32, step: step as u32 },
            seed,
            coeff: self.lr * alpha / self.n as f32,
        };
        // inject first: under the quantized wire format the origin must
        // apply the same rounded coefficient every other client will see
        let msg = self.floods[client].inject(msg);
        let t1 = std::time::Instant::now();
        self.accums[client].accumulate(&self.basis, &msg); // own update
        self.clock.add("MA", t1.elapsed());
        Ok(first_loss.unwrap_or(0.0))
    }

    fn communicate(&mut self, _step: usize, env: &Env, net: &mut Network) -> Result<()> {
        // (C) k synchronous flooding rounds; fold fresh messages as they
        // arrive (coordinate update is O(1) per message per layer)
        for _ in 0..self.flood_steps {
            for (i, st) in self.floods.iter_mut().enumerate() {
                st.send_round(i, net);
            }
            for i in 0..self.n {
                let fresh = self.floods[i].collect(i, net);
                if fresh.is_empty() {
                    continue;
                }
                let t0 = std::time::Instant::now();
                for m in &fresh {
                    self.accums[i].accumulate(&self.basis, m);
                }
                self.clock.add("MA", t0.elapsed());
            }
        }
        // apply the batched update through the pallas artifact (Eq. 10)
        for i in 0..self.n {
            let t0 = std::time::Instant::now();
            self.flush(i, env)?;
            self.clock.add("MA", t0.elapsed());
        }
        Ok(())
    }

    fn eval_gmp(&self, env: &Env, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)> {
        let refs: Vec<&ParamVec> = self.clients.iter().collect();
        let avg = ParamVec::average(&refs);
        env.eval_full(&avg, batches)
    }

    fn snapshot(&self) -> Vec<ParamVec> {
        self.clients.clone()
    }

    fn restore(&mut self, snap: Vec<ParamVec>) {
        assert_eq!(snap.len(), self.clients.len());
        self.clients = snap;
    }

    fn consensus_error(&self) -> f64 {
        consensus_error(&self.clients)
    }

    fn phase_ms(&self) -> Vec<(String, f64)> {
        vec![
            ("GE".into(), self.clock.total_ms("GE")),
            ("MA".into(), self.clock.total_ms("MA")),
        ]
    }
}
