//! PJRT runtime: load AOT HLO-text artifacts and execute them from the L3
//! hot path (no python at runtime).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are compiled once and cached; all graphs were lowered with
//! `return_tuple=True`, so every result is a tuple literal we decompose.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::model::{ArtifactSpec, Manifest};
use crate::tensor::{ParamVec, Tensor};
// real bindings with `--features xla`, in-repo stub otherwise (lib.rs)
use crate::xla;

/// Argument value for one artifact input. I32 carries its (small) shape by
/// value so call sites can build shapes inline.
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a [i32], Vec<usize>),
}

/// A compiled artifact plus its manifest signature.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

// SAFETY: the PJRT C API contract requires clients, loaded executables and
// buffers to be callable from multiple threads (the CPU plugin serializes
// internally where needed), and this crate only ever executes compiled
// artifacts — pure functions of their argument buffers — through these
// handles. The engine invokes `Executable::run` concurrently from
// worker threads during the local-step fan-out (ISSUE 1 tentpole item 2).
unsafe impl Send for Executable {}
// SAFETY: same argument as Send directly above — shared references only
// reach the thread-safe PJRT handles; `Executable` holds no rust-side
// mutable state at all.
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with positional args; returns one Tensor per manifest output.
    /// Scalars come back as shape-[] tensors.
    ///
    /// Internally uploads each arg as a device buffer and runs the buffer
    /// path: the crate's Literal-based `execute` both double-copies inputs
    /// and leaks the internally-created device buffers (~0.5 MB/call,
    /// measured in examples/leak_probe.rs) — `execute_b` with Drop-managed
    /// buffers does neither.
    pub fn run(&self, args: &[Arg]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: got {} args, expected {}",
                self.spec.tag,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let mut owned = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(self.spec.inputs.iter()) {
            let buf = match arg {
                Arg::F32(t) => {
                    if t.shape != spec.shape {
                        bail!(
                            "artifact {} input {}: shape {:?} != manifest {:?}",
                            self.spec.tag, spec.name, t.shape, spec.shape
                        );
                    }
                    self.client.buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)?
                }
                Arg::I32(data, shape) => {
                    if shape != &spec.shape {
                        bail!(
                            "artifact {} input {}: i32 shape {:?} != manifest {:?}",
                            self.spec.tag, spec.name, shape, spec.shape
                        );
                    }
                    self.client.buffer_from_host_buffer::<i32>(data, shape, None)?
                }
            };
            owned.push(buf);
        }
        let refs: Vec<&xla::PjRtBuffer> = owned.iter().collect();
        let result = self.exe.execute_b(&refs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        self.decode_outputs(parts)
    }

    /// Execute with device-resident buffer arguments (zero host->device
    /// copies for cached operands — the hot-path variant used by the
    /// SubCGE flush; see DESIGN.md §Perf).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Tensor>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "artifact {}: got {} buffer args, expected {}",
                self.spec.tag,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let result = self.exe.execute_b(args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        self.decode_outputs(parts)
    }

    fn decode_outputs(&self, parts: Vec<xla::Literal>) -> Result<Vec<Tensor>> {
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs, manifest says {}",
                self.spec.tag,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.into_iter().zip(self.spec.outputs.iter()) {
            let data = lit.to_vec::<f32>()?;
            out.push(Tensor::from_vec(&ospec.shape, data));
        }
        Ok(out)
    }
}

/// The PJRT client + executable cache. One per process (CPU platform).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: String,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    /// executions performed (metrics)
    pub executions: std::sync::atomic::AtomicU64,
}

// SAFETY: see `Executable` above — the client handle is thread-safe per the
// PJRT contract; all rust-side mutable state is behind Mutex/atomics.
unsafe impl Send for Runtime {}
// SAFETY: same argument as Send directly above — the executable cache is
// behind a Mutex and the execution counter is atomic, so `&Runtime` is
// safe to share across the worker threads.
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu(artifacts_dir: &str) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_string(),
            cache: Mutex::new(HashMap::new()),
            executions: Default::default(),
        })
    }

    /// Load + compile (cached) the artifact `tag` from the manifest.
    pub fn load(&self, manifest: &Manifest, tag: &str) -> Result<std::sync::Arc<Executable>> {
        let key = format!("{}:{}", manifest.config.name, tag);
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let spec = manifest.artifact(tag)?.clone();
        let path = Path::new(&self.dir).join(&spec.file);
        let path_str = path.to_str().unwrap();
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path_str}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {tag}"))?;
        let arc = std::sync::Arc::new(Executable { spec, exe, client: self.client.clone() });
        self.cache.lock().unwrap().insert(key, arc.clone());
        Ok(arc)
    }

    pub fn count_execution(&self) {
        self.executions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }

    /// Upload an f32 tensor to the device (single host->device copy; used
    /// to pin long-lived operands like the SubCGE basis across calls).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Upload an i32 tensor to the device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }
}

/// Convenience: build the arg list `[params..., ids, labels, class_tokens]`
/// shared by the loss/grad artifacts.
pub fn loss_args<'a>(
    params: &'a ParamVec,
    ids: &'a [i32],
    ids_shape: Vec<usize>,
    labels: &'a [i32],
    class_tokens: &'a [i32],
) -> Vec<Arg<'a>> {
    let n_labels = labels.len();
    let n_ct = class_tokens.len();
    let mut args: Vec<Arg> = params.tensors.iter().map(Arg::F32).collect();
    args.push(Arg::I32(ids, ids_shape));
    args.push(Arg::I32(labels, vec![n_labels]));
    args.push(Arg::I32(class_tokens, vec![n_ct]));
    args
}
