//! Repo-wide symbol/reference index for the cross-file sflint rules.
//!
//! The per-line channel scanner ([`super::scan`]) sees one file at a
//! time; every drift bug this repo has actually shipped was a fact
//! stated in one module silently diverging from its mirror in another
//! (the PR 4 `seed ^ i` sampler streams, the PR 5 fig6 grid-shift from
//! unparsed JSON fields). This module builds the cheap structural index
//! those rules need — still no `syn` in the image, so everything is
//! extracted from the scanner's code/literal channels:
//!
//! * enum declarations with their variants (`wire-conservation` checks
//!   every `Payload` variant against the `wire_bytes` match),
//! * string literals with line/column positions (help text, JSON keys,
//!   `format!` templates, match-arm keys),
//! * `pub fn` names,
//! * CLI flag occurrences — string keys passed to `args.get(..)` /
//!   `get_or` / `get_parse` / `get_parse_list` / `get_list` / `has`,
//!   matched by the receiver being literally named `args` (the codebase
//!   convention), so `Json::get("key")` never pollutes the flag set,
//! * function line-ranges inside `impl` blocks, so rules can scope a
//!   query to e.g. `RunRecord::from_json` or
//!   `ExperimentConfig::apply_toml`.
//!
//! [`RepoIndex`] borrows the scanned lines owned by the lint driver; it
//! is built once per `lint_files` call and shared by every cross-file
//! rule.

use super::scan::{find_word, Line};

/// Getter methods whose first string argument names a CLI flag when the
/// receiver is the conventional `args` binding.
pub const FLAG_GETTERS: &[&str] =
    &["get", "get_or", "get_parse", "get_parse_list", "get_list", "has"];

/// One `enum` declaration.
#[derive(Clone, Debug)]
pub struct EnumInfo {
    pub name: String,
    /// 1-based line of the `enum` keyword.
    pub decl_line: usize,
    /// `(variant name, 1-based declaration line)`.
    pub variants: Vec<(String, usize)>,
}

/// One CLI flag read site: `args.<getter>("<flag>")`.
#[derive(Clone, Debug)]
pub struct FlagUse {
    pub flag: String,
    /// 1-based line of the getter call.
    pub line: usize,
    pub in_test: bool,
}

/// Per-file slice of the index.
pub struct FileIndex<'a> {
    pub path: &'a str,
    pub lines: &'a [Line],
    pub enums: Vec<EnumInfo>,
    pub flags: Vec<FlagUse>,
    /// `(fn name, 1-based declaration line)` for every `pub fn`.
    pub pub_fns: Vec<(String, usize)>,
}

/// The whole scanned tree, indexed. Files keep the deterministic order
/// the driver scanned them in (sorted by path).
pub struct RepoIndex<'a> {
    pub files: Vec<FileIndex<'a>>,
}

impl<'a> RepoIndex<'a> {
    pub fn build(scanned: &'a [(String, Vec<Line>)]) -> RepoIndex<'a> {
        RepoIndex {
            files: scanned
                .iter()
                .map(|(path, lines)| FileIndex::build(path, lines))
                .collect(),
        }
    }

    pub fn get(&self, path: &str) -> Option<&FileIndex<'a>> {
        self.files.iter().find(|f| f.path == path)
    }
}

impl<'a> FileIndex<'a> {
    pub fn build(path: &'a str, lines: &'a [Line]) -> FileIndex<'a> {
        FileIndex {
            path,
            lines,
            enums: extract_enums(lines),
            flags: extract_flags(lines),
            pub_fns: extract_pub_fns(lines),
        }
    }

    /// Every string literal in the file joined by newlines — the
    /// "rendered text" of the file (help screens, println templates).
    pub fn literal_text(&self) -> String {
        let mut out = String::new();
        for line in self.lines {
            for (_, t) in &line.lits {
                out.push_str(t);
                out.push('\n');
            }
        }
        out
    }

    /// 0-based inclusive line-index range of `fn <fn_name>` inside any
    /// `impl <type_name>` block.
    pub fn fn_range(&self, type_name: &str, fn_name: &str) -> Option<(usize, usize)> {
        fn_range(self.lines, type_name, fn_name)
    }

    /// Match-arm key literals inside a 0-based line range: literals that
    /// appear left of a `=>` on their line (TOML/JSON dispatch keys).
    pub fn arm_keys(&self, range: (usize, usize)) -> Vec<(String, usize)> {
        let mut out = Vec::new();
        for line in &self.lines[range.0..=range.1.min(self.lines.len() - 1)] {
            let Some(arrow) = line.code.find("=>") else {
                continue;
            };
            let arrow_col = line.code[..arrow].chars().count();
            for (col, t) in &line.lits {
                if *col < arrow_col {
                    out.push((t.clone(), line.number));
                }
            }
        }
        out
    }

    /// Key literals read through getter calls inside a 0-based line
    /// range: a literal counts when it is the first argument of a call
    /// whose callee is `get`, `opt_*`, or `*_arr` (the record-parsing
    /// helpers), so default-value literals never register as keys.
    pub fn getter_keys(&self, range: (usize, usize)) -> Vec<(String, usize)> {
        let mut out = Vec::new();
        for line in &self.lines[range.0..=range.1.min(self.lines.len() - 1)] {
            for (col, t) in &line.lits {
                if *col == 0 {
                    continue; // continuation of a multi-line literal
                }
                let Some(callee) = callee_before(&line.code, *col) else {
                    continue;
                };
                if callee == "get" || callee.starts_with("opt_") || callee.ends_with("_arr") {
                    out.push((t.clone(), line.number));
                }
            }
        }
        out
    }
}

/// `[a-z0-9_]+` starting with a letter — the shape of a JSON/TOML key.
pub fn is_key(s: &str) -> bool {
    !s.is_empty()
        && s.starts_with(|c: char| c.is_ascii_lowercase())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// `[a-z0-9-]+` starting with a letter — the shape of a CLI flag name.
pub fn is_flag(s: &str) -> bool {
    !s.is_empty()
        && s.starts_with(|c: char| c.is_ascii_lowercase())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
}

/// True when `text` documents `--<flag>` at a flag boundary (so
/// `--seeds` never satisfies a `--seed` lookup).
pub fn doc_has_flag(text: &str, flag: &str) -> bool {
    let needle = format!("--{flag}");
    let bytes = text.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = text[from..].find(&needle) {
        let end = from + rel + needle.len();
        let boundary = match bytes.get(end) {
            Some(b) => !(b.is_ascii_lowercase() || b.is_ascii_digit() || *b == b'-'),
            None => true,
        };
        if boundary {
            return true;
        }
        from = from + rel + 1;
    }
    false
}

/// The callee of the call whose first argument is the literal starting
/// at char column `content_col`: walks back over the opening quote and
/// optional spaces, requires a `(`, and returns the identifier before
/// it — `None` when the literal is not a call's first argument.
fn callee_before(code: &str, content_col: usize) -> Option<String> {
    let chars: Vec<char> = code.chars().collect();
    let mut j = content_col.checked_sub(1)?; // opening quote
    if chars.get(j) != Some(&'"') {
        return None;
    }
    while j > 0 && chars[j - 1] == ' ' {
        j -= 1;
    }
    if j == 0 || chars[j - 1] != '(' {
        return None;
    }
    j -= 1; // the paren
    while j > 0 && chars[j - 1] == ' ' {
        j -= 1;
    }
    let end = j;
    while j > 0 && (chars[j - 1].is_alphanumeric() || chars[j - 1] == '_') {
        j -= 1;
    }
    if j == end {
        return None;
    }
    Some(chars[j..end].iter().collect())
}

/// The argument span of a call whose `(` sits at char column
/// `open_col`: text between the parens, balanced on this line, falling
/// back to the rest of the line for multi-line calls.
pub fn call_arg_span(code: &str, open_col: usize) -> String {
    let chars: Vec<char> = code.chars().collect();
    if chars.get(open_col) != Some(&'(') {
        return String::new();
    }
    let mut depth = 0i32;
    for (k, &c) in chars.iter().enumerate().skip(open_col) {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return chars[open_col + 1..k].iter().collect();
                }
            }
            _ => {}
        }
    }
    chars[open_col + 1..].iter().collect()
}

fn extract_enums(lines: &[Line]) -> Vec<EnumInfo> {
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let Some(p) = find_word(&line.code, "enum") else {
            continue;
        };
        let Some(name) = super::rules::leading_ident(line.code[p + 4..].trim_start()) else {
            continue;
        };
        if !name.starts_with(|c: char| c.is_ascii_uppercase()) {
            continue;
        }
        // Variants: depth-1 lines of the enum body, leading identifier
        // (skipping attributes and doc comments, which the code channel
        // already blanks or leaves as `#[...]`).
        let mut variants = Vec::new();
        let mut depth = 0i32;
        let mut started = false;
        for body in &lines[i..] {
            let depth_at_start = depth;
            for c in body.code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            if started && depth_at_start == 1 {
                let t = body.code.trim();
                if !t.starts_with('#') {
                    if let Some(v) = super::rules::leading_ident(t) {
                        if v.starts_with(|c: char| c.is_ascii_uppercase()) {
                            variants.push((v, body.number));
                        }
                    }
                }
            }
            if started && depth <= 0 {
                break;
            }
        }
        out.push(EnumInfo { name, decl_line: line.number, variants });
    }
    out
}

fn extract_flags(lines: &[Line]) -> Vec<FlagUse> {
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        for getter in FLAG_GETTERS {
            let needle = format!("args.{getter}(");
            let mut from = 0usize;
            while let Some(rel) = line.code[from..].find(&needle) {
                let at = from + rel;
                from = at + 1;
                // Word boundary on the receiver: `margs.get(` is not a
                // flag read.
                let before_ok = at == 0
                    || !line.code[..at]
                        .ends_with(|c: char| c.is_alphanumeric() || c == '_');
                if !before_ok {
                    continue;
                }
                let open_col = line.code[..at + needle.len()].chars().count() - 1;
                // First literal after the opening paren — on this line,
                // or (multi-line call) the first literal on the next.
                let lit = line
                    .lits
                    .iter()
                    .find(|(col, _)| *col > open_col)
                    .or_else(|| lines.get(i + 1).and_then(|l| l.lits.first()));
                if let Some((_, flag)) = lit {
                    if is_flag(flag) {
                        out.push(FlagUse {
                            flag: flag.clone(),
                            line: line.number,
                            in_test: line.in_test,
                        });
                    }
                }
            }
        }
    }
    out
}

fn extract_pub_fns(lines: &[Line]) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for line in lines {
        let Some(p) = find_word(&line.code, "fn") else {
            continue;
        };
        if find_word(&line.code[..p], "pub").is_none() {
            continue;
        }
        if let Some(name) = super::rules::leading_ident(line.code[p + 2..].trim_start()) {
            out.push((name, line.number));
        }
    }
    out
}

/// 0-based inclusive line range of the brace block opened at or after
/// line `start`.
pub fn region_end(lines: &[Line], start: usize) -> usize {
    let mut depth = 0i32;
    let mut started = false;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return i;
        }
    }
    lines.len().saturating_sub(1)
}

/// 0-based inclusive line range of `fn <fn_name>` inside any
/// `impl <type_name>` block.
pub fn fn_range(lines: &[Line], type_name: &str, fn_name: &str) -> Option<(usize, usize)> {
    use super::scan::has_word;
    for (i, line) in lines.iter().enumerate() {
        if !(has_word(&line.code, "impl") && has_word(&line.code, type_name)) {
            continue;
        }
        let end = region_end(lines, i);
        let mut j = i + 1;
        while j <= end {
            if has_word(&lines[j].code, "fn") && has_word(&lines[j].code, fn_name) {
                return Some((j, region_end(lines, j).min(end)));
            }
            j += 1;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan;

    #[test]
    fn enum_variants_extracted_with_lines() {
        let src = "/// doc\npub enum Payload {\n    /// seeds\n    Seeds(Vec<u8>),\n    \
                   GapFill { msgs: Vec<u8>, quantized: bool },\n}\n";
        let lines = scan(src);
        let enums = extract_enums(&lines);
        assert_eq!(enums.len(), 1);
        assert_eq!(enums[0].name, "Payload");
        assert_eq!(enums[0].decl_line, 2);
        let names: Vec<&str> = enums[0].variants.iter().map(|(v, _)| v.as_str()).collect();
        assert_eq!(names, vec!["Seeds", "GapFill"]);
        assert_eq!(enums[0].variants[1].1, 5);
    }

    #[test]
    fn flags_extracted_only_from_args_receiver() {
        let src = "fn f(args: &Args, j: &Json) {\n    \
                   let a = args.get_or(\"alpha\", \"x\");\n    \
                   let b = j.get(\"not_a_flag\");\n    \
                   let c = margs.get(\"also_not\");\n    \
                   let d = args.has(\"beta\");\n\
                   }\n";
        let lines = scan(src);
        let flags = extract_flags(&lines);
        let names: Vec<&str> = flags.iter().map(|f| f.flag.as_str()).collect();
        assert_eq!(names, vec!["alpha", "beta"]);
        assert_eq!(flags[0].line, 2);
    }

    #[test]
    fn multiline_getter_takes_next_line_literal() {
        let src = "fn f(args: &Args) {\n    let k = args.get_list(\n        \
                   \"topologies\",\n        &[\"ring\"],\n    );\n}\n";
        let lines = scan(src);
        let flags = extract_flags(&lines);
        assert_eq!(flags.len(), 1);
        assert_eq!(flags[0].flag, "topologies");
    }

    #[test]
    fn fn_range_scopes_to_impl_block() {
        let src = "impl Other {\n    pub fn to_json(&self) {}\n}\n\
                   impl RunRecord {\n    pub fn to_json(&self) {\n        body();\n    }\n}\n";
        let lines = scan(src);
        let (a, b) = fn_range(&lines, "RunRecord", "to_json").unwrap();
        assert_eq!((a, b), (4, 6));
        assert!(fn_range(&lines, "Missing", "to_json").is_none());
    }

    #[test]
    fn arm_and_getter_keys() {
        let src = "impl C {\n    fn apply(&mut self, v: &V) {\n        match k {\n            \
                   \"alpha_rate\" => self.a = v.as_f64()?,\n            \
                   other => bail!(\"unknown {other}\"),\n        }\n        \
                   let x = r.get(\"gmp\")?;\n        \
                   let y = opt_str(\"rates\", \"uniform\");\n    }\n}\n";
        let lines = scan(src);
        let idx = FileIndex::build("rust/src/config/mod.rs", &lines);
        let range = idx.fn_range("C", "apply").unwrap();
        let arms: Vec<&str> = idx.arm_keys(range).iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>();
        assert_eq!(arms, vec!["alpha_rate"]);
        let gets: Vec<String> = idx.getter_keys(range).into_iter().map(|(k, _)| k).collect();
        // default value "uniform" is not a key; "unknown {other}" is not
        // a getter first-arg
        assert_eq!(gets, vec!["gmp".to_string(), "rates".to_string()]);
    }

    #[test]
    fn flag_doc_lookup_is_boundary_aware() {
        assert!(doc_has_flag("use --seed N to pin it", "seed"));
        assert!(!doc_has_flag("use --seeds 0,1,2", "seed"));
        assert!(doc_has_flag("both --seeds and --seed", "seed"));
        assert!(doc_has_flag("(--flood-steps)", "flood-steps"));
    }

    #[test]
    fn key_and_flag_shapes() {
        assert!(is_key("total_bytes"));
        assert!(!is_key("total_bytes={}"));
        assert!(!is_key(""));
        assert!(is_flag("flood-steps"));
        assert!(!is_flag("Flood"));
    }

    #[test]
    fn call_spans_balance_parens() {
        assert_eq!(call_arg_span("Rng::new(mix(seed, 1))", 8), "mix(seed, 1)");
        assert_eq!(call_arg_span("Rng::new(seed ^", 8), "seed ^");
    }
}
