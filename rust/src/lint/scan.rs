//! Comment- and string-aware line scanner for sflint.
//!
//! The image ships no crate registry, so sflint cannot lean on `syn` or
//! `clippy-driver`. Instead this module implements a small hand-rolled
//! lexer that is just precise enough for line-oriented pattern rules:
//! for every source line it produces the raw text, the *code* text with
//! string/char-literal contents and comments blanked out, and the
//! *comment* text with everything else blanked out. Rules match patterns
//! against the code channel (so pattern constants inside string literals
//! never self-trigger) and parse allow-annotations from the comment
//! channel only.
//!
//! The lexer understands:
//! - line comments (`//`) and nested block comments (`/* /* */ */`),
//! - normal string literals with escapes, raw strings `r"…"`/`r#"…"#`
//!   (any number of hashes), and byte-string variants,
//! - char literals vs. lifetimes (`'a'` vs `'a`),
//! - `#[cfg(test)] mod …` regions, tracked by brace depth on the code
//!   channel so test-only code can be exempted from library rules.
//!
//! Besides blanking literal contents out of the code channel, the
//! scanner also *collects* them: each line carries the string literals
//! that start or continue on it (`lits`), which is what lets the
//! cross-file index ([`super::index`]) see flag names, JSON keys, help
//! text and `format!` templates without a second parse.

/// One scanned source line, split into channels.
#[derive(Clone, Debug)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The unmodified source line.
    pub raw: String,
    /// Code channel: comments and literal *contents* replaced by spaces.
    /// Quote characters are kept so token boundaries stay visible.
    pub code: String,
    /// Comment channel: comment text only, everything else blanked.
    pub comment: String,
    /// String-literal contents on this line: `(start_col, text)` where
    /// `start_col` is the char column of the first content char (0 for
    /// the continuation of a literal opened on an earlier line). Escape
    /// sequences are kept verbatim; char literals are not recorded.
    pub lits: Vec<(usize, String)>,
    /// True when the line sits inside a `#[cfg(test)] mod` region.
    pub in_test: bool,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    LineComment,
    BlockComment,
    Str,
    RawStr,
    Char,
}

/// Pending test-region bookkeeping: we saw `#[cfg(test)]` and are waiting
/// for the `mod` item it decorates (possibly with more attributes or a
/// doc comment in between).
#[derive(Clone, Copy, PartialEq)]
enum TestPending {
    No,
    /// Saw the cfg(test) attribute; waiting for `mod` / `{`.
    Armed,
}

/// Scan a whole source file into per-line channel records.
pub fn scan(source: &str) -> Vec<Line> {
    let mut lines: Vec<Line> = Vec::new();

    let mut mode = Mode::Code;
    // Depth of nested block comments (only meaningful in BlockComment).
    let mut block_depth = 0usize;
    // Number of hashes for the raw string currently open.
    let mut raw_hashes = 0usize;

    // Test-region tracking.
    let mut brace_depth = 0i64;
    // Stack of brace depths at which a #[cfg(test)] mod body was opened.
    let mut test_region_starts: Vec<i64> = Vec::new();
    let mut pending = TestPending::No;

    for (idx, raw_line) in source.lines().enumerate() {
        let chars: Vec<char> = raw_line.chars().collect();
        let mut code = String::with_capacity(chars.len());
        let mut comment = String::with_capacity(chars.len());
        let in_test_at_start = !test_region_starts.is_empty();

        // Literal collection for this line. A literal opened on an
        // earlier line continues at column 0.
        let mut lits: Vec<(usize, String)> = Vec::new();
        let mut lit_start = 0usize;
        let mut lit_buf = String::new();

        // LineComment never survives a newline.
        if mode == Mode::LineComment {
            mode = Mode::Code;
        }
        // Unterminated Str/Char across a newline: normal strings can
        // continue across lines (with or without a trailing backslash),
        // so keep Str mode; char literals cannot, reset them.
        if mode == Mode::Char {
            mode = Mode::Code;
        }
        // Escape flag inside Str/Char; never meaningful across lines.
        let mut escaped = false;

        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            let next = chars.get(i + 1).copied();
            match mode {
                Mode::Code => match c {
                    '/' if next == Some('/') => {
                        mode = Mode::LineComment;
                        code.push(' ');
                        code.push(' ');
                        comment.push('/');
                        comment.push('/');
                        i += 2;
                    }
                    '/' if next == Some('*') => {
                        mode = Mode::BlockComment;
                        block_depth = 1;
                        code.push(' ');
                        code.push(' ');
                        comment.push('/');
                        comment.push('*');
                        i += 2;
                    }
                    '"' => {
                        mode = Mode::Str;
                        escaped = false;
                        code.push('"');
                        comment.push(' ');
                        i += 1;
                        lit_start = i;
                    }
                    'r' | 'b' => {
                        // Possible raw / byte string start: r", r#", br", b".
                        // Look past an optional second prefix char and hashes.
                        let mut j = i + 1;
                        if c == 'b' && chars.get(j) == Some(&'r') {
                            j += 1;
                        }
                        let mut hashes = 0usize;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        let is_raw = (c == 'r' || chars.get(i + 1) == Some(&'r'))
                            && chars.get(j) == Some(&'"');
                        let is_plain_bstr =
                            c == 'b' && hashes == 0 && chars.get(i + 1) == Some(&'"');
                        // Reject identifiers like `for r in ...` followed by
                        // nothing string-like, and `number` chars before: only
                        // treat as a literal prefix when the previous code
                        // char is not identifier-ish.
                        let prev_ident = i > 0
                            && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
                        if !prev_ident && (is_raw || is_plain_bstr) {
                            if is_raw {
                                mode = Mode::RawStr;
                                raw_hashes = hashes;
                                for &pc in &chars[i..=j] {
                                    code.push(if pc == '"' { '"' } else { ' ' });
                                    comment.push(' ');
                                }
                                i = j + 1;
                                lit_start = i;
                            } else {
                                // b"..."
                                mode = Mode::Str;
                                escaped = false;
                                code.push(' ');
                                code.push('"');
                                comment.push(' ');
                                comment.push(' ');
                                i += 2;
                                lit_start = i;
                            }
                        } else {
                            code.push(c);
                            comment.push(' ');
                            i += 1;
                        }
                    }
                    '\'' => {
                        // Char literal vs lifetime. Heuristic: 'X' where the
                        // closing quote follows one char (or an escape) is a
                        // char literal; otherwise a lifetime.
                        if next == Some('\\') {
                            mode = Mode::Char;
                            escaped = false;
                            code.push('\'');
                            comment.push(' ');
                            i += 1;
                        } else if chars.get(i + 2) == Some(&'\'') {
                            // 'a'
                            code.push('\'');
                            code.push(' ');
                            code.push('\'');
                            comment.push(' ');
                            comment.push(' ');
                            comment.push(' ');
                            i += 3;
                        } else {
                            // Lifetime: keep as code.
                            code.push('\'');
                            comment.push(' ');
                            i += 1;
                        }
                    }
                    '{' => {
                        brace_depth += 1;
                        code.push('{');
                        comment.push(' ');
                        i += 1;
                    }
                    '}' => {
                        brace_depth -= 1;
                        if let Some(&start) = test_region_starts.last() {
                            if brace_depth < start {
                                test_region_starts.pop();
                            }
                        }
                        code.push('}');
                        comment.push(' ');
                        i += 1;
                    }
                    _ => {
                        code.push(c);
                        comment.push(' ');
                        i += 1;
                    }
                },
                Mode::LineComment => {
                    code.push(' ');
                    comment.push(c);
                    i += 1;
                }
                Mode::BlockComment => {
                    if c == '*' && next == Some('/') {
                        block_depth -= 1;
                        comment.push('*');
                        comment.push('/');
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                        if block_depth == 0 {
                            mode = Mode::Code;
                        }
                    } else if c == '/' && next == Some('*') {
                        block_depth += 1;
                        comment.push('/');
                        comment.push('*');
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                    } else {
                        code.push(' ');
                        comment.push(c);
                        i += 1;
                    }
                }
                Mode::Str => {
                    comment.push(' ');
                    if escaped {
                        escaped = false;
                        code.push(' ');
                        lit_buf.push(c);
                    } else if c == '\\' {
                        escaped = true;
                        code.push(' ');
                        lit_buf.push(c);
                    } else if c == '"' {
                        code.push('"');
                        mode = Mode::Code;
                        lits.push((lit_start, std::mem::take(&mut lit_buf)));
                    } else {
                        code.push(' ');
                        lit_buf.push(c);
                    }
                    i += 1;
                }
                Mode::RawStr => {
                    comment.push(' ');
                    if c == '"' {
                        // Check for closing hashes.
                        let mut ok = true;
                        for k in 0..raw_hashes {
                            if chars.get(i + 1 + k) != Some(&'#') {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            code.push('"');
                            for _ in 0..raw_hashes {
                                code.push(' ');
                                comment.push(' ');
                            }
                            i += 1 + raw_hashes;
                            mode = Mode::Code;
                            lits.push((lit_start, std::mem::take(&mut lit_buf)));
                        } else {
                            code.push(' ');
                            lit_buf.push(c);
                            i += 1;
                        }
                    } else {
                        code.push(' ');
                        lit_buf.push(c);
                        i += 1;
                    }
                }
                Mode::Char => {
                    comment.push(' ');
                    if escaped {
                        escaped = false;
                        code.push(' ');
                    } else if c == '\\' {
                        escaped = true;
                        code.push(' ');
                    } else if c == '\'' {
                        code.push('\'');
                        mode = Mode::Code;
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
            }
        }

        // Test-region detection works on the finished code channel so
        // attributes inside strings/comments are ignored. When a region
        // body opens on this line, record the depth just inside its
        // first opening brace: depth-before-line + 1, reconstructed from
        // the line's net brace delta.
        let code_trim = code.trim();
        let opens = code.matches('{').count() as i64;
        let closes = code.matches('}').count() as i64;
        let depth_inside = brace_depth - (opens - closes) + 1;
        match pending {
            TestPending::No => {
                if code_trim.contains("#[cfg(test)]") {
                    pending = TestPending::Armed;
                    // Same-line `#[cfg(test)] mod x { ... }` support.
                    if let Some(pos) = code_trim.find("#[cfg(test)]") {
                        let rest = &code_trim[pos + "#[cfg(test)]".len()..];
                        if has_word(rest, "mod") && rest.contains('{') && opens > closes {
                            test_region_starts.push(depth_inside);
                            pending = TestPending::No;
                        }
                    }
                }
            }
            TestPending::Armed => {
                if has_word(code_trim, "mod") || has_word(code_trim, "fn") {
                    let is_mod = has_word(code_trim, "mod");
                    if code_trim.contains('{') {
                        if is_mod && opens > closes {
                            test_region_starts.push(depth_inside);
                        }
                        // `#[cfg(test)] fn …` guards a single item; the
                        // line rules don't need region tracking for it.
                        pending = TestPending::No;
                    } else if code_trim.ends_with(';') {
                        // `#[cfg(test)] mod tests;` — out-of-line module.
                        pending = TestPending::No;
                    }
                } else if !code_trim.is_empty()
                    && !code_trim.starts_with("#[")
                    && !code_trim.starts_with("#!")
                {
                    // Some other item was decorated (use, struct, …);
                    // treat conservatively as not a region.
                    pending = TestPending::No;
                }
            }
        }

        // A literal still open at end of line (multi-line string):
        // record this line's slice of it; the rest continues at column 0
        // on the next line.
        if mode == Mode::Str || mode == Mode::RawStr {
            lits.push((lit_start, std::mem::take(&mut lit_buf)));
        }

        lines.push(Line {
            number: idx + 1,
            raw: raw_line.to_string(),
            code,
            comment,
            lits,
            in_test: in_test_at_start || !test_region_starts.is_empty(),
        });
    }

    lines
}

/// True when `needle` occurs in `hay` delimited by non-identifier chars.
pub fn has_word(hay: &str, needle: &str) -> bool {
    find_word(hay, needle).is_some()
}

/// Byte offset of the first word-boundary occurrence of `needle`.
pub fn find_word(hay: &str, needle: &str) -> Option<usize> {
    if needle.is_empty() {
        return None;
    }
    let hb = hay.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = hay[from..].find(needle) {
        let start = from + rel;
        let end = start + needle.len();
        let before_ok = start == 0 || !is_ident_byte(hb[start - 1]);
        let after_ok = end >= hb.len() || !is_ident_byte(hb[end]);
        if before_ok && after_ok {
            return Some(start);
        }
        from = start + 1;
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_are_blanked() {
        let src = "let s = \"Instant::now inside\"; s.len();\n";
        let lines = scan(src);
        assert!(!lines[0].code.contains("Instant::now"));
        assert!(lines[0].code.contains("s.len()"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "let s = r#\"HashMap::new() \"quoted\" \"#; foo();\n";
        let lines = scan(src);
        assert!(!lines[0].code.contains("HashMap"));
        assert!(lines[0].code.contains("foo()"));
    }

    #[test]
    fn multiline_raw_string() {
        let src = "let s = r#\"line one\nInstant::now()\n\"#;\nbar();\n";
        let lines = scan(src);
        assert!(!lines[1].code.contains("Instant::now"));
        assert!(lines[3].code.contains("bar()"));
    }

    #[test]
    fn comments_split_channels() {
        let src = "foo(); // sflint: allow(wall-clock, reason = \"x\")\n";
        let lines = scan(src);
        assert!(lines[0].code.contains("foo()"));
        assert!(!lines[0].code.contains("allow"));
        assert!(lines[0].comment.contains("sflint: allow(wall-clock"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still */ code();\n";
        let lines = scan(src);
        assert!(lines[0].code.contains("code()"));
        assert!(!lines[0].code.contains("outer"));
        assert!(!lines[0].code.contains("still"));
    }

    #[test]
    fn lifetimes_survive_char_blanking() {
        let src = "fn f<'a>(x: &'a str) -> char { 'x' }\n";
        let lines = scan(src);
        assert!(lines[0].code.contains("<'a>"));
        assert!(!lines[0].code.contains("'x'") || lines[0].code.contains("' '"));
    }

    #[test]
    fn escaped_quote_char_literal() {
        let src = "let q = '\\''; after();\n";
        let lines = scan(src);
        assert!(lines[0].code.contains("after()"));
    }

    #[test]
    fn cfg_test_region_tracked() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn lib2() {}\n";
        let lines = scan(src);
        assert!(!lines[0].in_test);
        assert!(lines[3].in_test, "body of test mod is in_test");
        assert!(!lines[5].in_test, "code after test mod is not in_test");
    }

    #[test]
    fn literals_are_collected_with_columns() {
        let src = "let a = args.get(\"alpha\"); let b = \"beta\";\n";
        let lines = scan(src);
        let texts: Vec<&str> = lines[0].lits.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, vec!["alpha", "beta"]);
        // start_col points at the first content char (after the quote)
        let (col, _) = lines[0].lits[0];
        assert_eq!(src.chars().nth(col).unwrap(), 'a');
        assert_eq!(src.chars().nth(col - 1).unwrap(), '"');
    }

    #[test]
    fn multiline_literal_split_across_lines() {
        let src = "let s = \"first\nsecond\"; tail();\n";
        let lines = scan(src);
        assert_eq!(lines[0].lits, vec![(9, "first".to_string())]);
        assert_eq!(lines[1].lits, vec![(0, "second".to_string())]);
    }

    #[test]
    fn raw_and_byte_literals_collected_escapes_verbatim() {
        let src = "let r = r#\"raw \"inner\" text\"#; let e = \"a\\\"b\"; let b = b\"bytes\";\n";
        let lines = scan(src);
        let texts: Vec<&str> = lines[0].lits.iter().map(|(_, t)| t.as_str()).collect();
        assert_eq!(texts, vec!["raw \"inner\" text", "a\\\"b", "bytes"]);
    }

    #[test]
    fn comments_and_chars_not_collected() {
        let src = "let c = 'x'; // \"not a literal\"\n";
        let lines = scan(src);
        assert!(lines[0].lits.is_empty());
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("a in_flight b", "in_flight"));
        assert!(!has_word("peak_in_flight_bytes", "in_flight_bytes"));
        assert!(has_word("x.in_flight_bytes", "in_flight_bytes"));
    }
}
