//! `sflint` — the in-repo determinism & accounting static-analysis pass.
//!
//! Every headline claim in this reproduction rests on bit-for-bit
//! determinism (parallel ≡ sequential, event+uniform ≡ lockstep, sweep
//! aggregates thread-invariant, CSR ≡ hashmap reference, sparse ≡ dense
//! dedup). Those guarantees are property-tested dynamically, but a
//! dynamic test can miss a nondeterministic path it never executes.
//! `sflint` is the static twin: a small line-oriented analysis (built on
//! the comment/string-aware lexer in [`scan`]) that forbids the source
//! patterns which historically cause silent nondeterminism or dropped
//! accounting. The image has no crate registry, so — like the vendored
//! `anyhow` shim — the scanner is hand-rolled rather than `syn`-based.
//!
//! Rules (see [`rules`] for the precise semantics):
//!
//! * `unordered-iter` — no iteration/drain over `HashMap`/`HashSet`
//!   bindings in result-bearing modules.
//! * `wall-clock` — `Instant::now`/`SystemTime` only in `util/{timer,bench}`
//!   or behind an allow.
//! * `thread-escape` — thread primitives only in `util/par`.
//! * `unsafe-audit` — every `unsafe` line needs its own adjacent
//!   `SAFETY:` comment.
//! * `accounting-conservation` — every `net::Accounting` field must be
//!   serialized, parsed, and consumed by the results pipeline (or carry
//!   an allow explaining why not).
//!
//! The v2 rules work cross-file, over a repo-wide symbol/reference
//! index ([`index`]) built from the same scanner output:
//!
//! * `wire-conservation` — every `Payload` variant has a `wire_bytes`
//!   arm, and every non-test construction site reaches a
//!   send/broadcast call.
//! * `rng-hygiene` — outside `rng/`, seeds fed to `Rng::new`/`fold_in`
//!   must be derived via `rng::mix`, never raw `seed ^ …` arithmetic.
//! * `cli-doc-drift` — every dispatched `--flag` appears in the
//!   main.rs help text and in EXPERIMENTS.md; every TOML key has a CLI
//!   counterpart.
//! * `json-parity` — `RunRecord::to_json` and `from_json` agree on the
//!   exact key set.
//! * `bench-ledger-drift` — every `BENCH_*.json` ledger key is emitted
//!   by a bench and its `--check` gate runs in CI.
//!
//! Findings are suppressed by an inline annotation written as a line
//! comment: the marker `sflint:` followed by `allow(<rule-name>,
//! reason = "<why this site is sound>")`. The reason is mandatory —
//! an annotation without one (or naming an unknown rule) is itself
//! reported as `invalid-allow`, which cannot be suppressed. An allow
//! covers its own line and the line directly below, so both trailing
//! comments and comment-above style work.
//!
//! Entry points: `seedflood lint [--root DIR] [--format text|json]
//! [--rule NAME]` or the standalone `sflint` binary. Exit codes: 0 for
//! a clean tree, 1 when unsuppressed findings exist, 2 on usage errors
//! (unknown format or rule name).

pub mod index;
pub mod rules;
pub mod scan;

use crate::util::cli::Args;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// The rule a finding or allow-annotation refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    UnorderedIter,
    WallClock,
    ThreadEscape,
    UnsafeAudit,
    AccountingConservation,
    WireConservation,
    RngHygiene,
    CliDocDrift,
    JsonParity,
    BenchLedgerDrift,
    /// Malformed allow annotation — reported, never suppressible.
    InvalidAllow,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnorderedIter => "unordered-iter",
            Rule::WallClock => "wall-clock",
            Rule::ThreadEscape => "thread-escape",
            Rule::UnsafeAudit => "unsafe-audit",
            Rule::AccountingConservation => "accounting-conservation",
            Rule::WireConservation => "wire-conservation",
            Rule::RngHygiene => "rng-hygiene",
            Rule::CliDocDrift => "cli-doc-drift",
            Rule::JsonParity => "json-parity",
            Rule::BenchLedgerDrift => "bench-ledger-drift",
            Rule::InvalidAllow => "invalid-allow",
        }
    }

    /// Rules that may be named in an allow annotation.
    pub fn from_name(s: &str) -> Option<Rule> {
        match s {
            "unordered-iter" => Some(Rule::UnorderedIter),
            "wall-clock" => Some(Rule::WallClock),
            "thread-escape" => Some(Rule::ThreadEscape),
            "unsafe-audit" => Some(Rule::UnsafeAudit),
            "accounting-conservation" => Some(Rule::AccountingConservation),
            "wire-conservation" => Some(Rule::WireConservation),
            "rng-hygiene" => Some(Rule::RngHygiene),
            "cli-doc-drift" => Some(Rule::CliDocDrift),
            "json-parity" => Some(Rule::JsonParity),
            "bench-ledger-drift" => Some(Rule::BenchLedgerDrift),
            _ => None,
        }
    }
}

/// One reported violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Repo-relative path, forward slashes.
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: {}: {}",
            self.path,
            self.line,
            self.rule.name(),
            self.msg
        )
    }
}

/// A parsed, well-formed allow annotation.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Line the annotation sits on; it suppresses this line and the next.
    pub line: usize,
    pub rule: Rule,
}

const ALLOW_MARKER: &str = "sflint: allow(";

/// Parse every allow annotation in a file's comment channel. Returns the
/// well-formed allows plus `invalid-allow` findings for malformed ones.
pub(crate) fn parse_allows(path: &str, lines: &[scan::Line]) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut findings = Vec::new();
    for line in lines {
        let mut from = 0usize;
        while let Some(rel) = line.comment[from..].find(ALLOW_MARKER) {
            let start = from + rel + ALLOW_MARKER.len();
            from = start;
            let rest = &line.comment[start..];
            match parse_allow_body(rest) {
                Ok(rule) => allows.push(Allow { line: line.number, rule }),
                Err(why) => findings.push(Finding {
                    path: path.to_string(),
                    line: line.number,
                    rule: Rule::InvalidAllow,
                    msg: why,
                }),
            }
        }
    }
    (allows, findings)
}

/// Parse `<rule>, reason = "<text>")` — the body following the marker.
fn parse_allow_body(rest: &str) -> Result<Rule, String> {
    let name_end = rest
        .find(|c: char| c == ',' || c == ')')
        .ok_or_else(|| "unterminated allow annotation".to_string())?;
    let name = rest[..name_end].trim();
    let rule = Rule::from_name(name)
        .ok_or_else(|| format!("unknown rule `{name}` in allow annotation"))?;
    if rest.as_bytes()[name_end] == b')' {
        return Err(format!(
            "allow({name}) is missing its mandatory `reason = \"...\"`"
        ));
    }
    let after = rest[name_end + 1..].trim_start();
    let after = after
        .strip_prefix("reason")
        .ok_or_else(|| format!("allow({name}) must give `reason = \"...\"` after the rule"))?
        .trim_start();
    let after = after
        .strip_prefix('=')
        .ok_or_else(|| format!("allow({name}): expected `=` after `reason`"))?
        .trim_start();
    let after = after
        .strip_prefix('"')
        .ok_or_else(|| format!("allow({name}): reason must be a quoted string"))?;
    let close = after
        .find('"')
        .ok_or_else(|| format!("allow({name}): unterminated reason string"))?;
    if after[..close].trim().is_empty() {
        return Err(format!(
            "allow({name}): reason must not be empty — say why the site is sound"
        ));
    }
    Ok(rule)
}

/// Result of a lint pass.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

/// Lint a set of in-memory files: `(repo-relative path, source)` pairs.
/// This is the seam the fixture tests drive; [`run_repo`] feeds it from
/// disk. Findings come back sorted by (path, line, rule).
pub fn lint_files(files: &[(String, String)]) -> Vec<Finding> {
    lint_files_with_docs(files, &[])
}

/// Like [`lint_files`], with non-Rust doc inputs (`EXPERIMENTS.md`,
/// `ci.yml`, `BENCH_*.json` ledgers) for the doc-coupled drift rules;
/// those rules opt out when their inputs are absent, so fixture sets
/// only engage what they provide.
pub fn lint_files_with_docs(files: &[(String, String)], docs: &[(String, String)]) -> Vec<Finding> {
    let scanned: Vec<(String, Vec<scan::Line>)> = files
        .iter()
        .map(|(path, src)| (path.clone(), scan::scan(src)))
        .collect();

    let mut findings = Vec::new();
    let mut allows_by_path: Vec<(&str, Vec<Allow>)> = Vec::new();
    for (path, lines) in &scanned {
        let (allows, invalid) = parse_allows(path, lines);
        findings.extend(invalid);
        findings.extend(rules::check_file(path, lines));
        allows_by_path.push((path.as_str(), allows));
    }
    findings.extend(rules::check_accounting(&scanned));
    let idx = index::RepoIndex::build(&scanned);
    findings.extend(rules::check_cross_file(&idx, docs));

    findings.retain(|f| {
        if f.rule == Rule::InvalidAllow {
            return true;
        }
        let allowed = allows_by_path
            .iter()
            .find(|(p, _)| *p == f.path)
            .map(|(_, allows)| {
                allows.iter().any(|a| {
                    a.rule == f.rule && (f.line == a.line || f.line == a.line + 1)
                })
            })
            .unwrap_or(false);
        !allowed
    });

    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule.name()).cmp(&(b.path.as_str(), b.line, b.rule.name()))
    });
    findings
}

/// Directories scanned relative to the repo root (when present).
const SCAN_ROOTS: &[&str] = &["rust/src", "rust/tests", "benches", "examples"];

/// Non-Rust inputs the doc-coupled drift rules read (when present).
const DOC_INPUTS: &[&str] = &[
    "EXPERIMENTS.md",
    ".github/workflows/ci.yml",
    "BENCH_scale.json",
    "BENCH_event.json",
    "BENCH_table4.json",
];

/// Lint the repository rooted at `root`. Errors if `root` does not look
/// like the seedflood repo (no `rust/src`).
pub fn run_repo(root: &Path) -> crate::Result<LintReport> {
    if !root.join("rust/src").is_dir() {
        anyhow::bail!(
            "sflint: `{}` has no rust/src — pass the repo root via --root",
            root.display()
        );
    }
    let mut paths: Vec<PathBuf> = Vec::new();
    for sub in SCAN_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut paths)?;
        }
    }
    let mut files: Vec<(String, String)> = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(p)?;
        files.push((rel, src));
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    let mut docs: Vec<(String, String)> = Vec::new();
    for rel in DOC_INPUTS {
        let p = root.join(rel);
        if p.is_file() {
            docs.push((rel.to_string(), fs::read_to_string(&p)?));
        }
    }
    Ok(LintReport {
        findings: lint_files_with_docs(&files, &docs),
        files_scanned: files.len(),
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// `seedflood lint [--root DIR] [--format text|json] [--rule NAME]` —
/// print findings, error when any exist so CI fails the build.
///
/// Exit codes: 0 clean, 1 unsuppressed findings, 2 usage error. The
/// JSON output is a stable array of objects with fields `rule`, `file`,
/// `line`, `message`, and `allow_hint` (the annotation that would
/// suppress the finding) — consumed by the CI annotation step.
pub fn cli_main(args: &Args) -> crate::Result<()> {
    let root = PathBuf::from(args.get_or("root", "."));
    let format = args.get_or("format", "text");
    if format != "text" && format != "json" {
        eprintln!("sflint: unknown --format `{format}` (expected `text` or `json`)");
        std::process::exit(2);
    }
    let rule_filter = match args.get("rule") {
        None => None,
        Some(name) => match Rule::from_name(name) {
            Some(r) => Some(r),
            None => {
                eprintln!("sflint: unknown rule `{name}` for --rule");
                std::process::exit(2);
            }
        },
    };

    let mut report = run_repo(&root)?;
    if let Some(rule) = rule_filter {
        // invalid-allow stays visible under any filter: a malformed
        // annotation can mask findings of the filtered rule itself.
        report
            .findings
            .retain(|f| f.rule == rule || f.rule == Rule::InvalidAllow);
    }

    if format == "json" {
        let arr: Vec<crate::util::json::Json> = report
            .findings
            .iter()
            .map(|f| {
                crate::util::json::Json::obj(vec![
                    ("rule", crate::util::json::Json::str(f.rule.name())),
                    ("file", crate::util::json::Json::str(&f.path)),
                    ("line", crate::util::json::Json::num(f.line as f64)),
                    ("message", crate::util::json::Json::str(&f.msg)),
                    (
                        "allow_hint",
                        crate::util::json::Json::str(&format!(
                            "// sflint: allow({}, reason = \"...\")",
                            f.rule.name()
                        )),
                    ),
                ])
            })
            .collect();
        println!("{}", crate::util::json::Json::Arr(arr).to_string_pretty());
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        if report.findings.is_empty() {
            println!("sflint: {} file(s) scanned, no findings", report.files_scanned);
        }
    }
    if report.findings.is_empty() {
        Ok(())
    } else {
        anyhow::bail!(
            "sflint: {} finding(s) in {} file(s) scanned",
            report.findings.len(),
            report.files_scanned
        )
    }
}
