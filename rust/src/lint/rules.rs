//! Rule implementations for `sflint`.
//!
//! All per-line rules match against the code channel produced by
//! [`super::scan`], so pattern text inside string literals or comments
//! never triggers a finding (which is also what lets this file define
//! the patterns as string constants and still scan itself cleanly).
//!
//! Scope conventions:
//! * `unordered-iter`, `wall-clock`, `thread-escape` apply to library
//!   code only (paths under `rust/src/`) and skip `#[cfg(test)] mod`
//!   regions — tests may time, thread, and hash-iterate freely.
//! * `unsafe-audit` applies to every scanned file including tests,
//!   benches and examples: a SAFETY argument is documentation, and
//!   documentation is owed everywhere.
//! * `accounting-conservation` is a cross-file structural check over
//!   the fixed trio net/mod.rs ↔ metrics/mod.rs ↔ sim/mod.rs; it is
//!   skipped when the trio is absent so fixture sets can opt in.

use super::scan::{find_word, has_word, Line};
use super::{Finding, Rule};

/// Modules whose output feeds reported results: any nondeterministic
/// iteration here can change a published number.
pub const RESULT_MODULES: &[&str] = &[
    "algos",
    "experiments",
    "flood",
    "net",
    "netcond",
    "sched",
    "sim",
    "topology",
];

/// The only library files allowed to read wall-clock time without an
/// allow annotation.
pub const WALLCLOCK_ALLOWED: &[&str] = &["rust/src/util/bench.rs", "rust/src/util/timer.rs"];

/// The only library file allowed to use thread primitives: everything
/// else must go through `util::par` so the parallel ≡ sequential
/// property has a single seam to guard.
pub const THREAD_ALLOWED: &[&str] = &["rust/src/util/par.rs"];

const WALLCLOCK_PATTERNS: &[&str] = &["Instant::now", "SystemTime"];
const THREAD_PATTERNS: &[&str] = &[
    "thread::spawn",
    "thread::scope",
    "thread::Builder",
    "rayon",
];
const HASH_TYPES: &[&str] = &["HashMap", "HashSet"];
/// Method calls that observe a collection in iteration order.
const ITER_SUFFIXES: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".drain(",
    ".keys(",
    ".values(",
    ".values_mut(",
];

/// Run every per-file rule on one scanned file.
pub fn check_file(path: &str, lines: &[Line]) -> Vec<Finding> {
    let mut out = Vec::new();
    check_unsafe_audit(path, lines, &mut out);
    if path.starts_with("rust/src/") {
        check_wall_clock(path, lines, &mut out);
        check_thread_escape(path, lines, &mut out);
        check_unordered_iter(path, lines, &mut out);
    }
    out
}

fn push(out: &mut Vec<Finding>, path: &str, line: usize, rule: Rule, msg: String) {
    out.push(Finding {
        path: path.to_string(),
        line,
        rule,
        msg,
    });
}

// ---------------------------------------------------------------- wall-clock

fn check_wall_clock(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if WALLCLOCK_ALLOWED.contains(&path) {
        return;
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        for pat in WALLCLOCK_PATTERNS {
            if has_word(&line.code, pat) {
                push(
                    out,
                    path,
                    line.number,
                    Rule::WallClock,
                    format!(
                        "wall-clock source `{pat}` outside util/timer|bench — \
                         timing may never feed results"
                    ),
                );
            }
        }
    }
}

// ------------------------------------------------------------- thread-escape

fn check_thread_escape(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if THREAD_ALLOWED.contains(&path) {
        return;
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        for pat in THREAD_PATTERNS {
            if has_word(&line.code, pat) {
                push(
                    out,
                    path,
                    line.number,
                    Rule::ThreadEscape,
                    format!(
                        "thread primitive `{pat}` outside util/par — all parallelism \
                         must go through the order-preserving par seam"
                    ),
                );
            }
        }
    }
}

// ------------------------------------------------------------ unordered-iter

/// True when `path` is inside one of [`RESULT_MODULES`].
fn in_result_module(path: &str) -> bool {
    let Some(rest) = path.strip_prefix("rust/src/") else {
        return false;
    };
    RESULT_MODULES.iter().any(|m| {
        rest.strip_prefix(m)
            .is_some_and(|r| r.starts_with('/') || r == ".rs")
    })
}

/// Byte offsets of every word-boundary occurrence of `needle` in `hay`.
fn word_positions(hay: &str, needle: &str) -> Vec<usize> {
    let mut at = Vec::new();
    let mut from = 0usize;
    while let Some(p) = find_word(&hay[from..], needle) {
        at.push(from + p);
        from += p + 1;
    }
    at
}

fn is_ident(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_alphanumeric() || c == '_')
        && !s.starts_with(|c: char| c.is_ascii_digit())
}

/// The identifier ending at the end of `s`, if any.
fn trailing_ident(s: &str) -> Option<String> {
    let end = s.len();
    let start = s
        .char_indices()
        .rev()
        .take_while(|(_, c)| c.is_alphanumeric() || *c == '_')
        .last()
        .map(|(i, _)| i)?;
    let id = &s[start..end];
    if is_ident(id) {
        Some(id.to_string())
    } else {
        None
    }
}

/// The identifier starting at the beginning of `s`, if any.
fn leading_ident(s: &str) -> Option<String> {
    let end = s
        .char_indices()
        .take_while(|(_, c)| c.is_alphanumeric() || *c == '_')
        .last()
        .map(|(i, c)| i + c.len_utf8())?;
    let id = &s[..end];
    if is_ident(id) {
        Some(id.to_string())
    } else {
        None
    }
}

/// Given a `HashMap`/`HashSet` type mention at `type_pos`, recover the
/// identifier it is declared for in `NAME: [&][mut] [path::]Hash…`
/// (struct fields, fn params, let-with-ascription).
fn binding_before_type(code: &str, type_pos: usize) -> Option<String> {
    let mut b = code[..type_pos].trim_end();
    // Strip a leading type path such as `std::collections::`.
    while b.ends_with("::") {
        b = b[..b.len() - 2].trim_end();
        b = b.trim_end_matches(|c: char| c.is_alphanumeric() || c == '_');
        b = b.trim_end();
    }
    b = b.trim_end_matches(['&', '<', '(']).trim_end();
    if let Some(stripped) = b.strip_suffix("mut") {
        b = stripped.trim_end().trim_end_matches('&').trim_end();
    }
    let b = b.strip_suffix(':')?;
    // `::` would have been consumed above, so a remaining ':' suffix
    // means this really was an ascription, not a path.
    if b.ends_with(':') {
        return None;
    }
    trailing_ident(b.trim_end())
}

/// Names bound to hash collections anywhere in the (non-test) file.
fn tracked_hash_bindings(lines: &[Line]) -> Vec<String> {
    let mut tracked: Vec<String> = Vec::new();
    for line in lines {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let mut mentions = Vec::new();
        for t in HASH_TYPES {
            mentions.extend(word_positions(code, t));
        }
        if mentions.is_empty() {
            continue;
        }
        // `let [mut] NAME = …HashMap…` — NAME now holds a hash collection.
        if let Some(p) = find_word(code, "let") {
            let rest = code[p + 3..].trim_start();
            let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
            if let Some(name) = leading_ident(rest) {
                tracked.push(name);
            }
        }
        // `NAME: HashMap<…>` — field, param, or ascribed binding.
        for p in mentions {
            if let Some(name) = binding_before_type(code, p) {
                tracked.push(name);
            }
        }
    }
    tracked.sort();
    tracked.dedup();
    tracked
}

fn check_unordered_iter(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if !in_result_module(path) {
        return;
    }
    let tracked = tracked_hash_bindings(lines);
    if tracked.is_empty() {
        return;
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        for name in &tracked {
            let mut hit = false;
            for suf in ITER_SUFFIXES {
                if has_word(code, &format!("{name}{suf}")) {
                    hit = true;
                    break;
                }
            }
            // `for x in [&[mut ]]NAME {` — direct loop over the collection.
            if !hit && has_word(code, "for") {
                if let Some(p) = find_word(code, "in") {
                    let rest = code[p + 2..].trim_end();
                    let rest = rest.trim_end_matches('{').trim_end();
                    let boundary_ok = rest.strip_suffix(name.as_str()).is_some_and(|r| {
                        r.is_empty() || r.ends_with(|c: char| !c.is_alphanumeric() && c != '_')
                    });
                    if boundary_ok {
                        hit = true;
                    }
                }
            }
            if hit {
                push(
                    out,
                    path,
                    line.number,
                    Rule::UnorderedIter,
                    format!(
                        "iteration over unordered hash collection `{name}` in a \
                         result-bearing module — order can differ between runs; \
                         use BTreeMap/BTreeSet, sort first, or allow with a reason \
                         if the sink is order-insensitive"
                    ),
                );
                break; // one finding per line is enough
            }
        }
    }
}

// -------------------------------------------------------------- unsafe-audit

fn check_unsafe_audit(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    for (idx, line) in lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        if line.comment.contains("SAFETY:") {
            continue; // same-line trailing safety comment
        }
        // Walk upward through contiguous comment-only lines.
        let mut justified = false;
        let mut j = idx;
        while j > 0 {
            j -= 1;
            let prev = &lines[j];
            let is_comment_only = prev.code.trim().is_empty() && !prev.comment.trim().is_empty();
            if !is_comment_only {
                break;
            }
            if prev.comment.contains("SAFETY:") {
                justified = true;
                break;
            }
        }
        if !justified {
            push(
                out,
                path,
                line.number,
                Rule::UnsafeAudit,
                "`unsafe` without its own immediately-preceding `// SAFETY:` comment \
                 — every unsafe site must argue its soundness adjacent to the code"
                    .to_string(),
            );
        }
    }
}

// --------------------------------------------------- accounting-conservation

/// The fixed file trio the conservation rule audits.
pub const ACCT_FILE: &str = "rust/src/net/mod.rs";
pub const RECORD_FILE: &str = "rust/src/metrics/mod.rs";
pub const CONSUME_FILE: &str = "rust/src/sim/mod.rs";

struct StructInfo {
    decl_line: usize,
    derives_default: bool,
    /// (field name, 1-based declaration line)
    fields: Vec<(String, usize)>,
}

/// Parse a `struct <name>` declaration: derive list and public fields.
fn parse_struct(lines: &[Line], name: &str) -> Option<StructInfo> {
    let decl_idx = lines
        .iter()
        .position(|l| has_word(&l.code, "struct") && has_word(&l.code, name))?;

    // Derives: contiguous attribute lines directly above the declaration.
    let mut derives_default = false;
    let mut j = decl_idx;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let comment_only = code.is_empty() && !lines[j].comment.trim().is_empty();
        if code.starts_with("#[") {
            if code.contains("derive") && has_word(code, "Default") {
                derives_default = true;
            }
        } else if !comment_only {
            break;
        }
    }

    let mut fields = Vec::new();
    let mut depth = 0i32;
    let mut started = false;
    for line in &lines[decl_idx..] {
        let depth_at_start = depth;
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth_at_start == 1 {
            let t = line.code.trim();
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some(colon) = rest.find(':') {
                    let fname = rest[..colon].trim();
                    if is_ident(fname) {
                        fields.push((fname.to_string(), line.number));
                    }
                }
            }
        }
        if started && depth <= 0 {
            break;
        }
    }

    Some(StructInfo {
        decl_line: lines[decl_idx].number,
        derives_default,
        fields,
    })
}

/// Index (inclusive) of the line closing the brace block opened at or
/// after `start`.
fn region_end(lines: &[Line], start: usize) -> usize {
    let mut depth = 0i32;
    let mut started = false;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return i;
        }
    }
    lines.len().saturating_sub(1)
}

/// Raw text of `fn <fn_name>` inside any `impl <type_name>` block.
fn fn_body_text(lines: &[Line], type_name: &str, fn_name: &str) -> String {
    for (i, line) in lines.iter().enumerate() {
        if !(has_word(&line.code, "impl") && has_word(&line.code, type_name)) {
            continue;
        }
        let end = region_end(lines, i);
        let mut j = i + 1;
        while j <= end {
            if has_word(&lines[j].code, "fn") && has_word(&lines[j].code, fn_name) {
                let fend = region_end(lines, j);
                let mut body = String::new();
                for l in &lines[j..=fend.min(end)] {
                    body.push_str(&l.raw);
                    body.push('\n');
                }
                return body;
            }
            j += 1;
        }
    }
    String::new()
}

/// Cross-file conservation audit: every `Accounting` field must flow
/// into the results pipeline — a same-named `RunRecord` mirror that is
/// serialized by `to_json`, parsed by `from_json`, and filled from
/// `acct.<field>` in sim — or carry an allow saying how it is consumed.
/// The reset leg is `Accounting: Default` (sim builds a fresh `Network`,
/// hence fresh zeroed counters, per run).
pub fn check_accounting(files: &[(String, Vec<Line>)]) -> Vec<Finding> {
    let get = |p: &str| {
        files
            .iter()
            .find(|(q, _)| q == p)
            .map(|(_, l)| l.as_slice())
    };
    let (Some(net), Some(metrics), Some(sim)) =
        (get(ACCT_FILE), get(RECORD_FILE), get(CONSUME_FILE))
    else {
        return Vec::new(); // fixture set without the trio: rule opts out
    };

    let mut out = Vec::new();
    let Some(acct) = parse_struct(net, "Accounting") else {
        push(
            &mut out,
            ACCT_FILE,
            1,
            Rule::AccountingConservation,
            "could not locate `struct Accounting`".to_string(),
        );
        return out;
    };
    if !acct.derives_default {
        push(
            &mut out,
            ACCT_FILE,
            acct.decl_line,
            Rule::AccountingConservation,
            "Accounting must derive Default — Network::new zero-fills it, which is \
             the per-run reset leg of conservation"
                .to_string(),
        );
    }

    let record_fields: Vec<String> = parse_struct(metrics, "RunRecord")
        .map(|s| s.fields.into_iter().map(|(n, _)| n).collect())
        .unwrap_or_default();
    let to_json = fn_body_text(metrics, "RunRecord", "to_json");
    let from_json = fn_body_text(metrics, "RunRecord", "from_json");
    let sim_raw: String = sim.iter().map(|l| l.raw.as_str()).collect::<Vec<_>>().join("\n");

    for (name, line) in &acct.fields {
        if record_fields.iter().any(|f| f == name) {
            let mut missing = Vec::new();
            if !has_word(&to_json, name) {
                missing.push("RunRecord::to_json");
            }
            if !has_word(&from_json, name) {
                missing.push("RunRecord::from_json");
            }
            if !has_word(&sim_raw, &format!("acct.{name}")) {
                missing.push("sim (no `acct.<field>` consumption)");
            }
            if !missing.is_empty() {
                push(
                    &mut out,
                    ACCT_FILE,
                    *line,
                    Rule::AccountingConservation,
                    format!(
                        "Accounting field `{name}` is mirrored by RunRecord but not \
                         covered by: {}",
                        missing.join(", ")
                    ),
                );
            }
        } else {
            push(
                &mut out,
                ACCT_FILE,
                *line,
                Rule::AccountingConservation,
                format!(
                    "Accounting field `{name}` has no same-named RunRecord mirror — \
                     new counters must reach the results pipeline (to_json/from_json/\
                     sim consumption) or carry an allow explaining how they are \
                     consumed"
                ),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{lint_files, parse_allows, scan};

    fn lint_one(path: &str, src: &str) -> Vec<Finding> {
        lint_files(&[(path.to_string(), src.to_string())])
    }

    fn rules_of(findings: &[Finding]) -> Vec<Rule> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---------------------------------------------------------- wall-clock

    #[test]
    fn wall_clock_flagged_in_lib_code() {
        let f = lint_one(
            "rust/src/algos/x.rs",
            "fn f() { let t = std::time::Instant::now(); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::WallClock]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn wall_clock_system_time_flagged() {
        let f = lint_one(
            "rust/src/sim/x.rs",
            "use std::time::SystemTime;\nfn f() { let t = SystemTime::now(); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::WallClock, Rule::WallClock]);
    }

    #[test]
    fn wall_clock_clean_in_timer_bench_tests_and_nonlib() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert!(lint_one("rust/src/util/timer.rs", src).is_empty());
        assert!(lint_one("rust/src/util/bench.rs", src).is_empty());
        assert!(lint_one("benches/x.rs", src).is_empty());
        let in_test = "#[cfg(test)]\n\
                       mod tests {\n    \
                       fn f() { let t = std::time::Instant::now(); }\n\
                       }\n";
        assert!(lint_one("rust/src/algos/x.rs", in_test).is_empty());
    }

    #[test]
    fn wall_clock_allow_with_reason_suppresses() {
        let src = "// sflint: allow(wall-clock, reason = \"fixture timing\")\n\
                   fn f() { let t = std::time::Instant::now(); }\n";
        assert!(lint_one("rust/src/algos/x.rs", src).is_empty());
        let trailing = "fn f() { let t = std::time::Instant::now(); } // sflint: allow(wall-clock, \
                        reason = \"fixture timing\")\n";
        assert!(lint_one("rust/src/algos/x.rs", trailing).is_empty());
    }

    #[test]
    fn wall_clock_allow_without_reason_rejected() {
        let src = "// sflint: allow(wall-clock)\nfn f() { let t = std::time::Instant::now(); }\n";
        let f = lint_one("rust/src/algos/x.rs", src);
        // The malformed allow is reported AND does not suppress.
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::WallClock]);
    }

    #[test]
    fn allow_with_empty_reason_rejected() {
        let src = "// sflint: allow(wall-clock, reason = \"\")\n\
                   fn f() { let t = std::time::Instant::now(); }\n";
        let f = lint_one("rust/src/algos/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::WallClock]);
    }

    #[test]
    fn allow_with_unknown_rule_rejected() {
        let lines = scan::scan("// sflint: allow(no-such-rule, reason = \"x\")\n");
        let (allows, invalid) = parse_allows("rust/src/algos/x.rs", &lines);
        assert!(allows.is_empty());
        assert_eq!(invalid.len(), 1);
        assert!(invalid[0].msg.contains("unknown rule"));
    }

    #[test]
    fn allow_only_covers_its_rule() {
        // A wall-clock allow must not suppress a thread-escape finding.
        let src = "// sflint: allow(wall-clock, reason = \"fixture\")\n\
                   fn f() { std::thread::spawn(|| {}); }\n";
        let f = lint_one("rust/src/sim/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::ThreadEscape]);
    }

    // ------------------------------------------------------- thread-escape

    #[test]
    fn thread_escape_flagged_outside_par() {
        let f = lint_one("rust/src/sim/x.rs", "fn f() { std::thread::spawn(|| {}); }\n");
        assert_eq!(rules_of(&f), vec![Rule::ThreadEscape]);
        let f = lint_one("rust/src/flood/x.rs", "fn f() { std::thread::scope(|s| {}); }\n");
        assert_eq!(rules_of(&f), vec![Rule::ThreadEscape]);
    }

    #[test]
    fn thread_escape_clean_in_par_and_tests() {
        let src = "fn f() { std::thread::scope(|s| {}); }\n";
        assert!(lint_one("rust/src/util/par.rs", src).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n    fn f() { std::thread::spawn(|| {}); }\n}\n";
        assert!(lint_one("rust/src/util/timer.rs", in_test).is_empty());
    }

    #[test]
    fn thread_escape_allow_with_reason_suppresses() {
        let src = "// sflint: allow(thread-escape, reason = \"fixture\")\n\
                   fn f() { std::thread::spawn(|| {}); }\n";
        assert!(lint_one("rust/src/sim/x.rs", src).is_empty());
    }

    // ------------------------------------------------------ unordered-iter

    #[test]
    fn unordered_iter_flags_method_iteration() {
        let src = "use std::collections::HashMap;\n\
                   fn f() {\n    \
                   let mut m: HashMap<u32, u32> = HashMap::new();\n    \
                   let s: u32 = m.keys().sum();\n\
                   }\n";
        let f = lint_one("rust/src/flood/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnorderedIter]);
        assert_eq!(f[0].line, 4);
        assert!(f[0].msg.contains('m'));
    }

    #[test]
    fn unordered_iter_flags_for_loop() {
        let src = "use std::collections::HashSet;\n\
                   fn f(seen: &HashSet<u64>) {\n    \
                   for x in seen {\n        \
                   sink(x);\n    \
                   }\n\
                   }\n";
        let f = lint_one("rust/src/net/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnorderedIter]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unordered_iter_flags_drain_on_field() {
        let src = "use std::collections::HashMap;\n\
                   struct S { pending: HashMap<u64, u64> }\n\
                   impl S {\n    \
                   fn f(&mut self) {\n        \
                   for (k, v) in self.pending.drain() {\n            \
                   sink(k, v);\n        \
                   }\n    \
                   }\n\
                   }\n";
        let f = lint_one("rust/src/sched/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnorderedIter]);
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn unordered_iter_clean_cases() {
        // Order-insensitive use: membership tests only.
        let contains = "use std::collections::HashSet;\n\
                        fn f(seen: &HashSet<u64>, x: u64) -> bool { seen.contains(&x) }\n";
        assert!(lint_one("rust/src/flood/x.rs", contains).is_empty());
        // Ordered collection.
        let btree = "use std::collections::BTreeMap;\n\
                     fn f(m: &BTreeMap<u32, u32>) -> u32 { m.keys().sum() }\n";
        assert!(lint_one("rust/src/algos/x.rs", btree).is_empty());
        // Outside result-bearing modules.
        let util = "use std::collections::HashMap;\n\
                    fn f(m: &HashMap<u32, u32>) -> u32 { m.keys().sum() }\n";
        assert!(lint_one("rust/src/util/x.rs", util).is_empty());
        // Inside #[cfg(test)].
        let in_test = "use std::collections::HashSet;\n\
                       #[cfg(test)]\n\
                       mod tests {\n    \
                       fn f(s: &HashSet<u64>) { for x in s { sink(x); } }\n\
                       }\n";
        assert!(lint_one("rust/src/topology/x.rs", in_test).is_empty());
    }

    #[test]
    fn unordered_iter_allow_with_reason_suppresses() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n    \
                   // sflint: allow(unordered-iter, reason = \"sum is order-insensitive\")\n    \
                   m.values().sum()\n\
                   }\n";
        assert!(lint_one("rust/src/flood/x.rs", src).is_empty());
    }

    #[test]
    fn unordered_iter_allow_without_reason_rejected() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n    \
                   // sflint: allow(unordered-iter)\n    \
                   m.values().sum()\n\
                   }\n";
        let f = lint_one("rust/src/flood/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::UnorderedIter]);
    }

    // -------------------------------------------------------- unsafe-audit

    #[test]
    fn unsafe_audit_flags_bare_unsafe() {
        let f = lint_one("rust/src/runtime/x.rs", "unsafe impl Send for X {}\n");
        assert_eq!(rules_of(&f), vec![Rule::UnsafeAudit]);
    }

    #[test]
    fn unsafe_audit_applies_in_tests_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let p = unsafe { danger() }; }\n}\n";
        let f = lint_one("rust/tests/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnsafeAudit]);
    }

    #[test]
    fn unsafe_audit_satisfied_by_adjacent_safety() {
        let above = "// SAFETY: fixture justification\nunsafe impl Send for X {}\n";
        assert!(lint_one("rust/src/runtime/x.rs", above).is_empty());
        let trailing = "unsafe impl Send for X {} // SAFETY: fixture justification\n";
        assert!(lint_one("rust/src/runtime/x.rs", trailing).is_empty());
        let multi = "// SAFETY: part one\n// continues here\nunsafe impl Send for X {}\n";
        assert!(lint_one("rust/src/runtime/x.rs", multi).is_empty());
    }

    #[test]
    fn unsafe_audit_requires_one_comment_per_impl() {
        // One shared SAFETY comment must NOT cover a second impl below it.
        let src = "// SAFETY: covers only the next line\n\
                   unsafe impl Send for X {}\n\
                   unsafe impl Sync for X {}\n";
        let f = lint_one("rust/src/runtime/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnsafeAudit]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unsafe_audit_allow_with_reason_suppresses() {
        let src = "// sflint: allow(unsafe-audit, reason = \"fixture\")\n\
                   unsafe impl Send for X {}\n";
        assert!(lint_one("rust/src/runtime/x.rs", src).is_empty());
    }

    // ------------------------------------------- accounting-conservation

    fn net_fixture(extra_field: &str) -> String {
        format!(
            "#[derive(Clone, Debug, Default)]\n\
             pub struct Accounting {{\n    \
             pub total_bytes: u64,\n\
             {extra_field}}}\n"
        )
    }

    const METRICS_FIXTURE: &str = "pub struct RunRecord {\n    \
                                   pub total_bytes: u64,\n\
                                   }\n\
                                   impl RunRecord {\n    \
                                   pub fn to_json(&self) -> String {\n        \
                                   format!(\"total_bytes={}\", self.total_bytes)\n    \
                                   }\n    \
                                   pub fn from_json(s: &str) -> Self {\n        \
                                   let total_bytes = parse(s);\n        \
                                   RunRecord { total_bytes }\n    \
                                   }\n\
                                   }\n";

    const SIM_FIXTURE: &str = "pub fn finalize(net: &Network, rec: &mut RunRecord) {\n    \
                               rec.total_bytes = net.acct.total_bytes;\n\
                               }\n";

    fn trio(net: String, metrics: &str, sim: &str) -> Vec<(String, String)> {
        vec![
            (ACCT_FILE.to_string(), net),
            (RECORD_FILE.to_string(), metrics.to_string()),
            (CONSUME_FILE.to_string(), sim.to_string()),
        ]
    }

    #[test]
    fn accounting_covered_field_passes() {
        let files = trio(net_fixture(""), METRICS_FIXTURE, SIM_FIXTURE);
        assert!(lint_files(&files).is_empty());
    }

    #[test]
    fn accounting_uncovered_new_field_fails() {
        let files = trio(
            net_fixture("    pub new_gauge: u64,\n"),
            METRICS_FIXTURE,
            SIM_FIXTURE,
        );
        let f = lint_files(&files);
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("new_gauge"));
        assert_eq!(f[0].path, ACCT_FILE);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn accounting_missing_from_json_leg_fails() {
        // Mirror exists but from_json never reads the key.
        let metrics = "pub struct RunRecord {\n    \
                       pub total_bytes: u64,\n\
                       }\n\
                       impl RunRecord {\n    \
                       pub fn to_json(&self) -> String {\n        \
                       format!(\"total_bytes={}\", self.total_bytes)\n    \
                       }\n    \
                       pub fn from_json(s: &str) -> Self {\n        \
                       todo!()\n    \
                       }\n\
                       }\n";
        let f = lint_files(&trio(net_fixture(""), metrics, SIM_FIXTURE));
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("from_json"));
    }

    #[test]
    fn accounting_missing_sim_consumption_fails() {
        let sim = "pub fn finalize(net: &Network, rec: &mut RunRecord) {}\n";
        let f = lint_files(&trio(net_fixture(""), METRICS_FIXTURE, sim));
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("sim"));
    }

    #[test]
    fn accounting_missing_default_derive_fails() {
        let net = "#[derive(Clone, Debug)]\n\
                   pub struct Accounting {\n    \
                   pub total_bytes: u64,\n\
                   }\n";
        let f = lint_files(&trio(net.to_string(), METRICS_FIXTURE, SIM_FIXTURE));
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("Default"));
    }

    #[test]
    fn accounting_allow_with_reason_suppresses() {
        let net = net_fixture(
            "    // sflint: allow(accounting-conservation, reason = \"fixture gauge, consumed via \
             peak\")\n    \
             pub new_gauge: u64,\n",
        );
        assert!(lint_files(&trio(net, METRICS_FIXTURE, SIM_FIXTURE)).is_empty());
    }

    #[test]
    fn accounting_skipped_without_the_trio() {
        // A fixture set without net/metrics/sim must not fire the rule.
        assert!(lint_one("rust/src/flood/x.rs", "fn f() {}\n").is_empty());
    }

    // ------------------------------------------------------- repo self-run

    #[test]
    fn repo_tree_is_clean() {
        // cargo test runs with cwd = package root.
        let report = crate::lint::run_repo(std::path::Path::new(".")).expect("repo scan");
        assert!(report.files_scanned >= 40, "scanned {}", report.files_scanned);
        let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
        assert!(rendered.is_empty(), "tree findings:\n{}", rendered.join("\n"));
    }
}
