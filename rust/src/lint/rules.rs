//! Rule implementations for `sflint`.
//!
//! All per-line rules match against the code channel produced by
//! [`super::scan`], so pattern text inside string literals or comments
//! never triggers a finding (which is also what lets this file define
//! the patterns as string constants and still scan itself cleanly).
//!
//! Scope conventions:
//! * `unordered-iter`, `wall-clock`, `thread-escape` apply to library
//!   code only (paths under `rust/src/`) and skip `#[cfg(test)] mod`
//!   regions — tests may time, thread, and hash-iterate freely.
//! * `rng-hygiene` applies to library code outside `rust/src/rng/`
//!   (the mixer itself may do raw seed arithmetic) and skips test
//!   regions.
//! * `unsafe-audit` applies to every scanned file including tests,
//!   benches and examples: a SAFETY argument is documentation, and
//!   documentation is owed everywhere.
//! * `accounting-conservation` is a cross-file structural check over
//!   the fixed trio net/mod.rs ↔ metrics/mod.rs ↔ sim/mod.rs; it is
//!   skipped when the trio is absent so fixture sets can opt in.
//! * `wire-conservation` and `json-parity` anchor on net/mod.rs and
//!   metrics/mod.rs respectively and opt out the same way (no
//!   `enum Payload` / no `RunRecord` json pair present → skipped).
//! * `cli-doc-drift` and `bench-ledger-drift` additionally consume the
//!   non-Rust doc inputs (`EXPERIMENTS.md`, CI workflow, `BENCH_*.json`
//!   ledgers) threaded through [`super::lint_files_with_docs`]; they
//!   opt out when those inputs are absent.

use super::index::{self, RepoIndex};
use super::scan::{find_word, has_word, Line};
use super::{Finding, Rule};

/// Modules whose output feeds reported results: any nondeterministic
/// iteration here can change a published number.
pub const RESULT_MODULES: &[&str] = &[
    "algos",
    "experiments",
    "flood",
    "net",
    "netcond",
    "sched",
    "sim",
    "topology",
];

/// The only library files allowed to read wall-clock time without an
/// allow annotation.
pub const WALLCLOCK_ALLOWED: &[&str] = &["rust/src/util/bench.rs", "rust/src/util/timer.rs"];

/// The only library file allowed to use thread primitives: everything
/// else must go through `util::par` so the parallel ≡ sequential
/// property has a single seam to guard.
pub const THREAD_ALLOWED: &[&str] = &["rust/src/util/par.rs"];

const WALLCLOCK_PATTERNS: &[&str] = &["Instant::now", "SystemTime"];
const THREAD_PATTERNS: &[&str] = &[
    "thread::spawn",
    "thread::scope",
    "thread::Builder",
    "rayon",
];
const HASH_TYPES: &[&str] = &["HashMap", "HashSet"];
/// Method calls that observe a collection in iteration order.
const ITER_SUFFIXES: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".drain(",
    ".keys(",
    ".values(",
    ".values_mut(",
];

/// Run every per-file rule on one scanned file.
pub fn check_file(path: &str, lines: &[Line]) -> Vec<Finding> {
    let mut out = Vec::new();
    check_unsafe_audit(path, lines, &mut out);
    if path.starts_with("rust/src/") {
        check_wall_clock(path, lines, &mut out);
        check_thread_escape(path, lines, &mut out);
        check_unordered_iter(path, lines, &mut out);
        check_rng_hygiene(path, lines, &mut out);
    }
    out
}

/// Run every cross-file rule over the repo index and doc inputs.
pub fn check_cross_file(idx: &RepoIndex, docs: &[(String, String)]) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(check_wire_conservation(idx));
    out.extend(check_json_parity(idx));
    out.extend(check_cli_doc_drift(idx, docs));
    out.extend(check_bench_ledger_drift(idx, docs));
    out
}

fn push(out: &mut Vec<Finding>, path: &str, line: usize, rule: Rule, msg: String) {
    out.push(Finding {
        path: path.to_string(),
        line,
        rule,
        msg,
    });
}

// ---------------------------------------------------------------- wall-clock

fn check_wall_clock(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if WALLCLOCK_ALLOWED.contains(&path) {
        return;
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        for pat in WALLCLOCK_PATTERNS {
            if has_word(&line.code, pat) {
                push(
                    out,
                    path,
                    line.number,
                    Rule::WallClock,
                    format!(
                        "wall-clock source `{pat}` outside util/timer|bench — \
                         timing may never feed results"
                    ),
                );
            }
        }
    }
}

// ------------------------------------------------------------- thread-escape

fn check_thread_escape(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if THREAD_ALLOWED.contains(&path) {
        return;
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        for pat in THREAD_PATTERNS {
            if has_word(&line.code, pat) {
                push(
                    out,
                    path,
                    line.number,
                    Rule::ThreadEscape,
                    format!(
                        "thread primitive `{pat}` outside util/par — all parallelism \
                         must go through the order-preserving par seam"
                    ),
                );
            }
        }
    }
}

// ------------------------------------------------------------ unordered-iter

/// True when `path` is inside one of [`RESULT_MODULES`].
fn in_result_module(path: &str) -> bool {
    let Some(rest) = path.strip_prefix("rust/src/") else {
        return false;
    };
    RESULT_MODULES.iter().any(|m| {
        rest.strip_prefix(m)
            .is_some_and(|r| r.starts_with('/') || r == ".rs")
    })
}

/// Byte offsets of every word-boundary occurrence of `needle` in `hay`.
fn word_positions(hay: &str, needle: &str) -> Vec<usize> {
    let mut at = Vec::new();
    let mut from = 0usize;
    while let Some(p) = find_word(&hay[from..], needle) {
        at.push(from + p);
        from += p + 1;
    }
    at
}

pub(crate) fn is_ident(s: &str) -> bool {
    !s.is_empty()
        && s.chars().all(|c| c.is_alphanumeric() || c == '_')
        && !s.starts_with(|c: char| c.is_ascii_digit())
}

/// The identifier ending at the end of `s`, if any.
pub(crate) fn trailing_ident(s: &str) -> Option<String> {
    let end = s.len();
    let start = s
        .char_indices()
        .rev()
        .take_while(|(_, c)| c.is_alphanumeric() || *c == '_')
        .last()
        .map(|(i, _)| i)?;
    let id = &s[start..end];
    if is_ident(id) {
        Some(id.to_string())
    } else {
        None
    }
}

/// The identifier starting at the beginning of `s`, if any.
pub(crate) fn leading_ident(s: &str) -> Option<String> {
    let end = s
        .char_indices()
        .take_while(|(_, c)| c.is_alphanumeric() || *c == '_')
        .last()
        .map(|(i, c)| i + c.len_utf8())?;
    let id = &s[..end];
    if is_ident(id) {
        Some(id.to_string())
    } else {
        None
    }
}

/// Given a `HashMap`/`HashSet` type mention at `type_pos`, recover the
/// identifier it is declared for in `NAME: [&][mut] [path::]Hash…`
/// (struct fields, fn params, let-with-ascription).
fn binding_before_type(code: &str, type_pos: usize) -> Option<String> {
    let mut b = code[..type_pos].trim_end();
    // Strip a leading type path such as `std::collections::`.
    while b.ends_with("::") {
        b = b[..b.len() - 2].trim_end();
        b = b.trim_end_matches(|c: char| c.is_alphanumeric() || c == '_');
        b = b.trim_end();
    }
    b = b.trim_end_matches(['&', '<', '(']).trim_end();
    if let Some(stripped) = b.strip_suffix("mut") {
        b = stripped.trim_end().trim_end_matches('&').trim_end();
    }
    let b = b.strip_suffix(':')?;
    // `::` would have been consumed above, so a remaining ':' suffix
    // means this really was an ascription, not a path.
    if b.ends_with(':') {
        return None;
    }
    trailing_ident(b.trim_end())
}

/// Names bound to hash collections anywhere in the (non-test) file.
fn tracked_hash_bindings(lines: &[Line]) -> Vec<String> {
    let mut tracked: Vec<String> = Vec::new();
    for line in lines {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        let mut mentions = Vec::new();
        for t in HASH_TYPES {
            mentions.extend(word_positions(code, t));
        }
        if mentions.is_empty() {
            continue;
        }
        // `let [mut] NAME = …HashMap…` — NAME now holds a hash collection.
        if let Some(p) = find_word(code, "let") {
            let rest = code[p + 3..].trim_start();
            let rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
            if let Some(name) = leading_ident(rest) {
                tracked.push(name);
            }
        }
        // `NAME: HashMap<…>` — field, param, or ascribed binding.
        for p in mentions {
            if let Some(name) = binding_before_type(code, p) {
                tracked.push(name);
            }
        }
    }
    tracked.sort();
    tracked.dedup();
    tracked
}

fn check_unordered_iter(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if !in_result_module(path) {
        return;
    }
    let tracked = tracked_hash_bindings(lines);
    if tracked.is_empty() {
        return;
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        for name in &tracked {
            let mut hit = false;
            for suf in ITER_SUFFIXES {
                if has_word(code, &format!("{name}{suf}")) {
                    hit = true;
                    break;
                }
            }
            // `for x in [&[mut ]]NAME {` — direct loop over the collection.
            if !hit && has_word(code, "for") {
                if let Some(p) = find_word(code, "in") {
                    let rest = code[p + 2..].trim_end();
                    let rest = rest.trim_end_matches('{').trim_end();
                    let boundary_ok = rest.strip_suffix(name.as_str()).is_some_and(|r| {
                        r.is_empty() || r.ends_with(|c: char| !c.is_alphanumeric() && c != '_')
                    });
                    if boundary_ok {
                        hit = true;
                    }
                }
            }
            if hit {
                push(
                    out,
                    path,
                    line.number,
                    Rule::UnorderedIter,
                    format!(
                        "iteration over unordered hash collection `{name}` in a \
                         result-bearing module — order can differ between runs; \
                         use BTreeMap/BTreeSet, sort first, or allow with a reason \
                         if the sink is order-insensitive"
                    ),
                );
                break; // one finding per line is enough
            }
        }
    }
}

// -------------------------------------------------------------- unsafe-audit

fn check_unsafe_audit(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    for (idx, line) in lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        if line.comment.contains("SAFETY:") {
            continue; // same-line trailing safety comment
        }
        // Walk upward through contiguous comment-only lines.
        let mut justified = false;
        let mut j = idx;
        while j > 0 {
            j -= 1;
            let prev = &lines[j];
            let is_comment_only = prev.code.trim().is_empty() && !prev.comment.trim().is_empty();
            if !is_comment_only {
                break;
            }
            if prev.comment.contains("SAFETY:") {
                justified = true;
                break;
            }
        }
        if !justified {
            push(
                out,
                path,
                line.number,
                Rule::UnsafeAudit,
                "`unsafe` without its own immediately-preceding `// SAFETY:` comment \
                 — every unsafe site must argue its soundness adjacent to the code"
                    .to_string(),
            );
        }
    }
}

// --------------------------------------------------------------- rng-hygiene

/// Seed sinks that apply no input mixing of their own: a raw
/// `seed ^ label` fed here gives correlated streams for nearby labels
/// (the PR 4 sampler bug). `Rng::fold_in` is itself a mixer with a
/// decorrelation draw, so literal stream labels (`seed ^ 0x10AA`) are
/// fine there — but deriving by another *variable* (`seed ^ i`) is the
/// exact adjacent-stream correlation the mixer exists to prevent.
const RNG_RAW_SINKS: &[&str] = &["Rng::new", "BatchSampler::new"];

fn check_rng_hygiene(path: &str, lines: &[Line], out: &mut Vec<Finding>) {
    if path.starts_with("rust/src/rng/") {
        return; // the mixer itself does raw seed arithmetic
    }
    for line in lines {
        if line.in_test {
            continue;
        }
        let code = &line.code;
        for sink in RNG_RAW_SINKS {
            for p in word_positions(code, sink) {
                let open = code[..p + sink.len()].chars().count();
                let span = index::call_arg_span(code, open);
                if span.contains('^') && !span.contains("mix(") {
                    push(
                        out,
                        path,
                        line.number,
                        Rule::RngHygiene,
                        format!(
                            "raw `seed ^ …` fed to `{sink}` — xor of a label or index \
                             gives correlated streams for nearby inputs; derive the \
                             seed via `rng::mix(seed, label)` instead"
                        ),
                    );
                }
            }
        }
        for p in word_positions(code, "fold_in") {
            let open = code[..p + "fold_in".len()].chars().count();
            let span = index::call_arg_span(code, open);
            if span_has_ident_xor(&span) {
                push(
                    out,
                    path,
                    line.number,
                    Rule::RngHygiene,
                    "variable-by-variable xor (`seed ^ i`) fed to `Rng::fold_in` — \
                     nearby indices collide across seeds; pass the index as \
                     `fold_in`'s second argument or derive via `rng::mix`"
                        .to_string(),
                );
            }
        }
    }
}

/// True when `span` contains `a ^ b` with identifiers on both sides
/// (numeric literals on either side do not count).
fn span_has_ident_xor(span: &str) -> bool {
    for (i, c) in span.char_indices() {
        if c != '^' {
            continue;
        }
        let lhs = trailing_ident(span[..i].trim_end());
        let rhs = leading_ident(span[i + 1..].trim_start());
        let ident_side = |s: Option<String>| {
            s.is_some_and(|id| !id.starts_with(|c: char| c.is_ascii_digit()))
        };
        if ident_side(lhs) && ident_side(rhs) {
            return true;
        }
    }
    false
}

// --------------------------------------------------- accounting-conservation

/// The fixed file trio the conservation rule audits.
pub const ACCT_FILE: &str = "rust/src/net/mod.rs";
pub const RECORD_FILE: &str = "rust/src/metrics/mod.rs";
pub const CONSUME_FILE: &str = "rust/src/sim/mod.rs";

struct StructInfo {
    decl_line: usize,
    derives_default: bool,
    /// (field name, 1-based declaration line)
    fields: Vec<(String, usize)>,
}

/// Parse a `struct <name>` declaration: derive list and public fields.
fn parse_struct(lines: &[Line], name: &str) -> Option<StructInfo> {
    let decl_idx = lines
        .iter()
        .position(|l| has_word(&l.code, "struct") && has_word(&l.code, name))?;

    // Derives: contiguous attribute lines directly above the declaration.
    let mut derives_default = false;
    let mut j = decl_idx;
    while j > 0 {
        j -= 1;
        let code = lines[j].code.trim();
        let comment_only = code.is_empty() && !lines[j].comment.trim().is_empty();
        if code.starts_with("#[") {
            if code.contains("derive") && has_word(code, "Default") {
                derives_default = true;
            }
        } else if !comment_only {
            break;
        }
    }

    let mut fields = Vec::new();
    let mut depth = 0i32;
    let mut started = false;
    for line in &lines[decl_idx..] {
        let depth_at_start = depth;
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth_at_start == 1 {
            let t = line.code.trim();
            if let Some(rest) = t.strip_prefix("pub ") {
                if let Some(colon) = rest.find(':') {
                    let fname = rest[..colon].trim();
                    if is_ident(fname) {
                        fields.push((fname.to_string(), line.number));
                    }
                }
            }
        }
        if started && depth <= 0 {
            break;
        }
    }

    Some(StructInfo {
        decl_line: lines[decl_idx].number,
        derives_default,
        fields,
    })
}

/// Index (inclusive) of the line closing the brace block opened at or
/// after `start`.
fn region_end(lines: &[Line], start: usize) -> usize {
    let mut depth = 0i32;
    let mut started = false;
    for (i, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    started = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if started && depth <= 0 {
            return i;
        }
    }
    lines.len().saturating_sub(1)
}

/// Raw text of `fn <fn_name>` inside any `impl <type_name>` block.
fn fn_body_text(lines: &[Line], type_name: &str, fn_name: &str) -> String {
    for (i, line) in lines.iter().enumerate() {
        if !(has_word(&line.code, "impl") && has_word(&line.code, type_name)) {
            continue;
        }
        let end = region_end(lines, i);
        let mut j = i + 1;
        while j <= end {
            if has_word(&lines[j].code, "fn") && has_word(&lines[j].code, fn_name) {
                let fend = region_end(lines, j);
                let mut body = String::new();
                for l in &lines[j..=fend.min(end)] {
                    body.push_str(&l.raw);
                    body.push('\n');
                }
                return body;
            }
            j += 1;
        }
    }
    String::new()
}

/// Cross-file conservation audit: every `Accounting` field must flow
/// into the results pipeline — a same-named `RunRecord` mirror that is
/// serialized by `to_json`, parsed by `from_json`, and filled from
/// `acct.<field>` in sim — or carry an allow saying how it is consumed.
/// The reset leg is `Accounting: Default` (sim builds a fresh `Network`,
/// hence fresh zeroed counters, per run).
pub fn check_accounting(files: &[(String, Vec<Line>)]) -> Vec<Finding> {
    let get = |p: &str| {
        files
            .iter()
            .find(|(q, _)| q == p)
            .map(|(_, l)| l.as_slice())
    };
    let (Some(net), Some(metrics), Some(sim)) =
        (get(ACCT_FILE), get(RECORD_FILE), get(CONSUME_FILE))
    else {
        return Vec::new(); // fixture set without the trio: rule opts out
    };

    let mut out = Vec::new();
    let Some(acct) = parse_struct(net, "Accounting") else {
        push(
            &mut out,
            ACCT_FILE,
            1,
            Rule::AccountingConservation,
            "could not locate `struct Accounting`".to_string(),
        );
        return out;
    };
    if !acct.derives_default {
        push(
            &mut out,
            ACCT_FILE,
            acct.decl_line,
            Rule::AccountingConservation,
            "Accounting must derive Default — Network::new zero-fills it, which is \
             the per-run reset leg of conservation"
                .to_string(),
        );
    }

    let record_fields: Vec<String> = parse_struct(metrics, "RunRecord")
        .map(|s| s.fields.into_iter().map(|(n, _)| n).collect())
        .unwrap_or_default();
    let to_json = fn_body_text(metrics, "RunRecord", "to_json");
    let from_json = fn_body_text(metrics, "RunRecord", "from_json");
    let sim_raw: String = sim.iter().map(|l| l.raw.as_str()).collect::<Vec<_>>().join("\n");

    for (name, line) in &acct.fields {
        if record_fields.iter().any(|f| f == name) {
            let mut missing = Vec::new();
            if !has_word(&to_json, name) {
                missing.push("RunRecord::to_json");
            }
            if !has_word(&from_json, name) {
                missing.push("RunRecord::from_json");
            }
            if !has_word(&sim_raw, &format!("acct.{name}")) {
                missing.push("sim (no `acct.<field>` consumption)");
            }
            if !missing.is_empty() {
                push(
                    &mut out,
                    ACCT_FILE,
                    *line,
                    Rule::AccountingConservation,
                    format!(
                        "Accounting field `{name}` is mirrored by RunRecord but not \
                         covered by: {}",
                        missing.join(", ")
                    ),
                );
            }
        } else {
            push(
                &mut out,
                ACCT_FILE,
                *line,
                Rule::AccountingConservation,
                format!(
                    "Accounting field `{name}` has no same-named RunRecord mirror — \
                     new counters must reach the results pipeline (to_json/from_json/\
                     sim consumption) or carry an allow explaining how they are \
                     consumed"
                ),
            );
        }
    }
    out
}

// --------------------------------------------------------- wire-conservation

/// Every `Payload` variant must have a `wire_bytes` match arm (no
/// uncountable payload kinds), and every non-test construction site
/// must reach `Network::send`/`broadcast` — on its own line or inside
/// its enclosing fn — so no payload is built that the byte ledger never
/// sees. Anchored on `net/mod.rs`; opts out when no `enum Payload` is
/// present there (fixture sets opt in by providing one).
fn check_wire_conservation(idx: &RepoIndex) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(net) = idx.get(ACCT_FILE) else {
        return out;
    };
    let Some(payload) = net.enums.iter().find(|e| e.name == "Payload") else {
        return out;
    };

    let wire_bytes = fn_body_text(net.lines, "Payload", "wire_bytes");
    if wire_bytes.is_empty() {
        push(
            &mut out,
            ACCT_FILE,
            payload.decl_line,
            Rule::WireConservation,
            "`enum Payload` has no `wire_bytes` method — every payload kind must \
             define its on-wire cost"
                .to_string(),
        );
        return out;
    }
    for (variant, line) in &payload.variants {
        if !has_word(&wire_bytes, variant) {
            push(
                &mut out,
                ACCT_FILE,
                *line,
                Rule::WireConservation,
                format!(
                    "Payload variant `{variant}` has no `wire_bytes` match arm — \
                     its bytes would never be counted"
                ),
            );
        }
    }

    for file in &idx.files {
        if file.path.starts_with("rust/tests/") {
            continue; // integration tests are test code wholesale
        }
        for (i, line) in file.lines.iter().enumerate() {
            if line.in_test {
                continue;
            }
            for (variant, _) in &payload.variants {
                let needle = format!("Payload::{variant}");
                for p in word_positions(&line.code, &needle) {
                    if is_match_position(&line.code, p, needle.len()) {
                        continue;
                    }
                    if line_sends(&line.code) || enclosing_fn_sends(file.lines, i) {
                        continue;
                    }
                    push(
                        &mut out,
                        file.path,
                        line.number,
                        Rule::WireConservation,
                        format!(
                            "`Payload::{variant}` constructed outside any \
                             send/broadcast path — bytes built here never reach the \
                             accounting ledger"
                        ),
                    );
                }
            }
        }
    }
    out
}

/// True when the `Payload::<V>` occurrence at byte offset `p` is a
/// pattern (match arm, `let`-destructure, or-pattern, `matches!`), not
/// a construction.
fn is_match_position(code: &str, p: usize, len: usize) -> bool {
    let before = code[..p].trim_end();
    if before.ends_with('|') || find_word(code, "matches!").is_some() {
        return true;
    }
    if let Some(id) = trailing_ident(before) {
        if id == "let" {
            return true;
        }
    }
    // Skip the payload's own (...) or {...} group, then look for a
    // match-arm arrow or an or-pattern bar.
    let after = code[p + len..].trim_start();
    let after = skip_group(after, '(', ')');
    let after = skip_group(after, '{', '}');
    let after = after.trim_start();
    after.starts_with("=>") || after.starts_with('|')
}

/// If `s` opens with `open`, drop the balanced group (unterminated
/// groups drop the rest — multi-line constructions resolve via the
/// enclosing-fn check instead).
fn skip_group(s: &str, open: char, close: char) -> &str {
    let t = s.trim_start();
    if !t.starts_with(open) {
        return s;
    }
    let mut depth = 0i32;
    for (i, c) in t.char_indices() {
        if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return &t[i + close.len_utf8()..];
            }
        }
    }
    ""
}

fn line_sends(code: &str) -> bool {
    code.contains(".send(") || code.contains(".broadcast(") || code.contains("send_on_edge(")
}

/// Does the fn enclosing line-index `at` contain a send/broadcast call?
fn enclosing_fn_sends(lines: &[Line], at: usize) -> bool {
    let mut j = at;
    loop {
        if has_word(&lines[j].code, "fn") {
            let end = region_end(lines, j);
            if end >= at {
                return lines[j..=end].iter().any(|l| line_sends(&l.code));
            }
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
}

// ------------------------------------------------------------- json-parity

/// Every key `RunRecord::to_json` writes must be read back by
/// `from_json` and vice versa — the whole-record generalization of the
/// accounting-conservation serialization leg (the PR 5 fig6 grid-shift
/// was exactly a written-but-never-parsed field). Key extraction:
/// writes are key-shaped string literals in `to_json`; reads are
/// first-argument literals of `get`/`opt_*`/`*_arr` calls in
/// `from_json` (plus `EvalPoint::from_json` for the nested eval
/// points), so default-value literals never count as keys.
fn check_json_parity(idx: &RepoIndex) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(metrics) = idx.get(RECORD_FILE) else {
        return out;
    };
    let (Some(to_r), Some(from_r)) = (
        metrics.fn_range("RunRecord", "to_json"),
        metrics.fn_range("RunRecord", "from_json"),
    ) else {
        return out;
    };

    let mut written: Vec<(String, usize)> = Vec::new();
    for line in &metrics.lines[to_r.0..=to_r.1] {
        for (_, t) in &line.lits {
            if index::is_key(t) && !written.iter().any(|(k, _)| k == t) {
                written.push((t.clone(), line.number));
            }
        }
    }
    let mut read: Vec<(String, usize)> = Vec::new();
    let mut ranges = vec![from_r];
    if let Some(er) = metrics.fn_range("EvalPoint", "from_json") {
        ranges.push(er);
    }
    for r in ranges {
        for (k, line) in metrics.getter_keys(r) {
            if index::is_key(&k) && !read.iter().any(|(q, _)| *q == k) {
                read.push((k, line));
            }
        }
    }

    for (k, line) in &written {
        if !read.iter().any(|(q, _)| q == k) {
            push(
                &mut out,
                RECORD_FILE,
                *line,
                Rule::JsonParity,
                format!(
                    "RunRecord::to_json writes key `{k}` that from_json never \
                     reads — the field would silently vanish on reload"
                ),
            );
        }
    }
    for (k, line) in &read {
        if !written.iter().any(|(q, _)| q == k) {
            push(
                &mut out,
                RECORD_FILE,
                *line,
                Rule::JsonParity,
                format!(
                    "RunRecord::from_json reads key `{k}` that to_json never \
                     writes — it can only ever see the default"
                ),
            );
        }
    }
    out
}

// ----------------------------------------------------------- cli-doc-drift

const MAIN_FILE: &str = "rust/src/main.rs";
const CONFIG_FILE: &str = "rust/src/config/mod.rs";
const EXPERIMENTS_DOC: &str = "EXPERIMENTS.md";

/// Every CLI flag dispatched anywhere in `rust/src` must appear as
/// `--<flag>` in the `main.rs` help text AND in EXPERIMENTS.md; every
/// TOML key in `ExperimentConfig::apply_toml` must have a same-named
/// (underscores → dashes) CLI flag or carry an allow. Opts out when
/// the EXPERIMENTS.md doc input or main.rs is absent.
fn check_cli_doc_drift(idx: &RepoIndex, docs: &[(String, String)]) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some((_, experiments_doc)) = docs.iter().find(|(p, _)| p == EXPERIMENTS_DOC) else {
        return out;
    };
    let Some(main) = idx.get(MAIN_FILE) else {
        return out;
    };
    let help = main.literal_text();

    // First non-test read site per flag, across library code.
    let mut flags: Vec<(String, &str, usize)> = Vec::new();
    for file in &idx.files {
        if !file.path.starts_with("rust/src/") {
            continue;
        }
        for u in &file.flags {
            if !u.in_test && !flags.iter().any(|(f, _, _)| *f == u.flag) {
                flags.push((u.flag.clone(), file.path, u.line));
            }
        }
    }
    flags.sort();

    for (flag, path, line) in &flags {
        if !index::doc_has_flag(&help, flag) {
            push(
                &mut out,
                path,
                *line,
                Rule::CliDocDrift,
                format!("flag `--{flag}` is dispatched here but missing from the main.rs help text"),
            );
        }
        if !index::doc_has_flag(experiments_doc, flag) {
            push(
                &mut out,
                path,
                *line,
                Rule::CliDocDrift,
                format!("flag `--{flag}` is dispatched here but undocumented in EXPERIMENTS.md"),
            );
        }
    }

    if let Some(cfg) = idx.get(CONFIG_FILE) {
        if let Some(range) = cfg.fn_range("ExperimentConfig", "apply_toml") {
            for (key, line) in cfg.arm_keys(range) {
                if !index::is_key(&key) {
                    continue;
                }
                let flag = key.replace('_', "-");
                if !flags.iter().any(|(f, _, _)| *f == flag) {
                    push(
                        &mut out,
                        CONFIG_FILE,
                        line,
                        Rule::CliDocDrift,
                        format!(
                            "TOML key `{key}` has no CLI counterpart `--{flag}` — \
                             config files can express what the CLI cannot"
                        ),
                    );
                }
            }
        }
    }
    out
}

// ------------------------------------------------------ bench-ledger-drift

/// Every key in a committed `BENCH_*.json` perf ledger must be emitted
/// by a bench under `benches/` that references that ledger file (exact
/// literal, or `format!` template prefix), and the CI workflow must
/// carry the ledger's enforcing `--check` gate — a ledger entry nothing
/// regenerates, or a gate CI never runs, is drift waiting to be trusted.
/// Opts out when no `BENCH_*.json` doc inputs are provided.
fn check_bench_ledger_drift(idx: &RepoIndex, docs: &[(String, String)]) -> Vec<Finding> {
    let mut out = Vec::new();
    let ci = docs.iter().find(|(p, _)| p.ends_with("ci.yml")).map(|(_, t)| t.as_str());
    for (ledger_path, ledger_text) in docs {
        if !(ledger_path.starts_with("BENCH_") && ledger_path.ends_with(".json")) {
            continue;
        }

        // Benches that own this ledger: any code literal mentions it.
        let owners: Vec<&index::FileIndex> = idx
            .files
            .iter()
            .filter(|f| {
                f.path.starts_with("benches/")
                    && f.lines.iter().any(|l| l.lits.iter().any(|(_, t)| t.contains(ledger_path)))
            })
            .collect();
        let Some(owner) = owners.first() else {
            push(
                &mut out,
                ledger_path,
                1,
                Rule::BenchLedgerDrift,
                format!(
                    "no bench under benches/ references `{ledger_path}` — nothing \
                     can regenerate this ledger"
                ),
            );
            continue;
        };
        // Anchor per-key findings on the owner's ledger-name mention, so
        // an allow annotation in the bench can cover them.
        let anchor = owner
            .lines
            .iter()
            .find(|l| l.lits.iter().any(|(_, t)| t.contains(ledger_path)))
            .map(|l| l.number)
            .unwrap_or(1);

        // Candidate emission patterns from every owning bench.
        let mut exact: Vec<&str> = Vec::new();
        let mut prefixes: Vec<String> = Vec::new();
        for o in &owners {
            for line in o.lines {
                for (_, t) in &line.lits {
                    if let Some(cut) = t.find('{') {
                        let prefix = &t[..cut];
                        if prefix.len() >= 4 && is_ledger_key_shape(prefix) {
                            prefixes.push(prefix.to_string());
                        }
                    } else if is_ledger_key_shape(t) {
                        exact.push(t);
                    }
                }
            }
        }

        for (key, key_line) in parse_ledger_keys(ledger_text) {
            let emitted = exact.iter().any(|e| *e == key)
                || prefixes.iter().any(|p| key.starts_with(p.as_str()));
            if !emitted {
                push(
                    &mut out,
                    owner.path,
                    anchor,
                    Rule::BenchLedgerDrift,
                    format!(
                        "ledger key `{key}` ({ledger_path}:{key_line}) is not emitted \
                         by this bench — no literal or format! template produces it"
                    ),
                );
            }
        }

        let gated = ci.is_some_and(|t| {
            t.lines().any(|l| l.contains("--check") && l.contains(ledger_path.as_str()))
        });
        if !gated {
            push(
                &mut out,
                ledger_path,
                1,
                Rule::BenchLedgerDrift,
                format!(
                    "no CI step runs this ledger's regression gate — expected a \
                     `--check {ledger_path}` line in .github/workflows/ci.yml"
                ),
            );
        }
    }
    out
}

/// Ledger key / emission-pattern shape: `[a-z0-9_-]`, letter first
/// (topology names put `-` inside keys like `construct_s_scale-free_1000`).
fn is_ledger_key_shape(s: &str) -> bool {
    !s.is_empty()
        && s.starts_with(|c: char| c.is_ascii_lowercase())
        && s.chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-')
}

/// `(key, 1-based line)` for every quoted key in a `BENCH_*.json`
/// ledger, skipping the structural `schema`/`timings`/`metrics` keys.
fn parse_ledger_keys(text: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix('"') else {
            continue;
        };
        let Some(end) = rest.find('"') else {
            continue;
        };
        let key = &rest[..end];
        if !rest[end + 1..].trim_start().starts_with(':') {
            continue;
        }
        if matches!(key, "schema" | "timings" | "metrics") || key.is_empty() {
            continue;
        }
        out.push((key.to_string(), i + 1));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::{lint_files, lint_files_with_docs, parse_allows, scan};

    fn lint_one(path: &str, src: &str) -> Vec<Finding> {
        lint_files(&[(path.to_string(), src.to_string())])
    }

    fn rules_of(findings: &[Finding]) -> Vec<Rule> {
        findings.iter().map(|f| f.rule).collect()
    }

    // ---------------------------------------------------------- wall-clock

    #[test]
    fn wall_clock_flagged_in_lib_code() {
        let f = lint_one(
            "rust/src/algos/x.rs",
            "fn f() { let t = std::time::Instant::now(); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::WallClock]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn wall_clock_system_time_flagged() {
        let f = lint_one(
            "rust/src/sim/x.rs",
            "use std::time::SystemTime;\nfn f() { let t = SystemTime::now(); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::WallClock, Rule::WallClock]);
    }

    #[test]
    fn wall_clock_clean_in_timer_bench_tests_and_nonlib() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        assert!(lint_one("rust/src/util/timer.rs", src).is_empty());
        assert!(lint_one("rust/src/util/bench.rs", src).is_empty());
        assert!(lint_one("benches/x.rs", src).is_empty());
        let in_test = "#[cfg(test)]\n\
                       mod tests {\n    \
                       fn f() { let t = std::time::Instant::now(); }\n\
                       }\n";
        assert!(lint_one("rust/src/algos/x.rs", in_test).is_empty());
    }

    #[test]
    fn wall_clock_allow_with_reason_suppresses() {
        let src = "// sflint: allow(wall-clock, reason = \"fixture timing\")\n\
                   fn f() { let t = std::time::Instant::now(); }\n";
        assert!(lint_one("rust/src/algos/x.rs", src).is_empty());
        let trailing = "fn f() { let t = std::time::Instant::now(); } // sflint: allow(wall-clock, \
                        reason = \"fixture timing\")\n";
        assert!(lint_one("rust/src/algos/x.rs", trailing).is_empty());
    }

    #[test]
    fn wall_clock_allow_without_reason_rejected() {
        let src = "// sflint: allow(wall-clock)\nfn f() { let t = std::time::Instant::now(); }\n";
        let f = lint_one("rust/src/algos/x.rs", src);
        // The malformed allow is reported AND does not suppress.
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::WallClock]);
    }

    #[test]
    fn allow_with_empty_reason_rejected() {
        let src = "// sflint: allow(wall-clock, reason = \"\")\n\
                   fn f() { let t = std::time::Instant::now(); }\n";
        let f = lint_one("rust/src/algos/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::WallClock]);
    }

    #[test]
    fn allow_with_unknown_rule_rejected() {
        let lines = scan::scan("// sflint: allow(no-such-rule, reason = \"x\")\n");
        let (allows, invalid) = parse_allows("rust/src/algos/x.rs", &lines);
        assert!(allows.is_empty());
        assert_eq!(invalid.len(), 1);
        assert!(invalid[0].msg.contains("unknown rule"));
    }

    #[test]
    fn allow_only_covers_its_rule() {
        // A wall-clock allow must not suppress a thread-escape finding.
        let src = "// sflint: allow(wall-clock, reason = \"fixture\")\n\
                   fn f() { std::thread::spawn(|| {}); }\n";
        let f = lint_one("rust/src/sim/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::ThreadEscape]);
    }

    // ------------------------------------------------------- thread-escape

    #[test]
    fn thread_escape_flagged_outside_par() {
        let f = lint_one("rust/src/sim/x.rs", "fn f() { std::thread::spawn(|| {}); }\n");
        assert_eq!(rules_of(&f), vec![Rule::ThreadEscape]);
        let f = lint_one("rust/src/flood/x.rs", "fn f() { std::thread::scope(|s| {}); }\n");
        assert_eq!(rules_of(&f), vec![Rule::ThreadEscape]);
    }

    #[test]
    fn thread_escape_clean_in_par_and_tests() {
        let src = "fn f() { std::thread::scope(|s| {}); }\n";
        assert!(lint_one("rust/src/util/par.rs", src).is_empty());
        let in_test = "#[cfg(test)]\nmod tests {\n    fn f() { std::thread::spawn(|| {}); }\n}\n";
        assert!(lint_one("rust/src/util/timer.rs", in_test).is_empty());
    }

    #[test]
    fn thread_escape_allow_with_reason_suppresses() {
        let src = "// sflint: allow(thread-escape, reason = \"fixture\")\n\
                   fn f() { std::thread::spawn(|| {}); }\n";
        assert!(lint_one("rust/src/sim/x.rs", src).is_empty());
    }

    // ------------------------------------------------------ unordered-iter

    #[test]
    fn unordered_iter_flags_method_iteration() {
        let src = "use std::collections::HashMap;\n\
                   fn f() {\n    \
                   let mut m: HashMap<u32, u32> = HashMap::new();\n    \
                   let s: u32 = m.keys().sum();\n\
                   }\n";
        let f = lint_one("rust/src/flood/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnorderedIter]);
        assert_eq!(f[0].line, 4);
        assert!(f[0].msg.contains('m'));
    }

    #[test]
    fn unordered_iter_flags_for_loop() {
        let src = "use std::collections::HashSet;\n\
                   fn f(seen: &HashSet<u64>) {\n    \
                   for x in seen {\n        \
                   sink(x);\n    \
                   }\n\
                   }\n";
        let f = lint_one("rust/src/net/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnorderedIter]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unordered_iter_flags_drain_on_field() {
        let src = "use std::collections::HashMap;\n\
                   struct S { pending: HashMap<u64, u64> }\n\
                   impl S {\n    \
                   fn f(&mut self) {\n        \
                   for (k, v) in self.pending.drain() {\n            \
                   sink(k, v);\n        \
                   }\n    \
                   }\n\
                   }\n";
        let f = lint_one("rust/src/sched/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnorderedIter]);
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn unordered_iter_clean_cases() {
        // Order-insensitive use: membership tests only.
        let contains = "use std::collections::HashSet;\n\
                        fn f(seen: &HashSet<u64>, x: u64) -> bool { seen.contains(&x) }\n";
        assert!(lint_one("rust/src/flood/x.rs", contains).is_empty());
        // Ordered collection.
        let btree = "use std::collections::BTreeMap;\n\
                     fn f(m: &BTreeMap<u32, u32>) -> u32 { m.keys().sum() }\n";
        assert!(lint_one("rust/src/algos/x.rs", btree).is_empty());
        // Outside result-bearing modules.
        let util = "use std::collections::HashMap;\n\
                    fn f(m: &HashMap<u32, u32>) -> u32 { m.keys().sum() }\n";
        assert!(lint_one("rust/src/util/x.rs", util).is_empty());
        // Inside #[cfg(test)].
        let in_test = "use std::collections::HashSet;\n\
                       #[cfg(test)]\n\
                       mod tests {\n    \
                       fn f(s: &HashSet<u64>) { for x in s { sink(x); } }\n\
                       }\n";
        assert!(lint_one("rust/src/topology/x.rs", in_test).is_empty());
    }

    #[test]
    fn unordered_iter_allow_with_reason_suppresses() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n    \
                   // sflint: allow(unordered-iter, reason = \"sum is order-insensitive\")\n    \
                   m.values().sum()\n\
                   }\n";
        assert!(lint_one("rust/src/flood/x.rs", src).is_empty());
    }

    #[test]
    fn unordered_iter_allow_without_reason_rejected() {
        let src = "use std::collections::HashMap;\n\
                   fn f(m: &HashMap<u32, u32>) -> u32 {\n    \
                   // sflint: allow(unordered-iter)\n    \
                   m.values().sum()\n\
                   }\n";
        let f = lint_one("rust/src/flood/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::UnorderedIter]);
    }

    // -------------------------------------------------------- unsafe-audit

    #[test]
    fn unsafe_audit_flags_bare_unsafe() {
        let f = lint_one("rust/src/runtime/x.rs", "unsafe impl Send for X {}\n");
        assert_eq!(rules_of(&f), vec![Rule::UnsafeAudit]);
    }

    #[test]
    fn unsafe_audit_applies_in_tests_too() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { let p = unsafe { danger() }; }\n}\n";
        let f = lint_one("rust/tests/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnsafeAudit]);
    }

    #[test]
    fn unsafe_audit_satisfied_by_adjacent_safety() {
        let above = "// SAFETY: fixture justification\nunsafe impl Send for X {}\n";
        assert!(lint_one("rust/src/runtime/x.rs", above).is_empty());
        let trailing = "unsafe impl Send for X {} // SAFETY: fixture justification\n";
        assert!(lint_one("rust/src/runtime/x.rs", trailing).is_empty());
        let multi = "// SAFETY: part one\n// continues here\nunsafe impl Send for X {}\n";
        assert!(lint_one("rust/src/runtime/x.rs", multi).is_empty());
    }

    #[test]
    fn unsafe_audit_requires_one_comment_per_impl() {
        // One shared SAFETY comment must NOT cover a second impl below it.
        let src = "// SAFETY: covers only the next line\n\
                   unsafe impl Send for X {}\n\
                   unsafe impl Sync for X {}\n";
        let f = lint_one("rust/src/runtime/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::UnsafeAudit]);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn unsafe_audit_allow_with_reason_suppresses() {
        let src = "// sflint: allow(unsafe-audit, reason = \"fixture\")\n\
                   unsafe impl Send for X {}\n";
        assert!(lint_one("rust/src/runtime/x.rs", src).is_empty());
    }

    // ------------------------------------------- accounting-conservation

    fn net_fixture(extra_field: &str) -> String {
        format!(
            "#[derive(Clone, Debug, Default)]\n\
             pub struct Accounting {{\n    \
             pub total_bytes: u64,\n\
             {extra_field}}}\n"
        )
    }

    const METRICS_FIXTURE: &str = "pub struct RunRecord {\n    \
                                   pub total_bytes: u64,\n\
                                   }\n\
                                   impl RunRecord {\n    \
                                   pub fn to_json(&self) -> String {\n        \
                                   format!(\"total_bytes={}\", self.total_bytes)\n    \
                                   }\n    \
                                   pub fn from_json(s: &str) -> Self {\n        \
                                   let total_bytes = parse(s);\n        \
                                   RunRecord { total_bytes }\n    \
                                   }\n\
                                   }\n";

    const SIM_FIXTURE: &str = "pub fn finalize(net: &Network, rec: &mut RunRecord) {\n    \
                               rec.total_bytes = net.acct.total_bytes;\n\
                               }\n";

    fn trio(net: String, metrics: &str, sim: &str) -> Vec<(String, String)> {
        vec![
            (ACCT_FILE.to_string(), net),
            (RECORD_FILE.to_string(), metrics.to_string()),
            (CONSUME_FILE.to_string(), sim.to_string()),
        ]
    }

    #[test]
    fn accounting_covered_field_passes() {
        let files = trio(net_fixture(""), METRICS_FIXTURE, SIM_FIXTURE);
        assert!(lint_files(&files).is_empty());
    }

    #[test]
    fn accounting_uncovered_new_field_fails() {
        let files = trio(
            net_fixture("    pub new_gauge: u64,\n"),
            METRICS_FIXTURE,
            SIM_FIXTURE,
        );
        let f = lint_files(&files);
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("new_gauge"));
        assert_eq!(f[0].path, ACCT_FILE);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn accounting_missing_from_json_leg_fails() {
        // Mirror exists but from_json never reads the key.
        let metrics = "pub struct RunRecord {\n    \
                       pub total_bytes: u64,\n\
                       }\n\
                       impl RunRecord {\n    \
                       pub fn to_json(&self) -> String {\n        \
                       format!(\"total_bytes={}\", self.total_bytes)\n    \
                       }\n    \
                       pub fn from_json(s: &str) -> Self {\n        \
                       todo!()\n    \
                       }\n\
                       }\n";
        let f = lint_files(&trio(net_fixture(""), metrics, SIM_FIXTURE));
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("from_json"));
    }

    #[test]
    fn accounting_missing_sim_consumption_fails() {
        let sim = "pub fn finalize(net: &Network, rec: &mut RunRecord) {}\n";
        let f = lint_files(&trio(net_fixture(""), METRICS_FIXTURE, sim));
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("sim"));
    }

    #[test]
    fn accounting_missing_default_derive_fails() {
        let net = "#[derive(Clone, Debug)]\n\
                   pub struct Accounting {\n    \
                   pub total_bytes: u64,\n\
                   }\n";
        let f = lint_files(&trio(net.to_string(), METRICS_FIXTURE, SIM_FIXTURE));
        assert_eq!(rules_of(&f), vec![Rule::AccountingConservation]);
        assert!(f[0].msg.contains("Default"));
    }

    #[test]
    fn accounting_allow_with_reason_suppresses() {
        let net = net_fixture(
            "    // sflint: allow(accounting-conservation, reason = \"fixture gauge, consumed via \
             peak\")\n    \
             pub new_gauge: u64,\n",
        );
        assert!(lint_files(&trio(net, METRICS_FIXTURE, SIM_FIXTURE)).is_empty());
    }

    #[test]
    fn accounting_skipped_without_the_trio() {
        // A fixture set without net/metrics/sim must not fire the rule.
        assert!(lint_one("rust/src/flood/x.rs", "fn f() {}\n").is_empty());
    }

    // --------------------------------------------------------- rng-hygiene

    #[test]
    fn rng_hygiene_flags_raw_xor_into_new() {
        let f = lint_one(
            "rust/src/data/x.rs",
            "fn f(seed: u64) { let r = Rng::new(seed ^ 0xD1B1); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::RngHygiene]);
        assert!(f[0].msg.contains("rng::mix"));
        let f = lint_one(
            "rust/src/experiments/x.rs",
            "fn f(seed: u64, t: &[u8]) { let s = BatchSampler::new(t, seed ^ 0x9E7A); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::RngHygiene]);
    }

    #[test]
    fn rng_hygiene_flags_ident_xor_into_fold_in() {
        let f = lint_one(
            "rust/src/algos/x.rs",
            "fn f(seed: u64, i: u64) { let r = Rng::fold_in(seed ^ i, 0); }\n",
        );
        assert_eq!(rules_of(&f), vec![Rule::RngHygiene]);
    }

    #[test]
    fn rng_hygiene_clean_cases() {
        // Derived via the mixer.
        let mixed = "fn f(seed: u64) { let r = Rng::new(crate::rng::mix(seed, 0xD1B1)); }\n";
        assert!(lint_one("rust/src/data/x.rs", mixed).is_empty());
        // fold_in with a literal stream label: the sink itself mixes.
        let label = "fn f(seed: u64, i: u64) { let r = Rng::fold_in(seed ^ 0x10AA, i); }\n";
        assert!(lint_one("rust/src/flood/x.rs", label).is_empty());
        // The mixer module may do raw seed arithmetic.
        let raw = "pub fn fold_in(seed: u64, i: u64) -> Rng { Rng::new(seed ^ i) }\n";
        assert!(lint_one("rust/src/rng/mod.rs", raw).is_empty());
        // Tests may seed however they like.
        let in_test = "#[cfg(test)]\nmod tests {\n    \
                       fn f(seed: u64) { let r = Rng::new(seed ^ 1); }\n}\n";
        assert!(lint_one("rust/src/data/x.rs", in_test).is_empty());
    }

    #[test]
    fn rng_hygiene_allow_with_reason_suppresses() {
        let src = "// sflint: allow(rng-hygiene, reason = \"protocol-coupled stream\")\n\
                   fn f(seed: u64) { let r = Rng::new(seed ^ 0x1D1D); }\n";
        assert!(lint_one("rust/src/zo/x.rs", src).is_empty());
    }

    #[test]
    fn rng_hygiene_allow_without_reason_rejected() {
        let src = "// sflint: allow(rng-hygiene)\n\
                   fn f(seed: u64) { let r = Rng::new(seed ^ 0x1D1D); }\n";
        let f = lint_one("rust/src/zo/x.rs", src);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::RngHygiene]);
    }

    // --------------------------------------------------- wire-conservation

    const NET_PAYLOAD_FIXTURE: &str = "pub enum Payload {\n    \
                                       Seeds(Vec<u64>),\n    \
                                       Summary,\n\
                                       }\n\
                                       impl Payload {\n    \
                                       pub fn wire_bytes(&self) -> u64 {\n        \
                                       match self {\n            \
                                       Payload::Seeds(s) => s.len() as u64 * 8,\n            \
                                       Payload::Summary => 8,\n        \
                                       }\n    \
                                       }\n\
                                       }\n";

    #[test]
    fn wire_conservation_net_fixture_is_self_clean() {
        // Match arms inside wire_bytes are patterns, not constructions.
        let files = vec![(ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string())];
        assert!(lint_files(&files).is_empty());
    }

    #[test]
    fn wire_conservation_missing_arm_fails() {
        let net = NET_PAYLOAD_FIXTURE.replace(
            "    Summary,\n",
            "    Summary,\n    Dense(Vec<f64>),\n",
        );
        let f = lint_files(&[(ACCT_FILE.to_string(), net)]);
        assert_eq!(rules_of(&f), vec![Rule::WireConservation]);
        assert!(f[0].msg.contains("Dense"));
        assert!(f[0].msg.contains("wire_bytes"));
    }

    #[test]
    fn wire_conservation_unsent_construction_fails() {
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            (
                "rust/src/flood/x.rs".to_string(),
                "fn build(v: Vec<u64>) -> Payload {\n    Payload::Seeds(v)\n}\n".to_string(),
            ),
        ];
        let f = lint_files(&files);
        assert_eq!(rules_of(&f), vec![Rule::WireConservation]);
        assert_eq!(f[0].path, "rust/src/flood/x.rs");
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn wire_conservation_clean_cases() {
        // Construction on the send line itself.
        let send_line = "fn f(net: &mut Network, v: Vec<u64>) {\n    \
                         net.broadcast(0, &Payload::Seeds(v));\n}\n";
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            ("rust/src/flood/x.rs".to_string(), send_line.to_string()),
        ];
        assert!(lint_files(&files).is_empty());
        // Construction earlier in a fn that sends later.
        let send_later = "fn f(net: &mut Network, v: Vec<u64>) {\n    \
                          let p = Payload::Seeds(v);\n    \
                          net.send(0, 1, &p);\n}\n";
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            ("rust/src/flood/x.rs".to_string(), send_later.to_string()),
        ];
        assert!(lint_files(&files).is_empty());
        // Pattern positions: match arms and let-destructures.
        let patterns = "fn f(p: &Payload) -> bool {\n    \
                        if let Payload::Seeds(s) = p { return true; }\n    \
                        matches!(p, Payload::Summary)\n}\n";
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            ("rust/src/sim/x.rs".to_string(), patterns.to_string()),
        ];
        assert!(lint_files(&files).is_empty());
        // Test code may construct payloads freely.
        let in_test = "#[cfg(test)]\nmod tests {\n    \
                       fn f() { let p = Payload::Summary; }\n}\n";
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            ("rust/src/net/x.rs".to_string(), in_test.to_string()),
        ];
        assert!(lint_files(&files).is_empty());
    }

    #[test]
    fn wire_conservation_allow_with_reason_suppresses() {
        let allowed = "fn build(v: Vec<u64>) -> Payload {\n    \
                       // sflint: allow(wire-conservation, reason = \"returned to a sender\")\n    \
                       Payload::Seeds(v)\n}\n";
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            ("rust/src/flood/x.rs".to_string(), allowed.to_string()),
        ];
        assert!(lint_files(&files).is_empty());
    }

    #[test]
    fn wire_conservation_allow_without_reason_rejected() {
        let bad = "fn build(v: Vec<u64>) -> Payload {\n    \
                   // sflint: allow(wire-conservation)\n    \
                   Payload::Seeds(v)\n}\n";
        let files = vec![
            (ACCT_FILE.to_string(), NET_PAYLOAD_FIXTURE.to_string()),
            ("rust/src/flood/x.rs".to_string(), bad.to_string()),
        ];
        let f = lint_files(&files);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::WireConservation]);
    }

    // --------------------------------------------------------- json-parity

    fn metrics_parity_fixture(to_extra: &str, from_extra: &str) -> String {
        format!(
            "pub struct RunRecord {{\n    pub step: u64,\n}}\n\
             impl RunRecord {{\n    \
             pub fn to_json(&self) -> String {{\n        \
             w_kv(&mut s, \"step\", self.step);\n\
             {to_extra}        s\n    \
             }}\n    \
             pub fn from_json(r: &Json) -> Self {{\n        \
             let step = r.get(\"step\")?;\n\
             {from_extra}        RunRecord {{ step }}\n    \
             }}\n\
             }}\n"
        )
    }

    #[test]
    fn json_parity_symmetric_record_is_clean() {
        let files = vec![(RECORD_FILE.to_string(), metrics_parity_fixture("", ""))];
        assert!(lint_files(&files).is_empty());
    }

    #[test]
    fn json_parity_written_but_never_read_fails() {
        let fixture = metrics_parity_fixture("        w_kv(&mut s, \"loss\", self.loss);\n", "");
        let f = lint_files(&[(RECORD_FILE.to_string(), fixture)]);
        assert_eq!(rules_of(&f), vec![Rule::JsonParity]);
        assert!(f[0].msg.contains("`loss`"));
        assert!(f[0].msg.contains("never"));
    }

    #[test]
    fn json_parity_read_but_never_written_fails() {
        let fixture = metrics_parity_fixture("", "        let ghost = r.opt_f64(\"ghost\")?;\n");
        let f = lint_files(&[(RECORD_FILE.to_string(), fixture)]);
        assert_eq!(rules_of(&f), vec![Rule::JsonParity]);
        assert!(f[0].msg.contains("`ghost`"));
    }

    #[test]
    fn json_parity_allow_with_reason_suppresses() {
        let fixture = metrics_parity_fixture(
            "        // sflint: allow(json-parity, reason = \"write-only debug key\")\n        \
             w_kv(&mut s, \"loss\", self.loss);\n",
            "",
        );
        assert!(lint_files(&[(RECORD_FILE.to_string(), fixture)]).is_empty());
    }

    #[test]
    fn json_parity_allow_without_reason_rejected() {
        let fixture = metrics_parity_fixture(
            "        // sflint: allow(json-parity)\n        \
             w_kv(&mut s, \"loss\", self.loss);\n",
            "",
        );
        let f = lint_files(&[(RECORD_FILE.to_string(), fixture)]);
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::JsonParity]);
    }

    // ------------------------------------------------------- cli-doc-drift

    const MAIN_FIXTURE: &str = "fn run(args: &Args) {\n    \
                                let a = args.get_or(\"alpha\", \"1\");\n    \
                                let b = args.get(\"beta\");\n\
                                }\n\
                                fn print_help() {\n    \
                                println!(\"--alpha N  sets alpha\");\n\
                                }\n";

    fn doc(experiments: &str) -> Vec<(String, String)> {
        vec![("EXPERIMENTS.md".to_string(), experiments.to_string())]
    }

    #[test]
    fn cli_doc_drift_flags_missing_help_and_doc_rows() {
        let files = vec![(MAIN_FILE.to_string(), MAIN_FIXTURE.to_string())];
        let f = lint_files_with_docs(&files, &doc("only `--alpha` is documented"));
        // `beta` is missing from both the help text and EXPERIMENTS.md.
        assert_eq!(rules_of(&f), vec![Rule::CliDocDrift, Rule::CliDocDrift]);
        assert!(f.iter().all(|x| x.msg.contains("--beta")));
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn cli_doc_drift_documented_flags_are_clean() {
        let main = "fn run(args: &Args) {\n    let a = args.get_or(\"alpha\", \"1\");\n}\n\
                    fn print_help() {\n    println!(\"--alpha N  sets alpha\");\n}\n";
        let files = vec![(MAIN_FILE.to_string(), main.to_string())];
        assert!(lint_files_with_docs(&files, &doc("use --alpha to set alpha")).is_empty());
        // Boundary-aware: `--alphabet` must not satisfy `--alpha`.
        let f = lint_files_with_docs(&files, &doc("use --alphabet instead"));
        assert_eq!(rules_of(&f), vec![Rule::CliDocDrift]);
        // Without the EXPERIMENTS.md doc input the rule opts out.
        assert!(lint_files_with_docs(&files, &[]).is_empty());
    }

    #[test]
    fn cli_doc_drift_toml_key_without_cli_counterpart_fails() {
        let cfg = "impl ExperimentConfig {\n    \
                   fn apply_toml(&mut self, k: &str, v: &V) -> Result<()> {\n        \
                   match k {\n            \
                   \"gamma_rate\" => self.gamma = v.as_f64()?,\n            \
                   other => bail!(\"unknown key\"),\n        \
                   }\n        \
                   Ok(())\n    \
                   }\n\
                   }\n";
        let files = vec![
            (MAIN_FILE.to_string(), MAIN_FIXTURE.to_string()),
            (CONFIG_FILE.to_string(), cfg.to_string()),
        ];
        let f = lint_files_with_docs(&files, &doc("--alpha and --beta are documented"));
        let toml: Vec<&Finding> = f.iter().filter(|x| x.msg.contains("TOML")).collect();
        assert_eq!(toml.len(), 1);
        assert!(toml[0].msg.contains("gamma_rate"));
        assert_eq!(toml[0].path, CONFIG_FILE);
    }

    #[test]
    fn cli_doc_drift_allow_with_reason_suppresses() {
        let main = "fn run(args: &Args) {\n    \
                    // sflint: allow(cli-doc-drift, reason = \"internal debug flag\")\n    \
                    let b = args.get(\"beta\");\n\
                    }\n";
        let files = vec![(MAIN_FILE.to_string(), main.to_string())];
        assert!(lint_files_with_docs(&files, &doc("no flags documented")).is_empty());
    }

    #[test]
    fn cli_doc_drift_allow_without_reason_rejected() {
        let main = "fn run(args: &Args) {\n    \
                    // sflint: allow(cli-doc-drift)\n    \
                    let b = args.get(\"beta\");\n\
                    }\n";
        let files = vec![(MAIN_FILE.to_string(), main.to_string())];
        let f = lint_files_with_docs(&files, &doc("no flags documented"));
        assert_eq!(
            rules_of(&f),
            vec![Rule::InvalidAllow, Rule::CliDocDrift, Rule::CliDocDrift]
        );
    }

    // -------------------------------------------------- bench-ledger-drift

    const BENCH_FIXTURE: &str = "fn main() {\n    \
                                 emit(\"construct_s_ring_1000\", 1.0);\n    \
                                 emit(&format!(\"flood_s_{n}\"), 2.0);\n    \
                                 println!(\"wrote BENCH_scale.json\");\n\
                                 }\n";

    const LEDGER_FIXTURE: &str = "{\n  \
                                  \"schema\": 1,\n  \
                                  \"metrics\": {\n    \
                                  \"construct_s_ring_1000\": 1.0,\n    \
                                  \"flood_s_1000\": 2.0\n  \
                                  }\n\
                                  }\n";

    const CI_GATE: &str = "      - run: cargo bench --bench scale -- --smoke --check BENCH_scale.json\n";

    fn bench_docs(ledger: &str, ci: &str) -> Vec<(String, String)> {
        vec![
            ("BENCH_scale.json".to_string(), ledger.to_string()),
            (".github/workflows/ci.yml".to_string(), ci.to_string()),
        ]
    }

    #[test]
    fn bench_ledger_emitted_and_gated_is_clean() {
        let files = vec![("benches/scale.rs".to_string(), BENCH_FIXTURE.to_string())];
        let f = lint_files_with_docs(&files, &bench_docs(LEDGER_FIXTURE, CI_GATE));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn bench_ledger_orphan_key_fails() {
        let ledger = LEDGER_FIXTURE.replace(
            "\"flood_s_1000\": 2.0\n",
            "\"flood_s_1000\": 2.0,\n    \"orphan_key\": 3.0\n",
        );
        let files = vec![("benches/scale.rs".to_string(), BENCH_FIXTURE.to_string())];
        let f = lint_files_with_docs(&files, &bench_docs(&ledger, CI_GATE));
        assert_eq!(rules_of(&f), vec![Rule::BenchLedgerDrift]);
        assert!(f[0].msg.contains("orphan_key"));
        // Anchored on the bench's ledger-name mention, so allows work.
        assert_eq!(f[0].path, "benches/scale.rs");
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn bench_ledger_without_owner_or_gate_fails() {
        // Ledger present but no bench references it.
        let f = lint_files_with_docs(&[], &bench_docs(LEDGER_FIXTURE, CI_GATE));
        assert_eq!(rules_of(&f), vec![Rule::BenchLedgerDrift]);
        assert!(f[0].msg.contains("no bench"));
        assert_eq!(f[0].path, "BENCH_scale.json");
        // Owner exists but CI has no --check gate for the ledger.
        let files = vec![("benches/scale.rs".to_string(), BENCH_FIXTURE.to_string())];
        let f = lint_files_with_docs(&files, &bench_docs(LEDGER_FIXTURE, "steps: []\n"));
        assert_eq!(rules_of(&f), vec![Rule::BenchLedgerDrift]);
        assert!(f[0].msg.contains("regression gate"));
    }

    #[test]
    fn bench_ledger_allow_with_reason_suppresses() {
        let ledger = LEDGER_FIXTURE.replace(
            "\"flood_s_1000\": 2.0\n",
            "\"flood_s_1000\": 2.0,\n    \"orphan_key\": 3.0\n",
        );
        let bench = BENCH_FIXTURE.replace(
            "    println!(\"wrote BENCH_scale.json\");\n",
            "    // sflint: allow(bench-ledger-drift, reason = \"key kept for history\")\n    \
             println!(\"wrote BENCH_scale.json\");\n",
        );
        let files = vec![("benches/scale.rs".to_string(), bench)];
        assert!(lint_files_with_docs(&files, &bench_docs(&ledger, CI_GATE)).is_empty());
    }

    #[test]
    fn bench_ledger_allow_without_reason_rejected() {
        let ledger = LEDGER_FIXTURE.replace(
            "\"flood_s_1000\": 2.0\n",
            "\"flood_s_1000\": 2.0,\n    \"orphan_key\": 3.0\n",
        );
        let bench = BENCH_FIXTURE.replace(
            "    println!(\"wrote BENCH_scale.json\");\n",
            "    // sflint: allow(bench-ledger-drift)\n    \
             println!(\"wrote BENCH_scale.json\");\n",
        );
        let files = vec![("benches/scale.rs".to_string(), bench)];
        let f = lint_files_with_docs(&files, &bench_docs(&ledger, CI_GATE));
        assert_eq!(rules_of(&f), vec![Rule::InvalidAllow, Rule::BenchLedgerDrift]);
    }

    // ------------------------------------------------------- rule registry

    #[test]
    fn new_rules_round_trip_through_names() {
        for rule in [
            Rule::WireConservation,
            Rule::RngHygiene,
            Rule::CliDocDrift,
            Rule::JsonParity,
            Rule::BenchLedgerDrift,
        ] {
            assert_eq!(Rule::from_name(rule.name()), Some(rule));
        }
        assert_eq!(Rule::from_name("invalid-allow"), None);
    }

    // ------------------------------------------------------- repo self-run

    #[test]
    fn repo_tree_is_clean() {
        // cargo test runs with cwd = package root.
        let report = crate::lint::run_repo(std::path::Path::new(".")).expect("repo scan");
        assert!(report.files_scanned >= 60, "scanned {}", report.files_scanned);
        let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
        assert!(rendered.is_empty(), "tree findings:\n{}", rendered.join("\n"));
    }
}
