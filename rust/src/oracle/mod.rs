//! Loss oracles behind [`crate::sim::Env`].
//!
//! The engine refactor splits "what the algorithms do" from "who computes
//! the loss": every algorithm talks to the environment through
//! `Env::{loss_acc, grad, …}`, and the environment routes to one of two
//! backends:
//!
//! * [`AotBackend`] — the real path: AOT HLO artifacts (with the pallas
//!   kernels lowered in) executed through PJRT. Needs `make artifacts` and
//!   the real xla-rs bindings wired in for `crate::xla` (the offline image
//!   ships a stub — see rust/src/xla/).
//! * [`SyntheticOracle`] — a pure-rust, artifact-free oracle
//!   (`--model synthetic`): a deterministic logistic model over hashed
//!   token features with an analytic gradient. It exists so the whole
//!   simulator — flooding, byte accounting, SubCGE folding, the parallel
//!   engine, its determinism tests and benches — runs end-to-end in an
//!   image with no XLA runtime. Loss values are meaningful (the planted
//!   lexicon tasks are genuinely learnable by a linear scorer) but are not
//!   the paper's transformer numbers.
//!
//! Both backends are `Send + Sync`: local steps of different clients call
//! them concurrently from worker threads (tentpole item 2 of ISSUE 1).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::model::{Manifest, ModelConfig, TensorSpec};
use crate::rng::Rng;
use crate::runtime::{Executable, Runtime};
use crate::tensor::ParamVec;

/// Which oracle computes losses/gradients for an experiment.
pub enum Backend {
    Aot(AotBackend),
    Synthetic(SyntheticOracle),
}

/// The PJRT path: one runtime + the five compiled graphs every method uses.
pub struct AotBackend {
    pub rt: Runtime,
    pub exe_loss: Arc<Executable>,
    pub exe_grad: Arc<Executable>,
    pub exe_loss_lora: Arc<Executable>,
    pub exe_grad_lora: Arc<Executable>,
    pub exe_subcge: Arc<Executable>,
}

impl AotBackend {
    pub fn load(artifacts_dir: &str, manifest: &Manifest) -> Result<AotBackend> {
        let rt = Runtime::cpu(artifacts_dir).context("starting PJRT runtime")?;
        let exe_loss = rt.load(manifest, "loss")?;
        let exe_grad = rt.load(manifest, "grad")?;
        let exe_loss_lora = rt.load(manifest, "loss_lora")?;
        let exe_grad_lora = rt.load(manifest, "grad_lora")?;
        let exe_subcge = rt.load(manifest, "subcge")?;
        Ok(AotBackend { rt, exe_loss, exe_grad, exe_loss_lora, exe_grad_lora, exe_subcge })
    }
}

/// Feature width of the synthetic model's data-dependent head (the first
/// `FEAT` coordinates of the flattened parameter vector score the batch;
/// the rest enter through the ridge term, so every coordinate moves the
/// loss and zeroth-order probing behaves like on the real model).
pub const FEAT: usize = 1024;
const GAIN: f32 = 25.0;
const DECAY: f32 = 1e-4;

/// Deterministic artifact-free loss oracle: logistic classification on
/// per-token pseudo-random features.
///
/// For an example with tokens `t_1..t_s`, the feature vector is
/// `φ = Σ_j dir(t_j) / √(s·FEAT)` with `dir(tok)` a fixed `FEAT`-dim
/// normal direction per vocab id (cached at construction — the planted
/// lexicon tokens shared across examples are what make the task linearly
/// learnable). The score is `z = GAIN · ⟨head(θ), φ⟩` with `head(θ)` the
/// first FEAT flattened coordinates, and
/// `loss = mean_e softplus(−y_e z_e) + DECAY/2 · ‖θ‖²`, `y_e = ±1`.
pub struct SyntheticOracle {
    /// per-token feature directions, flat `[vocab × FEAT]`
    tok_dirs: Vec<f32>,
    vocab: usize,
}

impl SyntheticOracle {
    pub fn new(manifest: &Manifest, seed: u64) -> SyntheticOracle {
        let vocab = manifest.config.vocab;
        let mut tok_dirs = vec![0.0f32; vocab * FEAT];
        for tok in 0..vocab {
            let mut rng = Rng::fold_in(seed ^ 0x0ACC_1E5E, tok as u64);
            rng.fill_normal(&mut tok_dirs[tok * FEAT..(tok + 1) * FEAT]);
        }
        SyntheticOracle { tok_dirs, vocab }
    }

    /// φ for every example in the batch, flat `[b × FEAT]`.
    fn features(&self, ids: &[i32], b: usize, s: usize) -> Vec<f32> {
        assert_eq!(ids.len(), b * s, "ids length != batch × seq");
        let norm = 1.0 / ((s * FEAT) as f32).sqrt();
        let mut phi = vec![0.0f32; b * FEAT];
        for e in 0..b {
            let dst = &mut phi[e * FEAT..(e + 1) * FEAT];
            for &tok in &ids[e * s..(e + 1) * s] {
                let tok = (tok.max(0) as usize) % self.vocab;
                let dir = &self.tok_dirs[tok * FEAT..(tok + 1) * FEAT];
                for (d, &x) in dst.iter_mut().zip(dir.iter()) {
                    *d += x;
                }
            }
            for d in dst.iter_mut() {
                *d *= norm;
            }
        }
        phi
    }

    /// The first `FEAT` flattened coordinates of `p` (fewer if p is small).
    fn head(p: &ParamVec) -> Vec<f32> {
        let mut head = Vec::with_capacity(FEAT);
        'outer: for t in &p.tensors {
            for &x in &t.data {
                head.push(x);
                if head.len() == FEAT {
                    break 'outer;
                }
            }
        }
        head
    }

    fn scores(&self, head: &[f32], ids: &[i32], b: usize, s: usize) -> Vec<f32> {
        let phi = self.features(ids, b, s);
        (0..b)
            .map(|e| {
                let pe = &phi[e * FEAT..(e + 1) * FEAT];
                let dot: f32 = head.iter().zip(pe.iter()).map(|(&h, &f)| h * f).sum();
                GAIN * dot
            })
            .collect()
    }

    fn ridge(p: &ParamVec) -> f32 {
        let ss: f64 = p
            .tensors
            .iter()
            .map(|t| t.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
            .sum();
        0.5 * DECAY * ss as f32
    }

    fn softplus(x: f32) -> f32 {
        x.max(0.0) + (-x.abs()).exp().ln_1p()
    }

    fn sigmoid(x: f32) -> f32 {
        1.0 / (1.0 + (-x).exp())
    }

    /// (mean loss, #correct) of `params` on one batch — the synthetic
    /// analogue of the `loss` artifact.
    pub fn loss_acc(
        &self,
        params: &ParamVec,
        ids: &[i32],
        labels: &[i32],
        seq: usize,
    ) -> (f32, f32) {
        let b = labels.len();
        let zs = self.scores(&Self::head(params), ids, b, seq);
        let mut loss = 0.0f32;
        let mut correct = 0.0f32;
        for (&z, &label) in zs.iter().zip(labels.iter()) {
            let y = if label == 1 { 1.0f32 } else { -1.0 };
            loss += Self::softplus(-y * z);
            if (z > 0.0) == (label == 1) {
                correct += 1.0;
            }
        }
        (loss / b as f32 + Self::ridge(params), correct)
    }

    /// (mean loss, ∂loss/∂θ) — the synthetic analogue of the `grad`
    /// artifact (analytic, so FO baselines run artifact-free too).
    pub fn grad(
        &self,
        params: &ParamVec,
        ids: &[i32],
        labels: &[i32],
        seq: usize,
    ) -> (f32, ParamVec) {
        let b = labels.len();
        let head = Self::head(params);
        let phi = self.features(ids, b, seq);
        let mut loss = 0.0f32;
        let mut ghead = vec![0.0f32; head.len()];
        for (e, &label) in labels.iter().enumerate() {
            let pe = &phi[e * FEAT..(e + 1) * FEAT];
            let dot: f32 = head.iter().zip(pe.iter()).map(|(&h, &f)| h * f).sum();
            let z = GAIN * dot;
            let y = if label == 1 { 1.0f32 } else { -1.0 };
            loss += Self::softplus(-y * z);
            // d softplus(−yz)/dz = −y·σ(−yz)
            let coef = GAIN * (-y) * Self::sigmoid(-y * z) / b as f32;
            for (g, &f) in ghead.iter_mut().zip(pe.iter()) {
                *g += coef * f;
            }
        }
        // ridge gradient over every coordinate + head term on the first FEAT
        let mut grads = params.zeros_like();
        let mut k = 0usize;
        for (gt, pt) in grads.tensors.iter_mut().zip(params.tensors.iter()) {
            for (g, &x) in gt.data.iter_mut().zip(pt.data.iter()) {
                *g = DECAY * x;
                if k < ghead.len() {
                    *g += ghead[k];
                    k += 1;
                }
            }
        }
        (loss / b as f32 + Self::ridge(params), grads)
    }

    /// LoRA variant: the frozen base contributes a fixed score offset, the
    /// adapters contribute through their own head — so adapter training
    /// moves the loss while the base stays untouched.
    pub fn loss_acc_lora(
        &self,
        base: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
        seq: usize,
    ) -> (f32, f32) {
        let b = labels.len();
        let zb = self.scores(&Self::head(base), ids, b, seq);
        let zl = self.scores(&Self::head(lora), ids, b, seq);
        let mut loss = 0.0f32;
        let mut correct = 0.0f32;
        for ((&z0, &z1), &label) in zb.iter().zip(zl.iter()).zip(labels.iter()) {
            let z = z0 + z1;
            let y = if label == 1 { 1.0f32 } else { -1.0 };
            loss += Self::softplus(-y * z);
            if (z > 0.0) == (label == 1) {
                correct += 1.0;
            }
        }
        (loss / b as f32 + Self::ridge(lora), correct)
    }

    /// (mean loss, ∂loss/∂lora) with the base frozen.
    pub fn grad_lora(
        &self,
        base: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
        seq: usize,
    ) -> (f32, ParamVec) {
        let b = labels.len();
        let base_head = Self::head(base);
        let lora_head = Self::head(lora);
        let phi = self.features(ids, b, seq);
        let mut loss = 0.0f32;
        let mut ghead = vec![0.0f32; lora_head.len()];
        for (e, &label) in labels.iter().enumerate() {
            let pe = &phi[e * FEAT..(e + 1) * FEAT];
            let dotb: f32 = base_head.iter().zip(pe.iter()).map(|(&h, &f)| h * f).sum();
            let dotl: f32 = lora_head.iter().zip(pe.iter()).map(|(&h, &f)| h * f).sum();
            let z = GAIN * (dotb + dotl);
            let y = if label == 1 { 1.0f32 } else { -1.0 };
            loss += Self::softplus(-y * z);
            let coef = GAIN * (-y) * Self::sigmoid(-y * z) / b as f32;
            for (g, &f) in ghead.iter_mut().zip(pe.iter()) {
                *g += coef * f;
            }
        }
        let mut grads = lora.zeros_like();
        let mut k = 0usize;
        for (gt, pt) in grads.tensors.iter_mut().zip(lora.tensors.iter()) {
            for (g, &x) in gt.data.iter_mut().zip(pt.data.iter()) {
                *g = DECAY * x;
                if k < ghead.len() {
                    *g += ghead[k];
                    k += 1;
                }
            }
        }
        (loss / b as f32 + Self::ridge(lora), grads)
    }
}

/// In-code manifest for the synthetic model — transformer-shaped parameter
/// list (so SubCGE's 2D subset, LoRA adapters and init conventions all
/// behave like on the AOT models) with no artifact files.
pub fn synthetic_manifest() -> Manifest {
    let (vocab, seq, dim) = (256usize, 32usize, 64usize);
    let (layers, heads, batch) = (2usize, 4usize, 8usize);
    let lora_rank = 4usize;
    let mlp = 4 * dim;
    let mut params: Vec<TensorSpec> = vec![spec("embed.tok", &[vocab, dim])];
    let mut lora_params: Vec<TensorSpec> = vec![];
    let mut params2d: Vec<String> = vec!["embed.tok".to_string()];
    for l in 0..layers {
        let p = |suffix: &str| format!("block{l}.{suffix}");
        params.push(spec(&p("ln1.scale"), &[dim]));
        params.push(spec(&p("ln1.bias"), &[dim]));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            params.push(spec(&p(w), &[dim, dim]));
            params2d.push(p(w));
        }
        params.push(spec(&p("ln2.scale"), &[dim]));
        params.push(spec(&p("ln2.bias"), &[dim]));
        params.push(spec(&p("mlp.w1"), &[dim, mlp]));
        params2d.push(p("mlp.w1"));
        params.push(spec(&p("mlp.b1"), &[mlp]));
        params.push(spec(&p("mlp.w2"), &[mlp, dim]));
        params2d.push(p("mlp.w2"));
        params.push(spec(&p("mlp.b2"), &[dim]));
        for w in ["attn.wq", "attn.wv"] {
            lora_params.push(spec(&format!("{}.lora_a", p(w)), &[dim, lora_rank]));
            lora_params.push(spec(&format!("{}.lora_b", p(w)), &[lora_rank, dim]));
        }
    }
    params.push(spec("final.ln.scale", &[dim]));
    params.push(spec("final.ln.bias", &[dim]));
    let num_params = params.iter().map(|s| s.numel()).sum();
    Manifest {
        config: ModelConfig {
            name: "synthetic".to_string(),
            vocab,
            seq,
            dim,
            layers,
            heads,
            batch,
            num_classes: 2,
            lora_rank,
            subcge_rank: 64,
            num_params,
        },
        params,
        lora_params,
        params2d,
        artifacts: vec![],
    }
}

/// In-code manifest for `--model cheap`: the same transformer-shaped
/// parameter list and loss-surface API as [`synthetic_manifest`], shrunk
/// (~6k parameters vs ~58k, seq 8, batch 2) until a local step costs
/// microseconds. Massive-scale runs (10k–100k clients) use it so the
/// limiting axis is client count and topology, not model math — loss
/// values are learnable-but-toy, exactly like the synthetic oracle's.
pub fn cheap_manifest() -> Manifest {
    let (vocab, seq, dim) = (160usize, 8usize, 16usize);
    let (layers, heads, batch) = (1usize, 2usize, 2usize);
    let lora_rank = 2usize;
    let mlp = 4 * dim;
    let mut params: Vec<TensorSpec> = vec![spec("embed.tok", &[vocab, dim])];
    let mut lora_params: Vec<TensorSpec> = vec![];
    let mut params2d: Vec<String> = vec!["embed.tok".to_string()];
    for l in 0..layers {
        let p = |suffix: &str| format!("block{l}.{suffix}");
        params.push(spec(&p("ln1.scale"), &[dim]));
        params.push(spec(&p("ln1.bias"), &[dim]));
        for w in ["attn.wq", "attn.wk", "attn.wv", "attn.wo"] {
            params.push(spec(&p(w), &[dim, dim]));
            params2d.push(p(w));
        }
        params.push(spec(&p("ln2.scale"), &[dim]));
        params.push(spec(&p("ln2.bias"), &[dim]));
        params.push(spec(&p("mlp.w1"), &[dim, mlp]));
        params2d.push(p("mlp.w1"));
        params.push(spec(&p("mlp.b1"), &[mlp]));
        params.push(spec(&p("mlp.w2"), &[mlp, dim]));
        params2d.push(p("mlp.w2"));
        params.push(spec(&p("mlp.b2"), &[dim]));
        for w in ["attn.wq", "attn.wv"] {
            lora_params.push(spec(&format!("{}.lora_a", p(w)), &[dim, lora_rank]));
            lora_params.push(spec(&format!("{}.lora_b", p(w)), &[lora_rank, dim]));
        }
    }
    params.push(spec("final.ln.scale", &[dim]));
    params.push(spec("final.ln.bias", &[dim]));
    let num_params = params.iter().map(|s| s.numel()).sum();
    Manifest {
        config: ModelConfig {
            name: "cheap".to_string(),
            vocab,
            seq,
            dim,
            layers,
            heads,
            batch,
            num_classes: 2,
            lora_rank,
            subcge_rank: 16,
            num_params,
        },
        params,
        lora_params,
        params2d,
        artifacts: vec![],
    }
}

fn spec(name: &str, shape: &[usize]) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ParamStore;

    fn setup() -> (Manifest, SyntheticOracle, ParamVec, Vec<i32>, Vec<i32>) {
        let m = synthetic_manifest();
        let o = SyntheticOracle::new(&m, 7);
        let p = ParamStore::init(&m, 0);
        let (b, s) = (m.config.batch, m.config.seq);
        let ids: Vec<i32> = (0..b * s).map(|i| ((i * 131) % m.config.vocab) as i32).collect();
        let labels: Vec<i32> = (0..b).map(|i| (i % 2) as i32).collect();
        (m, o, p, ids, labels)
    }

    #[test]
    fn synthetic_manifest_is_well_formed() {
        let m = synthetic_manifest();
        assert!(m.config.num_params > 50_000);
        assert_eq!(m.param2d_indices().len(), m.params2d.len());
        for &i in &m.param2d_indices() {
            assert_eq!(m.params[i].shape.len(), 2);
        }
        // LoRA adapters exist and are much smaller than the full model
        let d_lora: usize = m.lora_params.iter().map(|s| s.numel()).sum();
        assert!(d_lora >= FEAT, "lora dim {d_lora} must cover the feature head");
        assert!(d_lora * 10 < m.config.num_params);
    }

    #[test]
    fn cheap_manifest_is_well_formed_and_much_smaller() {
        let m = cheap_manifest();
        // same structural contracts as the synthetic manifest…
        assert_eq!(m.param2d_indices().len(), m.params2d.len());
        for &i in &m.param2d_indices() {
            assert_eq!(m.params[i].shape.len(), 2);
        }
        // …at a fraction of the size (the point of --model cheap), and
        // with a vocab the planted-lexicon task generator can still use
        assert!(m.config.num_params * 5 < synthetic_manifest().config.num_params);
        assert!(m.config.vocab as i32 > crate::data::FILLER_BASE + 16);
        // the oracle API works on it end-to-end
        let o = SyntheticOracle::new(&m, 7);
        let p = ParamStore::init(&m, 0);
        let (b, s) = (m.config.batch, m.config.seq);
        let ids: Vec<i32> = (0..b * s).map(|i| ((i * 131) % m.config.vocab) as i32).collect();
        let labels: Vec<i32> = (0..b).map(|i| (i % 2) as i32).collect();
        let (loss, grads) = o.grad(&p, &ids, &labels, s);
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(grads.num_elements(), p.num_elements());
    }

    #[test]
    fn loss_is_deterministic_and_finite() {
        let (m, o, p, ids, labels) = setup();
        let (l1, c1) = o.loss_acc(&p, &ids, &labels, m.config.seq);
        let (l2, c2) = o.loss_acc(&p, &ids, &labels, m.config.seq);
        assert_eq!(l1, l2);
        assert_eq!(c1, c2);
        assert!(l1.is_finite() && l1 > 0.0);
        assert!((0.0..=labels.len() as f32).contains(&c1));
    }

    #[test]
    fn analytic_grad_matches_finite_difference() {
        let (m, o, mut p, ids, labels) = setup();
        let (_, g) = o.grad(&p, &ids, &labels, m.config.seq);
        // finite differences on head coordinates (large enough signal for
        // f32 central differences)
        for ei in [0usize, 5, 500, 999] {
            let eps = 1e-2f32;
            let orig = p.tensors[0].data[ei];
            p.tensors[0].data[ei] = orig + eps;
            let (lp, _) = o.loss_acc(&p, &ids, &labels, m.config.seq);
            p.tensors[0].data[ei] = orig - eps;
            let (lm, _) = o.loss_acc(&p, &ids, &labels, m.config.seq);
            p.tensors[0].data[ei] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            let an = g.tensors[0].data[ei];
            assert!(
                (fd - an).abs() < 2e-2 * an.abs().max(1e-2),
                "head[{ei}]: fd {fd} vs analytic {an}"
            );
        }
        // outside the head only the ridge term acts — exact, no FD needed
        let an = g.tensors[4].data[3];
        assert!((an - DECAY * p.tensors[4].data[3]).abs() < 1e-9, "tail grad {an}");
    }

    #[test]
    fn gradient_step_descends() {
        let (m, o, mut p, ids, labels) = setup();
        let (l0, g) = o.grad(&p, &ids, &labels, m.config.seq);
        p.axpy(-0.05, &g);
        let (l1, _) = o.loss_acc(&p, &ids, &labels, m.config.seq);
        assert!(l1 < l0, "descent failed: {l0} -> {l1}");
    }

    #[test]
    fn lora_grad_descends_with_base_frozen() {
        let (m, o, base, ids, labels) = setup();
        let mut lora = ParamStore::init_lora(&m, 3);
        let (l0, g) = o.grad_lora(&base, &lora, &ids, &labels, m.config.seq);
        lora.axpy(-0.05, &g);
        let (l1, _) = o.loss_acc_lora(&base, &lora, &ids, &labels, m.config.seq);
        assert!(l1 < l0, "lora descent failed: {l0} -> {l1}");
    }
}
