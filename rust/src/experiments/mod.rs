//! Experiment harnesses — one entry per paper table/figure (DESIGN.md
//! per-experiment index). Each prints the paper's rows and writes
//! `results/<id>.json`.

pub mod hopgrid;
pub mod sweep;

use anyhow::Result;

use crate::config::{ExperimentConfig, Method};
use crate::metrics::RunRecord;
use crate::sim::{self, Env};
use crate::topology::Kind;
use crate::util::human_bytes;
use crate::util::json::Json;

/// Run one config, reusing a cached Env core when the
/// (model, task, clients) triple matches ([`sim::shared_core`]) —
/// re-deriving only the per-run state (seeded θ⁰, Dirichlet partitions).
/// A cached run is bit-identical to a fresh [`sim::run_experiment`]
/// (tests/sweep.rs).
pub fn run_one(cfg: ExperimentConfig) -> Result<RunRecord> {
    log::info!(
        "run: {} task={} clients={} topo={:?} steps={}",
        cfg.method.name(), cfg.task, cfg.clients, cfg.topology, cfg.steps
    );
    let core = sim::shared_core(&cfg)?;
    sim::run_with_env(&Env::from_core(core, cfg)?)
}

fn save_records(id: &str, records: &[RunRecord]) -> Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{id}.json");
    let j = Json::Arr(records.iter().map(|r| r.to_json()).collect());
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

/// Load a `results/<id>.json` record array ([`RunRecord::from_json`]).
pub fn load_records(path: &str) -> Result<Vec<RunRecord>> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text)?;
    j.as_arr()?.iter().map(RunRecord::from_json).collect()
}

/// Methods of the paper's main grid (Fig 3 / Table 8).
pub fn main_grid_methods() -> Vec<Method> {
    vec![
        Method::Dsgd,
        Method::ChocoSgd,
        Method::DsgdLora,
        Method::ChocoLora,
        Method::Dzsgd,
        Method::DzsgdLora,
        Method::SeedFlood,
    ]
}

/// Fig 3 / Table 8: per-task GMP + communication cost for every method on
/// one topology. FO methods run `steps/10` iterations (paper: 500 vs 5000).
pub fn fig3(base: &ExperimentConfig, tasks: &[String], topo: Kind) -> Result<Vec<RunRecord>> {
    let mut records = vec![];
    for task in tasks {
        for method in main_grid_methods() {
            let mut cfg = base.clone();
            cfg.method = method;
            cfg.task = task.clone();
            cfg.topology = topo;
            if !method.is_zeroth_order() {
                cfg.steps = (base.steps / 10).max(1);
                cfg.lr = base.lr * 10.0; // FO tolerates larger steps (Table 5)
            }
            records.push(run_one(cfg)?);
        }
    }
    Ok(records)
}

pub fn print_table8(records: &[RunRecord]) {
    println!("\n{:<12} {:>10} {:>10} {:>12} {:>14}", "method", "task", "GMP%", "loss", "cost/edge");
    for r in records {
        println!(
            "{:<12} {:>10} {:>10.2} {:>12.4} {:>14}",
            r.method,
            r.task,
            100.0 * r.gmp,
            r.final_loss,
            human_bytes(r.per_edge_bytes as u64)
        );
    }
}

/// Fig 4 / Table 2: scaling over client counts on ring + meshgrid.
pub fn scaling(
    base: &ExperimentConfig,
    tasks: &[String],
    client_counts: &[usize],
) -> Result<Vec<RunRecord>> {
    let mut records = vec![];
    for &topo in &[Kind::Ring, Kind::Meshgrid] {
        for task in tasks {
            for &n in client_counts {
                for method in [
                    Method::Dsgd,
                    Method::ChocoSgd,
                    Method::DsgdLora,
                    Method::ChocoLora,
                    Method::SeedFlood,
                ] {
                    let mut cfg = base.clone();
                    cfg.method = method;
                    cfg.task = task.clone();
                    cfg.topology = topo;
                    cfg.clients = n;
                    if !method.is_zeroth_order() {
                        cfg.steps = (base.steps / 10).max(1);
                        cfg.lr = base.lr * 10.0;
                    }
                    records.push(run_one(cfg)?);
                }
            }
        }
    }
    Ok(records)
}

/// Table 2 view: GMP normalized by DSGD@16 clients, per topology.
pub fn print_table2(records: &[RunRecord]) {
    for topo in ["ring", "meshgrid"] {
        let base: Vec<&RunRecord> = records
            .iter()
            .filter(|r| r.topology == topo && r.method == "DSGD" && r.clients == 16)
            .collect();
        if base.is_empty() {
            continue;
        }
        let norm: f64 = base.iter().map(|r| r.gmp).sum::<f64>() / base.len() as f64;
        println!("\n== {topo} (normalized by DSGD@16 = {:.2}%) ==", norm * 100.0);
        println!("{:<12} {:>8} {:>12}", "method", "clients", "rel GMP%");
        let mut rows: Vec<(&str, usize, f64)> = vec![];
        for r in records.iter().filter(|r| r.topology == topo) {
            rows.push((&r.method, r.clients, r.gmp));
        }
        rows.sort_by(|a, b| (a.1, a.0).cmp(&(b.1, b.0)));
        for (m, n, g) in rows {
            println!("{:<12} {:>8} {:>12.2}", m, n, 100.0 * g / norm);
        }
    }
}

/// Table 3: single-client MeZO vs SubCGE across tasks.
pub fn table3(base: &ExperimentConfig, tasks: &[String]) -> Result<Vec<RunRecord>> {
    let mut records = vec![];
    for task in tasks {
        for method in [Method::Mezo, Method::SubCge] {
            let mut cfg = base.clone();
            cfg.method = method;
            cfg.task = task.clone();
            cfg.clients = 1;
            cfg.topology = Kind::Ring; // irrelevant at n=1
            records.push(run_one(cfg)?);
        }
    }
    Ok(records)
}

/// Fig 6: SubCGE sensitivity to rank × refresh period (single client).
pub fn fig6(
    base: &ExperimentConfig,
    tasks: &[String],
    ranks: &[usize],
    periods: &[usize],
) -> Result<Vec<RunRecord>> {
    let mut records = vec![];
    for task in tasks {
        for &rank in ranks {
            for &period in periods {
                let mut cfg = base.clone();
                cfg.method = Method::SubCge;
                cfg.task = task.clone();
                cfg.clients = 1;
                cfg.rank = rank;
                cfg.refresh = period;
                records.push(run_one(cfg)?);
            }
        }
    }
    Ok(records)
}

/// Render the fig6 rank × refresh-period GMP grids, one per task.
///
/// Cells are keyed by the records' `(task, rank, refresh)` provenance
/// fields (ISSUE 5) — the old renderer walked an iterator positionally
/// (with a consecutive-only `dedup` for tasks), so one missing or failed
/// cell silently shifted every subsequent cell and truncated the grid.
/// Absent cells (including every cell of a pre-ISSUE-5 file, which
/// recorded no rank/refresh) render as an explicit `--`.
pub fn render_fig6(records: &[RunRecord], ranks: &[usize], periods: &[usize]) -> String {
    use std::fmt::Write as _;
    let mut tasks: Vec<&str> = vec![];
    for r in records {
        if !tasks.contains(&r.task.as_str()) {
            tasks.push(&r.task);
        }
    }
    let mut out = String::new();
    for task in tasks {
        let _ = writeln!(out, "\n== {task}: GMP% by rank (rows) × refresh period (cols) ==");
        let _ = write!(out, "{:>6}", "rank");
        for p in periods {
            let _ = write!(out, "{p:>10}");
        }
        let _ = writeln!(out);
        for &rank in ranks {
            let _ = write!(out, "{rank:>6}");
            for &period in periods {
                let cell = records
                    .iter()
                    .find(|r| r.task == task && r.rank == rank && r.refresh == period);
                match cell {
                    Some(r) => {
                        let _ = write!(out, "{:>10.2}", 100.0 * r.gmp);
                    }
                    None => {
                        let _ = write!(out, "{:>10}", "--");
                    }
                }
            }
            let _ = writeln!(out);
        }
    }
    out
}

pub fn print_fig6(records: &[RunRecord], ranks: &[usize], periods: &[usize]) {
    print!("{}", render_fig6(records, ranks, periods));
}

/// Fig 7: delayed flooding k sweep vs the DZSGD reference line.
pub fn fig7(base: &ExperimentConfig, tasks: &[String], ks: &[usize]) -> Result<Vec<RunRecord>> {
    let mut records = vec![];
    for task in tasks {
        for &k in ks {
            let mut cfg = base.clone();
            cfg.method = Method::SeedFlood;
            cfg.task = task.clone();
            cfg.flood_steps = k;
            records.push(run_one(cfg)?);
        }
        // DZSGD reference
        let mut cfg = base.clone();
        cfg.method = Method::Dzsgd;
        cfg.task = task.clone();
        records.push(run_one(cfg)?);
    }
    Ok(records)
}

/// Robustness grid (ISSUE 2): the four-method comparison — DSGD, ChocoSGD,
/// DZSGD, SeedFlood — under unreliable-network & churn scenarios
/// ([`crate::netcond::preset`] names or raw spec strings). Presets pin the
/// topology they are named after.
///
/// Unlike fig3, every method runs the *same* number of iterations: fault
/// windows are expressed on the iteration clock, so the usual FO steps/10
/// scale would expose FO methods to a different (raw specs: possibly
/// empty) slice of the scenario and make the comparison meaningless. Only
/// the FO learning rate keeps its Table 5 scale.
pub fn churn(base: &ExperimentConfig, scenarios: &[String]) -> Result<Vec<RunRecord>> {
    let mut records = vec![];
    for scenario in scenarios {
        for method in [Method::Dsgd, Method::ChocoSgd, Method::Dzsgd, Method::SeedFlood] {
            let mut cfg = base.clone();
            cfg.method = method;
            cfg.netcond = scenario.clone();
            if !method.is_zeroth_order() {
                cfg.lr = base.lr * 10.0;
            }
            records.push(run_one(cfg)?);
        }
    }
    Ok(records)
}

/// Churn/loss table: how far does each method drift from consensus, how
/// much of its traffic survives, and what does staying robust cost —
/// including the repair traffic itself (`repairB`, gap-request summaries
/// + gap-fills or legacy re-floods).
pub fn print_churn(records: &[RunRecord]) {
    println!(
        "\n{:<12} {:<14} {:>8} {:>12} {:>8} {:>12} {:>10} {:>10}",
        "method", "scenario", "GMP%", "consensus", "deliv%", "cost/edge", "repairB", "staleness"
    );
    for r in records {
        let consensus = r.evals.last().map(|e| e.consensus_error).unwrap_or(0.0);
        let scenario = if r.netcond.is_empty() { "reliable" } else { r.netcond.as_str() };
        println!(
            "{:<12} {:<14} {:>8.2} {:>12.2e} {:>8.1} {:>12} {:>10} {:>10}",
            r.method,
            scenario,
            100.0 * r.gmp,
            consensus,
            100.0 * r.delivery_ratio,
            human_bytes(r.per_edge_bytes as u64),
            human_bytes(r.repair_bytes),
            r.max_staleness,
        );
    }
}

/// Fig 1: aggregate (cost, GMP) scatter out of a set of table-8 records.
pub fn print_fig1(records: &[RunRecord]) {
    println!("\n== Fig 1: task performance vs total per-edge communication ==");
    println!("{:<12} {:>14} {:>8}", "method", "cost/edge", "GMP%");
    let mut by_method: std::collections::BTreeMap<String, (f64, f64, usize)> = Default::default();
    for r in records {
        let e = by_method.entry(r.method.clone()).or_insert((0.0, 0.0, 0));
        e.0 += r.per_edge_bytes;
        e.1 += r.gmp;
        e.2 += 1;
    }
    for (m, (bytes, gmp, k)) in by_method {
        println!(
            "{:<12} {:>14} {:>8.2}",
            m,
            human_bytes((bytes / k as f64) as u64),
            100.0 * gmp / k as f64
        );
    }
}

/// Dispatch `seedflood experiment <id>` from the CLI.
pub fn dispatch(id: &str, base: ExperimentConfig, args: &crate::util::cli::Args) -> Result<()> {
    let tasks = args.get_list("tasks", &["sst2", "rte"]);
    match id {
        "fig3" | "table8" => {
            let topo = base.topology;
            let records = fig3(&base, &tasks, topo)?;
            print_table8(&records);
            print_fig1(&records);
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "fig1" => {
            // fig1 is a *view* over the fig3 grid: render it from saved
            // records when they exist instead of re-running every cell
            let records = match load_records("results/fig3.json") {
                Ok(r) if !r.is_empty() => {
                    println!("fig1: rendering from results/fig3.json ({} records)", r.len());
                    r
                }
                _ => fig3(&base, &tasks, base.topology)?,
            };
            print_fig1(&records);
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "scaling" | "fig4" | "table2" => {
            let counts = args.get_parse_list("clients-list", &[4usize, 8, 16])?;
            let records = scaling(&base, &tasks, &counts)?;
            print_table2(&records);
            // saved under the id actually invoked (the aliases used to
            // all clobber results/scaling.json)
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "table3" => {
            let records = table3(&base, &tasks)?;
            print_table8(&records);
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "fig6" => {
            let ranks = args.get_parse_list("ranks", &[8usize, 16, 32, 64])?;
            let periods = args.get_parse_list("periods", &[50usize, 500, 2000])?;
            let records = fig6(&base, &tasks, &ranks, &periods)?;
            print_fig6(&records, &ranks, &periods);
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "churn" => {
            let scenarios =
                args.get_list("scenarios", &["lossy-ring", "flaky-torus", "churn-er"]);
            let records = churn(&base, &scenarios)?;
            print_churn(&records);
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "fig7" => {
            let ks = args.get_parse_list("ks", &[1usize, 2, 4, 8, 16])?;
            let records = fig7(&base, &tasks, &ks)?;
            print_table8(&records);
            let p = save_records(id, &records)?;
            println!("saved {p}");
        }
        "hopgrid" => {
            let kind_names = args.get_list(
                "topologies",
                &["ring", "small-world", "scale-free", "hierarchical", "hub-spoke"],
            );
            let kinds: Vec<Kind> = kind_names
                .iter()
                .map(|s| {
                    Kind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown topology {s:?}"))
                })
                .collect::<Result<_>>()?;
            let ns = args.get_parse_list("hop-ns", &[64usize, 256, 1024, 4096])?;
            let eps: f64 = args.get_parse("gossip-eps", 1e-3)?;
            let cap: usize = args.get_parse("gossip-cap", 20_000)?;
            let cells = hopgrid::run(&kinds, &ns, base.topology_seed, eps, cap)?;
            hopgrid::print_table(&cells);
            let path = "results/hopgrid.json";
            hopgrid::save(&cells, path)?;
            println!("saved {path}");
        }
        other => anyhow::bail!(
            "unknown experiment {other:?}; have fig1, fig3/table8, scaling/fig4/table2, \
             table3, fig6, fig7, churn, hopgrid"
        ),
    }
    Ok(())
}

/// Build the shared "pretrained" θ⁰ that stands in for the paper's OPT
/// checkpoints (DESIGN.md#Substitutions): first-order training on a
/// multi-task mixture of planted-rule tasks whose seeds are disjoint from
/// every evaluation task, saved as a checkpoint all experiments load.
/// This puts the model in the fine-tuning regime where MeZO-style ZO
/// methods operate (Malladi et al. 2023 assume a pretrained LM).
pub fn pretrain(
    model: &str,
    artifacts_dir: &str,
    out_path: &str,
    mix_tasks: usize,
    steps: usize,
    lr: f32,
    seed: u64,
    target_acc: f32,
) -> Result<()> {
    use crate::data::{BatchSampler, Dataset, TaskSpec};
    use crate::model::{checkpoint, Manifest, ParamStore};
    use crate::runtime::Runtime;

    let manifest = Manifest::load(&format!("{artifacts_dir}/{model}_manifest.json"))?;
    let rt = Runtime::cpu(artifacts_dir)?;
    let exe_grad = rt.load(&manifest, "grad")?;
    let exe_loss = rt.load(&manifest, "loss")?;

    // mixture (DESIGN.md#Substitutions): the six eval-task *distributions*
    // on a sample stream disjoint from every train/val/test split (this is
    // what makes the eval tasks zero-shot feasible, playing the role of
    // OPT's pretraining corpus), plus background tasks with fresh seeds.
    let mut train = vec![];
    let mut val = vec![];
    for name in TaskSpec::all_names() {
        let spec = TaskSpec::named(name).unwrap();
        let ex =
            Dataset::pretrain_split(&spec, manifest.config.vocab, manifest.config.seq, 512);
        val.extend(ex[..64].to_vec());
        train.extend(ex[64..].to_vec());
    }
    let _ = mix_tasks; // per-task lexicon blocks are fixed; the corpus is
                       // the six task distributions on the pretrain stream
    let mut sampler = BatchSampler::new(train, crate::rng::mix(seed, 0x9E7A));
    let mut params = ParamStore::init(&manifest, seed);
    let mut momentum = params.zeros_like();
    let b = manifest.config.batch;
    let class_tokens = crate::data::CLASS_TOKENS.to_vec();
    let val_batches = crate::sim::batchify(&val, b);

    let loss_of = |params: &crate::tensor::ParamVec, ids: &[i32], labels: &[i32]| {
        let args = crate::runtime::loss_args(
            params, ids, vec![b, manifest.config.seq], labels, &class_tokens);
        let out = exe_loss.run(&args)?;
        anyhow::Ok((out[0].data[0], out[1].data[0]))
    };

    for t in 0..steps {
        let (ids, labels) = sampler.next_batch(b);
        let args = crate::runtime::loss_args(
            &params, &ids, vec![b, manifest.config.seq], &labels, &class_tokens);
        let out = exe_grad.run(&args)?;
        let loss = out[0].data[0];
        let grads = crate::tensor::ParamVec::new(params.names.clone(), out[1..].to_vec());
        // heavy-ball momentum SGD (pretraining only; baselines use plain SGD)
        momentum.scale(0.9);
        momentum.axpy(1.0, &grads);
        params.axpy(-lr, &momentum);
        if (t + 1) % 50 == 0 || t + 1 == steps {
            let mut correct = 0.0;
            let mut total = 0.0;
            for (ids, labels) in val_batches.iter().take(12) {
                let (_, c) = loss_of(&params, ids, labels)?;
                correct += c;
                total += labels.len() as f32;
            }
            let acc = correct / total;
            log::info!("pretrain step {}: loss {:.4} mix-val acc {:.3}", t + 1, loss, acc);
            // stop inside the paper's zero-shot band (Table 8 ZeroShot row:
            // 45–70%) so fine-tuning has headroom — a fully-converged
            // "pretrained" model would leave nothing for the methods to do
            if acc >= target_acc {
                log::info!("pretrain: target acc {target_acc} reached, stopping");
                break;
            }
        }
    }
    checkpoint::save(&params, out_path)?;
    println!("pretrained checkpoint saved to {out_path}");
    Ok(())
}


/// `seedflood report` — re-render the markdown tables from saved
/// `results/*.json` records (so EXPERIMENTS.md can be regenerated without
/// re-running anything). Record parsing lives in [`RunRecord::from_json`]
/// (shared with the sweep driver's resume path); sweep files (a JSON
/// object with a `cells` section) re-render their aggregate table.
pub fn report(paths: &[String]) -> Result<()> {
    for path in paths {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        if j.get("cells").is_ok() {
            let cells = sweep::parse_cells(&j)?;
            println!("\n### {path} (sweep, {} cells)", cells.len());
            print!("{}", sweep::render_table(&sweep::aggregate(&cells)));
            continue;
        }
        let records: Vec<RunRecord> =
            j.as_arr()?.iter().map(RunRecord::from_json).collect::<Result<_>>()?;
        println!("\n### {path} ({} records)", records.len());
        print_table8(&records);
        print_fig1(&records);
        if records.iter().any(|r| !r.netcond.is_empty()) {
            print_churn(&records);
        }
    }
    Ok(())
}
