//! `seedflood experiment hopgrid` — flooding vs gossip
//! message-rounds-to-consensus across topology families.
//!
//! The paper's information-decay argument says gossip averaging needs
//! Θ(1/spectral-gap) rounds to mix while flooding covers the graph in
//! diameter rounds. This experiment measures both empirically on the
//! same graphs: every client originates one update, flooding runs until
//! every client has heard every origin (round count certified against
//! [`Topology::diameter_bounds`] — the loop refuses to run past the
//! upper bound), and gossip runs scalar Metropolis averaging until the
//! worst-case deviation from the (preserved) mean falls below `eps` of
//! the initial spread. Where gossip does not converge within the round
//! cap the spectral estimate `ln(1/eps)/gap` stands in, flagged `est` —
//! on a 4096-ring that is millions of rounds, which is exactly the
//! point: the hop advantage `gossip/flood` grows with the graph, and
//! the table shows it growing alongside the certified diameter bounds.

use anyhow::Result;

use crate::flood::{flood_rounds, FloodState};
use crate::net::{MsgId, Network, SeedUpdate};
use crate::rng::Rng;
use crate::topology::{Kind, Topology};
use crate::util::json::Json;

/// One (topology kind, n) cell of the grid.
#[derive(Clone, Debug)]
pub struct HopCell {
    pub kind: String,
    pub n: usize,
    /// Certified diameter bounds `(lb, ub)` from BFS double sweeps.
    pub diam_lb: usize,
    pub diam_ub: usize,
    /// Empirical synchronous flood rounds until every client has seen
    /// every origin. Always within `[diam_lb, diam_ub]`.
    pub flood_rounds: usize,
    /// Messages the flood put on the wire in total.
    pub flood_messages: u64,
    /// Gossip rounds until max deviation ≤ eps × initial spread; when
    /// `gossip_est` is set, the cap was hit and this is the spectral
    /// estimate `ln(1/eps)/gap` instead of a measured count.
    pub gossip_rounds: usize,
    pub gossip_est: bool,
}

impl HopCell {
    /// Rounds-to-consensus ratio gossip/flood — the "hop advantage" of
    /// flooding one update everywhere over averaging it in.
    pub fn advantage(&self) -> f64 {
        self.gossip_rounds as f64 / self.flood_rounds.max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str(&self.kind)),
            ("n", Json::Num(self.n as f64)),
            ("diam_lb", Json::Num(self.diam_lb as f64)),
            ("diam_ub", Json::Num(self.diam_ub as f64)),
            ("flood_rounds", Json::Num(self.flood_rounds as f64)),
            ("flood_messages", Json::Num(self.flood_messages as f64)),
            ("gossip_rounds", Json::Num(self.gossip_rounds as f64)),
            ("gossip_est", Json::Bool(self.gossip_est)),
            ("advantage", Json::Num(self.advantage())),
        ])
    }
}

/// The grid's default topology families: one short-diameter extreme
/// (hub-spoke), one long (ring), and the three in between.
pub fn default_kinds() -> Vec<Kind> {
    vec![Kind::Ring, Kind::SmallWorld, Kind::ScaleFree, Kind::Hierarchical, Kind::HubSpoke]
}

/// All-origin flood on `topo` until full coverage, one synchronous round
/// at a time. Returns (rounds, total messages). The round loop is capped
/// by the certified diameter upper bound — flooding that has not covered
/// the graph by then indicates a broken graph or dedup filter, and the
/// cell errors rather than spinning.
pub fn flood_consensus_rounds(topo: &Topology) -> Result<(usize, u64)> {
    let n = topo.n;
    let (_, ub) = topo.diameter_bounds();
    let mut net = Network::new(topo.clone());
    let mut states: Vec<FloodState> = (0..n)
        .map(|_| {
            let mut st = FloodState::new();
            st.retain = 8;
            // every client is an origin: size the dedup floor universe
            // up front so the sparse filter compresses (flood/mod.rs)
            st.seen.reserve_origins(n);
            st
        })
        .collect();
    for (i, st) in states.iter_mut().enumerate() {
        st.inject(SeedUpdate {
            id: MsgId { origin: i as u32, step: 0 },
            seed: 0x5eed ^ i as u64,
            coeff: 1.0,
        });
    }
    let covered = |states: &[FloodState]| states.iter().all(|s| s.seen.len() == n);
    let mut rounds = 0;
    while !covered(&states) {
        anyhow::ensure!(
            rounds < ub,
            "flood on {} n={n} not covered after ub={ub} rounds",
            topo.kind
        );
        flood_rounds(&mut states, &mut net, 1, |_, _| {});
        rounds += 1;
    }
    Ok((rounds, net.acct.total_messages))
}

/// Scalar Metropolis gossip on `topo`: client i starts from a seeded
/// uniform draw, each round averages with neighbors under
/// [`Topology::mixing_weights`] (doubly stochastic, so the mean is
/// invariant). Returns (rounds, est): rounds until the max deviation
/// from the mean is ≤ `eps` × the initial spread, or — when `cap`
/// rounds do not get there — the spectral estimate `ln(1/eps)/gap`
/// with `est = true`.
pub fn gossip_consensus_rounds(topo: &Topology, seed: u64, eps: f64, cap: usize) -> (usize, bool) {
    let n = topo.n;
    let w = topo.mixing_weights();
    let mut x: Vec<f64> = (0..n)
        .map(|i| Rng::new(crate::rng::mix(seed, i as u64)).next_f64())
        .collect();
    let mean = x.iter().sum::<f64>() / n as f64;
    let spread = |x: &[f64]| x.iter().map(|v| (v - mean).abs()).fold(0.0, f64::max);
    let spread0 = spread(&x);
    if spread0 <= 0.0 {
        return (0, false);
    }
    for rounds in 0..=cap {
        if spread(&x) <= eps * spread0 {
            return (rounds, false);
        }
        if rounds == cap {
            break;
        }
        let mut y = vec![0.0; n];
        for (i, row) in w.iter().enumerate() {
            for &(j, wij) in row {
                y[i] += wij as f64 * x[j];
            }
        }
        x = y;
    }
    // cap hit: certify the order of magnitude spectrally instead. The
    // estimate is at least the cap — the measured rounds already proved
    // the true count exceeds it.
    let gap = topo.spectral_gap();
    let est = if gap > 1e-12 { ((1.0 / eps).ln() / gap).ceil() as usize } else { usize::MAX };
    (est.max(cap), true)
}

/// Run one grid cell. n must be ≥ 2 (n = 1 has no rounds to count).
pub fn run_cell(kind: Kind, n: usize, seed: u64, eps: f64, cap: usize) -> Result<HopCell> {
    anyhow::ensure!(n >= 2, "hopgrid needs n >= 2, got {n}");
    let topo = Topology::build(kind, n, seed);
    let (diam_lb, diam_ub) = topo.diameter_bounds();
    let (flood, flood_messages) = flood_consensus_rounds(&topo)?;
    anyhow::ensure!(
        diam_lb <= flood && flood <= diam_ub,
        "{} n={n}: flood rounds {flood} outside certified bounds [{diam_lb},{diam_ub}]",
        kind.name()
    );
    let (gossip, gossip_est) = gossip_consensus_rounds(&topo, seed, eps, cap);
    Ok(HopCell {
        kind: kind.name().to_string(),
        n,
        diam_lb,
        diam_ub,
        flood_rounds: flood,
        flood_messages,
        gossip_rounds: gossip,
        gossip_est,
    })
}

/// Run the full kinds × ns grid.
pub fn run(kinds: &[Kind], ns: &[usize], seed: u64, eps: f64, cap: usize) -> Result<Vec<HopCell>> {
    let mut cells = Vec::with_capacity(kinds.len() * ns.len());
    for &kind in kinds {
        for &n in ns {
            let cell = run_cell(kind, n, seed, eps, cap)?;
            log::info!(
                "hopgrid {} n={}: flood {} gossip {}{}",
                cell.kind,
                cell.n,
                cell.flood_rounds,
                cell.gossip_rounds,
                if cell.gossip_est { " (est)" } else { "" }
            );
            cells.push(cell);
        }
    }
    Ok(cells)
}

pub fn print_table(cells: &[HopCell]) {
    println!(
        "\n{:<14} {:>8} {:>12} {:>8} {:>12} {:>12} {:>10}",
        "topology", "n", "diam lb..ub", "flood", "gossip", "flood msgs", "advantage"
    );
    for c in cells {
        println!(
            "{:<14} {:>8} {:>12} {:>8} {:>12} {:>12} {:>9.1}x",
            c.kind,
            c.n,
            format!("{}..{}", c.diam_lb, c.diam_ub),
            c.flood_rounds,
            format!("{}{}", c.gossip_rounds, if c.gossip_est { "*" } else { "" }),
            c.flood_messages,
            c.advantage(),
        );
    }
    if cells.iter().any(|c| c.gossip_est) {
        println!("(* gossip cap hit — spectral estimate ln(1/eps)/gap)");
    }
}

pub fn save(cells: &[HopCell], path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let j = Json::Arr(cells.iter().map(HopCell::to_json).collect());
    std::fs::write(path, j.to_string_pretty() + "\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_flood_rounds_equal_the_exact_diameter() {
        let cell = run_cell(Kind::Ring, 16, 0, 1e-3, 20_000).unwrap();
        // synchronous flooding covers a graph in exactly diameter rounds
        assert_eq!(cell.flood_rounds, 8);
        assert!(cell.diam_lb <= 8 && 8 <= cell.diam_ub);
        assert!(!cell.gossip_est);
        // gossip on a ring is much slower than flooding
        assert!(cell.gossip_rounds > cell.flood_rounds);
        assert!(cell.advantage() > 1.0);
    }

    #[test]
    fn hub_spoke_floods_in_at_most_three_rounds() {
        let cell = run_cell(Kind::HubSpoke, 100, 0, 1e-3, 20_000).unwrap();
        assert!(cell.flood_rounds <= 3, "hub-spoke flood took {}", cell.flood_rounds);
        assert!(cell.flood_messages > 0);
    }

    #[test]
    fn gossip_cap_falls_back_to_the_spectral_estimate() {
        let topo = Topology::ring(64);
        let (rounds, est) = gossip_consensus_rounds(&topo, 0, 1e-6, 3);
        assert!(est, "a 3-round cap cannot mix a 64-ring to 1e-6");
        // the estimate is never below the cap the measurement disproved
        assert!(rounds >= 3);
        // uncapped, the same cell measures for real
        let (measured, est) = gossip_consensus_rounds(&topo, 0, 1e-2, 1_000_000);
        assert!(!est);
        assert!(measured > topo.diameter());
    }

    #[test]
    fn gossip_identical_values_converge_in_zero_rounds() {
        // spread0 == 0 short-circuit: an n=1 singleton has a single
        // client, so its one draw equals the mean exactly
        let topo = Topology::build(Kind::Ring, 1, 0);
        let (rounds, est) = gossip_consensus_rounds(&topo, 7, 1e-3, 100);
        assert_eq!((rounds, est), (0, false));
    }

    #[test]
    fn hierarchical_above_the_exact_diameter_limit_stays_certified() {
        // n = 1025 crosses EXACT_DIAMETER_LIMIT: Topology::diameter()
        // switches to the upper bound, and the hopgrid contract (lb ≤
        // flood ≤ ub) must hold on the bounds-only path too
        let cell = run_cell(Kind::Hierarchical, 1025, 0, 1e-3, 10).unwrap();
        assert!(cell.diam_lb <= cell.flood_rounds && cell.flood_rounds <= cell.diam_ub);
        let exact = Topology::hierarchical(1025).diameter_exact();
        assert_eq!(cell.flood_rounds, exact);
    }

    #[test]
    fn cells_roundtrip_through_json() {
        let cell = run_cell(Kind::SmallWorld, 32, 3, 1e-3, 20_000).unwrap();
        let j = cell.to_json();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), cell.kind);
        assert_eq!(j.get("flood_rounds").unwrap().as_usize().unwrap(), cell.flood_rounds);
        assert_eq!(j.get("advantage").unwrap().as_f64().unwrap(), cell.advantage());
    }
}
