//! Parallel multi-run sweep driver (ROADMAP item 1, ISSUE 5).
//!
//! A [`SweepSpec`] expands a configuration grid —
//! methods × topologies × netcond scenarios × rate specs × seeds — into
//! one [`ExperimentConfig`] per cell, fans the cells over the
//! [`crate::util::par`] scoped-thread pool (one full `run_with_env` per
//! cell, each on a [`crate::sim::shared_core`]-cached environment built
//! once per (model, task, clients) group), and aggregates the per-seed
//! records into per-group mean±std GMP / cost / staleness.
//!
//! Everything lands in a single `results/sweep_<name>.json`:
//!
//! ```json
//! { "name": "...",
//!   "cells":  [ { "key": { method, topology, netcond, rates, seed },
//!                 "record": { ...RunRecord... } }, ... ],
//!   "groups": [ { method, topology, netcond, rates, seeds,
//!                 gmp_mean, gmp_std, ... }, ... ] }
//! ```
//!
//! Sweeps are **resumable**: the output file is checkpointed after every
//! completed cell, and cells whose key is already present in it are
//! skipped on re-invocation — so an interrupted (Ctrl-C, OOM-killed),
//! partially failed, or partially *panicked* sweep (panics are caught and
//! charged to their cell) picks up where it left off, and a widened grid
//! re-runs only the new cells.
//!
//! # Determinism
//!
//! Cell results are collected in expansion order regardless of how the OS
//! schedules the workers, each cell runs with `threads = 1` (the sweep
//! pool owns the parallelism), and groups aggregate their seeds in
//! expansion order — so the `groups` section (and every trajectory field
//! of `cells`; wall-clock timing necessarily varies) is bit-identical for
//! every `--threads` value (tests/sweep.rs).
//!
//! # Grammar
//!
//! CLI: `--methods seedflood,dsgd` `--topologies ring,torus`
//! `--netconds reliable,lossy-ring` (`reliable`/`none`/empty = the fault-
//! free network) `--rates uniform/lognormal:0.5` (slash-separated — rate
//! specs contain commas) `--seeds 0,1,2`. The same axes live in a TOML
//! `[sweep]` table (string values, same separators) under `--config
//! <file.toml>`, whose root table holds ordinary experiment keys;
//! precedence is CLI > TOML > defaults. Cells with a non-uniform rate
//! spec automatically select the event engine.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::config::{toml, ExperimentConfig, Method};
use crate::metrics::RunRecord;
use crate::sched::{RateSpec, TimeModel};
use crate::sim::{self, Env};
use crate::topology::Kind;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::{human_bytes, par, stats};

/// Grid coordinates of one sweep cell. The key — not the possibly
/// preset-pinned topology the run reports — is what resume matching and
/// grouping use, so a `lossy-ring` cell keyed under `topology = "ring"`
/// stays addressable even though its record says the same thing.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellKey {
    pub method: String,
    pub topology: String,
    pub netcond: String,
    pub rates: String,
    pub seed: u64,
}

impl CellKey {
    /// Aggregation identity: every axis except the seed.
    pub fn group(&self) -> GroupKey {
        GroupKey {
            method: self.method.clone(),
            topology: self.topology.clone(),
            netcond: self.netcond.clone(),
            rates: self.rates.clone(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("topology", Json::str(&self.topology)),
            ("netcond", Json::str(&self.netcond)),
            ("rates", Json::str(&self.rates)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<CellKey> {
        Ok(CellKey {
            method: j.get("method")?.as_str()?.to_string(),
            topology: j.get("topology")?.as_str()?.to_string(),
            netcond: j.get("netcond")?.as_str()?.to_string(),
            rates: j.get("rates")?.as_str()?.to_string(),
            seed: j.get("seed")?.as_f64()? as u64,
        })
    }
}

/// A [`CellKey`] minus the seed: the unit sweep statistics aggregate over.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct GroupKey {
    pub method: String,
    pub topology: String,
    pub netcond: String,
    pub rates: String,
}

/// Per-group (mean, sample std) over the group's seeds. Std is 0 for a
/// single seed ([`stats::stddev`]).
#[derive(Clone, Debug)]
pub struct GroupAgg {
    pub key: GroupKey,
    pub seeds: usize,
    pub gmp: (f64, f64),
    pub final_loss: (f64, f64),
    pub per_edge_bytes: (f64, f64),
    pub staleness_p99: (f64, f64),
    pub delivery_ratio: (f64, f64),
}

impl GroupAgg {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.key.method)),
            ("topology", Json::str(&self.key.topology)),
            ("netcond", Json::str(&self.key.netcond)),
            ("rates", Json::str(&self.key.rates)),
            ("seeds", Json::num(self.seeds as f64)),
            ("gmp_mean", Json::num(self.gmp.0)),
            ("gmp_std", Json::num(self.gmp.1)),
            ("final_loss_mean", Json::num(self.final_loss.0)),
            ("final_loss_std", Json::num(self.final_loss.1)),
            ("per_edge_bytes_mean", Json::num(self.per_edge_bytes.0)),
            ("per_edge_bytes_std", Json::num(self.per_edge_bytes.1)),
            ("staleness_p99_mean", Json::num(self.staleness_p99.0)),
            ("staleness_p99_std", Json::num(self.staleness_p99.1)),
            ("delivery_ratio_mean", Json::num(self.delivery_ratio.0)),
            ("delivery_ratio_std", Json::num(self.delivery_ratio.1)),
        ])
    }
}

/// Group completed cells by [`CellKey::group`] and reduce each metric to
/// mean±std over the group's seeds, in deterministic (BTreeMap key,
/// seeds in cell order) order.
pub fn aggregate(cells: &[(CellKey, RunRecord)]) -> Vec<GroupAgg> {
    let mut groups: BTreeMap<GroupKey, Vec<&RunRecord>> = BTreeMap::new();
    for (k, r) in cells {
        groups.entry(k.group()).or_default().push(r);
    }
    groups
        .into_iter()
        .map(|(key, rs)| {
            let col = |f: fn(&RunRecord) -> f64| {
                let xs: Vec<f64> = rs.iter().map(|&r| f(r)).collect();
                (stats::mean(&xs), stats::stddev(&xs))
            };
            GroupAgg {
                key,
                seeds: rs.len(),
                gmp: col(|r| r.gmp),
                final_loss: col(|r| r.final_loss),
                per_edge_bytes: col(|r| r.per_edge_bytes),
                staleness_p99: col(|r| r.staleness_p99),
                delivery_ratio: col(|r| r.delivery_ratio),
            }
        })
        .collect()
}

/// The comparison table a finished sweep prints: one row per group.
pub fn render_table(groups: &[GroupAgg]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "\n{:<12} {:<10} {:<14} {:<18} {:>5} {:>14} {:>15} {:>19} {:>12}",
        "method", "topology", "netcond", "rates", "seeds", "GMP%±std", "loss±std",
        "cost/edge±std", "stale p99±"
    );
    for g in groups {
        let nc = if g.key.netcond.is_empty() { "reliable" } else { g.key.netcond.as_str() };
        let _ = writeln!(
            out,
            "{:<12} {:<10} {:<14} {:<18} {:>5} {:>8.2}±{:<5.2} {:>9.4}±{:<5.4} \
             {:>10}±{:<8} {:>7.1}±{:<4.1}",
            g.key.method,
            g.key.topology,
            nc,
            g.key.rates,
            g.seeds,
            100.0 * g.gmp.0,
            100.0 * g.gmp.1,
            g.final_loss.0,
            g.final_loss.1,
            human_bytes(g.per_edge_bytes.0 as u64),
            human_bytes(g.per_edge_bytes.1 as u64),
            g.staleness_p99.0,
            g.staleness_p99.1,
        );
    }
    out
}

/// The sweep grid: axis value lists plus the base config every cell
/// inherits its remaining fields from.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// Output identity: the sweep writes `<out_dir>/sweep_<name>.json`.
    pub name: String,
    pub methods: Vec<Method>,
    pub topologies: Vec<Kind>,
    /// netcond scenario specs; "" = the reliable network.
    pub netconds: Vec<String>,
    /// rate specs (see [`RateSpec`]); non-uniform entries run their cells
    /// under the event engine.
    pub rates: Vec<String>,
    pub seeds: Vec<u64>,
    pub base: ExperimentConfig,
    /// Sweep-pool width: how many cells run concurrently (0 = all cores).
    /// Cells themselves run with `threads = 1`.
    pub threads: usize,
    pub out_dir: String,
}

impl SweepSpec {
    /// Single-cell spec around `base`: every axis defaults to the base
    /// config's value, so axes are opt-in per dimension.
    pub fn new(base: ExperimentConfig) -> SweepSpec {
        SweepSpec {
            name: "default".into(),
            methods: vec![base.method],
            topologies: vec![base.topology],
            netconds: vec![base.netcond.clone()],
            rates: vec![base.rates.clone()],
            seeds: vec![base.seed],
            threads: base.threads,
            out_dir: "results".into(),
            base,
        }
    }

    /// Build from the CLI: `--config <file.toml>` (root table = experiment
    /// keys, `[sweep]` table = axes) over the defaults, then CLI options
    /// over both. `--rates` is the sweep axis here (slash-separated), so
    /// it is withheld from the base-config overlay.
    pub fn from_args(args: &Args) -> Result<SweepSpec> {
        let mut base = ExperimentConfig::default();
        let mut doc = None;
        if let Some(path) = args.get("config") {
            let d = toml::parse_file(path)
                .with_context(|| format!("reading sweep config {path}"))?;
            base.apply_toml(&d.root)
                .with_context(|| format!("applying root table of {path}"))?;
            doc = Some(d);
        }
        let mut cfg_args = args.clone();
        cfg_args.options.remove("rates"); // the axis, not the base field
        base.overlay_args(&cfg_args)?;
        base.validate()?;
        let mut spec = SweepSpec::new(base);
        if let Some(tbl) = doc.as_ref().and_then(|d| d.section("sweep")) {
            spec.apply_toml(tbl)?;
        }
        spec.overlay_args(args)?;
        spec.validate()?;
        Ok(spec)
    }

    /// Apply a TOML `[sweep]` table (string values, CLI separators).
    pub fn apply_toml(&mut self, tbl: &toml::Table) -> Result<()> {
        for (k, v) in tbl.iter() {
            match k.as_str() {
                "name" => self.name = v.as_str()?.to_string(),
                "methods" => self.methods = parse_methods(v.as_str()?)?,
                "topologies" => self.topologies = parse_topologies(v.as_str()?)?,
                "netconds" => self.netconds = split_netconds(v.as_str()?),
                "rates" => self.rates = split_rates(v.as_str()?),
                "seeds" => self.seeds = parse_seeds(v.as_str()?)?,
                "out_dir" => self.out_dir = v.as_str()?.to_string(),
                other => bail!("unknown [sweep] key {other:?}"),
            }
        }
        Ok(())
    }

    fn overlay_args(&mut self, args: &Args) -> Result<()> {
        if let Some(n) = args.get("name") {
            self.name = n.to_string();
        }
        if let Some(s) = args.get("methods") {
            self.methods = parse_methods(s)?;
        }
        if let Some(s) = args.get("topologies") {
            self.topologies = parse_topologies(s)?;
        }
        if let Some(s) = args.get("netconds") {
            self.netconds = split_netconds(s);
        }
        if let Some(s) = args.get("rates") {
            self.rates = split_rates(s);
        }
        if let Some(s) = args.get("seeds") {
            self.seeds = parse_seeds(s)?;
        }
        if let Some(d) = args.get("out-dir") {
            self.out_dir = d.to_string();
        }
        self.threads = self.base.threads;
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty()
            || !self.name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            bail!("sweep name {:?} must be non-empty [A-Za-z0-9_-]", self.name);
        }
        for (axis, len) in [
            ("methods", self.methods.len()),
            ("topologies", self.topologies.len()),
            ("netconds", self.netconds.len()),
            ("rates", self.rates.len()),
            ("seeds", self.seeds.len()),
        ] {
            if len == 0 {
                bail!("sweep axis {axis} is empty");
            }
        }
        // resume matching compares seeds parsed back from JSON, where
        // numbers are f64: exact only up to 2^53
        for &s in &self.seeds {
            if s > (1u64 << 53) {
                bail!("seed {s} exceeds 2^53 and would not round-trip through the \
                       results file exactly (resume matching); use a smaller seed");
            }
        }
        for r in &self.rates {
            RateSpec::parse(r).with_context(|| format!("sweep rates entry {r:?}"))?;
        }
        for nc in &self.netconds {
            if nc.is_empty() {
                continue;
            }
            let (pin, _) = crate::netcond::resolve(nc, self.base.clients, self.base.steps)
                .with_context(|| format!("sweep netconds entry {nc:?}"))?;
            // a preset pins its topology: crossing it with a topologies
            // axis would run identical cells mislabeled by axis value
            if let Some(kind) = pin {
                if self.topologies.len() > 1 {
                    bail!(
                        "netcond {nc:?} pins the topology to {kind:?}; crossing it \
                         with {} topologies would run duplicate cells labeled with \
                         the wrong topology — use a single --topologies value (or a \
                         raw netcond spec, which leaves the topology free)",
                        self.topologies.len()
                    );
                }
            }
        }
        Ok(())
    }

    /// Expand the grid into (key, config) cells, in axis order (methods
    /// outermost, seeds innermost). Non-uniform rate cells select the
    /// event engine; every cell runs sequentially within itself
    /// (`threads = 1` — the sweep pool owns the parallelism, and per-run
    /// results are thread-count-invariant anyway).
    pub fn expand(&self) -> Vec<(CellKey, ExperimentConfig)> {
        let mut cells = vec![];
        for &method in &self.methods {
            for &topo in &self.topologies {
                for nc in &self.netconds {
                    for rt in &self.rates {
                        for &seed in &self.seeds {
                            let mut cfg = self.base.clone();
                            cfg.method = method;
                            cfg.topology = topo;
                            cfg.netcond = nc.clone();
                            cfg.rates = rt.clone();
                            cfg.seed = seed;
                            cfg.threads = 1;
                            if !RateSpec::parse(rt)
                                .map(|s| s.is_uniform())
                                .unwrap_or(true)
                            {
                                cfg.time_model = TimeModel::Event;
                            }
                            let key = CellKey {
                                method: method.name().to_string(),
                                topology: topo.name().to_string(),
                                netcond: nc.clone(),
                                rates: rt.clone(),
                                seed,
                            };
                            cells.push((key, cfg));
                        }
                    }
                }
            }
        }
        cells
    }

    pub fn path(&self) -> String {
        format!("{}/sweep_{}.json", self.out_dir, self.name)
    }

    /// Run the sweep: skip cells already in the output file, pre-build
    /// each distinct Env core exactly once, fan the rest over the thread
    /// pool, aggregate, and save. Individual cell failures — `Err`s *and*
    /// panics (caught per cell) — don't abort the sweep; the output file
    /// is checkpointed after every completed cell, so an interrupted
    /// (Ctrl-C, OOM-killed) invocation also resumes from what finished.
    pub fn run(&self) -> Result<SweepOutcome> {
        self.validate()?;
        let path = self.path();
        let done = load_done(&path)?;
        let mut seen = BTreeSet::new();
        let cells: Vec<(CellKey, ExperimentConfig)> = self
            .expand()
            .into_iter()
            .filter(|(k, _)| seen.insert(k.clone())) // repeated list entries
            .collect();
        let mut pending: Vec<(CellKey, ExperimentConfig)> = cells
            .iter()
            .filter(|(k, _)| !done.contains_key(k))
            .cloned()
            .collect();
        let skipped = cells.len() - pending.len();
        log::info!(
            "sweep {}: {} cells ({} already in {}), running {} on {} threads",
            self.name,
            cells.len(),
            skipped,
            path,
            pending.len(),
            par::num_threads(self.threads)
        );
        // build each distinct (model, task, clients) core once, before the
        // fan-out — workers then only ever hit the cache
        for (_, cfg) in &pending {
            sim::shared_core(cfg)?;
        }
        let progress: Mutex<BTreeMap<CellKey, RunRecord>> = Mutex::new(BTreeMap::new());
        let results: Vec<(CellKey, Result<RunRecord>)> =
            par::par_map_mut(&mut pending, self.threads, |_, (key, cfg)| {
                // a panic (e.g. an assert deep in an algorithm) must cost
                // one cell, not the sweep — and not the pool worker
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    sim::shared_core(cfg)
                        .and_then(|core| Env::from_core(core, cfg.clone()))
                        .and_then(|env| sim::run_with_env(&env))
                }))
                .unwrap_or_else(|p| {
                    Err(anyhow::anyhow!("cell panicked: {}", panic_message(p.as_ref())))
                });
                if let Ok(rec) = &run {
                    // checkpoint: rewrite the file with everything
                    // completed so far, so interruption loses nothing
                    let mut prog = progress.lock().unwrap_or_else(|p| p.into_inner());
                    prog.insert(key.clone(), rec.clone());
                    let snapshot = assemble(&cells, &done, &prog);
                    if let Err(e) = save(&path, &self.name, &snapshot, &aggregate(&snapshot)) {
                        log::warn!("sweep {}: checkpoint save failed: {e}", self.name);
                    }
                }
                (key.clone(), run)
            });
        let mut failed = vec![];
        for (key, r) in results {
            if let Err(e) = r {
                failed.push((key, format!("{e:?}")));
            }
        }
        let fresh = progress.into_inner().unwrap_or_else(|p| p.into_inner());
        let ran = fresh.len();
        let out_cells = assemble(&cells, &done, &fresh);
        let groups = aggregate(&out_cells);
        save(&path, &self.name, &out_cells, &groups)?;
        Ok(SweepOutcome { path, ran, skipped, failed, cells: out_cells, groups })
    }
}

/// What [`SweepSpec::run`] did and produced.
pub struct SweepOutcome {
    pub path: String,
    /// cells executed this invocation
    pub ran: usize,
    /// cells skipped because the output file already had them
    pub skipped: usize,
    pub failed: Vec<(CellKey, String)>,
    /// every completed cell (resumed + fresh), in expansion order
    pub cells: Vec<(CellKey, RunRecord)>,
    pub groups: Vec<GroupAgg>,
}

/// Parse the `cells` section of a sweep results file (also used by
/// `seedflood report` to re-render sweep tables from disk).
pub fn parse_cells(j: &Json) -> Result<Vec<(CellKey, RunRecord)>> {
    j.get("cells")?
        .as_arr()?
        .iter()
        .map(|c| Ok((CellKey::from_json(c.get("key")?)?, RunRecord::from_json(c.get("record")?)?)))
        .collect()
}

/// Completed cells in output order: grid cells (expansion order, resumed
/// before fresh) first, then completed cells outside the current grid (a
/// narrower re-invocation) — those are preserved, never silently deleted.
fn assemble(
    cells: &[(CellKey, ExperimentConfig)],
    done: &BTreeMap<CellKey, RunRecord>,
    fresh: &BTreeMap<CellKey, RunRecord>,
) -> Vec<(CellKey, RunRecord)> {
    let mut out: Vec<(CellKey, RunRecord)> = cells
        .iter()
        .filter_map(|(k, _)| {
            done.get(k).or_else(|| fresh.get(k)).map(|r| (k.clone(), r.clone()))
        })
        .collect();
    let grid_keys: BTreeSet<&CellKey> = cells.iter().map(|(k, _)| k).collect();
    for (k, r) in done {
        if !grid_keys.contains(k) {
            out.push((k.clone(), r.clone()));
        }
    }
    out
}

/// Best-effort rendering of a caught panic payload.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn load_done(path: &str) -> Result<BTreeMap<CellKey, RunRecord>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(_) => return Ok(BTreeMap::new()), // no file yet: nothing done
    };
    let j = Json::parse(&text).with_context(|| {
        format!("existing sweep file {path} is not valid JSON (delete it to start over)")
    })?;
    Ok(parse_cells(&j)
        .with_context(|| format!("existing sweep file {path} has an unreadable cell"))?
        .into_iter()
        .collect())
}

fn save(
    path: &str,
    name: &str,
    cells: &[(CellKey, RunRecord)],
    groups: &[GroupAgg],
) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let j = Json::obj(vec![
        ("name", Json::str(name)),
        (
            "cells",
            Json::Arr(
                cells
                    .iter()
                    .map(|(k, r)| {
                        Json::obj(vec![("key", k.to_json()), ("record", r.to_json())])
                    })
                    .collect(),
            ),
        ),
        ("groups", Json::Arr(groups.iter().map(|g| g.to_json()).collect())),
    ]);
    std::fs::write(path, j.to_string_pretty())?;
    Ok(())
}

fn parse_methods(s: &str) -> Result<Vec<Method>> {
    s.split(',')
        .map(|x| x.trim())
        .filter(|x| !x.is_empty())
        .map(|x| Method::parse(x).ok_or_else(|| anyhow::anyhow!("unknown method {x:?}")))
        .collect()
}

fn parse_topologies(s: &str) -> Result<Vec<Kind>> {
    s.split(',')
        .map(|x| x.trim())
        .filter(|x| !x.is_empty())
        .map(|x| Kind::parse(x).ok_or_else(|| anyhow::anyhow!("unknown topology {x:?}")))
        .collect()
}

/// Comma-separated netcond scenarios; `reliable`/`none` (and a bare empty
/// element, e.g. `--netconds ,lossy-ring`) mean the fault-free network.
fn split_netconds(s: &str) -> Vec<String> {
    s.split(',')
        .map(|x| x.trim())
        .map(|x| {
            if x.eq_ignore_ascii_case("reliable") || x.eq_ignore_ascii_case("none") {
                String::new()
            } else {
                x.to_string()
            }
        })
        .collect()
}

/// Slash-separated rate specs (rate specs contain commas:
/// `stragglers:0.25,4`). An empty list entry means `uniform`.
fn split_rates(s: &str) -> Vec<String> {
    s.split('/')
        .map(|x| x.trim())
        .map(|x| if x.is_empty() { "uniform".to_string() } else { x.to_string() })
        .collect()
}

fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    s.split(',')
        .map(|x| x.trim())
        .filter(|x| !x.is_empty())
        .map(|x| x.parse::<u64>().map_err(|e| anyhow::anyhow!("seed {x:?}: {e}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_parsers() {
        assert_eq!(parse_methods("seedflood, dsgd").unwrap().len(), 2);
        assert!(parse_methods("sgd").is_err());
        assert_eq!(parse_topologies("ring,mesh").unwrap(), vec![Kind::Ring, Kind::Meshgrid]);
        assert!(parse_topologies("donut").is_err());
        assert_eq!(split_netconds("reliable,lossy-ring,none"), vec!["", "lossy-ring", ""]);
        assert_eq!(split_netconds(",churn-er"), vec!["", "churn-er"]);
        assert_eq!(
            split_rates("uniform/stragglers:0.25,4/lognormal:0.5"),
            vec!["uniform", "stragglers:0.25,4", "lognormal:0.5"]
        );
        assert_eq!(parse_seeds("0, 1,2").unwrap(), vec![0, 1, 2]);
        assert!(parse_seeds("0,x").is_err());
    }

    #[test]
    fn expand_crosses_every_axis_and_upgrades_time_model() {
        let mut spec = SweepSpec::new(ExperimentConfig::default());
        spec.methods = vec![Method::SeedFlood, Method::Dsgd];
        spec.topologies = vec![Kind::Ring, Kind::Complete];
        spec.netconds = vec!["".into(), "lossy-ring".into()];
        spec.rates = vec!["uniform".into(), "lognormal:0.5".into()];
        spec.seeds = vec![0, 1, 2];
        let cells = spec.expand();
        assert_eq!(cells.len(), 2 * 2 * 2 * 2 * 3);
        // no duplicate coordinates
        let keys: BTreeSet<&CellKey> = cells.iter().map(|(k, _)| k).collect();
        assert_eq!(keys.len(), cells.len());
        for (key, cfg) in &cells {
            assert_eq!(cfg.threads, 1, "cells must not nest parallelism");
            assert_eq!(cfg.seed, key.seed);
            let expect_event = key.rates != "uniform";
            assert_eq!(
                cfg.time_model == TimeModel::Event,
                expect_event,
                "{key:?} time model"
            );
        }
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let spec = SweepSpec::new(ExperimentConfig::default());
        spec.validate().unwrap();
        let mut bad = spec.clone();
        bad.name = "no spaces".into();
        assert!(bad.validate().is_err());
        let mut bad = spec.clone();
        bad.seeds.clear();
        assert!(bad.validate().is_err());
        let mut bad = spec.clone();
        bad.rates = vec!["warp:9".into()];
        assert!(bad.validate().is_err());
        let mut bad = spec.clone();
        bad.netconds = vec!["loss=nope".into()];
        assert!(bad.validate().is_err());
        // seeds above 2^53 would not round-trip through the JSON file
        let mut bad = spec.clone();
        bad.seeds = vec![u64::MAX];
        assert!(bad.validate().is_err());
        assert!(SweepSpec { seeds: vec![1 << 53], ..spec.clone() }.validate().is_ok());
    }

    #[test]
    fn validate_rejects_pinned_preset_crossed_with_topologies() {
        // lossy-ring pins Kind::Ring: crossing it with a 2-topology axis
        // would run identical cells labeled ring and torus
        let mut spec = SweepSpec::new(ExperimentConfig::default());
        spec.topologies = vec![Kind::Ring, Kind::Torus];
        spec.netconds = vec!["lossy-ring".into()];
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("pins the topology"), "{err}");
        // a single topology value is fine (the preset still pins it)...
        spec.topologies = vec![Kind::Torus];
        spec.validate().unwrap();
        // ...and raw specs leave the topology axis free
        spec.topologies = vec![Kind::Ring, Kind::Torus];
        spec.netconds = vec!["loss=0.05".into()];
        spec.validate().unwrap();
    }

    #[test]
    fn aggregate_groups_over_seeds_only() {
        let rec = |gmp: f64| RunRecord { gmp, delivery_ratio: 1.0, ..Default::default() };
        let key = |m: &str, seed| CellKey {
            method: m.into(),
            topology: "ring".into(),
            netcond: String::new(),
            rates: "uniform".into(),
            seed,
        };
        let cells = vec![
            (key("A", 0), rec(0.5)),
            (key("A", 1), rec(0.7)),
            (key("B", 0), rec(0.9)),
        ];
        let groups = aggregate(&cells);
        assert_eq!(groups.len(), 2);
        let a = groups.iter().find(|g| g.key.method == "A").unwrap();
        assert_eq!(a.seeds, 2);
        assert!((a.gmp.0 - 0.6).abs() < 1e-12);
        assert!(a.gmp.1 > 0.0);
        let b = groups.iter().find(|g| g.key.method == "B").unwrap();
        assert_eq!((b.seeds, b.gmp.1), (1, 0.0));
        assert!(render_table(&groups).contains("reliable"));
    }
}
