//! TOML-subset parser: `[section]` headers and `key = value` pairs where
//! value is a string, integer, float or boolean. Comments with `#`.
//! Covers everything `configs/*.toml` needs; arrays/tables-of-tables are
//! intentionally out of scope.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

pub type Table = BTreeMap<String, Value>;

#[derive(Clone, Debug, Default)]
pub struct Document {
    /// keys before any [section]
    pub root: Table,
    pub sections: BTreeMap<String, Table>,
}

impl Document {
    pub fn section(&self, name: &str) -> Option<&Table> {
        self.sections.get(name)
    }
}

pub fn parse(src: &str) -> Result<Document> {
    let mut doc = Document::default();
    let mut current: Option<String> = None;
    for (lineno, raw) in src.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: unterminated section header", lineno + 1);
            };
            let name = name.trim().to_string();
            doc.sections.entry(name.clone()).or_default();
            current = Some(name);
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("line {}: expected key = value, got {line:?}", lineno + 1);
        };
        let key = k.trim().to_string();
        let value = parse_value(v.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        let tbl = match &current {
            Some(s) => doc.sections.get_mut(s).unwrap(),
            None => &mut doc.root,
        };
        tbl.insert(key, value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // naive but sufficient: no '#' inside our config strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            bail!("unterminated string {s:?}");
        };
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

pub fn parse_file(path: &str) -> Result<Document> {
    parse(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document() {
        let doc = parse(
            r#"
# paper Table 5 defaults
title = "seedflood"
steps = 5000

[seedflood]
lr = 1e-5          # swept
rank = 32
flood_full = true

[dsgd]
lr = 1e-4
"#,
        )
        .unwrap();
        assert_eq!(doc.root["title"], Value::Str("seedflood".into()));
        assert_eq!(doc.root["steps"], Value::Int(5000));
        let sf = doc.section("seedflood").unwrap();
        assert_eq!(sf["lr"].as_float().unwrap(), 1e-5);
        assert_eq!(sf["rank"].as_int().unwrap(), 32);
        assert!(sf["flood_full"].as_bool().unwrap());
        assert!(doc.section("dsgd").is_some());
    }

    #[test]
    fn int_coerces_to_float() {
        let doc = parse("x = 3\n").unwrap();
        assert_eq!(doc.root["x"].as_float().unwrap(), 3.0);
    }

    #[test]
    fn errors() {
        assert!(parse("[oops\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse("x = \"unterminated\n").is_err());
        assert!(parse("x = 1.2.3\n").is_err());
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = parse("x = \"a#b\"\n").unwrap();
        assert_eq!(doc.root["x"].as_str().unwrap(), "a#b");
    }
}
