//! Experiment configuration: a TOML-subset parser (no `serde` facade in
//! this offline image) + the typed [`ExperimentConfig`] consumed by the
//! simulator. Defaults mirror the paper's Table 5 hyperparameters, scaled
//! to the substitute substrate where noted.

pub mod toml;

use anyhow::{bail, Result};

use crate::flood::RepairMode;
use crate::sched::{RateSpec, TimeModel};
use crate::topology::Kind;
use crate::util::cli::Args;

/// Which training algorithm to run (every method in the paper's grids).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Dsgd,
    ChocoSgd,
    DsgdLora,
    ChocoLora,
    Dzsgd,
    DzsgdLora,
    SeedFlood,
    /// single-client MeZO (Table 3 baseline)
    Mezo,
    /// single-client SubCGE (Table 3)
    SubCge,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "dsgd" => Method::Dsgd,
            "chocosgd" | "choco" => Method::ChocoSgd,
            "dsgd-lora" | "dsgdlora" => Method::DsgdLora,
            "choco-lora" | "chocolora" => Method::ChocoLora,
            "dzsgd" => Method::Dzsgd,
            "dzsgd-lora" | "dzsgdlora" => Method::DzsgdLora,
            "seedflood" => Method::SeedFlood,
            "mezo" => Method::Mezo,
            "subcge" => Method::SubCge,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dsgd => "DSGD",
            Method::ChocoSgd => "ChocoSGD",
            Method::DsgdLora => "DSGD-LoRA",
            Method::ChocoLora => "Choco-LoRA",
            Method::Dzsgd => "DZSGD",
            Method::DzsgdLora => "DZSGD-LoRA",
            Method::SeedFlood => "SeedFlood",
            Method::Mezo => "MeZO",
            Method::SubCge => "SubCGE",
        }
    }

    pub fn is_zeroth_order(&self) -> bool {
        matches!(
            self,
            Method::Dzsgd | Method::DzsgdLora | Method::SeedFlood | Method::Mezo | Method::SubCge
        )
    }

    pub fn is_lora(&self) -> bool {
        matches!(self, Method::DsgdLora | Method::ChocoLora | Method::DzsgdLora)
    }
}

/// Full experiment description. Paper Table 5 defaults, with iteration
/// counts scaled by `--steps` for the CPU substrate.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub method: Method,
    pub model: String,
    pub task: String,
    pub clients: usize,
    pub topology: Kind,
    pub topology_seed: u64,
    /// total local optimization steps (paper: 5000 ZO / 500 FO)
    pub steps: usize,
    /// local steps per communication round (paper: 5)
    pub local_steps: usize,
    pub lr: f32,
    pub batch: usize,
    /// ZO perturbation scale ε (paper: 1e-3)
    pub eps: f32,
    /// SubCGE subspace rank r (paper: 32 / 64)
    pub rank: usize,
    /// SubCGE refresh period τ (paper: 1000 / 5000)
    pub refresh: usize,
    /// flooding steps per iteration; 0 = network diameter (paper default)
    pub flood_steps: usize,
    /// ChocoSGD top-K keep ratio (paper: 0.01 == 99% sparsification)
    pub topk_ratio: f32,
    /// ChocoSGD consensus step size (paper: 1)
    pub consensus_lr: f32,
    pub lora_rank: usize,
    pub seed: u64,
    /// evaluate GMP every `eval_every` steps (0 = only at end)
    pub eval_every: usize,
    pub artifacts_dir: String,
    /// shared θ⁰ checkpoint (stands in for the paper's pretrained OPT);
    /// empty = random init
    pub init_from: String,
    /// SeedFlood: use the 9-byte µ-law-quantized message wire format
    /// (Zelikman et al. 2023 ablation)
    pub quantize_msgs: bool,
    /// label-skew heterogeneity: Dirichlet α for the client partition
    /// (0 = the paper's uniform split)
    pub dirichlet_alpha: f64,
    /// unreliable-network & churn scenario: a [`crate::netcond`] spec
    /// string (`"loss=0.05;node:3@10..20"`) or preset name (`lossy-ring`,
    /// `flaky-torus`, `churn-er` — presets also pin the topology). Empty =
    /// the paper's reliable static graph.
    pub netcond: String,
    /// SeedFlood repair-window capacity: how many recent messages each
    /// client retains for netcond repair (gap-fill responses / re-floods).
    /// 0 retains everything — required for `repair_mode = reflood` to
    /// replay the full history; the default keeps per-client memory
    /// O(n + window) on long runs
    pub flood_retain: usize,
    /// how SeedFlood answers netcond repair triggers: `gap` (summary +
    /// gap-fill, O(gap) on the wire — default) or `reflood` (legacy full
    /// re-flood of the retention window)
    pub repair_mode: RepairMode,
    /// worker threads for the local-step fan-out (1 = sequential,
    /// 0 = all cores). Never changes results: a parallel run reproduces the
    /// sequential `RunRecord` exactly (tests/engine.rs).
    pub threads: usize,
    /// which execution engine drives the loop (`--time-model`): `lockstep`
    /// (default, the historical shared-step loop) or `event` (discrete-
    /// event virtual time — heterogeneous client speeds, asynchronous
    /// flooding). `event` with uniform rates reproduces lockstep results
    /// bit-for-bit (rust/tests/properties.rs)
    pub time_model: TimeModel,
    /// seeded client speed model for event mode (`--rates`): `uniform`,
    /// `lognormal:<sigma>`, `stragglers:<frac>,<slowdown>`, or
    /// `jitter:<sigma>` (per-step duration noise). Non-uniform rates
    /// require `time_model = event` — the lockstep clock cannot represent
    /// them ([`ExperimentConfig::validate`])
    pub rates: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            method: Method::SeedFlood,
            model: "tiny".into(),
            task: "sst2".into(),
            clients: 16,
            topology: Kind::Ring,
            topology_seed: 0,
            steps: 400,
            local_steps: 5,
            lr: 1e-3,
            batch: 8,
            eps: 1e-3,
            rank: 32,
            refresh: 1000,
            flood_steps: 0,
            topk_ratio: 0.01,
            consensus_lr: 1.0,
            lora_rank: 8,
            seed: 0,
            eval_every: 0,
            artifacts_dir: "artifacts".into(),
            init_from: String::new(),
            quantize_msgs: false,
            dirichlet_alpha: 0.0,
            netcond: String::new(),
            flood_retain: 4096,
            repair_mode: RepairMode::Gap,
            threads: 1,
            time_model: TimeModel::Lockstep,
            rates: "uniform".into(),
        }
    }
}

impl ExperimentConfig {
    /// Build from CLI args (every field overridable).
    pub fn from_args(args: &Args) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        c.overlay_args(args)?;
        c.validate()?;
        Ok(c)
    }

    /// Apply CLI options over the current values — the body of
    /// [`Self::from_args`], split out so the sweep driver can layer CLI
    /// flags on top of a TOML-loaded base (no validation here; callers
    /// validate once all sources are applied).
    pub fn overlay_args(&mut self, args: &Args) -> Result<()> {
        let c = self;
        if let Some(m) = args.get("method") {
            c.method = match Method::parse(m) {
                Some(m) => m,
                None => bail!("unknown method {m:?}"),
            };
        }
        c.model = args.get_or("model", &c.model).to_string();
        c.task = args.get_or("task", &c.task).to_string();
        c.clients = args.get_parse("clients", c.clients)?;
        if let Some(t) = args.get("topology") {
            c.topology = match Kind::parse(t) {
                Some(k) => k,
                None => bail!("unknown topology {t:?}"),
            };
        }
        c.steps = args.get_parse("steps", c.steps)?;
        c.local_steps = args.get_parse("local-steps", c.local_steps)?;
        c.lr = args.get_parse("lr", c.lr)?;
        c.batch = args.get_parse("batch", c.batch)?;
        c.eps = args.get_parse("eps", c.eps)?;
        c.rank = args.get_parse("rank", c.rank)?;
        c.refresh = args.get_parse("refresh", c.refresh)?;
        c.flood_steps = args.get_parse("flood-steps", c.flood_steps)?;
        c.topk_ratio = args.get_parse("topk-ratio", c.topk_ratio)?;
        c.consensus_lr = args.get_parse("consensus-lr", c.consensus_lr)?;
        c.lora_rank = args.get_parse("lora-rank", c.lora_rank)?;
        c.seed = args.get_parse("seed", c.seed)?;
        c.eval_every = args.get_parse("eval-every", c.eval_every)?;
        c.artifacts_dir = args.get_or("artifacts", &c.artifacts_dir).to_string();
        c.init_from = args.get_or("init-from", &c.init_from).to_string();
        c.quantize_msgs = args.has("quantize") || c.quantize_msgs;
        c.dirichlet_alpha = args.get_parse("dirichlet-alpha", c.dirichlet_alpha)?;
        c.netcond = args.get_or("netcond", &c.netcond).to_string();
        c.flood_retain = args.get_parse("flood-retain", c.flood_retain)?;
        if let Some(m) = args.get("repair-mode") {
            c.repair_mode = match RepairMode::parse(m) {
                Some(m) => m,
                None => bail!("unknown repair mode {m:?} (have gap, reflood)"),
            };
        }
        c.threads = args.get_parse("threads", c.threads)?;
        if let Some(t) = args.get("time-model") {
            c.time_model = match TimeModel::parse(t) {
                Some(t) => t,
                None => bail!("unknown time model {t:?} (have lockstep, event)"),
            };
        }
        c.rates = args.get_or("rates", &c.rates).to_string();
        Ok(())
    }

    /// Cross-field validation shared by every config source (CLI, TOML,
    /// programmatic): the rate spec must parse, and non-uniform rates
    /// require the event engine — the lockstep clock has no notion of a
    /// client taking longer than a step. Also called by the simulator
    /// before a run, so TOML- and struct-built configs are covered.
    pub fn validate(&self) -> Result<()> {
        let spec = RateSpec::parse(&self.rates)?;
        if self.time_model == TimeModel::Lockstep && !spec.is_uniform() {
            bail!(
                "rates {:?} require --time-model event (lockstep has no \
                 heterogeneous-speed clock)",
                self.rates
            );
        }
        Ok(())
    }

    /// Apply a parsed TOML table section (`key = value` pairs).
    pub fn apply_toml(&mut self, tbl: &toml::Table) -> Result<()> {
        for (k, v) in tbl.iter() {
            match k.as_str() {
                "method" => {
                    self.method = Method::parse(v.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("unknown method"))?
                }
                "model" => self.model = v.as_str()?.to_string(),
                "task" => self.task = v.as_str()?.to_string(),
                "clients" => self.clients = v.as_int()? as usize,
                "topology" => {
                    self.topology = Kind::parse(v.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("unknown topology"))?
                }
                "steps" => self.steps = v.as_int()? as usize,
                "local_steps" => self.local_steps = v.as_int()? as usize,
                "lr" => self.lr = v.as_float()? as f32,
                "batch" => self.batch = v.as_int()? as usize,
                "eps" => self.eps = v.as_float()? as f32,
                "rank" => self.rank = v.as_int()? as usize,
                "refresh" => self.refresh = v.as_int()? as usize,
                "flood_steps" => self.flood_steps = v.as_int()? as usize,
                "topk_ratio" => self.topk_ratio = v.as_float()? as f32,
                "consensus_lr" => self.consensus_lr = v.as_float()? as f32,
                "lora_rank" => self.lora_rank = v.as_int()? as usize,
                "seed" => self.seed = v.as_int()? as u64,
                "eval_every" => self.eval_every = v.as_int()? as usize,
                // sflint: allow(cli-doc-drift, reason = "the CLI spells this flag --artifacts")
                "artifacts_dir" => self.artifacts_dir = v.as_str()?.to_string(),
                "init_from" => self.init_from = v.as_str()?.to_string(),
                // sflint: allow(cli-doc-drift, reason = "the CLI spells this boolean flag --quantize")
                "quantize_msgs" => self.quantize_msgs = v.as_bool()?,
                "dirichlet_alpha" => self.dirichlet_alpha = v.as_float()?,
                "netcond" => self.netcond = v.as_str()?.to_string(),
                "flood_retain" => self.flood_retain = v.as_int()? as usize,
                "repair_mode" => {
                    self.repair_mode = RepairMode::parse(v.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("unknown repair mode"))?
                }
                "threads" => self.threads = v.as_int()? as usize,
                "time_model" => {
                    self.time_model = TimeModel::parse(v.as_str()?)
                        .ok_or_else(|| anyhow::anyhow!("unknown time model"))?
                }
                "rates" => self.rates = v.as_str()?.to_string(),
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            "dsgd", "choco", "dsgd-lora", "choco-lora", "dzsgd", "dzsgd-lora", "seedflood",
            "mezo", "subcge",
        ] {
            assert!(Method::parse(m).is_some(), "{m}");
        }
        assert!(Method::parse("sgd").is_none());
        assert!(Method::SeedFlood.is_zeroth_order());
        assert!(!Method::Dsgd.is_zeroth_order());
        assert!(Method::ChocoLora.is_lora());
    }

    #[test]
    fn from_args_overrides() {
        let args = Args::parse(
            [
                "--method", "dsgd", "--clients", "32", "--topology", "mesh", "--lr", "0.0001",
                "--steps", "50", "--threads", "4", "--netcond", "loss=0.1;delay=1",
                "--consensus-lr", "0.5", "--lora-rank", "16",
            ]
            .iter()
            .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(c.method, Method::Dsgd);
        assert_eq!(c.clients, 32);
        assert_eq!(c.topology, Kind::Meshgrid);
        assert_eq!(c.lr, 1e-4);
        assert_eq!(c.steps, 50);
        assert_eq!(c.threads, 4);
        assert_eq!(c.netcond, "loss=0.1;delay=1");
        assert_eq!(c.consensus_lr, 0.5);
        assert_eq!(c.lora_rank, 16);
        // default: the reliable network
        assert!(ExperimentConfig::default().netcond.is_empty());
    }

    #[test]
    fn threads_defaults_to_sequential() {
        assert_eq!(ExperimentConfig::default().threads, 1);
    }

    #[test]
    fn repair_knobs_parse_and_default() {
        let d = ExperimentConfig::default();
        assert_eq!(d.repair_mode, RepairMode::Gap);
        assert_eq!(d.flood_retain, 4096);
        let args = Args::parse(
            ["--repair-mode", "reflood", "--flood-retain", "0"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(c.repair_mode, RepairMode::Reflood);
        assert_eq!(c.flood_retain, 0);
        let bad = Args::parse(
            ["--repair-mode", "full-log"].iter().map(|s| s.to_string()),
            &[],
        );
        assert!(ExperimentConfig::from_args(&bad).is_err());
    }

    #[test]
    fn time_model_knobs_parse_and_validate() {
        let d = ExperimentConfig::default();
        assert_eq!(d.time_model, TimeModel::Lockstep);
        assert_eq!(d.rates, "uniform");
        d.validate().unwrap();
        let args = Args::parse(
            ["--time-model", "event", "--rates", "stragglers:0.25,4"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(c.time_model, TimeModel::Event);
        assert_eq!(c.rates, "stragglers:0.25,4");
        // non-uniform rates on the lockstep clock are a config error
        let bad = Args::parse(
            ["--rates", "lognormal:0.5"].iter().map(|s| s.to_string()),
            &[],
        );
        assert!(ExperimentConfig::from_args(&bad).is_err());
        // as is an unparseable spec or an unknown time model
        let bad = Args::parse(
            ["--time-model", "event", "--rates", "warp:9"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        assert!(ExperimentConfig::from_args(&bad).is_err());
        let bad = Args::parse(
            ["--time-model", "sometimes"].iter().map(|s| s.to_string()),
            &[],
        );
        assert!(ExperimentConfig::from_args(&bad).is_err());
    }

    #[test]
    fn time_model_toml_keys() {
        let parsed = toml::parse("time_model = \"event\"\nrates = \"lognormal:0.5\"\n").unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_toml(&parsed.root).unwrap();
        assert_eq!(c.time_model, TimeModel::Event);
        assert_eq!(c.rates, "lognormal:0.5");
        c.validate().unwrap();
        // TOML can set fields independently; the simulator's validate()
        // catches an inconsistent combination
        c.time_model = TimeModel::Lockstep;
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_args_rejects_bad() {
        let args = Args::parse(
            ["--method", "nope"].iter().map(|s| s.to_string()),
            &[],
        );
        assert!(ExperimentConfig::from_args(&args).is_err());
    }

    #[test]
    fn apply_toml_section() {
        let parsed = toml::parse(
            "method = \"seedflood\"\nrank = 64\nrefresh = 5000\nlr = 1e-5\n\
             netcond = \"churn-er\"\nflood_retain = 512\nrepair_mode = \"reflood\"\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::default();
        c.apply_toml(&parsed.root).unwrap();
        assert_eq!(c.rank, 64);
        assert_eq!(c.refresh, 5000);
        assert_eq!(c.lr, 1e-5);
        assert_eq!(c.netcond, "churn-er");
        assert_eq!(c.flood_retain, 512);
        assert_eq!(c.repair_mode, RepairMode::Reflood);
    }
}
