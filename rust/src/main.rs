//! `seedflood` — CLI for the SeedFlood decentralized-training framework.
//!
//! Subcommands:
//!   train        run one experiment configuration and report GMP + cost
//!   sweep        run a methods × topologies × netconds × rates × seeds
//!                grid in parallel, aggregate mean±std per group
//!   experiment   regenerate a paper table/figure (fig1, fig3/table8,
//!                scaling/fig4/table2, table3, fig6, fig7, churn, hopgrid)
//!   topo         inspect a topology (diameter, spectral gap, edges)
//!   info         print manifest / artifact info
//!   lint         run sflint, the determinism & accounting static
//!                analysis (CI-enforcing; also built as `sflint`)
//!
//! Examples:
//!   seedflood train --method seedflood --clients 16 --topology ring \
//!       --task sst2 --steps 400 --model tiny
//!   seedflood train --method seedflood --model synthetic --netcond churn-er
//!   seedflood sweep --name robust --model synthetic --methods seedflood,dsgd \
//!       --netconds reliable,lossy-ring --seeds 0,1,2 --threads 4
//!   seedflood experiment churn --scenarios lossy-ring,churn-er --steps 200
//!   seedflood topo --topology meshgrid --clients 64

use anyhow::Result;
use seedflood::config::ExperimentConfig;
use seedflood::model::Manifest;
use seedflood::topology::{Kind, Topology};
use seedflood::util::cli::Args;
use seedflood::util::human_bytes;
use seedflood::{experiments, sim};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::Level::Info
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{}] {}", record.level(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

fn main() -> Result<()> {
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(log::LevelFilter::Info);

    let args = Args::from_env(&["quiet", "json", "quantize"]);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "experiment" => {
            let id = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: seedflood experiment <id>"))?;
            let base = ExperimentConfig::from_args(&args)?;
            experiments::dispatch(id, base, &args)
        }
        "pretrain" => {
            let model = args.get_or("model", "tiny").to_string();
            experiments::pretrain(
                &model,
                args.get_or("artifacts", "artifacts"),
                args.get_or("out", &format!("checkpoints/{model}_pretrained.sfck")),
                args.get_parse("mix-tasks", 8)?,
                args.get_parse("steps", 600)?,
                args.get_parse("lr", 5e-3)?,
                args.get_parse("seed", 0)?,
                args.get_parse("target-acc", 0.66)?,
            )
        }
        "report" => {
            let paths: Vec<String> = if args.positional.len() > 1 {
                args.positional[1..].to_vec()
            } else {
                let mut v: Vec<String> = std::fs::read_dir("results")?
                    .filter_map(|e| e.ok())
                    .map(|e| e.path().display().to_string())
                    .filter(|p| p.ends_with(".json"))
                    .collect();
                v.sort();
                v
            };
            experiments::report(&paths)
        }
        "topo" => cmd_topo(&args),
        "info" => cmd_info(&args),
        "lint" => seedflood::lint::cli_main(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let record = sim::run_experiment(cfg)?;
    println!(
        "\n{} on {} ({} clients, {}): GMP {:.2}%  loss {:.4}",
        record.method, record.task, record.clients, record.topology,
        100.0 * record.gmp, record.final_loss
    );
    println!(
        "communication: total {} | per-edge {} | wall {:.1}s",
        human_bytes(record.total_bytes),
        human_bytes(record.per_edge_bytes as u64),
        record.wall_secs
    );
    if record.time_model == "event" {
        println!(
            "virtual time ({}): makespan {:.1} steps | idle {:.1}% | \
             staleness p50/p90/p99 {}/{}/{} iter",
            record.rates,
            record.virtual_makespan,
            100.0 * record.idle_frac,
            record.staleness_p50,
            record.staleness_p90,
            record.staleness_p99,
        );
    }
    if !record.netcond.is_empty() {
        println!(
            "netcond {}: delivery {:.1}% | dropped {} | flood duplicates {} | \
             max staleness {} iter",
            record.netcond,
            100.0 * record.delivery_ratio,
            record.dropped_messages,
            record.flood_duplicates,
            record.max_staleness
        );
        println!(
            "repair: {} in {} messages | flood retained {} entries/client max | \
             dedup {} /client max",
            human_bytes(record.repair_bytes),
            record.repair_messages,
            record.flood_retained,
            human_bytes(record.flood_dedup_bytes)
        );
    }
    for (phase, ms) in &record.phase_ms {
        println!("phase {phase}: {ms:.1} ms total");
    }
    if let Some(out) = args.get("out") {
        record.save(out)?;
        println!("saved {out}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let spec = experiments::sweep::SweepSpec::from_args(args)?;
    let outcome = spec.run()?;
    print!("{}", experiments::sweep::render_table(&outcome.groups));
    println!(
        "\nsweep {}: {} cell(s) run, {} resumed from file, {} failed -> {}",
        spec.name,
        outcome.ran,
        outcome.skipped,
        outcome.failed.len(),
        outcome.path
    );
    if !outcome.failed.is_empty() {
        for (key, err) in &outcome.failed {
            eprintln!("failed cell {key:?}: {err}");
        }
        anyhow::bail!(
            "{} sweep cell(s) failed (completed cells were saved; re-invoke to resume)",
            outcome.failed.len()
        );
    }
    Ok(())
}

fn cmd_topo(args: &Args) -> Result<()> {
    let kind = Kind::parse(args.get_or("topology", "ring"))
        .ok_or_else(|| anyhow::anyhow!("unknown topology"))?;
    let n: usize = args.get_parse("clients", 16)?;
    let seed: u64 = args.get_parse("seed", 0)?;
    let t = Topology::build(kind, n, seed);
    println!("topology {} n={}", t.kind, t.n);
    println!("edges          {}", t.num_edges());
    println!("max degree     {}", t.max_degree());
    println!("diameter       {}", t.diameter());
    println!("spectral gap   {:.4}", t.spectral_gap());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "tiny");
    let m = Manifest::load(&format!("{dir}/{model}_manifest.json"))?;
    println!(
        "model config {}: d={} params, vocab={}, seq={}, dim={}, layers={}",
        m.config.name, m.config.num_params, m.config.vocab, m.config.seq, m.config.dim,
        m.config.layers
    );
    println!(
        "2D params under SubCGE: {} (artifact rank {})",
        m.params2d.len(),
        m.config.subcge_rank
    );
    println!("artifacts:");
    for a in &m.artifacts {
        println!(
            "  {:<12} {} ({} inputs, {} outputs)",
            a.tag,
            a.file,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}

fn print_help() {
    println!(
        "seedflood — decentralized training via flooded seed-reconstructible ZO updates

USAGE: seedflood <train|sweep|experiment|pretrain|report|topo|info|lint> [--options]

train        --method <dsgd|choco|dsgd-lora|choco-lora|dzsgd|dzsgd-lora|seedflood|mezo|subcge>
             --model <tiny|small|base|synthetic|cheap> (cheap = shrunk
             synthetic oracle for massive-scale runs — 10k+ clients stay
             topology-bound, not model-bound)
             --task <sst2|rte|boolq|wic|multirc|record>
             --clients N
             --topology <ring|mesh|torus|complete|star|er|ws|scale-free|
             hierarchical|hub-spoke> (the last three are O(m)-construction
             massive-scale generators)
             --steps N --local-steps N --lr F --batch N --eps F --rank N
             --refresh N --flood-steps N --seed N --eval-every N
             --topk-ratio F (choco gossip sparsification)
             --consensus-lr F (choco consensus step size)
             --lora-rank N (rank of the LoRA adapters for *-lora methods)
             --dirichlet-alpha F (non-IID label-skew partition strength)
             --init-from PATH (warm-start from a pretrain checkpoint)
             --artifacts DIR (tokenizer/dataset cache directory)
             --quantize (4-bit quantized seed-flood messages)
             --threads N (local-step worker threads; 1 = sequential, 0 = all
             cores — results are identical for every value)
             --netcond SPEC (unreliable-network & churn injection: a preset
             <lossy-ring|flaky-torus|churn-er> or a spec string such as
             \"loss=0.05;delay=1;node:3@10..20;link:0-1@5..15;repair=25\";
             presets pin their topology; default: reliable network)
             --repair-mode <gap|reflood> (how flooding answers repair
             triggers: gap-request summaries + gap-fills, or the legacy
             full re-flood; default gap)
             --flood-retain N (repair-window capacity per client; 0 keeps
             everything — required for reflood; default 4096)
             --time-model <lockstep|event> (execution engine: the default
             shared-step loop, or discrete-event virtual time — per-client
             compute speeds, asynchronous flooding; `event` with uniform
             rates reproduces lockstep bit-for-bit)
             --rates SPEC (event-mode client speed model:
             uniform | lognormal:<sigma> | stragglers:<frac>,<slowdown> |
             jitter:<sigma>; default uniform)
             [--out results/run.json]
sweep        run a config grid in parallel and aggregate mean±std per
             (method, topology, netcond, rates) group over seeds:
             --name ID (output: results/sweep_<ID>.json; cells already in
             the file are skipped on re-invocation — sweeps resume)
             --methods a,b --topologies a,b
             --netconds reliable,lossy-ring,... (reliable/none = no faults)
             --rates uniform/lognormal:0.5/... (slash-separated — rate
             specs contain commas; non-uniform cells use the event engine)
             --seeds 0,1,2
             --out-dir DIR (where sweep_<ID>.json lands; default results/)
             --threads N (cells in flight; each cell runs single-threaded.
             aggregates are bit-identical for every thread count)
             --config sweep.toml (root table = experiment keys, [sweep]
             table = the axes above; CLI overrides TOML)
             plus any train option as the base config for every cell
experiment   <fig1|fig3|table8|scaling|fig4|table2|table3|fig6|fig7|churn|
             hopgrid>
             [--tasks a,b] [--scenarios lossy-ring,flaky-torus,churn-er]
             scaling: --clients-list 4,8,16   table2: --ks 1,2,4,8,16
             table3: --ranks 8,16,32,64 --periods 50,500,2000
             hopgrid: flooding vs gossip message-rounds-to-consensus across
             topology families (--topologies a,b --hop-ns 64,256,...
             --gossip-eps F --gossip-cap N)
pretrain     --model tiny [--steps N --lr F --target-acc F --mix-tasks N
             --seed N --artifacts DIR --out PATH] -> checkpoints/
report       [results/foo.json ...]   re-render tables from saved records
topo         --topology K --clients N
info         --model tiny [--artifacts DIR]
lint         [--root DIR] [--format text|json] [--rule NAME]
             sflint static analysis: unordered-iter, wall-clock,
             thread-escape, unsafe-audit, accounting-conservation,
             wire-conservation, rng-hygiene, cli-doc-drift, json-parity,
             bench-ledger-drift; exit 0 = clean, 1 = findings without an
             inline allow-with-reason annotation, 2 = usage error"
    );
}
