//! Flooding — the consensus primitive that replaces gossip (paper §3.3).
//!
//! Upon first receipt of a message, a client forwards it to all neighbors;
//! repeated for `D` (network diameter) steps, every update generated in an
//! iteration reaches every client — an all-gather-equivalent consensus
//! with cost independent of model dimension.
//!
//! *Delayed flooding* (paper §4.5): run only `k` flood steps per local
//! iteration; the outbox persists across iterations so messages keep
//! propagating with a bounded delay of ≤ ⌈D/k⌉ iterations.
//!
//! # Dedup in O(origins off the floor), not O(T·n)
//!
//! Message ids are `(origin, step)` pairs and every origin emits exactly
//! one message per step, so the dedup filter ([`FloodDedup`]) stores, per
//! origin, a contiguous high-water mark (all steps below it seen) plus a
//! small tail bitset for out-of-order arrivals ([`StepSet`]) — instead of
//! one hash entry per message ever received. A million-step flood retains
//! a few words per origin. Below [`DENSE_ORIGIN_CROSSOVER`] the per-origin
//! sets live in a dense table; past it the filter switches to an
//! origin-sparse representation that compresses the flood's steady state
//! ("every origin at step t") to a floor scalar plus a bitset, so
//! per-client memory is O(n) *bits* transiently and O(stragglers) between
//! iterations rather than O(n) sets — the change that makes full
//! 100k-client floods simulable (ARCHITECTURE.md, "The n² memory wall").
//! Accept/duplicate decisions are bit-identical to a reference
//! `HashSet<MsgId>` and representation-independent (property-tested in
//! `rust/tests/properties.rs`).
//!
//! # Unreliable networks
//!
//! Under an installed [`crate::netcond::NetCond`] fault model, messages
//! can be lost (packet loss, down links) or stranded (node churn). The
//! flooding state answers with *repair*: a bounded [`FloodState::window`]
//! retains the most recent `retain` messages in first-seen order, and when
//! the network signals a recovery or an anti-entropy heartbeat
//! ([`crate::net::Network::should_repair`]) the client runs one of two
//! repair protocols ([`RepairMode`]):
//!
//! * [`RepairMode::Gap`] (default) — broadcast a
//!   [`crate::net::Payload::Summary`] of per-origin high-water marks
//!   (O(n) bytes); each neighbor answers with a
//!   [`crate::net::Payload::GapFill`] carrying only the retained messages
//!   the summary shows missing — repair cost is O(gap) on the wire.
//! * [`RepairMode::Reflood`] — legacy: re-broadcast the whole retention
//!   window; receivers dedup, so the cost is duplicate traffic
//!   proportional to the *entire history* retained (requires unbounded
//!   retention, `retain = 0`).
//!
//! Either way delivery degrades to *bounded staleness* instead of silent
//! loss, provided the retention window covers the longest outage
//! (`retain` ≥ messages generated per outage; 0 retains everything).
//!
//! A 4-node ring floods to full coverage in D = 2 rounds:
//!
//! ```
//! use seedflood::flood::{flood_rounds, FloodState};
//! use seedflood::net::{MsgId, Network, SeedUpdate};
//! use seedflood::topology::Topology;
//!
//! let topo = Topology::ring(4);
//! let d = topo.diameter();
//! let mut net = Network::new(topo);
//! let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
//! for (i, st) in states.iter_mut().enumerate() {
//!     st.inject(SeedUpdate {
//!         id: MsgId { origin: i as u32, step: 0 },
//!         seed: i as u64,
//!         coeff: 0.25,
//!     });
//! }
//! flood_rounds(&mut states, &mut net, d, |_client, _fresh| {});
//! assert!(states.iter().all(|s| s.seen.len() == 4)); // everyone has everything
//! ```

use std::collections::{HashSet, VecDeque};
use std::sync::Arc;

use crate::net::{Message, MsgId, Network, Payload, SeedUpdate};

/// On-wire encoding for flooded messages.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum WireFormat {
    /// 20 B per message: id + seed + f32 coefficient.
    #[default]
    Full,
    /// 9 B per message (Zelikman et al. 2023): 1-byte µ-law coefficient
    /// around the given scale; values are quantized at injection so every
    /// client applies identical (dequantized) coefficients — consensus is
    /// preserved exactly.
    Quantized(f32),
}

/// How a client answers a repair trigger (recovery or anti-entropy).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RepairMode {
    /// Gap-request protocol: broadcast a [`Payload::Summary`] of
    /// per-origin high-water marks; neighbors reply with
    /// [`Payload::GapFill`] carrying only the missing ranges they retain.
    /// Repair cost is O(gap) on the wire.
    #[default]
    Gap,
    /// Legacy full re-flood: re-broadcast the whole retention window
    /// (minus anything already outbound). Repair cost is O(everything
    /// retained) in duplicate traffic; requires unbounded retention.
    Reflood,
}

impl RepairMode {
    pub fn parse(s: &str) -> Option<RepairMode> {
        match s.to_ascii_lowercase().as_str() {
            "gap" => Some(RepairMode::Gap),
            "reflood" => Some(RepairMode::Reflood),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RepairMode::Gap => "gap",
            RepairMode::Reflood => "reflood",
        }
    }
}

/// Set of seen step numbers for one origin: a contiguous high-water mark
/// (every step below [`Self::hwm`] seen) plus a tail bitset for
/// out-of-order arrivals. Memory is O(reorder gap / 64) words and drops
/// back to zero once the gap closes — the structure the `(origin, step)`
/// id scheme makes exact.
///
/// ```
/// use seedflood::flood::StepSet;
///
/// let mut s = StepSet::default();
/// assert!(s.insert(1)); // out of order: goes to the tail bitset
/// assert!(s.insert(0)); // closes the gap: hwm jumps to 2, tail empties
/// assert!(!s.insert(1)); // duplicate
/// assert_eq!(s.hwm(), 2);
/// assert_eq!(s.tail_entries(), 0);
/// ```
#[derive(Clone, Debug, Default)]
pub struct StepSet {
    /// every step `< hwm` has been seen
    hwm: u64,
    /// bit `b` of `tail[w]` set ⇔ step `hwm + 64·w + b` seen (out of order)
    tail: Vec<u64>,
}

impl StepSet {
    /// The contiguous high-water mark: every step below it has been seen.
    pub fn hwm(&self) -> u64 {
        self.hwm
    }

    pub fn contains(&self, step: u32) -> bool {
        let s = step as u64;
        if s < self.hwm {
            return true;
        }
        let off = (s - self.hwm) as usize;
        self.tail.get(off / 64).is_some_and(|w| w >> (off % 64) & 1 == 1)
    }

    /// Record `step` as seen; returns true iff it was new. Inserting the
    /// step at the high-water mark compacts the tail (the mark advances
    /// over every contiguously seen step, freeing the bitset words).
    pub fn insert(&mut self, step: u32) -> bool {
        let s = step as u64;
        if s < self.hwm {
            return false;
        }
        let off = (s - self.hwm) as usize;
        let (w, b) = (off / 64, off % 64);
        if self.tail.len() <= w {
            self.tail.resize(w + 1, 0);
        }
        if self.tail[w] >> b & 1 == 1 {
            return false;
        }
        self.tail[w] |= 1 << b;
        if off == 0 {
            self.compact();
        }
        true
    }

    /// Advance `hwm` over the contiguous run of seen steps at the front of
    /// the tail and shift the bitset down accordingly.
    fn compact(&mut self) {
        while let Some(&w0) = self.tail.first() {
            let run = (!w0).trailing_zeros() as usize;
            if run == 0 {
                break;
            }
            if run == 64 {
                self.tail.remove(0);
                self.hwm += 64;
            } else {
                for i in 0..self.tail.len() {
                    self.tail[i] >>= run;
                    if i + 1 < self.tail.len() {
                        self.tail[i] |= self.tail[i + 1] << (64 - run);
                    }
                }
                self.hwm += run as u64;
                break;
            }
        }
        while self.tail.last() == Some(&0) {
            self.tail.pop();
        }
    }

    /// Total steps seen.
    pub fn len(&self) -> u64 {
        self.hwm + self.tail_entries()
    }

    pub fn is_empty(&self) -> bool {
        self.hwm == 0 && self.tail.is_empty()
    }

    /// Out-of-order steps currently held above the high-water mark.
    pub fn tail_entries(&self) -> u64 {
        self.tail.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Bitset words currently allocated (the memory-bound metric).
    pub fn tail_words(&self) -> usize {
        self.tail.len()
    }
}

/// Origin ids below this stay in the dense per-origin table; the first
/// insert at or above it switches the filter to the origin-sparse
/// representation (see [`FloodDedup`]). Small simulations therefore keep
/// the historical dense layout bit-for-bit, while 100k-client runs pay
/// only for origins actually off the floor.
pub const DENSE_ORIGIN_CROSSOVER: u32 = 1024;

/// The flooding dedup filter, replacing the historical `HashSet<MsgId>`:
/// same accept/duplicate decisions (property-tested against the hash-set
/// reference in `rust/tests/properties.rs`), memory proportional to the
/// origins that deviate from the flood's steady state instead of O(T·n).
///
/// Two representations, switched adaptively on the origin id space:
///
/// * **dense** — one [`StepSet`] per origin id, indexed directly; used
///   while every origin id is below [`DENSE_ORIGIN_CROSSOVER`]. Identical
///   to the pre-sparse layout, so small-n paths stay bit-for-bit
///   unchanged (decisions *and* allocation pattern).
/// * **origin-sparse** — entered on the first insert past the crossover
///   (or a large [`Self::reserve_origins`] hint). The steady state of a
///   healthy flood — "every origin exactly at step `floor`" — is one
///   scalar; origins whose message for the current step has arrived are
///   one *bump* bit each; only origins with reorder gaps or that ran
///   ahead hold a real [`StepSet`], in a compact open-addressing map
///   ([`OriginMap`]). When every origin passes the floor it advances and
///   the bumped population collapses back to the default state en masse —
///   per-client memory is O(n) bits transiently and O(stragglers) between
///   iterations, instead of the O(n) `StepSet`s whose simulation-wide n²
///   total was the 100k-client memory wall (ARCHITECTURE.md).
///
/// The sparse path reuses its allocations (map slab, bump bitset, rebuild
/// scratch) the way [`crate::net::Network`]'s `MsgPool` pools message
/// slots: the per-message path never allocates, and floor advances cost
/// O(deviating origins) moves through pooled buffers.
#[derive(Clone, Debug)]
pub struct FloodDedup {
    /// dense representation: `dense[o]` is origin `o`'s step set
    dense: Vec<StepSet>,
    /// sparse representation; `dense` is empty once this is set
    sparse: Option<Box<SparseDedup>>,
    /// dense→sparse switch point on the origin id space
    crossover: u32,
    total: u64,
}

impl Default for FloodDedup {
    fn default() -> Self {
        FloodDedup {
            dense: vec![],
            sparse: None,
            crossover: DENSE_ORIGIN_CROSSOVER,
            total: 0,
        }
    }
}

impl FloodDedup {
    /// A filter with a non-default dense→sparse crossover: `0` forces the
    /// origin-sparse representation from the first insert, `u32::MAX`
    /// pins the dense table forever. Decisions and summaries are
    /// representation-invariant (property-tested in
    /// `rust/tests/properties.rs`); tests and benches use this to compare
    /// the two representations on identical streams.
    pub fn with_crossover(crossover: u32) -> FloodDedup {
        FloodDedup { crossover, ..FloodDedup::default() }
    }

    /// Hint the expected origin population (the client count). On the
    /// sparse path this sizes the floor universe up front, which is what
    /// lets the floor advance once *all* n origins pass it — without the
    /// hint the universe is learned from the stream, which is still
    /// correct but can freeze early and strand late-arriving origins on
    /// the uncompressed map path. On the dense path this is a plain
    /// capacity reservation. Observable behavior (decisions, `hwms()`,
    /// summaries) never changes.
    pub fn reserve_origins(&mut self, n: usize) {
        let n32 = n.min(u32::MAX as usize) as u32;
        if n32 > self.crossover {
            if self.sparse.is_none() {
                self.to_sparse();
            }
            let sp = self.sparse.as_deref_mut().unwrap();
            if sp.floor == 0 && (n32 as u64) > sp.universe {
                sp.grow_universe(n32 as u64);
            }
        } else if self.sparse.is_none() {
            self.dense.reserve(n.saturating_sub(self.dense.len()));
        }
    }

    /// Migrate dense → sparse: at most `crossover` entries move, once per
    /// filter lifetime (triggered by the first past-the-crossover origin
    /// or an explicit [`Self::reserve_origins`]).
    fn to_sparse(&mut self) {
        let dense = std::mem::take(&mut self.dense);
        let mut sp = Box::new(SparseDedup::default());
        sp.universe = dense.len() as u64;
        sp.max_origin = dense.len() as u64;
        for (o, set) in dense.into_iter().enumerate() {
            if set.is_empty() {
                continue;
            }
            // floor is 0, so anything with a mark is past it
            if set.hwm > 0 {
                sp.map_above += 1;
                if set.hwm == 1 && set.tail.is_empty() {
                    sp.map_bumps += 1;
                }
            }
            sp.map.insert_new(o as u32, set);
        }
        // no floor-advance check here: the universe is still being
        // learned mid-stream, and freezing it now would strand every
        // later origin on the map path — the next insert re-checks
        self.sparse = Some(sp);
    }

    /// Record `id` as seen; returns true iff it was new (the exact
    /// contract of `HashSet::insert`).
    pub fn insert(&mut self, id: MsgId) -> bool {
        if self.sparse.is_none() && id.origin >= self.crossover {
            self.to_sparse();
        }
        let fresh = match self.sparse.as_deref_mut() {
            Some(sp) => sp.insert(id.origin, id.step),
            None => {
                let o = id.origin as usize;
                if self.dense.len() <= o {
                    self.dense.resize_with(o + 1, StepSet::default);
                }
                self.dense[o].insert(id.step)
            }
        };
        if fresh {
            self.total += 1;
        }
        fresh
    }

    pub fn contains(&self, id: &MsgId) -> bool {
        match self.sparse.as_deref() {
            Some(sp) => sp.contains(id.origin, id.step),
            None => {
                self.dense.get(id.origin as usize).is_some_and(|s| s.contains(id.step))
            }
        }
    }

    /// Total messages seen (what `HashSet::len` used to report).
    pub fn len(&self) -> usize {
        self.total as usize
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// 1 + the highest origin id ever inserted — the length of
    /// [`Self::hwms`] / [`Self::summary`], exactly the dense table length
    /// of the historical representation.
    pub fn num_origins(&self) -> usize {
        match self.sparse.as_deref() {
            Some(sp) => sp.max_origin as usize,
            None => self.dense.len(),
        }
    }

    /// Contiguous high-water mark for one origin (0 if never heard from).
    pub fn hwm(&self, origin: u32) -> u64 {
        match self.sparse.as_deref() {
            Some(sp) => sp.hwm_of(origin),
            None => self.dense.get(origin as usize).map_or(0, |s| s.hwm()),
        }
    }

    /// Per-origin high-water marks, origin-indexed — the O(n)-byte state
    /// summary of the gap-request repair protocol
    /// ([`Payload::Summary`]). Conservative by construction: out-of-order
    /// tail entries above a mark are *not* advertised, so a responder may
    /// re-send a few already-seen messages (dedup absorbs them).
    pub fn summary(&self) -> Vec<u32> {
        self.hwms().map(|h| h.min(u32::MAX as u64) as u32).collect()
    }

    /// Per-origin high-water marks as an iterator — the allocation-free
    /// view behind [`Self::summary`]. [`FloodState::collect`] answers each
    /// incoming summary through this instead of materializing an O(n)
    /// vector per neighbor per repair round (at n = 100k that allocation
    /// was the gap-protocol hot path).
    pub fn hwms(&self) -> impl Iterator<Item = u64> + '_ {
        (0..self.num_origins() as u64).map(move |o| self.hwm(o as u32))
    }

    /// Out-of-order entries retained above the high-water marks.
    pub fn tail_entries(&self) -> u64 {
        match self.sparse.as_deref() {
            Some(sp) => sp.map.values().map(|s| s.tail_entries()).sum(),
            None => self.dense.iter().map(|s| s.tail_entries()).sum(),
        }
    }

    /// Bitset words currently allocated across all origins.
    pub fn tail_words(&self) -> usize {
        match self.sparse.as_deref() {
            Some(sp) => sp.map.values().map(|s| s.tail_words()).sum(),
            None => self.dense.iter().map(|s| s.tail_words()).sum(),
        }
    }

    /// Resident footprint of the filter in bytes, from allocation
    /// capacities — the dedup-memory metric behind
    /// [`crate::metrics::RunRecord::flood_dedup_bytes`] and the
    /// `benches/scale.rs` ledger gate. Dense: the origin table plus tail
    /// bitsets, O(max origin id). Sparse: bump bitset + map slab + rebuild
    /// scratch, O(origins off the floor).
    pub fn mem_bytes(&self) -> usize {
        let heap = match self.sparse.as_deref() {
            Some(sp) => sp.mem_bytes(),
            None => {
                self.dense.capacity() * std::mem::size_of::<StepSet>()
                    + self.dense.iter().map(|s| s.tail.capacity() * 8).sum::<usize>()
            }
        };
        std::mem::size_of::<Self>() + heap
    }
}

/// The origin-sparse dedup state (see [`FloodDedup`]): the flood's steady
/// state compressed to a floor scalar plus a bitset, with a compact map
/// for the origins that deviate.
///
/// Every origin `o < universe` is in exactly one of three states:
///
/// * **default** — not in `map`, bump bit clear: hwm = `floor`, no tail.
///   Zero bytes; the state almost every origin is in between iterations
///   of a healthy flood.
/// * **bumped** — bump bit set: hwm = `floor + 1`, no tail (the origin's
///   one message for the current step arrived in order). One bit.
/// * **mapped** — entry in `map`: any other [`StepSet`], stored with its
///   absolute mark. Reorder gaps, origins that ran ahead, and — once the
///   floor has advanced, freezing `universe` — origins first heard
///   beyond it.
///
/// The floor advances only when every origin in `0..universe` is past it
/// (`bump_count + map_above == universe`), collapsing the bumped
/// population back to the default state en masse.
#[derive(Clone, Debug, Default)]
struct SparseDedup {
    /// every step `< floor` seen from every origin `< universe`
    floor: u64,
    /// origin population the floor quantifies over: learned from the
    /// stream (or hinted via [`FloodDedup::reserve_origins`]) while
    /// `floor == 0`, frozen once it advances — widening it afterwards
    /// would silently claim the new origins' history below the floor
    universe: u64,
    /// 1 + highest origin id ever inserted (`hwms()` length); ≥ universe
    /// whenever the floor has advanced
    max_origin: u64,
    /// lazily allocated bitset over `0..universe`: bit `o` ⇔ origin `o`
    /// bumped. Empty until the bumped population outgrows its map cost
    /// ([`Self::maybe_spill`]) and freed at every floor advance, so a
    /// small active set (the 64-origin bounded floods) never pays n bits
    bump: Vec<u64>,
    /// bumped origins currently held in the bitset
    bump_count: u64,
    /// origin → [`StepSet`] for the deviating origins
    map: OriginMap,
    /// map entries with key `< universe` and hwm past the floor
    map_above: u64,
    /// map entries that are exactly bump-shaped (hwm == floor+1, empty
    /// tail) — bitset candidates; always 0 while the bitset is live
    map_bumps: u64,
    /// pooled rebuild buffer for floor advances and bitset spills
    scratch: Vec<(u32, StepSet)>,
}

impl SparseDedup {
    fn bit(&self, o: u32) -> bool {
        !self.bump.is_empty() && self.bump[(o / 64) as usize] >> (o % 64) & 1 == 1
    }

    /// Record `(o, step)`; returns true iff new. Decision-for-decision
    /// identical to `StepSet::insert` on a dense table: the default and
    /// bumped states are exact encodings (hwm = floor / floor + 1, empty
    /// tail), so reconstructing a real [`StepSet`] on demand reproduces
    /// the dense transition precisely.
    fn insert(&mut self, o: u32, step: u32) -> bool {
        let o64 = o as u64;
        if o64 >= self.max_origin {
            self.max_origin = o64 + 1;
        }
        if o64 >= self.universe {
            if self.floor == 0 {
                self.grow_universe(o64 + 1);
            } else {
                // late origin outside the frozen universe: a plain
                // absolute StepSet in the map, no floor accounting
                return match self.map.get_mut(o) {
                    Some(set) => set.insert(step),
                    None => {
                        let mut set = StepSet::default();
                        set.insert(step);
                        self.map.insert_new(o, set);
                        true
                    }
                };
            }
        }
        let s = step as u64;
        if !self.map.is_empty() {
            if let Some(set) = self.map.get_mut(o) {
                let was_above = set.hwm > self.floor;
                let was_bump = set.hwm == self.floor + 1 && set.tail.is_empty();
                let fresh = set.insert(step);
                if fresh {
                    let now_above = set.hwm > self.floor;
                    let now_bump = set.hwm == self.floor + 1 && set.tail.is_empty();
                    match (was_bump, now_bump) {
                        (false, true) => self.map_bumps += 1,
                        (true, false) => self.map_bumps -= 1,
                        _ => {}
                    }
                    if !was_above && now_above {
                        self.map_above += 1;
                        self.maybe_advance_floor();
                    }
                }
                return fresh;
            }
        }
        if self.bit(o) {
            // bumped: hwm == floor + 1, empty tail
            if s <= self.floor {
                return false;
            }
            let mut set = StepSet { hwm: self.floor + 1, tail: vec![] };
            set.insert(step);
            // the origin leaves the bitset for the map; it stays past the
            // floor either way, so the advance condition is untouched
            self.clear_bit(o);
            self.bump_count -= 1;
            self.map_above += 1;
            self.map.insert_new(o, set);
            return true;
        }
        // default: hwm == floor, empty tail
        if s < self.floor {
            return false;
        }
        if s == self.floor {
            // the steady-state path: the origin's next in-order step
            if !self.bump.is_empty() {
                self.set_bit(o);
                self.bump_count += 1;
                self.maybe_advance_floor();
            } else {
                self.map.insert_new(o, StepSet { hwm: self.floor + 1, tail: vec![] });
                self.map_bumps += 1;
                self.map_above += 1;
                self.maybe_advance_floor();
                self.maybe_spill();
            }
        } else {
            // out-of-order arrival above the floor: a real reorder gap
            let mut set = StepSet { hwm: self.floor, tail: vec![] };
            set.insert(step);
            self.map.insert_new(o, set);
            // hwm stays at the floor (the gap below `step` is open):
            // neither bumped nor above
        }
        true
    }

    fn contains(&self, o: u32, step: u32) -> bool {
        if let Some(set) = self.map.get(o) {
            return set.contains(step);
        }
        let s = step as u64;
        if (o as u64) < self.universe {
            if self.bit(o) {
                s <= self.floor
            } else {
                s < self.floor
            }
        } else {
            false
        }
    }

    fn hwm_of(&self, o: u32) -> u64 {
        if let Some(set) = self.map.get(o) {
            return set.hwm;
        }
        if (o as u64) < self.universe {
            if self.bit(o) {
                self.floor + 1
            } else {
                self.floor
            }
        } else {
            0
        }
    }

    /// Widen the floor universe (stream growth while `floor == 0`, or the
    /// [`FloodDedup::reserve_origins`] hint). The bitset, if live, grows
    /// with it so bit indices stay in range.
    fn grow_universe(&mut self, to: u64) {
        debug_assert_eq!(self.floor, 0, "the universe is frozen once the floor moves");
        self.universe = to;
        if !self.bump.is_empty() {
            self.bump.resize((to as usize).div_ceil(64), 0);
        }
    }

    /// Bitset slots are worth paying for once the bumped population's map
    /// cost exceeds the whole bitset — below that the map alone is
    /// smaller (a 64-origin bounded flood at n = 100k keeps a ~64-entry
    /// map instead of a 12.5 KB bitset).
    fn spill_threshold(&self) -> u64 {
        let slot = (std::mem::size_of::<u64>() + std::mem::size_of::<StepSet>()) as u64;
        (self.universe / 8 / slot).clamp(32, 4096)
    }

    /// Move the bump-shaped map entries into a freshly allocated bitset
    /// once they outgrow it ([`Self::spill_threshold`]).
    fn maybe_spill(&mut self) {
        if !self.bump.is_empty() || self.map_bumps < self.spill_threshold() {
            return;
        }
        self.bump = vec![0u64; (self.universe as usize).div_ceil(64)];
        let mut scratch = std::mem::take(&mut self.scratch);
        self.map.drain_into(&mut scratch);
        self.map_bumps = 0;
        for (k, set) in scratch.drain(..) {
            if (k as u64) < self.universe
                && set.hwm == self.floor + 1
                && set.tail.is_empty()
            {
                self.set_bit(k);
                self.bump_count += 1;
                self.map_above -= 1;
            } else {
                self.map.insert_new(k, set);
            }
        }
        self.retire_scratch(scratch);
    }

    /// Advance the floor while every origin in the universe is past it.
    fn maybe_advance_floor(&mut self) {
        while self.universe > 0 && self.bump_count + self.map_above == self.universe {
            self.advance_floor();
        }
    }

    /// One floor advance: bumped origins collapse to the default state,
    /// the bitset is released (holding n/8 bytes per client across the
    /// whole simulation is exactly the wall this representation removes;
    /// the next spill re-allocates it — one bounded allocation per
    /// advance, never one per message), and the map is rebuilt against
    /// the new floor through the pooled scratch buffer.
    fn advance_floor(&mut self) {
        self.floor += 1;
        self.bump_count = 0;
        self.bump = Vec::new();
        let mut scratch = std::mem::take(&mut self.scratch);
        self.map.drain_into(&mut scratch);
        self.map_above = 0;
        self.map_bumps = 0;
        for (k, set) in scratch.drain(..) {
            if (k as u64) < self.universe {
                debug_assert!(set.hwm >= self.floor, "advance requires everyone past");
                if set.hwm == self.floor && set.tail.is_empty() {
                    continue; // collapsed into the floor
                }
                if set.hwm > self.floor {
                    self.map_above += 1;
                }
                if set.hwm == self.floor + 1 && set.tail.is_empty() {
                    self.map_bumps += 1;
                }
            }
            self.map.insert_new(k, set);
        }
        self.retire_scratch(scratch);
        self.maybe_spill();
    }

    /// Return the rebuild buffer to the pool — unless a spike grew it
    /// past what steady state ever needs, in which case it is released
    /// (same policy as [`OriginMap::KEEP_SLOTS`]): the end-of-run
    /// footprint must reflect the steady state, not the worst transient.
    fn retire_scratch(&mut self, scratch: Vec<(u32, StepSet)>) {
        if scratch.capacity() <= OriginMap::KEEP_SLOTS {
            self.scratch = scratch;
        }
    }

    fn set_bit(&mut self, o: u32) {
        self.bump[(o / 64) as usize] |= 1 << (o % 64);
    }

    fn clear_bit(&mut self, o: u32) {
        self.bump[(o / 64) as usize] &= !(1 << (o % 64));
    }

    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + self.bump.capacity() * 8
            + self.map.mem_bytes()
            + self.scratch.capacity() * std::mem::size_of::<(u32, StepSet)>()
    }
}

/// Vacant-slot marker for [`OriginMap`]. Keys are stored widened to u64
/// so every u32 origin id (including `u32::MAX`) is distinguishable from
/// an empty slot.
const ORIGIN_MAP_EMPTY: u64 = u64::MAX;

/// Open-addressing origin → [`StepSet`] map behind [`SparseDedup`]:
/// linear probing over a power-of-two table with Fibonacci-hashed keys
/// and parallel key/value slabs. There is deliberately no single-key
/// removal — entries only leave through whole-table rebuilds (floor
/// advances, bitset spills, [`Self::drain_into`]), which sidesteps
/// tombstones and backward-shift deletion entirely and keeps probe
/// sequences trivially correct. Lookup order never affects observable
/// results (hwms are read origin-indexed), so iteration order is free to
/// be table order.
#[derive(Clone, Debug, Default)]
struct OriginMap {
    /// slot keys, [`ORIGIN_MAP_EMPTY`] = vacant; length is a power of two
    keys: Vec<u64>,
    /// slot values, parallel to `keys` (vacant slots hold empty sets)
    vals: Vec<StepSet>,
    len: usize,
}

impl OriginMap {
    /// Tables at or below this many slots are kept across
    /// [`Self::drain_into`] (pooled for the next build-up); larger ones
    /// are released so a transient spike cannot pin memory for the rest
    /// of the run.
    const KEEP_SLOTS: usize = 64;

    fn hash(k: u32) -> usize {
        // Fibonacci multiplicative hash; the table mask takes the low
        // bits, so fold the high half down where the entropy lands
        let h = (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h >> 32) as usize
    }

    fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn slot_of(&self, k: u32) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        let mask = self.keys.len() - 1;
        let mut i = Self::hash(k) & mask;
        loop {
            match self.keys[i] {
                ORIGIN_MAP_EMPTY => return None,
                kk if kk == k as u64 => return Some(i),
                _ => i = (i + 1) & mask,
            }
        }
    }

    fn get(&self, k: u32) -> Option<&StepSet> {
        self.slot_of(k).map(|i| &self.vals[i])
    }

    fn get_mut(&mut self, k: u32) -> Option<&mut StepSet> {
        self.slot_of(k).map(|i| &mut self.vals[i])
    }

    /// Insert a key that is not present (callers always look up first;
    /// enforced in debug builds). Grows at 7/8 load.
    fn insert_new(&mut self, k: u32, v: StepSet) {
        if (self.len + 1) * 8 > self.keys.len() * 7 {
            self.grow();
        }
        let mask = self.keys.len() - 1;
        let mut i = Self::hash(k) & mask;
        while self.keys[i] != ORIGIN_MAP_EMPTY {
            debug_assert_ne!(self.keys[i], k as u64, "insert_new on a present key");
            i = (i + 1) & mask;
        }
        self.keys[i] = k as u64;
        self.vals[i] = v;
        self.len += 1;
    }

    fn grow(&mut self) {
        let cap = (self.keys.len() * 2).max(16);
        let old_keys = std::mem::replace(&mut self.keys, vec![ORIGIN_MAP_EMPTY; cap]);
        let old_vals =
            std::mem::replace(&mut self.vals, vec![StepSet::default(); cap]);
        let mask = cap - 1;
        for (k, v) in old_keys.into_iter().zip(old_vals) {
            if k == ORIGIN_MAP_EMPTY {
                continue;
            }
            let mut i = Self::hash(k as u32) & mask;
            while self.keys[i] != ORIGIN_MAP_EMPTY {
                i = (i + 1) & mask;
            }
            self.keys[i] = k;
            self.vals[i] = v;
        }
    }

    /// Move every entry into `out` (cleared first) and empty the table,
    /// keeping small tables pooled ([`Self::KEEP_SLOTS`]) and releasing
    /// large ones.
    fn drain_into(&mut self, out: &mut Vec<(u32, StepSet)>) {
        out.clear();
        for i in 0..self.keys.len() {
            if self.keys[i] != ORIGIN_MAP_EMPTY {
                out.push((self.keys[i] as u32, std::mem::take(&mut self.vals[i])));
                self.keys[i] = ORIGIN_MAP_EMPTY;
            }
        }
        self.len = 0;
        if self.keys.len() > Self::KEEP_SLOTS {
            self.keys = Vec::new();
            self.vals = Vec::new();
        }
    }

    fn values(&self) -> impl Iterator<Item = &StepSet> + '_ {
        self.keys
            .iter()
            .zip(&self.vals)
            .filter(|(k, _)| **k != ORIGIN_MAP_EMPTY)
            .map(|(_, v)| v)
    }

    fn mem_bytes(&self) -> usize {
        self.keys.capacity() * std::mem::size_of::<u64>()
            + self.vals.capacity() * std::mem::size_of::<StepSet>()
            + self.vals.iter().map(|s| s.tail.capacity() * 8).sum::<usize>()
    }
}

/// Per-client flooding protocol state (Alg. 1: S_i = seen, R_i = outbox).
#[derive(Debug, Default)]
pub struct FloodState {
    /// S_i — dedup filter over every message id received, as per-origin
    /// step intervals + tail bitsets (O(n + window), not O(T·n))
    pub seen: FloodDedup,
    /// R_i — messages received last step, to forward this step
    pub outbox: Vec<SeedUpdate>,
    /// bounded retention of recent messages in first-seen order — the
    /// source for repair (gap-fill responses, legacy re-floods); 20 bytes
    /// per entry, at most [`Self::retain`] entries
    pub window: VecDeque<SeedUpdate>,
    /// retention-window capacity; 0 retains everything (legacy behavior —
    /// required for [`RepairMode::Reflood`] to replay the full history)
    pub retain: usize,
    /// how repair triggers are answered (see [`RepairMode`])
    pub repair_mode: RepairMode,
    /// gap protocol: a repair trigger arms a summary broadcast for the
    /// next send round
    pub summary_due: bool,
    /// gap protocol: per-neighbor gap-fill replies queued for the next
    /// send round (computed in [`Self::collect`] from incoming summaries)
    pub gap_out: Vec<(usize, Vec<SeedUpdate>)>,
    /// reflood protocol: retained messages queued for a repair broadcast
    /// next send round — only messages *not* already outbound, so the
    /// attribution to [`crate::net::Accounting::repair_bytes`] counts
    /// nothing that would have been transmitted anyway
    pub repair_batch: Vec<SeedUpdate>,
    /// duplicate receptions filtered (metrics: flooding overhead)
    pub duplicates: u64,
    /// gap-fill responses where the requester's *oldest* missing step had
    /// already been evicted from the retention window — that history
    /// cannot be replayed from here. Persistently nonzero means `retain`
    /// is too small for the outage lengths (silent-loss warning,
    /// surfaced as `RunRecord::repair_gap_misses`)
    pub gap_misses: u64,
    /// worst (apply iteration − origin iteration) observed, recorded via
    /// [`Self::note_staleness`] — 0 on a reliable full-depth flood
    pub max_staleness: u64,
    /// staleness histogram: `stale_hist[s]` counts messages applied `s`
    /// iterations after their origin iteration (clamped to
    /// [`STALE_BUCKETS`] − 1). Feeds the per-run staleness percentiles
    /// (`RunRecord::staleness_p50/p90/p99`) — the distribution the
    /// straggler experiments report, not just the worst case
    pub stale_hist: Vec<u64>,
    /// wire encoding used by send_round
    pub wire: WireFormat,
}

/// Histogram resolution for [`FloodState::stale_hist`]: staleness values
/// at or above this clamp into the last bucket (percentiles saturate
/// there; `max_staleness` stays exact).
pub const STALE_BUCKETS: usize = 1024;

impl FloodState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Retention-window push with eviction (first-seen order, capped at
    /// [`Self::retain`] entries; 0 = unbounded).
    fn remember(&mut self, msg: SeedUpdate) {
        self.window.push_back(msg);
        if self.retain > 0 && self.window.len() > self.retain {
            self.window.pop_front();
        }
    }

    /// Entries currently held for dedup + repair: retention-window
    /// messages plus out-of-order dedup tail entries — the O(n + window)
    /// memory bound ([`crate::metrics::RunRecord::flood_retained`]).
    pub fn retained_entries(&self) -> usize {
        self.window.len() + self.seen.tail_entries() as usize
    }

    /// Inject this client's own freshly generated update (start of Alg. 1
    /// step C): goes into the dedup filter, the retention window, and the
    /// outbox. Under the quantized wire format the coefficient is rounded
    /// here so the origin applies exactly what the network will carry.
    /// Returns the message as it will circulate.
    pub fn inject(&mut self, msg: SeedUpdate) -> SeedUpdate {
        let msg = match self.wire {
            WireFormat::Full => msg,
            WireFormat::Quantized(scale) => msg.quantized(scale),
        };
        self.seen.insert(msg.id);
        self.remember(msg);
        self.outbox.push(msg);
        msg
    }

    /// Answer a repair trigger ([`crate::net::Network::should_repair`])
    /// according to [`Self::repair_mode`]:
    ///
    /// * `Gap` — arm a [`Payload::Summary`] broadcast for the next send
    ///   round; neighbors reply with only the missing ranges
    ///   ([`Payload::GapFill`]). The outbox is left untouched.
    /// * `Reflood` — legacy: queue the whole retention window (minus
    ///   anything already outbound) for re-broadcast. Receivers dedup, so
    ///   only genuinely missed messages propagate as fresh; the duplicate
    ///   traffic is the (counted) price.
    pub fn repair(&mut self) {
        match self.repair_mode {
            RepairMode::Gap => self.summary_due = true,
            RepairMode::Reflood => {
                let outbound: HashSet<MsgId> = self.outbox.iter().map(|m| m.id).collect();
                self.repair_batch = self
                    .window
                    .iter()
                    .filter(|m| !outbound.contains(&m.id))
                    .copied()
                    .collect();
            }
        }
    }

    /// Record delivery staleness for freshly applied messages at training
    /// iteration `step` (staleness = apply iteration − origin iteration).
    /// On a reliable full-depth flood every message applies in its origin
    /// iteration; delayed flooding bounds this by ⌈D/k⌉, and netcond
    /// faults stretch it up to the repair latency.
    pub fn note_staleness(&mut self, step: usize, fresh: &[SeedUpdate]) {
        if self.stale_hist.is_empty() && !fresh.is_empty() {
            self.stale_hist = vec![0; STALE_BUCKETS];
        }
        for m in fresh {
            let stale = (step as u64).saturating_sub(m.id.step as u64);
            self.max_staleness = self.max_staleness.max(stale);
            self.stale_hist[(stale as usize).min(STALE_BUCKETS - 1)] += 1;
        }
    }

    /// One flooding step for client `me`: send R_i to all neighbors, plus
    /// any armed repair traffic (summary broadcast, queued gap-fill
    /// replies — both counted into
    /// [`crate::net::Accounting::repair_bytes`] by the network).
    /// Call [`Self::collect`] after *all* clients have sent (synchronous
    /// round semantics — matches Alg. 1's lockstep `for d = 0..D-1`).
    pub fn send_round(&mut self, me: usize, net: &mut Network) {
        if self.summary_due {
            self.summary_due = false;
            net.broadcast(me, &Payload::Summary(Arc::new(self.seen.summary())));
        }
        let quantized = matches!(self.wire, WireFormat::Quantized(_));
        for (dst, msgs) in std::mem::take(&mut self.gap_out) {
            net.send(me, dst, Payload::GapFill { msgs, quantized });
        }
        if !self.repair_batch.is_empty() {
            // legacy reflood repair: its own broadcast, so exactly these
            // bytes — and nothing that was already outbound — are
            // attributed to the repair accounting (Seeds payloads carry no
            // header, so the split costs no extra wire bytes)
            let batch = std::mem::take(&mut self.repair_batch);
            let payload = self.wire_payload(batch);
            let (bytes0, msgs0) = (net.acct.total_bytes, net.acct.total_messages);
            net.broadcast(me, &payload);
            net.acct.repair_bytes += net.acct.total_bytes - bytes0;
            net.acct.repair_messages += net.acct.total_messages - msgs0;
        }
        if self.outbox.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.outbox);
        let payload = self.wire_payload(batch);
        net.broadcast(me, &payload);
    }

    /// Wrap a seed batch in this client's wire encoding.
    fn wire_payload(&self, batch: Vec<SeedUpdate>) -> Payload {
        match self.wire {
            // sflint: allow(wire-conservation, reason = "wire_payload results are always broadcast by send_round")
            WireFormat::Full => Payload::Seeds(batch),
            // sflint: allow(wire-conservation, reason = "wire_payload results are always broadcast by send_round")
            WireFormat::Quantized(_) => Payload::SeedsQuantized(batch),
        }
    }

    /// Receive + dedup; newly seen messages become the next outbox and are
    /// returned for the caller to apply (Alg. 1: R_i ← received \ S_i).
    /// [`Payload::GapFill`] batches are folded exactly like flooded seeds;
    /// an incoming [`Payload::Summary`] queues a gap-fill reply (sent next
    /// round) with the retained messages the requester's high-water marks
    /// show missing.
    pub fn collect(&mut self, me: usize, net: &mut Network) -> Vec<SeedUpdate> {
        let mut fresh = vec![];
        for Message { from, payload } in net.recv_all(me) {
            let batch = match payload {
                Payload::Seeds(b) | Payload::SeedsQuantized(b) => b,
                Payload::GapFill { msgs, .. } => msgs,
                Payload::Summary(hwms) => {
                    // linear scan of the retention window per summary:
                    // O(retain) on the rare repair path; index the window
                    // by origin if anti-entropy periods ever get aggressive
                    let gaps: Vec<SeedUpdate> = self
                        .window
                        .iter()
                        .filter(|m| {
                            let their_hwm =
                                hwms.get(m.id.origin as usize).copied().unwrap_or(0);
                            m.id.step as u64 >= their_hwm as u64
                        })
                        .copied()
                        .collect();
                    // the requester's oldest missing step per origin is
                    // below our high-water mark, so we saw it — if it is
                    // not among the gaps, the window evicted it and this
                    // client cannot replay that history: count it
                    for (o, my_hwm) in self.seen.hwms().enumerate() {
                        let their = hwms.get(o).copied().unwrap_or(0);
                        let covered = gaps
                            .iter()
                            .any(|m| m.id.origin as usize == o && m.id.step == their);
                        if (their as u64) < my_hwm && !covered {
                            self.gap_misses += 1;
                        }
                    }
                    if !gaps.is_empty() {
                        self.gap_out.push((from, gaps));
                    }
                    continue;
                }
                _ => panic!("flooding received non-seed payload"),
            };
            for msg in batch {
                if self.seen.insert(msg.id) {
                    self.remember(msg);
                    fresh.push(msg);
                } else {
                    self.duplicates += 1;
                }
            }
        }
        self.outbox.extend_from_slice(&fresh);
        fresh
    }
}

/// The lockstep flooding loop, generic over where each client's
/// [`FloodState`] lives (`flood_of` projects it out of the per-client
/// item) — the single production copy of the round protocol, shared by
/// [`flood_rounds`] over bare `FloodState`s and by SeedFlood's
/// `communicate` over engine `ClientState`s.
///
/// Each round advances the network's delivery clock ([`Network::tick`])
/// and skips offline clients ([`Network::is_online`]): an offline client
/// neither drains its outbox (so nothing is lost while churned out) nor
/// receives — both no-ops on the reliable default network. `apply` runs
/// on the whole item, with the `FloodState` borrow released, whenever a
/// round delivered fresh messages to that client.
pub fn flood_rounds_by<S, G, F>(
    items: &mut [S],
    net: &mut Network,
    k: usize,
    mut flood_of: G,
    mut apply: F,
) where
    G: FnMut(&mut S) -> &mut FloodState,
    F: FnMut(&mut S, usize, &[SeedUpdate]),
{
    for _ in 0..k {
        net.tick();
        for (i, it) in items.iter_mut().enumerate() {
            if net.is_online(i) {
                flood_of(it).send_round(i, net);
            }
        }
        for (i, it) in items.iter_mut().enumerate() {
            if !net.is_online(i) {
                continue;
            }
            let fresh = flood_of(it).collect(i, net);
            if !fresh.is_empty() {
                apply(it, i, &fresh);
            }
        }
    }
}

/// Run `k` synchronous flooding rounds over all clients; calls `apply`
/// with (client, &fresh messages) after each round. Thin wrapper over
/// [`flood_rounds_by`] for plain `FloodState` slices (tests, benches,
/// examples).
pub fn flood_rounds<F>(states: &mut [FloodState], net: &mut Network, k: usize, mut apply: F)
where
    F: FnMut(usize, &[SeedUpdate]),
{
    // fn item, not a closure: projection callbacks returning borrows of
    // their argument need late-bound lifetimes to satisfy the for<'a>
    // bound, which closure inference does not reliably produce
    fn itself(s: &mut FloodState) -> &mut FloodState {
        s
    }
    flood_rounds_by(states, net, k, itself, |_, i, fresh| apply(i, fresh));
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    use super::*;
    use crate::topology::Topology;

    #[test]
    fn quantized_wire_floods_identically_and_costs_less() {
        let run = |wire: WireFormat| {
            let topo = Topology::ring(8);
            let d = topo.diameter();
            let mut net = Network::new(topo);
            let mut states: Vec<FloodState> = (0..8)
                .map(|_| FloodState { wire, ..FloodState::new() })
                .collect();
            for (i, st) in states.iter_mut().enumerate() {
                st.inject(SeedUpdate {
                    id: MsgId { origin: i as u32, step: 0 },
                    seed: i as u64,
                    coeff: 1.7e-4 * (i as f32 - 3.5),
                });
            }
            flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
            (states.iter().map(|s| s.seen.len()).min().unwrap(), net.acct.total_bytes)
        };
        let (cov_full, bytes_full) = run(WireFormat::Full);
        let (cov_q, bytes_q) = run(WireFormat::Quantized(1e-3));
        assert_eq!(cov_full, 8);
        assert_eq!(cov_q, 8);
        assert!(bytes_q * 2 < bytes_full, "{bytes_q} vs {bytes_full}");
    }

    fn msg(origin: u32, step: u32) -> SeedUpdate {
        SeedUpdate {
            id: MsgId { origin, step },
            seed: origin as u64 * 1000 + step as u64,
            coeff: 1.0,
        }
    }

    #[test]
    fn step_set_in_order_stays_compact() {
        let mut s = StepSet::default();
        for step in 0..1000 {
            assert!(s.insert(step), "step {step}");
            assert!(!s.insert(step), "duplicate step {step}");
        }
        assert_eq!(s.hwm(), 1000);
        assert_eq!(s.len(), 1000);
        assert_eq!(s.tail_words(), 0, "in-order inserts must not retain tail");
    }

    #[test]
    fn step_set_out_of_order_compacts_when_gap_closes() {
        let mut s = StepSet::default();
        // arrive 0..200 in reversed 100-blocks: [100..200), then [0..100)
        for step in 100..200 {
            assert!(s.insert(step));
        }
        assert_eq!(s.hwm(), 0);
        assert_eq!(s.tail_entries(), 100);
        for step in 0..100 {
            assert!(s.insert(step));
        }
        assert_eq!(s.hwm(), 200, "closing the gap must advance the mark");
        assert_eq!(s.tail_words(), 0, "compaction must free the bitset");
        assert_eq!(s.len(), 200);
        for step in 0..200 {
            assert!(s.contains(step));
        }
        assert!(!s.contains(200));
    }

    #[test]
    fn step_set_matches_hashset_on_word_boundaries() {
        // exercise the cross-word shift in compact(): runs of 63/64/65
        let mut s = StepSet::default();
        let mut reference = HashSet::new();
        for &step in &[64u32, 0, 63, 1, 2, 130, 65, 64, 129, 128, 3] {
            assert_eq!(s.insert(step), reference.insert(step), "step {step}");
        }
        for step in 0..200 {
            assert_eq!(s.contains(step), reference.contains(&step), "step {step}");
        }
        assert_eq!(s.len(), reference.len() as u64);
    }

    #[test]
    fn dedup_summary_reports_contiguous_prefix_only() {
        let mut d = FloodDedup::default();
        d.insert(MsgId { origin: 0, step: 0 });
        d.insert(MsgId { origin: 0, step: 1 });
        d.insert(MsgId { origin: 2, step: 5 }); // origin 2: gap below 5
        assert_eq!(d.summary(), vec![2, 0, 0]);
        assert_eq!(d.len(), 3);
        assert_eq!(d.tail_entries(), 1);
        assert!(d.contains(&MsgId { origin: 2, step: 5 }));
        assert!(!d.contains(&MsgId { origin: 2, step: 4 }));
        assert!(!d.contains(&MsgId { origin: 7, step: 0 }));
    }

    #[test]
    fn million_step_flood_memory_stays_bounded() {
        // acceptance: per-client dedup memory is O(n + window) retained
        // entries on a million-step run, not O(T·n)
        let retain = 1024;
        let mut st = FloodState { retain, ..FloodState::new() };
        for step in 0..1_000_000u32 {
            st.inject(msg(0, step));
            st.outbox.clear(); // stand-in for a drained send round
        }
        assert_eq!(st.seen.len(), 1_000_000);
        assert_eq!(st.window.len(), retain, "window must evict to its cap");
        assert_eq!(st.seen.tail_words(), 0, "in-order steps retain no bitset");
        assert!(st.retained_entries() <= retain);
    }

    /// Everyone receives everything after D rounds — the paper's perfect-
    /// consensus claim, checked on every topology we ship.
    #[test]
    fn full_flooding_reaches_all_clients() {
        for topo in [
            Topology::ring(9),
            Topology::meshgrid(16),
            Topology::star(7),
            Topology::complete(5),
            Topology::erdos_renyi(12, 3),
        ] {
            let n = topo.n;
            let d = topo.diameter();
            let mut net = Network::new(topo);
            let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
            for (i, st) in states.iter_mut().enumerate() {
                st.inject(msg(i as u32, 0));
            }
            let mut received = vec![0usize; n];
            flood_rounds(&mut states, &mut net, d, |i, fresh| {
                received[i] += fresh.len();
            });
            for (i, st) in states.iter().enumerate() {
                assert_eq!(st.seen.len(), n, "client {i} missing messages");
                assert_eq!(received[i], n - 1);
            }
        }
    }

    #[test]
    fn each_message_applied_exactly_once() {
        let topo = Topology::meshgrid(16);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..16).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        let mut apply_counts = vec![std::collections::HashMap::new(); 16];
        flood_rounds(&mut states, &mut net, d, |i, fresh| {
            for m in fresh {
                *apply_counts[i].entry(m.id).or_insert(0) += 1;
            }
        });
        for counts in &apply_counts {
            assert!(counts.values().all(|&c| c == 1), "message applied twice");
        }
    }

    #[test]
    fn delayed_flooding_bounded_staleness() {
        // k=1 on a ring of 8 (D=4): message from client 0 reaches the
        // antipodal client 4 after exactly 4 iterations, not before.
        let topo = Topology::ring(8);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..8).map(|_| FloodState::new()).collect();
        states[0].inject(msg(0, 0));
        for iter in 1..=4 {
            flood_rounds(&mut states, &mut net, 1, |_, _| {});
            let reached = states[4].seen.contains(&MsgId { origin: 0, step: 0 });
            assert_eq!(reached, iter >= 4, "iter {iter}");
        }
    }

    #[test]
    fn flooding_cost_independent_of_extra_rounds() {
        // once everyone has seen everything, further rounds send nothing
        let topo = Topology::ring(6);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..6).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        // D rounds deliver everything; one extra round drains the final
        // outboxes (messages first seen in round D are forwarded once more)
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        let bytes_after_drain = net.acct.total_bytes;
        flood_rounds(&mut states, &mut net, 10, |_, _| {});
        assert_eq!(net.acct.total_bytes, bytes_after_drain);
    }

    #[test]
    fn per_iteration_message_volume_is_o_n() {
        // Table 1: SeedFlood communicated bytes per edge per iteration is
        // O(n), independent of model size by construction.
        let n = 16;
        let topo = Topology::ring(n);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d, |_, _| {});
        // each message traverses each directed edge at most twice
        let max_bytes = (2 * n) as u64 * SeedUpdate::WIRE_BYTES * 2 * n as u64;
        assert!(net.acct.total_bytes <= max_bytes);
    }

    #[test]
    fn window_records_first_seen_order_and_reflood_repair_resends_it() {
        let topo = Topology::ring(4);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4)
            .map(|_| FloodState { repair_mode: RepairMode::Reflood, ..FloodState::new() })
            .collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        for st in &states {
            assert_eq!(st.window.len(), 4, "window holds everything (retain=0)");
            assert!(st.outbox.is_empty(), "drained after D+1 rounds");
        }
        // reflood repair queues the full window (nothing is outbound) for
        // re-broadcast; receivers dedup, so a re-flood round only costs
        // duplicate (repair) traffic
        let bytes_before = net.acct.total_bytes;
        states[0].repair();
        assert_eq!(states[0].repair_batch.len(), 4);
        assert!(states[0].outbox.is_empty(), "repair must not touch the outbox");
        flood_rounds(&mut states, &mut net, 1, |_, fresh| {
            panic!("nothing should be fresh, got {fresh:?}")
        });
        assert!(net.acct.total_bytes > bytes_before);
        assert_eq!(
            net.acct.repair_bytes,
            net.acct.total_bytes - bytes_before,
            "the whole re-flood must be attributed to repair"
        );
        assert!(states.iter().skip(1).any(|s| s.duplicates > 0));
    }

    #[test]
    fn reflood_repair_excludes_already_outbound_messages() {
        let topo = Topology::ring(4);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4)
            .map(|_| FloodState { repair_mode: RepairMode::Reflood, ..FloodState::new() })
            .collect();
        for step in 0..5 {
            states[0].inject(msg(0, step));
        }
        // everything is still outbound (never sent) → nothing to re-flood:
        // those messages would have been transmitted anyway and must not
        // inflate the repair accounting
        states[0].repair();
        assert!(states[0].repair_batch.is_empty());
        states[0].send_round(0, &mut net);
        let normal_bytes = net.acct.total_bytes;
        assert!(normal_bytes > 0);
        assert_eq!(net.acct.repair_bytes, 0, "outbound traffic is not repair");
        // with the outbox drained, a repair re-floods the whole window —
        // and exactly that broadcast is attributed to repair
        states[0].repair();
        assert_eq!(states[0].repair_batch.len(), 5);
        states[0].send_round(0, &mut net);
        assert_eq!(net.acct.repair_bytes, net.acct.total_bytes - normal_bytes);
    }

    #[test]
    fn gap_repair_requests_only_the_missing_range() {
        // client 1 on a 2-ring misses steps 3..10 from origin 0; a gap
        // repair must move exactly the missing messages plus the summary,
        // not the whole history
        let topo = Topology::ring(2);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..2).map(|_| FloodState::new()).collect();
        for step in 0..10 {
            states[0].inject(msg(0, step));
        }
        // steps 0..3 reached client 1 before the (simulated) outage
        for step in 0..3 {
            states[1].seen.insert(MsgId { origin: 0, step });
        }
        states[0].outbox.clear(); // outage: the normal flood never happened
        states[1].repair(); // recovery trigger → summary next round
        let mut fresh_at_1 = vec![];
        flood_rounds(&mut states, &mut net, 2, |i, fresh| {
            if i == 1 {
                fresh_at_1.extend_from_slice(fresh);
            }
        });
        // round 1: summary 1→0; round 2: gap-fill 0→1 with steps 3..10
        let got: Vec<u32> = fresh_at_1.iter().map(|m| m.id.step).collect();
        assert_eq!(got, (3..10).collect::<Vec<u32>>());
        assert_eq!(states[1].seen.len(), 10);
        // repair accounting: one summary + one 7-message gap-fill, plus the
        // requester forwarding nothing it already had
        let expect = Payload::Summary(Arc::new(states[1].seen.summary())).wire_bytes()
            + Payload::GapFill { msgs: fresh_at_1.clone(), quantized: false }.wire_bytes();
        assert_eq!(net.acct.repair_bytes, expect);
        assert_eq!(net.acct.repair_messages, 2);
    }

    #[test]
    fn gap_repair_counts_history_evicted_from_the_window() {
        // responder retains only the last 2 of 10 messages; a requester
        // missing everything gets those 2 — and the unfillable older
        // history is counted instead of silently ignored
        let topo = Topology::ring(2);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..2)
            .map(|_| FloodState { retain: 2, ..FloodState::new() })
            .collect();
        for step in 0..10 {
            states[0].inject(msg(0, step));
        }
        states[0].outbox.clear(); // outage: the normal flood never happened
        states[1].repair();
        let mut fresh_at_1 = vec![];
        flood_rounds(&mut states, &mut net, 2, |i, fresh| {
            if i == 1 {
                fresh_at_1.extend_from_slice(fresh);
            }
        });
        let got: Vec<u32> = fresh_at_1.iter().map(|m| m.id.step).collect();
        assert_eq!(got, vec![8, 9], "only the retained tail is replayable");
        assert_eq!(states[0].gap_misses, 1, "the evicted gap must be counted");
    }

    #[test]
    fn gap_repair_is_a_noop_when_nothing_is_missing() {
        let topo = Topology::ring(4);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        states[0].repair();
        flood_rounds(&mut states, &mut net, 2, |_, fresh| {
            panic!("nothing should be fresh, got {fresh:?}")
        });
        // the summary's marks (hwm = 1 per origin) cover every retained
        // message, so neighbors send no gap-fill replies at all — repair
        // cost is the two summary broadcasts and nothing else
        assert_eq!(
            net.acct.repair_messages, 2,
            "one summary per neighbor, no gap-fill replies"
        );
    }

    #[test]
    fn staleness_tracks_apply_minus_origin_step() {
        let mut st = FloodState::new();
        st.note_staleness(5, &[msg(0, 3), msg(1, 5)]);
        assert_eq!(st.max_staleness, 2);
        st.note_staleness(7, &[msg(2, 1)]);
        assert_eq!(st.max_staleness, 6);
        // a message applied "before" its origin step never underflows
        st.note_staleness(0, &[msg(3, 9)]);
        assert_eq!(st.max_staleness, 6);
        // the histogram records the full distribution, not just the max
        assert_eq!(st.stale_hist[0], 2); // staleness 0: (1,5)@5 and (3,9)@0
        assert_eq!(st.stale_hist[2], 1);
        assert_eq!(st.stale_hist[6], 1);
        assert_eq!(st.stale_hist.iter().sum::<u64>(), 4);
        // extreme staleness clamps into the last bucket
        st.note_staleness(5000, &[msg(4, 0)]);
        assert_eq!(st.stale_hist[STALE_BUCKETS - 1], 1);
        assert_eq!(st.max_staleness, 5000, "max stays exact beyond the clamp");
    }

    #[test]
    fn duplicates_are_counted_not_applied() {
        let topo = Topology::complete(4); // lots of redundant paths
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, 2, |_, _| {});
        let dup_total: u64 = states.iter().map(|s| s.duplicates).sum();
        assert!(dup_total > 0, "complete graph must produce duplicate receipts");
    }

    #[test]
    fn repair_mode_parses() {
        assert_eq!(RepairMode::parse("gap"), Some(RepairMode::Gap));
        assert_eq!(RepairMode::parse("Reflood"), Some(RepairMode::Reflood));
        assert_eq!(RepairMode::parse("full-log"), None);
        assert_eq!(RepairMode::default().name(), "gap");
    }

    #[test]
    fn step_set_gap_closes_across_full_word_blocks() {
        // exercise compact()'s run == 64 whole-word removal: fill three
        // full words above the mark, then close the gap last
        let mut s = StepSet::default();
        for step in 64..192 {
            assert!(s.insert(step));
        }
        for step in (0..64).rev() {
            assert!(s.insert(step), "step {step}");
        }
        assert_eq!(s.hwm(), 192, "three full words must compact at once");
        assert_eq!(s.tail_words(), 0);
        assert_eq!(s.len(), 192);
    }

    #[test]
    fn step_set_hwm_saturates_at_the_u32_step_ceiling() {
        // steps are u32, so the mark tops out at 2^32: walk the last few
        // steps of the id space (the mark itself is u64, so no overflow)
        let top = u32::MAX as u64 + 1;
        let mut s = StepSet { hwm: top - 3, tail: vec![] };
        assert!(s.insert(u32::MAX - 2));
        assert!(s.insert(u32::MAX));
        assert_eq!(s.hwm(), top - 1, "gap at MAX-1 still open");
        assert!(s.insert(u32::MAX - 1), "closing the last gap");
        assert_eq!(s.hwm(), top, "the mark saturates the u32 step space");
        assert_eq!(s.tail_words(), 0);
        assert!(s.contains(u32::MAX));
        assert!(!s.insert(u32::MAX), "duplicate at the ceiling");
    }

    #[test]
    fn dedup_summary_clamps_saturated_marks_to_u32() {
        // a fully saturated origin advertises u32::MAX (not a wrapped 0)
        let d = FloodDedup {
            dense: vec![StepSet { hwm: u32::MAX as u64 + 1, tail: vec![] }],
            ..FloodDedup::default()
        };
        assert_eq!(d.summary(), vec![u32::MAX]);
        assert_eq!(d.hwms().collect::<Vec<_>>(), vec![u32::MAX as u64 + 1]);
    }

    #[test]
    fn sparse_dedup_matches_dense_on_an_interleaved_stream() {
        // in-module smoke for the representation equivalence (the heavy
        // randomized version lives in rust/tests/properties.rs): mixed
        // small/huge origins, duplicates, reorder gaps
        let stream: Vec<(u32, u32)> = vec![
            (0, 0), (3, 2), (3, 0), (90_000, 5), (0, 0), (3, 1), (7, 0),
            (90_000, 0), (1024, 0), (1023, 9), (3, 3), (90_000, 5), (7, 1),
            (0, 1), (1024, 1), (90_000, 1), (1023, 0), (7, 0),
        ];
        let mut auto = FloodDedup::default(); // converts at origin 90_000
        let mut sparse = FloodDedup::with_crossover(0);
        let mut dense = FloodDedup::with_crossover(u32::MAX);
        let mut reference = HashSet::new();
        for &(origin, step) in &stream {
            let id = MsgId { origin, step };
            let expect = reference.insert(id);
            assert_eq!(auto.insert(id), expect, "auto {id:?}");
            assert_eq!(sparse.insert(id), expect, "sparse {id:?}");
            assert_eq!(dense.insert(id), expect, "dense {id:?}");
        }
        assert_eq!(auto.len(), reference.len());
        assert_eq!(sparse.len(), reference.len());
        assert_eq!(dense.len(), reference.len());
        assert_eq!(auto.num_origins(), dense.num_origins());
        assert_eq!(sparse.num_origins(), dense.num_origins());
        let hwms: Vec<u64> = dense.hwms().collect();
        assert_eq!(auto.hwms().collect::<Vec<_>>(), hwms);
        assert_eq!(sparse.hwms().collect::<Vec<_>>(), hwms);
        assert_eq!(auto.summary(), dense.summary());
        assert_eq!(sparse.summary(), dense.summary());
        assert_eq!(sparse.tail_entries(), dense.tail_entries());
        for &(origin, step) in &stream {
            let id = MsgId { origin, step };
            assert!(auto.contains(&id) && sparse.contains(&id) && dense.contains(&id));
        }
        assert!(!sparse.contains(&MsgId { origin: 90_000, step: 2 }));
        assert!(!sparse.contains(&MsgId { origin: 50_000, step: 0 }));
    }

    #[test]
    fn sparse_floor_advance_collapses_steady_state_memory() {
        // full-population flood, sparse representation: after every
        // origin delivers step t, per-origin state must collapse into the
        // floor — memory stays bounded by the transient bitset, not O(n)
        // StepSets, and decisions stay exact
        let n: u32 = 50_000;
        let mut d = FloodDedup::with_crossover(0);
        d.reserve_origins(n as usize);
        for step in 0..3u32 {
            for origin in 0..n {
                assert!(d.insert(MsgId { origin, step }));
                assert!(!d.insert(MsgId { origin, step }), "duplicate accepted");
            }
        }
        assert_eq!(d.len(), 3 * n as usize);
        assert_eq!(d.hwm(0), 3);
        assert_eq!(d.hwm(n - 1), 3);
        assert!(!d.contains(&MsgId { origin: 17, step: 3 }));
        assert!(d.contains(&MsgId { origin: 17, step: 2 }));
        // after the collapse: no bitset, no map entries — just the floor.
        // The whole filter fits in a few hundred bytes where the dense
        // table holds n StepSets (~32 B each).
        assert!(
            d.mem_bytes() < 8 * 1024,
            "steady-state sparse footprint leaked: {} B",
            d.mem_bytes()
        );
        assert_eq!(d.tail_entries(), 0);
    }

    #[test]
    fn sparse_universe_freezes_once_the_floor_moves() {
        // origins first heard after the floor advanced must not inherit
        // the floor's history: they live on the absolute map path
        let mut d = FloodDedup::with_crossover(0);
        d.reserve_origins(4);
        for origin in 0..4 {
            d.insert(MsgId { origin, step: 0 });
        }
        // floor is now 1 for origins 0..4; a brand-new origin appears
        assert!(d.insert(MsgId { origin: 9, step: 0 }));
        assert_eq!(d.hwm(9), 1);
        assert_eq!(d.hwm(5), 0, "never-heard origin must stay at 0");
        assert!(!d.contains(&MsgId { origin: 5, step: 0 }));
        assert_eq!(d.num_origins(), 10);
        assert_eq!(d.summary(), vec![1, 1, 1, 1, 0, 0, 0, 0, 0, 1]);
    }

    #[test]
    fn gap_repair_answers_a_requester_that_never_saw_the_origin() {
        // satellite: Summary/GapFill against a sparse filter that has no
        // entry at all for the requested origin — the requester's summary
        // advertises hwm 0 (or is too short), and the responder's window
        // replay must still deliver the whole retained history
        let topo = Topology::ring(2);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..2).map(|_| FloodState::new()).collect();
        // requester 1 runs the sparse representation from the start
        states[1].seen = FloodDedup::with_crossover(0);
        for step in 0..6 {
            states[0].inject(msg(0, step));
        }
        states[0].outbox.clear(); // outage: the flood never reached 1
        states[1].repair();
        let mut fresh_at_1 = vec![];
        flood_rounds(&mut states, &mut net, 2, |i, fresh| {
            if i == 1 {
                fresh_at_1.extend_from_slice(fresh);
            }
        });
        let got: Vec<u32> = fresh_at_1.iter().map(|m| m.id.step).collect();
        assert_eq!(got, (0..6).collect::<Vec<u32>>());
        assert_eq!(states[1].seen.len(), 6);
        assert_eq!(states[1].seen.hwm(0), 6);
        assert_eq!(states[0].gap_misses, 0, "nothing was evicted");
    }
}
