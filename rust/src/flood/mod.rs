//! Flooding — the consensus primitive that replaces gossip (paper §3.3).
//!
//! Upon first receipt of a message, a client forwards it to all neighbors;
//! repeated for `D` (network diameter) steps, every update generated in an
//! iteration reaches every client — an all-gather-equivalent consensus
//! with cost independent of model dimension.
//!
//! *Delayed flooding* (paper §4.5): run only `k` flood steps per local
//! iteration; the outbox persists across iterations so messages keep
//! propagating with a bounded delay of ≤ ⌈D/k⌉ iterations.
//!
//! # Unreliable networks
//!
//! Under an installed [`crate::netcond::NetCond`] fault model, messages
//! can be lost (packet loss, down links) or stranded (node churn). The
//! flooding state answers with *repair*: every message ever seen is kept
//! in an append-only [`FloodState::log`] (cheap by construction — a
//! seed–scalar message is 20 bytes, the paper's core point), and when the
//! network signals a recovery or an anti-entropy heartbeat
//! ([`crate::net::Network::should_repair`]) the client re-floods the whole
//! log via [`FloodState::repair`]. Receivers dedup as usual, so only the
//! genuinely missed messages propagate as fresh — delivery degrades to
//! *bounded staleness* instead of silent loss.
//!
//! A 4-node ring floods to full coverage in D = 2 rounds:
//!
//! ```
//! use seedflood::flood::{flood_rounds, FloodState};
//! use seedflood::net::{MsgId, Network, SeedUpdate};
//! use seedflood::topology::Topology;
//!
//! let topo = Topology::ring(4);
//! let d = topo.diameter();
//! let mut net = Network::new(topo);
//! let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
//! for (i, st) in states.iter_mut().enumerate() {
//!     st.inject(SeedUpdate {
//!         id: MsgId { origin: i as u32, step: 0 },
//!         seed: i as u64,
//!         coeff: 0.25,
//!     });
//! }
//! flood_rounds(&mut states, &mut net, d, |_client, _fresh| {});
//! assert!(states.iter().all(|s| s.seen.len() == 4)); // everyone has everything
//! ```

use std::collections::HashSet;

use crate::net::{Message, MsgId, Network, Payload, SeedUpdate};

/// On-wire encoding for flooded messages.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum WireFormat {
    /// 20 B per message: id + seed + f32 coefficient.
    #[default]
    Full,
    /// 9 B per message (Zelikman et al. 2023): 1-byte µ-law coefficient
    /// around the given scale; values are quantized at injection so every
    /// client applies identical (dequantized) coefficients — consensus is
    /// preserved exactly.
    Quantized(f32),
}

/// Per-client flooding protocol state (Alg. 1: S_i = seen, R_i = outbox).
#[derive(Debug, Default)]
pub struct FloodState {
    /// S_i — every message id ever received (dedup filter)
    pub seen: HashSet<MsgId>,
    /// R_i — messages received last step, to forward this step
    pub outbox: Vec<SeedUpdate>,
    /// append-only record of every message in first-seen order — the
    /// source for netcond recovery re-floods ([`Self::repair`]); 20 bytes
    /// per entry, the same order of memory as the dedup set
    pub log: Vec<SeedUpdate>,
    /// duplicate receptions filtered (metrics: flooding overhead)
    pub duplicates: u64,
    /// worst (apply iteration − origin iteration) observed, recorded via
    /// [`Self::note_staleness`] — 0 on a reliable full-depth flood
    pub max_staleness: u64,
    /// wire encoding used by send_round
    pub wire: WireFormat,
}

impl FloodState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Inject this client's own freshly generated update (start of Alg. 1
    /// step C): goes into both the seen-set and the outbox. Under the
    /// quantized wire format the coefficient is rounded here so the origin
    /// applies exactly what the network will carry. Returns the message as
    /// it will circulate.
    pub fn inject(&mut self, msg: SeedUpdate) -> SeedUpdate {
        let msg = match self.wire {
            WireFormat::Full => msg,
            WireFormat::Quantized(scale) => msg.quantized(scale),
        };
        self.seen.insert(msg.id);
        self.log.push(msg);
        self.outbox.push(msg);
        msg
    }

    /// Re-flood everything this client has ever seen: reset the outbox to
    /// the full message log. Called when the network signals a recovery or
    /// an anti-entropy heartbeat ([`crate::net::Network::should_repair`]).
    /// Receivers dedup, so only genuinely missed messages propagate as
    /// fresh; the duplicate traffic is the (counted) price of repair. The
    /// outbox is always a subset of the log, so nothing is lost here.
    pub fn repair(&mut self) {
        self.outbox = self.log.clone();
    }

    /// Record delivery staleness for freshly applied messages at training
    /// iteration `step` (staleness = apply iteration − origin iteration).
    /// On a reliable full-depth flood every message applies in its origin
    /// iteration; delayed flooding bounds this by ⌈D/k⌉, and netcond
    /// faults stretch it up to the repair latency.
    pub fn note_staleness(&mut self, step: usize, fresh: &[SeedUpdate]) {
        for m in fresh {
            let stale = (step as u64).saturating_sub(m.id.step as u64);
            self.max_staleness = self.max_staleness.max(stale);
        }
    }

    /// One flooding step for client `me`: send R_i to all neighbors.
    /// Call [`Self::collect`] after *all* clients have sent (synchronous
    /// round semantics — matches Alg. 1's lockstep `for d = 0..D-1`).
    pub fn send_round(&mut self, me: usize, net: &mut Network) {
        if self.outbox.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.outbox);
        let payload = match self.wire {
            WireFormat::Full => Payload::Seeds(batch),
            WireFormat::Quantized(_) => Payload::SeedsQuantized(batch),
        };
        net.broadcast(me, &payload);
    }

    /// Receive + dedup; newly seen messages become the next outbox and are
    /// returned for the caller to apply (Alg. 1: R_i ← received \ S_i).
    pub fn collect(&mut self, me: usize, net: &mut Network) -> Vec<SeedUpdate> {
        let mut fresh = vec![];
        for Message { payload, .. } in net.recv_all(me) {
            let batch = match payload {
                Payload::Seeds(b) | Payload::SeedsQuantized(b) => b,
                _ => panic!("flooding received non-seed payload"),
            };
            for msg in batch {
                if self.seen.insert(msg.id) {
                    self.log.push(msg);
                    fresh.push(msg);
                } else {
                    self.duplicates += 1;
                }
            }
        }
        self.outbox.extend_from_slice(&fresh);
        fresh
    }
}

/// The lockstep flooding loop, generic over where each client's
/// [`FloodState`] lives (`flood_of` projects it out of the per-client
/// item) — the single production copy of the round protocol, shared by
/// [`flood_rounds`] over bare `FloodState`s and by SeedFlood's
/// `communicate` over engine `ClientState`s.
///
/// Each round advances the network's delivery clock ([`Network::tick`])
/// and skips offline clients ([`Network::is_online`]): an offline client
/// neither drains its outbox (so nothing is lost while churned out) nor
/// receives — both no-ops on the reliable default network. `apply` runs
/// on the whole item, with the `FloodState` borrow released, whenever a
/// round delivered fresh messages to that client.
pub fn flood_rounds_by<S, G, F>(
    items: &mut [S],
    net: &mut Network,
    k: usize,
    mut flood_of: G,
    mut apply: F,
) where
    G: FnMut(&mut S) -> &mut FloodState,
    F: FnMut(&mut S, usize, &[SeedUpdate]),
{
    for _ in 0..k {
        net.tick();
        for (i, it) in items.iter_mut().enumerate() {
            if net.is_online(i) {
                flood_of(it).send_round(i, net);
            }
        }
        for (i, it) in items.iter_mut().enumerate() {
            if !net.is_online(i) {
                continue;
            }
            let fresh = flood_of(it).collect(i, net);
            if !fresh.is_empty() {
                apply(it, i, &fresh);
            }
        }
    }
}

/// Run `k` synchronous flooding rounds over all clients; calls `apply`
/// with (client, &fresh messages) after each round. Thin wrapper over
/// [`flood_rounds_by`] for plain `FloodState` slices (tests, benches,
/// examples).
pub fn flood_rounds<F>(
    states: &mut [FloodState],
    net: &mut Network,
    k: usize,
    mut apply: F,
) where
    F: FnMut(usize, &[SeedUpdate]),
{
    // fn item, not a closure: projection callbacks returning borrows of
    // their argument need late-bound lifetimes to satisfy the for<'a>
    // bound, which closure inference does not reliably produce
    fn itself(s: &mut FloodState) -> &mut FloodState {
        s
    }
    flood_rounds_by(states, net, k, itself, |_, i, fresh| apply(i, fresh));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn quantized_wire_floods_identically_and_costs_less() {
        let run = |wire: WireFormat| {
            let topo = Topology::ring(8);
            let d = topo.diameter();
            let mut net = Network::new(topo);
            let mut states: Vec<FloodState> = (0..8)
                .map(|_| FloodState { wire, ..FloodState::new() })
                .collect();
            for (i, st) in states.iter_mut().enumerate() {
                st.inject(SeedUpdate {
                    id: MsgId { origin: i as u32, step: 0 },
                    seed: i as u64,
                    coeff: 1.7e-4 * (i as f32 - 3.5),
                });
            }
            flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
            (states.iter().map(|s| s.seen.len()).min().unwrap(), net.acct.total_bytes)
        };
        let (cov_full, bytes_full) = run(WireFormat::Full);
        let (cov_q, bytes_q) = run(WireFormat::Quantized(1e-3));
        assert_eq!(cov_full, 8);
        assert_eq!(cov_q, 8);
        assert!(bytes_q * 2 < bytes_full, "{bytes_q} vs {bytes_full}");
    }

    fn msg(origin: u32, step: u32) -> SeedUpdate {
        SeedUpdate {
            id: MsgId { origin, step },
            seed: origin as u64 * 1000 + step as u64,
            coeff: 1.0,
        }
    }

    /// Everyone receives everything after D rounds — the paper's perfect-
    /// consensus claim, checked on every topology we ship.
    #[test]
    fn full_flooding_reaches_all_clients() {
        for topo in [
            Topology::ring(9),
            Topology::meshgrid(16),
            Topology::star(7),
            Topology::complete(5),
            Topology::erdos_renyi(12, 3),
        ] {
            let n = topo.n;
            let d = topo.diameter();
            let mut net = Network::new(topo);
            let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
            for (i, st) in states.iter_mut().enumerate() {
                st.inject(msg(i as u32, 0));
            }
            let mut received = vec![0usize; n];
            flood_rounds(&mut states, &mut net, d, |i, fresh| {
                received[i] += fresh.len();
            });
            for (i, st) in states.iter().enumerate() {
                assert_eq!(st.seen.len(), n, "client {i} missing messages");
                assert_eq!(received[i], n - 1);
            }
        }
    }

    #[test]
    fn each_message_applied_exactly_once() {
        let topo = Topology::meshgrid(16);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..16).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        let mut apply_counts = vec![std::collections::HashMap::new(); 16];
        flood_rounds(&mut states, &mut net, d, |i, fresh| {
            for m in fresh {
                *apply_counts[i].entry(m.id).or_insert(0) += 1;
            }
        });
        for counts in &apply_counts {
            assert!(counts.values().all(|&c| c == 1), "message applied twice");
        }
    }

    #[test]
    fn delayed_flooding_bounded_staleness() {
        // k=1 on a ring of 8 (D=4): message from client 0 reaches the
        // antipodal client 4 after exactly 4 iterations, not before.
        let topo = Topology::ring(8);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..8).map(|_| FloodState::new()).collect();
        states[0].inject(msg(0, 0));
        for iter in 1..=4 {
            flood_rounds(&mut states, &mut net, 1, |_, _| {});
            let reached = states[4].seen.contains(&MsgId { origin: 0, step: 0 });
            assert_eq!(reached, iter >= 4, "iter {iter}");
        }
    }

    #[test]
    fn flooding_cost_independent_of_extra_rounds() {
        // once everyone has seen everything, further rounds send nothing
        let topo = Topology::ring(6);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..6).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        // D rounds deliver everything; one extra round drains the final
        // outboxes (messages first seen in round D are forwarded once more)
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        let bytes_after_drain = net.acct.total_bytes;
        flood_rounds(&mut states, &mut net, 10, |_, _| {});
        assert_eq!(net.acct.total_bytes, bytes_after_drain);
    }

    #[test]
    fn per_iteration_message_volume_is_o_n() {
        // Table 1: SeedFlood communicated bytes per edge per iteration is
        // O(n), independent of model size by construction.
        let n = 16;
        let topo = Topology::ring(n);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d, |_, _| {});
        // each message traverses each directed edge at most twice
        let max_bytes = (2 * n) as u64 * SeedUpdate::WIRE_BYTES * 2 * n as u64;
        assert!(net.acct.total_bytes <= max_bytes);
    }

    #[test]
    fn log_records_first_seen_order_and_repair_refloods() {
        let topo = Topology::ring(4);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        for st in &states {
            assert_eq!(st.log.len(), 4, "log holds everything ever seen");
            assert!(st.outbox.is_empty(), "drained after D+1 rounds");
        }
        // repair resets the outbox to the full log; receivers dedup, so a
        // re-flood round only costs duplicate traffic
        let bytes_before = net.acct.total_bytes;
        states[0].repair();
        assert_eq!(states[0].outbox.len(), 4);
        flood_rounds(&mut states, &mut net, 1, |_, fresh| {
            panic!("nothing should be fresh, got {fresh:?}")
        });
        assert!(net.acct.total_bytes > bytes_before);
        assert!(states.iter().skip(1).any(|s| s.duplicates > 0));
    }

    #[test]
    fn staleness_tracks_apply_minus_origin_step() {
        let mut st = FloodState::new();
        st.note_staleness(5, &[msg(0, 3), msg(1, 5)]);
        assert_eq!(st.max_staleness, 2);
        st.note_staleness(7, &[msg(2, 1)]);
        assert_eq!(st.max_staleness, 6);
        // a message applied "before" its origin step never underflows
        st.note_staleness(0, &[msg(3, 9)]);
        assert_eq!(st.max_staleness, 6);
    }

    #[test]
    fn duplicates_are_counted_not_applied() {
        let topo = Topology::complete(4); // lots of redundant paths
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, 2, |_, _| {});
        let dup_total: u64 = states.iter().map(|s| s.duplicates).sum();
        assert!(dup_total > 0, "complete graph must produce duplicate receipts");
    }
}
