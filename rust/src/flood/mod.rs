//! Flooding — the consensus primitive that replaces gossip (paper §3.3).
//!
//! Upon first receipt of a message, a client forwards it to all neighbors;
//! repeated for `D` (network diameter) steps, every update generated in an
//! iteration reaches every client — an all-gather-equivalent consensus
//! with cost independent of model dimension.
//!
//! *Delayed flooding* (paper §4.5): run only `k` flood steps per local
//! iteration; the outbox persists across iterations so messages keep
//! propagating with a bounded delay of ≤ ⌈D/k⌉ iterations.
//!
//! # Dedup in O(n + window), not O(T·n)
//!
//! Message ids are `(origin, step)` pairs and every origin emits exactly
//! one message per step, so the dedup filter ([`FloodDedup`]) stores, per
//! origin, a contiguous high-water mark (all steps below it seen) plus a
//! small tail bitset for out-of-order arrivals ([`StepSet`]) — per-client
//! memory is O(n) plus the transient reorder gap, instead of one hash
//! entry per message ever received. A million-step flood retains a few
//! words per origin. Accept/duplicate decisions are bit-identical to a
//! reference `HashSet<MsgId>` (property-tested in
//! `rust/tests/properties.rs`).
//!
//! # Unreliable networks
//!
//! Under an installed [`crate::netcond::NetCond`] fault model, messages
//! can be lost (packet loss, down links) or stranded (node churn). The
//! flooding state answers with *repair*: a bounded [`FloodState::window`]
//! retains the most recent `retain` messages in first-seen order, and when
//! the network signals a recovery or an anti-entropy heartbeat
//! ([`crate::net::Network::should_repair`]) the client runs one of two
//! repair protocols ([`RepairMode`]):
//!
//! * [`RepairMode::Gap`] (default) — broadcast a
//!   [`crate::net::Payload::Summary`] of per-origin high-water marks
//!   (O(n) bytes); each neighbor answers with a
//!   [`crate::net::Payload::GapFill`] carrying only the retained messages
//!   the summary shows missing — repair cost is O(gap) on the wire.
//! * [`RepairMode::Reflood`] — legacy: re-broadcast the whole retention
//!   window; receivers dedup, so the cost is duplicate traffic
//!   proportional to the *entire history* retained (requires unbounded
//!   retention, `retain = 0`).
//!
//! Either way delivery degrades to *bounded staleness* instead of silent
//! loss, provided the retention window covers the longest outage
//! (`retain` ≥ messages generated per outage; 0 retains everything).
//!
//! A 4-node ring floods to full coverage in D = 2 rounds:
//!
//! ```
//! use seedflood::flood::{flood_rounds, FloodState};
//! use seedflood::net::{MsgId, Network, SeedUpdate};
//! use seedflood::topology::Topology;
//!
//! let topo = Topology::ring(4);
//! let d = topo.diameter();
//! let mut net = Network::new(topo);
//! let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
//! for (i, st) in states.iter_mut().enumerate() {
//!     st.inject(SeedUpdate {
//!         id: MsgId { origin: i as u32, step: 0 },
//!         seed: i as u64,
//!         coeff: 0.25,
//!     });
//! }
//! flood_rounds(&mut states, &mut net, d, |_client, _fresh| {});
//! assert!(states.iter().all(|s| s.seen.len() == 4)); // everyone has everything
//! ```

use std::collections::{HashSet, VecDeque};
use std::sync::Arc;

use crate::net::{Message, MsgId, Network, Payload, SeedUpdate};

/// On-wire encoding for flooded messages.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum WireFormat {
    /// 20 B per message: id + seed + f32 coefficient.
    #[default]
    Full,
    /// 9 B per message (Zelikman et al. 2023): 1-byte µ-law coefficient
    /// around the given scale; values are quantized at injection so every
    /// client applies identical (dequantized) coefficients — consensus is
    /// preserved exactly.
    Quantized(f32),
}

/// How a client answers a repair trigger (recovery or anti-entropy).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RepairMode {
    /// Gap-request protocol: broadcast a [`Payload::Summary`] of
    /// per-origin high-water marks; neighbors reply with
    /// [`Payload::GapFill`] carrying only the missing ranges they retain.
    /// Repair cost is O(gap) on the wire.
    #[default]
    Gap,
    /// Legacy full re-flood: re-broadcast the whole retention window
    /// (minus anything already outbound). Repair cost is O(everything
    /// retained) in duplicate traffic; requires unbounded retention.
    Reflood,
}

impl RepairMode {
    pub fn parse(s: &str) -> Option<RepairMode> {
        match s.to_ascii_lowercase().as_str() {
            "gap" => Some(RepairMode::Gap),
            "reflood" => Some(RepairMode::Reflood),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RepairMode::Gap => "gap",
            RepairMode::Reflood => "reflood",
        }
    }
}

/// Set of seen step numbers for one origin: a contiguous high-water mark
/// (every step below [`Self::hwm`] seen) plus a tail bitset for
/// out-of-order arrivals. Memory is O(reorder gap / 64) words and drops
/// back to zero once the gap closes — the structure the `(origin, step)`
/// id scheme makes exact.
///
/// ```
/// use seedflood::flood::StepSet;
///
/// let mut s = StepSet::default();
/// assert!(s.insert(1)); // out of order: goes to the tail bitset
/// assert!(s.insert(0)); // closes the gap: hwm jumps to 2, tail empties
/// assert!(!s.insert(1)); // duplicate
/// assert_eq!(s.hwm(), 2);
/// assert_eq!(s.tail_entries(), 0);
/// ```
#[derive(Clone, Debug, Default)]
pub struct StepSet {
    /// every step `< hwm` has been seen
    hwm: u64,
    /// bit `b` of `tail[w]` set ⇔ step `hwm + 64·w + b` seen (out of order)
    tail: Vec<u64>,
}

impl StepSet {
    /// The contiguous high-water mark: every step below it has been seen.
    pub fn hwm(&self) -> u64 {
        self.hwm
    }

    pub fn contains(&self, step: u32) -> bool {
        let s = step as u64;
        if s < self.hwm {
            return true;
        }
        let off = (s - self.hwm) as usize;
        self.tail.get(off / 64).is_some_and(|w| w >> (off % 64) & 1 == 1)
    }

    /// Record `step` as seen; returns true iff it was new. Inserting the
    /// step at the high-water mark compacts the tail (the mark advances
    /// over every contiguously seen step, freeing the bitset words).
    pub fn insert(&mut self, step: u32) -> bool {
        let s = step as u64;
        if s < self.hwm {
            return false;
        }
        let off = (s - self.hwm) as usize;
        let (w, b) = (off / 64, off % 64);
        if self.tail.len() <= w {
            self.tail.resize(w + 1, 0);
        }
        if self.tail[w] >> b & 1 == 1 {
            return false;
        }
        self.tail[w] |= 1 << b;
        if off == 0 {
            self.compact();
        }
        true
    }

    /// Advance `hwm` over the contiguous run of seen steps at the front of
    /// the tail and shift the bitset down accordingly.
    fn compact(&mut self) {
        while let Some(&w0) = self.tail.first() {
            let run = (!w0).trailing_zeros() as usize;
            if run == 0 {
                break;
            }
            if run == 64 {
                self.tail.remove(0);
                self.hwm += 64;
            } else {
                for i in 0..self.tail.len() {
                    self.tail[i] >>= run;
                    if i + 1 < self.tail.len() {
                        self.tail[i] |= self.tail[i + 1] << (64 - run);
                    }
                }
                self.hwm += run as u64;
                break;
            }
        }
        while self.tail.last() == Some(&0) {
            self.tail.pop();
        }
    }

    /// Total steps seen.
    pub fn len(&self) -> u64 {
        self.hwm + self.tail_entries()
    }

    pub fn is_empty(&self) -> bool {
        self.hwm == 0 && self.tail.is_empty()
    }

    /// Out-of-order steps currently held above the high-water mark.
    pub fn tail_entries(&self) -> u64 {
        self.tail.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Bitset words currently allocated (the memory-bound metric).
    pub fn tail_words(&self) -> usize {
        self.tail.len()
    }
}

/// The flooding dedup filter: one [`StepSet`] per origin, replacing the
/// historical `HashSet<MsgId>`. Same accept/duplicate decisions, O(n +
/// reorder gap) memory instead of O(T·n) (property-tested against the
/// hash-set reference in `rust/tests/properties.rs`).
#[derive(Clone, Debug, Default)]
pub struct FloodDedup {
    origins: Vec<StepSet>,
    total: u64,
}

impl FloodDedup {
    /// Record `id` as seen; returns true iff it was new (the exact
    /// contract of `HashSet::insert`).
    pub fn insert(&mut self, id: MsgId) -> bool {
        let o = id.origin as usize;
        if self.origins.len() <= o {
            self.origins.resize_with(o + 1, StepSet::default);
        }
        let fresh = self.origins[o].insert(id.step);
        if fresh {
            self.total += 1;
        }
        fresh
    }

    pub fn contains(&self, id: &MsgId) -> bool {
        self.origins.get(id.origin as usize).is_some_and(|s| s.contains(id.step))
    }

    /// Total messages seen (what `HashSet::len` used to report).
    pub fn len(&self) -> usize {
        self.total as usize
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Contiguous high-water mark for one origin (0 if never heard from).
    pub fn hwm(&self, origin: u32) -> u64 {
        self.origins.get(origin as usize).map_or(0, |s| s.hwm())
    }

    /// Per-origin high-water marks, origin-indexed — the O(n)-byte state
    /// summary of the gap-request repair protocol
    /// ([`Payload::Summary`]). Conservative by construction: out-of-order
    /// tail entries above a mark are *not* advertised, so a responder may
    /// re-send a few already-seen messages (dedup absorbs them).
    pub fn summary(&self) -> Vec<u32> {
        self.hwms().map(|h| h.min(u32::MAX as u64) as u32).collect()
    }

    /// Per-origin high-water marks as an iterator — the allocation-free
    /// view behind [`Self::summary`]. [`FloodState::collect`] answers each
    /// incoming summary through this instead of materializing an O(n)
    /// vector per neighbor per repair round (at n = 100k that allocation
    /// was the gap-protocol hot path).
    pub fn hwms(&self) -> impl Iterator<Item = u64> + '_ {
        self.origins.iter().map(|s| s.hwm())
    }

    /// Out-of-order entries retained above the high-water marks.
    pub fn tail_entries(&self) -> u64 {
        self.origins.iter().map(|s| s.tail_entries()).sum()
    }

    /// Bitset words currently allocated across all origins.
    pub fn tail_words(&self) -> usize {
        self.origins.iter().map(|s| s.tail_words()).sum()
    }
}

/// Per-client flooding protocol state (Alg. 1: S_i = seen, R_i = outbox).
#[derive(Debug, Default)]
pub struct FloodState {
    /// S_i — dedup filter over every message id received, as per-origin
    /// step intervals + tail bitsets (O(n + window), not O(T·n))
    pub seen: FloodDedup,
    /// R_i — messages received last step, to forward this step
    pub outbox: Vec<SeedUpdate>,
    /// bounded retention of recent messages in first-seen order — the
    /// source for repair (gap-fill responses, legacy re-floods); 20 bytes
    /// per entry, at most [`Self::retain`] entries
    pub window: VecDeque<SeedUpdate>,
    /// retention-window capacity; 0 retains everything (legacy behavior —
    /// required for [`RepairMode::Reflood`] to replay the full history)
    pub retain: usize,
    /// how repair triggers are answered (see [`RepairMode`])
    pub repair_mode: RepairMode,
    /// gap protocol: a repair trigger arms a summary broadcast for the
    /// next send round
    pub summary_due: bool,
    /// gap protocol: per-neighbor gap-fill replies queued for the next
    /// send round (computed in [`Self::collect`] from incoming summaries)
    pub gap_out: Vec<(usize, Vec<SeedUpdate>)>,
    /// reflood protocol: retained messages queued for a repair broadcast
    /// next send round — only messages *not* already outbound, so the
    /// attribution to [`crate::net::Accounting::repair_bytes`] counts
    /// nothing that would have been transmitted anyway
    pub repair_batch: Vec<SeedUpdate>,
    /// duplicate receptions filtered (metrics: flooding overhead)
    pub duplicates: u64,
    /// gap-fill responses where the requester's *oldest* missing step had
    /// already been evicted from the retention window — that history
    /// cannot be replayed from here. Persistently nonzero means `retain`
    /// is too small for the outage lengths (silent-loss warning,
    /// surfaced as `RunRecord::repair_gap_misses`)
    pub gap_misses: u64,
    /// worst (apply iteration − origin iteration) observed, recorded via
    /// [`Self::note_staleness`] — 0 on a reliable full-depth flood
    pub max_staleness: u64,
    /// staleness histogram: `stale_hist[s]` counts messages applied `s`
    /// iterations after their origin iteration (clamped to
    /// [`STALE_BUCKETS`] − 1). Feeds the per-run staleness percentiles
    /// (`RunRecord::staleness_p50/p90/p99`) — the distribution the
    /// straggler experiments report, not just the worst case
    pub stale_hist: Vec<u64>,
    /// wire encoding used by send_round
    pub wire: WireFormat,
}

/// Histogram resolution for [`FloodState::stale_hist`]: staleness values
/// at or above this clamp into the last bucket (percentiles saturate
/// there; `max_staleness` stays exact).
pub const STALE_BUCKETS: usize = 1024;

impl FloodState {
    pub fn new() -> Self {
        Self::default()
    }

    /// Retention-window push with eviction (first-seen order, capped at
    /// [`Self::retain`] entries; 0 = unbounded).
    fn remember(&mut self, msg: SeedUpdate) {
        self.window.push_back(msg);
        if self.retain > 0 && self.window.len() > self.retain {
            self.window.pop_front();
        }
    }

    /// Entries currently held for dedup + repair: retention-window
    /// messages plus out-of-order dedup tail entries — the O(n + window)
    /// memory bound ([`crate::metrics::RunRecord::flood_retained`]).
    pub fn retained_entries(&self) -> usize {
        self.window.len() + self.seen.tail_entries() as usize
    }

    /// Inject this client's own freshly generated update (start of Alg. 1
    /// step C): goes into the dedup filter, the retention window, and the
    /// outbox. Under the quantized wire format the coefficient is rounded
    /// here so the origin applies exactly what the network will carry.
    /// Returns the message as it will circulate.
    pub fn inject(&mut self, msg: SeedUpdate) -> SeedUpdate {
        let msg = match self.wire {
            WireFormat::Full => msg,
            WireFormat::Quantized(scale) => msg.quantized(scale),
        };
        self.seen.insert(msg.id);
        self.remember(msg);
        self.outbox.push(msg);
        msg
    }

    /// Answer a repair trigger ([`crate::net::Network::should_repair`])
    /// according to [`Self::repair_mode`]:
    ///
    /// * `Gap` — arm a [`Payload::Summary`] broadcast for the next send
    ///   round; neighbors reply with only the missing ranges
    ///   ([`Payload::GapFill`]). The outbox is left untouched.
    /// * `Reflood` — legacy: queue the whole retention window (minus
    ///   anything already outbound) for re-broadcast. Receivers dedup, so
    ///   only genuinely missed messages propagate as fresh; the duplicate
    ///   traffic is the (counted) price.
    pub fn repair(&mut self) {
        match self.repair_mode {
            RepairMode::Gap => self.summary_due = true,
            RepairMode::Reflood => {
                let outbound: HashSet<MsgId> = self.outbox.iter().map(|m| m.id).collect();
                self.repair_batch = self
                    .window
                    .iter()
                    .filter(|m| !outbound.contains(&m.id))
                    .copied()
                    .collect();
            }
        }
    }

    /// Record delivery staleness for freshly applied messages at training
    /// iteration `step` (staleness = apply iteration − origin iteration).
    /// On a reliable full-depth flood every message applies in its origin
    /// iteration; delayed flooding bounds this by ⌈D/k⌉, and netcond
    /// faults stretch it up to the repair latency.
    pub fn note_staleness(&mut self, step: usize, fresh: &[SeedUpdate]) {
        if self.stale_hist.is_empty() && !fresh.is_empty() {
            self.stale_hist = vec![0; STALE_BUCKETS];
        }
        for m in fresh {
            let stale = (step as u64).saturating_sub(m.id.step as u64);
            self.max_staleness = self.max_staleness.max(stale);
            self.stale_hist[(stale as usize).min(STALE_BUCKETS - 1)] += 1;
        }
    }

    /// One flooding step for client `me`: send R_i to all neighbors, plus
    /// any armed repair traffic (summary broadcast, queued gap-fill
    /// replies — both counted into
    /// [`crate::net::Accounting::repair_bytes`] by the network).
    /// Call [`Self::collect`] after *all* clients have sent (synchronous
    /// round semantics — matches Alg. 1's lockstep `for d = 0..D-1`).
    pub fn send_round(&mut self, me: usize, net: &mut Network) {
        if self.summary_due {
            self.summary_due = false;
            net.broadcast(me, &Payload::Summary(Arc::new(self.seen.summary())));
        }
        let quantized = matches!(self.wire, WireFormat::Quantized(_));
        for (dst, msgs) in std::mem::take(&mut self.gap_out) {
            net.send(me, dst, Payload::GapFill { msgs, quantized });
        }
        if !self.repair_batch.is_empty() {
            // legacy reflood repair: its own broadcast, so exactly these
            // bytes — and nothing that was already outbound — are
            // attributed to the repair accounting (Seeds payloads carry no
            // header, so the split costs no extra wire bytes)
            let batch = std::mem::take(&mut self.repair_batch);
            let payload = self.wire_payload(batch);
            let (bytes0, msgs0) = (net.acct.total_bytes, net.acct.total_messages);
            net.broadcast(me, &payload);
            net.acct.repair_bytes += net.acct.total_bytes - bytes0;
            net.acct.repair_messages += net.acct.total_messages - msgs0;
        }
        if self.outbox.is_empty() {
            return;
        }
        let batch = std::mem::take(&mut self.outbox);
        let payload = self.wire_payload(batch);
        net.broadcast(me, &payload);
    }

    /// Wrap a seed batch in this client's wire encoding.
    fn wire_payload(&self, batch: Vec<SeedUpdate>) -> Payload {
        match self.wire {
            WireFormat::Full => Payload::Seeds(batch),
            WireFormat::Quantized(_) => Payload::SeedsQuantized(batch),
        }
    }

    /// Receive + dedup; newly seen messages become the next outbox and are
    /// returned for the caller to apply (Alg. 1: R_i ← received \ S_i).
    /// [`Payload::GapFill`] batches are folded exactly like flooded seeds;
    /// an incoming [`Payload::Summary`] queues a gap-fill reply (sent next
    /// round) with the retained messages the requester's high-water marks
    /// show missing.
    pub fn collect(&mut self, me: usize, net: &mut Network) -> Vec<SeedUpdate> {
        let mut fresh = vec![];
        for Message { from, payload } in net.recv_all(me) {
            let batch = match payload {
                Payload::Seeds(b) | Payload::SeedsQuantized(b) => b,
                Payload::GapFill { msgs, .. } => msgs,
                Payload::Summary(hwms) => {
                    // linear scan of the retention window per summary:
                    // O(retain) on the rare repair path; index the window
                    // by origin if anti-entropy periods ever get aggressive
                    let gaps: Vec<SeedUpdate> = self
                        .window
                        .iter()
                        .filter(|m| {
                            let their_hwm =
                                hwms.get(m.id.origin as usize).copied().unwrap_or(0);
                            m.id.step as u64 >= their_hwm as u64
                        })
                        .copied()
                        .collect();
                    // the requester's oldest missing step per origin is
                    // below our high-water mark, so we saw it — if it is
                    // not among the gaps, the window evicted it and this
                    // client cannot replay that history: count it
                    for (o, my_hwm) in self.seen.hwms().enumerate() {
                        let their = hwms.get(o).copied().unwrap_or(0);
                        let covered = gaps
                            .iter()
                            .any(|m| m.id.origin as usize == o && m.id.step == their);
                        if (their as u64) < my_hwm && !covered {
                            self.gap_misses += 1;
                        }
                    }
                    if !gaps.is_empty() {
                        self.gap_out.push((from, gaps));
                    }
                    continue;
                }
                _ => panic!("flooding received non-seed payload"),
            };
            for msg in batch {
                if self.seen.insert(msg.id) {
                    self.remember(msg);
                    fresh.push(msg);
                } else {
                    self.duplicates += 1;
                }
            }
        }
        self.outbox.extend_from_slice(&fresh);
        fresh
    }
}

/// The lockstep flooding loop, generic over where each client's
/// [`FloodState`] lives (`flood_of` projects it out of the per-client
/// item) — the single production copy of the round protocol, shared by
/// [`flood_rounds`] over bare `FloodState`s and by SeedFlood's
/// `communicate` over engine `ClientState`s.
///
/// Each round advances the network's delivery clock ([`Network::tick`])
/// and skips offline clients ([`Network::is_online`]): an offline client
/// neither drains its outbox (so nothing is lost while churned out) nor
/// receives — both no-ops on the reliable default network. `apply` runs
/// on the whole item, with the `FloodState` borrow released, whenever a
/// round delivered fresh messages to that client.
pub fn flood_rounds_by<S, G, F>(
    items: &mut [S],
    net: &mut Network,
    k: usize,
    mut flood_of: G,
    mut apply: F,
) where
    G: FnMut(&mut S) -> &mut FloodState,
    F: FnMut(&mut S, usize, &[SeedUpdate]),
{
    for _ in 0..k {
        net.tick();
        for (i, it) in items.iter_mut().enumerate() {
            if net.is_online(i) {
                flood_of(it).send_round(i, net);
            }
        }
        for (i, it) in items.iter_mut().enumerate() {
            if !net.is_online(i) {
                continue;
            }
            let fresh = flood_of(it).collect(i, net);
            if !fresh.is_empty() {
                apply(it, i, &fresh);
            }
        }
    }
}

/// Run `k` synchronous flooding rounds over all clients; calls `apply`
/// with (client, &fresh messages) after each round. Thin wrapper over
/// [`flood_rounds_by`] for plain `FloodState` slices (tests, benches,
/// examples).
pub fn flood_rounds<F>(states: &mut [FloodState], net: &mut Network, k: usize, mut apply: F)
where
    F: FnMut(usize, &[SeedUpdate]),
{
    // fn item, not a closure: projection callbacks returning borrows of
    // their argument need late-bound lifetimes to satisfy the for<'a>
    // bound, which closure inference does not reliably produce
    fn itself(s: &mut FloodState) -> &mut FloodState {
        s
    }
    flood_rounds_by(states, net, k, itself, |_, i, fresh| apply(i, fresh));
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    use super::*;
    use crate::topology::Topology;

    #[test]
    fn quantized_wire_floods_identically_and_costs_less() {
        let run = |wire: WireFormat| {
            let topo = Topology::ring(8);
            let d = topo.diameter();
            let mut net = Network::new(topo);
            let mut states: Vec<FloodState> = (0..8)
                .map(|_| FloodState { wire, ..FloodState::new() })
                .collect();
            for (i, st) in states.iter_mut().enumerate() {
                st.inject(SeedUpdate {
                    id: MsgId { origin: i as u32, step: 0 },
                    seed: i as u64,
                    coeff: 1.7e-4 * (i as f32 - 3.5),
                });
            }
            flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
            (states.iter().map(|s| s.seen.len()).min().unwrap(), net.acct.total_bytes)
        };
        let (cov_full, bytes_full) = run(WireFormat::Full);
        let (cov_q, bytes_q) = run(WireFormat::Quantized(1e-3));
        assert_eq!(cov_full, 8);
        assert_eq!(cov_q, 8);
        assert!(bytes_q * 2 < bytes_full, "{bytes_q} vs {bytes_full}");
    }

    fn msg(origin: u32, step: u32) -> SeedUpdate {
        SeedUpdate {
            id: MsgId { origin, step },
            seed: origin as u64 * 1000 + step as u64,
            coeff: 1.0,
        }
    }

    #[test]
    fn step_set_in_order_stays_compact() {
        let mut s = StepSet::default();
        for step in 0..1000 {
            assert!(s.insert(step), "step {step}");
            assert!(!s.insert(step), "duplicate step {step}");
        }
        assert_eq!(s.hwm(), 1000);
        assert_eq!(s.len(), 1000);
        assert_eq!(s.tail_words(), 0, "in-order inserts must not retain tail");
    }

    #[test]
    fn step_set_out_of_order_compacts_when_gap_closes() {
        let mut s = StepSet::default();
        // arrive 0..200 in reversed 100-blocks: [100..200), then [0..100)
        for step in 100..200 {
            assert!(s.insert(step));
        }
        assert_eq!(s.hwm(), 0);
        assert_eq!(s.tail_entries(), 100);
        for step in 0..100 {
            assert!(s.insert(step));
        }
        assert_eq!(s.hwm(), 200, "closing the gap must advance the mark");
        assert_eq!(s.tail_words(), 0, "compaction must free the bitset");
        assert_eq!(s.len(), 200);
        for step in 0..200 {
            assert!(s.contains(step));
        }
        assert!(!s.contains(200));
    }

    #[test]
    fn step_set_matches_hashset_on_word_boundaries() {
        // exercise the cross-word shift in compact(): runs of 63/64/65
        let mut s = StepSet::default();
        let mut reference = HashSet::new();
        for &step in &[64u32, 0, 63, 1, 2, 130, 65, 64, 129, 128, 3] {
            assert_eq!(s.insert(step), reference.insert(step), "step {step}");
        }
        for step in 0..200 {
            assert_eq!(s.contains(step), reference.contains(&step), "step {step}");
        }
        assert_eq!(s.len(), reference.len() as u64);
    }

    #[test]
    fn dedup_summary_reports_contiguous_prefix_only() {
        let mut d = FloodDedup::default();
        d.insert(MsgId { origin: 0, step: 0 });
        d.insert(MsgId { origin: 0, step: 1 });
        d.insert(MsgId { origin: 2, step: 5 }); // origin 2: gap below 5
        assert_eq!(d.summary(), vec![2, 0, 0]);
        assert_eq!(d.len(), 3);
        assert_eq!(d.tail_entries(), 1);
        assert!(d.contains(&MsgId { origin: 2, step: 5 }));
        assert!(!d.contains(&MsgId { origin: 2, step: 4 }));
        assert!(!d.contains(&MsgId { origin: 7, step: 0 }));
    }

    #[test]
    fn million_step_flood_memory_stays_bounded() {
        // acceptance: per-client dedup memory is O(n + window) retained
        // entries on a million-step run, not O(T·n)
        let retain = 1024;
        let mut st = FloodState { retain, ..FloodState::new() };
        for step in 0..1_000_000u32 {
            st.inject(msg(0, step));
            st.outbox.clear(); // stand-in for a drained send round
        }
        assert_eq!(st.seen.len(), 1_000_000);
        assert_eq!(st.window.len(), retain, "window must evict to its cap");
        assert_eq!(st.seen.tail_words(), 0, "in-order steps retain no bitset");
        assert!(st.retained_entries() <= retain);
    }

    /// Everyone receives everything after D rounds — the paper's perfect-
    /// consensus claim, checked on every topology we ship.
    #[test]
    fn full_flooding_reaches_all_clients() {
        for topo in [
            Topology::ring(9),
            Topology::meshgrid(16),
            Topology::star(7),
            Topology::complete(5),
            Topology::erdos_renyi(12, 3),
        ] {
            let n = topo.n;
            let d = topo.diameter();
            let mut net = Network::new(topo);
            let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
            for (i, st) in states.iter_mut().enumerate() {
                st.inject(msg(i as u32, 0));
            }
            let mut received = vec![0usize; n];
            flood_rounds(&mut states, &mut net, d, |i, fresh| {
                received[i] += fresh.len();
            });
            for (i, st) in states.iter().enumerate() {
                assert_eq!(st.seen.len(), n, "client {i} missing messages");
                assert_eq!(received[i], n - 1);
            }
        }
    }

    #[test]
    fn each_message_applied_exactly_once() {
        let topo = Topology::meshgrid(16);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..16).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        let mut apply_counts = vec![std::collections::HashMap::new(); 16];
        flood_rounds(&mut states, &mut net, d, |i, fresh| {
            for m in fresh {
                *apply_counts[i].entry(m.id).or_insert(0) += 1;
            }
        });
        for counts in &apply_counts {
            assert!(counts.values().all(|&c| c == 1), "message applied twice");
        }
    }

    #[test]
    fn delayed_flooding_bounded_staleness() {
        // k=1 on a ring of 8 (D=4): message from client 0 reaches the
        // antipodal client 4 after exactly 4 iterations, not before.
        let topo = Topology::ring(8);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..8).map(|_| FloodState::new()).collect();
        states[0].inject(msg(0, 0));
        for iter in 1..=4 {
            flood_rounds(&mut states, &mut net, 1, |_, _| {});
            let reached = states[4].seen.contains(&MsgId { origin: 0, step: 0 });
            assert_eq!(reached, iter >= 4, "iter {iter}");
        }
    }

    #[test]
    fn flooding_cost_independent_of_extra_rounds() {
        // once everyone has seen everything, further rounds send nothing
        let topo = Topology::ring(6);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..6).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        // D rounds deliver everything; one extra round drains the final
        // outboxes (messages first seen in round D are forwarded once more)
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        let bytes_after_drain = net.acct.total_bytes;
        flood_rounds(&mut states, &mut net, 10, |_, _| {});
        assert_eq!(net.acct.total_bytes, bytes_after_drain);
    }

    #[test]
    fn per_iteration_message_volume_is_o_n() {
        // Table 1: SeedFlood communicated bytes per edge per iteration is
        // O(n), independent of model size by construction.
        let n = 16;
        let topo = Topology::ring(n);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..n).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d, |_, _| {});
        // each message traverses each directed edge at most twice
        let max_bytes = (2 * n) as u64 * SeedUpdate::WIRE_BYTES * 2 * n as u64;
        assert!(net.acct.total_bytes <= max_bytes);
    }

    #[test]
    fn window_records_first_seen_order_and_reflood_repair_resends_it() {
        let topo = Topology::ring(4);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4)
            .map(|_| FloodState { repair_mode: RepairMode::Reflood, ..FloodState::new() })
            .collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        for st in &states {
            assert_eq!(st.window.len(), 4, "window holds everything (retain=0)");
            assert!(st.outbox.is_empty(), "drained after D+1 rounds");
        }
        // reflood repair queues the full window (nothing is outbound) for
        // re-broadcast; receivers dedup, so a re-flood round only costs
        // duplicate (repair) traffic
        let bytes_before = net.acct.total_bytes;
        states[0].repair();
        assert_eq!(states[0].repair_batch.len(), 4);
        assert!(states[0].outbox.is_empty(), "repair must not touch the outbox");
        flood_rounds(&mut states, &mut net, 1, |_, fresh| {
            panic!("nothing should be fresh, got {fresh:?}")
        });
        assert!(net.acct.total_bytes > bytes_before);
        assert_eq!(
            net.acct.repair_bytes,
            net.acct.total_bytes - bytes_before,
            "the whole re-flood must be attributed to repair"
        );
        assert!(states.iter().skip(1).any(|s| s.duplicates > 0));
    }

    #[test]
    fn reflood_repair_excludes_already_outbound_messages() {
        let topo = Topology::ring(4);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4)
            .map(|_| FloodState { repair_mode: RepairMode::Reflood, ..FloodState::new() })
            .collect();
        for step in 0..5 {
            states[0].inject(msg(0, step));
        }
        // everything is still outbound (never sent) → nothing to re-flood:
        // those messages would have been transmitted anyway and must not
        // inflate the repair accounting
        states[0].repair();
        assert!(states[0].repair_batch.is_empty());
        states[0].send_round(0, &mut net);
        let normal_bytes = net.acct.total_bytes;
        assert!(normal_bytes > 0);
        assert_eq!(net.acct.repair_bytes, 0, "outbound traffic is not repair");
        // with the outbox drained, a repair re-floods the whole window —
        // and exactly that broadcast is attributed to repair
        states[0].repair();
        assert_eq!(states[0].repair_batch.len(), 5);
        states[0].send_round(0, &mut net);
        assert_eq!(net.acct.repair_bytes, net.acct.total_bytes - normal_bytes);
    }

    #[test]
    fn gap_repair_requests_only_the_missing_range() {
        // client 1 on a 2-ring misses steps 3..10 from origin 0; a gap
        // repair must move exactly the missing messages plus the summary,
        // not the whole history
        let topo = Topology::ring(2);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..2).map(|_| FloodState::new()).collect();
        for step in 0..10 {
            states[0].inject(msg(0, step));
        }
        // steps 0..3 reached client 1 before the (simulated) outage
        for step in 0..3 {
            states[1].seen.insert(MsgId { origin: 0, step });
        }
        states[0].outbox.clear(); // outage: the normal flood never happened
        states[1].repair(); // recovery trigger → summary next round
        let mut fresh_at_1 = vec![];
        flood_rounds(&mut states, &mut net, 2, |i, fresh| {
            if i == 1 {
                fresh_at_1.extend_from_slice(fresh);
            }
        });
        // round 1: summary 1→0; round 2: gap-fill 0→1 with steps 3..10
        let got: Vec<u32> = fresh_at_1.iter().map(|m| m.id.step).collect();
        assert_eq!(got, (3..10).collect::<Vec<u32>>());
        assert_eq!(states[1].seen.len(), 10);
        // repair accounting: one summary + one 7-message gap-fill, plus the
        // requester forwarding nothing it already had
        let expect = Payload::Summary(Arc::new(states[1].seen.summary())).wire_bytes()
            + Payload::GapFill { msgs: fresh_at_1.clone(), quantized: false }.wire_bytes();
        assert_eq!(net.acct.repair_bytes, expect);
        assert_eq!(net.acct.repair_messages, 2);
    }

    #[test]
    fn gap_repair_counts_history_evicted_from_the_window() {
        // responder retains only the last 2 of 10 messages; a requester
        // missing everything gets those 2 — and the unfillable older
        // history is counted instead of silently ignored
        let topo = Topology::ring(2);
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..2)
            .map(|_| FloodState { retain: 2, ..FloodState::new() })
            .collect();
        for step in 0..10 {
            states[0].inject(msg(0, step));
        }
        states[0].outbox.clear(); // outage: the normal flood never happened
        states[1].repair();
        let mut fresh_at_1 = vec![];
        flood_rounds(&mut states, &mut net, 2, |i, fresh| {
            if i == 1 {
                fresh_at_1.extend_from_slice(fresh);
            }
        });
        let got: Vec<u32> = fresh_at_1.iter().map(|m| m.id.step).collect();
        assert_eq!(got, vec![8, 9], "only the retained tail is replayable");
        assert_eq!(states[0].gap_misses, 1, "the evicted gap must be counted");
    }

    #[test]
    fn gap_repair_is_a_noop_when_nothing_is_missing() {
        let topo = Topology::ring(4);
        let d = topo.diameter();
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, d + 1, |_, _| {});
        states[0].repair();
        flood_rounds(&mut states, &mut net, 2, |_, fresh| {
            panic!("nothing should be fresh, got {fresh:?}")
        });
        // the summary's marks (hwm = 1 per origin) cover every retained
        // message, so neighbors send no gap-fill replies at all — repair
        // cost is the two summary broadcasts and nothing else
        assert_eq!(
            net.acct.repair_messages, 2,
            "one summary per neighbor, no gap-fill replies"
        );
    }

    #[test]
    fn staleness_tracks_apply_minus_origin_step() {
        let mut st = FloodState::new();
        st.note_staleness(5, &[msg(0, 3), msg(1, 5)]);
        assert_eq!(st.max_staleness, 2);
        st.note_staleness(7, &[msg(2, 1)]);
        assert_eq!(st.max_staleness, 6);
        // a message applied "before" its origin step never underflows
        st.note_staleness(0, &[msg(3, 9)]);
        assert_eq!(st.max_staleness, 6);
        // the histogram records the full distribution, not just the max
        assert_eq!(st.stale_hist[0], 2); // staleness 0: (1,5)@5 and (3,9)@0
        assert_eq!(st.stale_hist[2], 1);
        assert_eq!(st.stale_hist[6], 1);
        assert_eq!(st.stale_hist.iter().sum::<u64>(), 4);
        // extreme staleness clamps into the last bucket
        st.note_staleness(5000, &[msg(4, 0)]);
        assert_eq!(st.stale_hist[STALE_BUCKETS - 1], 1);
        assert_eq!(st.max_staleness, 5000, "max stays exact beyond the clamp");
    }

    #[test]
    fn duplicates_are_counted_not_applied() {
        let topo = Topology::complete(4); // lots of redundant paths
        let mut net = Network::new(topo);
        let mut states: Vec<FloodState> = (0..4).map(|_| FloodState::new()).collect();
        for (i, st) in states.iter_mut().enumerate() {
            st.inject(msg(i as u32, 0));
        }
        flood_rounds(&mut states, &mut net, 2, |_, _| {});
        let dup_total: u64 = states.iter().map(|s| s.duplicates).sum();
        assert!(dup_total > 0, "complete graph must produce duplicate receipts");
    }

    #[test]
    fn repair_mode_parses() {
        assert_eq!(RepairMode::parse("gap"), Some(RepairMode::Gap));
        assert_eq!(RepairMode::parse("Reflood"), Some(RepairMode::Reflood));
        assert_eq!(RepairMode::parse("full-log"), None);
        assert_eq!(RepairMode::default().name(), "gap");
    }
}
