//! Shared deterministic randomness — the paper's communication primitive.
//!
//! §3.1: *“all clients have access to the same random number generator,
//! which enables any client to deterministically reconstruct the same
//! perturbation vector from a given random seed.”*  This module is that
//! RNG: a single splitmix64-based generator with Box–Muller normals.  Every
//! client in the process uses this one implementation, so a `(seed, scalar)`
//! message reconstructs bit-identically everywhere — the shared-randomness
//! assumption holds by construction.

/// Mix a seed with an index through the splitmix64 finalizer: a stateless
/// avalanche in which every input bit flips each output bit with
/// probability ~1/2. Use this to derive per-entity seeds (per-client
/// samplers, per-(client, step) jitter draws) — unlike an xor of the raw
/// index, adjacent indices yield uncorrelated streams.
#[inline]
pub fn mix(seed: u64, index: u64) -> u64 {
    let mut z = seed
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(index.wrapping_mul(0xA076_1D64_78BD_642F));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Splitmix64 PRNG. Small state, splittable by construction (`fold_in`),
/// passes BigCrush on its output function; exactly reproducible across
/// clients/platforms (pure integer arithmetic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. Equal seeds ⇒ identical streams (the paper's
    /// seed-reconstructibility contract).
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream from this seed and an index
    /// (jax-style `fold_in`; used for per-layer / per-step substreams).
    pub fn fold_in(seed: u64, index: u64) -> Self {
        let mut r = Rng::new(seed ^ index.wrapping_mul(0xA076_1D64_78BD_642F));
        r.next_u64(); // decorrelate nearby indices
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias < 2^-64, irrelevant at our ranges
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call; second draw is
    /// discarded to keep the stream position independent of call parity).
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        // Box–Muller pairwise: both outputs used (2× fewer u64 draws than
        // next_normal in the bulk path).
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = self.next_f64().max(1e-300);
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            out[i] = (r * c) as f32;
            out[i + 1] = (r * s) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.next_normal();
        }
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_seeds_equal_streams() {
        let (mut a, mut b) = (Rng::new(42), Rng::new(42));
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fold_in_streams_independent() {
        let mut a = Rng::fold_in(7, 0);
        let mut b = Rng::fold_in(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
        // deterministic given (seed, index)
        let mut a2 = Rng::fold_in(7, 0);
        assert_eq!(Rng::fold_in(7, 0).next_u64(), a2.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let mut buf = vec![0.0f32; 200_000];
        r.fill_normal(&mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn fill_normal_matches_seed_reconstruction() {
        // the paper's seed-scalar contract: same seed, same z, any client
        let mut z1 = vec![0.0f32; 1001];
        let mut z2 = vec![0.0f32; 1001];
        Rng::new(1234).fill_normal(&mut z1);
        Rng::new(1234).fill_normal(&mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.next_below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mix_avalanches_adjacent_indices() {
        // regression for the sampler-seed fix: seed ^ i gives adjacent
        // clients streams differing in one bit; mix must decorrelate them
        let mut outs = std::collections::HashSet::new();
        for i in 0..256u64 {
            let m = mix(7, i);
            assert!(outs.insert(m), "collision at index {i}");
            // adjacent indices differ in roughly half the output bits
            // (5σ band around 32 — xor-of-index schemes flip 1 bit)
            let dist = (m ^ mix(7, i + 1)).count_ones();
            assert!((12..=52).contains(&dist), "index {i}: hamming {dist}");
        }
        // deterministic, and seed-sensitive
        assert_eq!(mix(7, 3), mix(7, 3));
        assert_ne!(mix(7, 3), mix(8, 3));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(11);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<u32>>());
    }
}
