//! Shared deterministic randomness — the paper's communication primitive.
//!
//! §3.1: *“all clients have access to the same random number generator,
//! which enables any client to deterministically reconstruct the same
//! perturbation vector from a given random seed.”*  This module is that
//! RNG: a single splitmix64-based generator with Box–Muller normals.  Every
//! client in the process uses this one implementation, so a `(seed, scalar)`
//! message reconstructs bit-identically everywhere — the shared-randomness
//! assumption holds by construction.

/// The splitmix64 state increment: draw j after state S outputs
/// `finalize(S + (j+1)·GAMMA)` — a pure function of the counter, which is
/// what makes the stream block-generable and jumpable ([`Rng::advance`]).
const GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The splitmix64 output function (stateless avalanche).
#[inline]
fn finalize(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniforms generated per block in the bulk normal path. Even (Box–Muller
/// pairs never straddle a block) and small enough to live on the stack.
const NORMAL_BLOCK: usize = 128;

/// Mix a seed with an index through the splitmix64 finalizer: a stateless
/// avalanche in which every input bit flips each output bit with
/// probability ~1/2. Use this to derive per-entity seeds (per-client
/// samplers, per-(client, step) jitter draws) — unlike an xor of the raw
/// index, adjacent indices yield uncorrelated streams.
#[inline]
pub fn mix(seed: u64, index: u64) -> u64 {
    finalize(seed.wrapping_add(GAMMA).wrapping_add(index.wrapping_mul(0xA076_1D64_78BD_642F)))
}

/// Splitmix64 PRNG. Small state, splittable by construction (`fold_in`),
/// passes BigCrush on its output function; exactly reproducible across
/// clients/platforms (pure integer arithmetic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create from a seed. Equal seeds ⇒ identical streams (the paper's
    /// seed-reconstructibility contract).
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(GAMMA) }
    }

    /// Derive an independent stream from this seed and an index
    /// (jax-style `fold_in`; used for per-layer / per-step substreams).
    pub fn fold_in(seed: u64, index: u64) -> Self {
        let mut r = Rng::new(seed ^ index.wrapping_mul(0xA076_1D64_78BD_642F));
        r.next_u64(); // decorrelate nearby indices
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GAMMA);
        finalize(self.state)
    }

    /// Jump the stream forward by `draws` u64 outputs without generating
    /// them. Splitmix64's state is a counter (`state += GAMMA` per draw),
    /// so `advance(k)` lands bit-exactly where k `next_u64` calls would —
    /// the random-access property the chunk-parallel reconstruction path
    /// ([`crate::zo::apply_dense_updates_par`]) is built on. Only valid
    /// for rejection-free draw sequences (the bulk normal path qualifies:
    /// it clamps `u1` instead of rejecting).
    #[inline]
    pub fn advance(&mut self, draws: u64) {
        self.state = self.state.wrapping_add(draws.wrapping_mul(GAMMA));
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias < 2^-64, irrelevant at our ranges
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call; second draw is
    /// discarded to keep the stream position independent of call parity).
    #[inline]
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Generate the next `buf.len()` uniform draws in one pass. The state
    /// is a counter, so draw j of the block is `finalize(state + (j+1)·Γ)`
    /// — a branch-free loop with no loop-carried dependency, which the
    /// compiler can unroll/vectorize (the sequential `next_f64` chain
    /// serializes on the state update). Bit-identical to `buf.len()`
    /// `next_f64` calls, including the final state.
    #[inline]
    fn uniform_block(&mut self, buf: &mut [f64]) {
        let base = self.state;
        for (j, u) in buf.iter_mut().enumerate() {
            let s = base.wrapping_add((j as u64 + 1).wrapping_mul(GAMMA));
            *u = (finalize(s) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        }
        self.advance(buf.len() as u64);
    }

    /// Even-length bulk of [`Self::fill_normal`]: blocked uniform
    /// generation + pairwise Box–Muller. `out.len()` must be even so pair
    /// parity is preserved across consecutive calls on one stream.
    fn fill_normal_pairs(&mut self, out: &mut [f32]) {
        debug_assert_eq!(out.len() % 2, 0, "bulk normal path needs an even length");
        let mut uni = [0f64; NORMAL_BLOCK];
        for chunk in out.chunks_mut(NORMAL_BLOCK) {
            let u = &mut uni[..chunk.len()];
            self.uniform_block(u);
            for (pair, uu) in chunk.chunks_exact_mut(2).zip(u.chunks_exact(2)) {
                let u1 = uu[0].max(1e-300);
                let r = (-2.0 * u1.ln()).sqrt();
                let (s, c) = (2.0 * std::f64::consts::PI * uu[1]).sin_cos();
                pair[0] = (r * c) as f32;
                pair[1] = (r * s) as f32;
            }
        }
    }

    /// Fill a slice with iid standard normals — Box–Muller pairwise (both
    /// outputs used) over block-generated uniforms; odd lengths take one
    /// trailing [`Self::next_normal`]. Bit-identical to the historical
    /// scalar loop: same u64 draws, same f64 math, same f32 casts
    /// (property-tested against the element-at-a-time reference).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        let even = out.len() & !1;
        let (bulk, tail) = out.split_at_mut(even);
        self.fill_normal_pairs(bulk);
        if let [last] = tail {
            *last = self.next_normal();
        }
    }

    /// Fused fill+axpy: `out[i] += scale · z_i` with `z ~ N(0, I)` drawn
    /// from this stream — one pass, no intermediate buffer. Bit-identical
    /// to [`Self::fill_normal`] into a scratch slice followed by a
    /// separate `out[i] += scale * z[i]` loop (same draws, same
    /// per-element f32 operation order) — the contract the dense
    /// reconstruct-and-apply fast path hangs on.
    pub fn axpy_normal(&mut self, out: &mut [f32], scale: f32) {
        let even = out.len() & !1;
        let (bulk, tail) = out.split_at_mut(even);
        let mut uni = [0f64; NORMAL_BLOCK];
        for chunk in bulk.chunks_mut(NORMAL_BLOCK) {
            let u = &mut uni[..chunk.len()];
            self.uniform_block(u);
            for (pair, uu) in chunk.chunks_exact_mut(2).zip(u.chunks_exact(2)) {
                let u1 = uu[0].max(1e-300);
                let r = (-2.0 * u1.ln()).sqrt();
                let (s, c) = (2.0 * std::f64::consts::PI * uu[1]).sin_cos();
                pair[0] += scale * ((r * c) as f32);
                pair[1] += scale * ((r * s) as f32);
            }
        }
        if let [last] = tail {
            *last += scale * self.next_normal();
        }
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_seeds_equal_streams() {
        let (mut a, mut b) = (Rng::new(42), Rng::new(42));
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fold_in_streams_independent() {
        let mut a = Rng::fold_in(7, 0);
        let mut b = Rng::fold_in(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
        // deterministic given (seed, index)
        let mut a2 = Rng::fold_in(7, 0);
        assert_eq!(Rng::fold_in(7, 0).next_u64(), a2.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let mut buf = vec![0.0f32; 200_000];
        r.fill_normal(&mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    /// The historical element-at-a-time fill loop, kept verbatim as the
    /// bit-identity oracle for the blocked/fused bulk paths.
    fn fill_normal_reference(rng: &mut Rng, out: &mut [f32]) {
        let mut i = 0;
        while i + 1 < out.len() {
            let u1 = rng.next_f64().max(1e-300);
            let u2 = rng.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            out[i] = (r * c) as f32;
            out[i + 1] = (r * s) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = rng.next_normal();
        }
    }

    #[test]
    fn blocked_fill_normal_is_bit_identical_to_scalar_reference() {
        // block boundaries, odd tails, and continuing streams across
        // multiple calls (the SubspaceBasis::regenerate pattern)
        for seed in [0u64, 1, 42, u64::MAX / 2] {
            for lens in [vec![7usize], vec![1000, 3], vec![129, 128, 1], vec![2], vec![255, 257]]
            {
                let (mut a, mut b) = (Rng::new(seed), Rng::new(seed));
                for &len in &lens {
                    let mut want = vec![0f32; len];
                    let mut got = vec![0f32; len];
                    fill_normal_reference(&mut a, &mut want);
                    b.fill_normal(&mut got);
                    assert!(
                        want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "seed {seed} lens {lens:?}"
                    );
                }
                // streams stay aligned after mixed even/odd fills
                assert_eq!(a.next_u64(), b.next_u64(), "seed {seed} lens {lens:?}");
            }
        }
    }

    #[test]
    fn axpy_normal_is_bit_identical_to_fill_then_axpy() {
        for len in [0usize, 1, 2, 7, 128, 129, 513] {
            let (mut a, mut b) = (Rng::new(77), Rng::new(77));
            let mut x1: Vec<f32> = (0..len).map(|i| 0.25 * i as f32).collect();
            let mut x2 = x1.clone();
            let mut z = vec![0f32; len];
            a.fill_normal(&mut z);
            for (x, &zz) in x1.iter_mut().zip(z.iter()) {
                *x += -0.3 * zz;
            }
            b.axpy_normal(&mut x2, -0.3);
            assert!(
                x1.iter().zip(&x2).all(|(p, q)| p.to_bits() == q.to_bits()),
                "len {len}"
            );
            assert_eq!(a.next_u64(), b.next_u64(), "len {len}: streams diverged");
        }
    }

    #[test]
    fn advance_matches_sequential_draws() {
        let mut seq = Rng::new(9);
        for _ in 0..1000 {
            seq.next_u64();
        }
        let mut jump = Rng::new(9);
        jump.advance(1000);
        assert_eq!(seq.next_u64(), jump.next_u64());
        // jumping by an even draw count preserves the bulk fill prefix:
        // the random-access property of the chunk-parallel apply
        let mut whole = Rng::new(5);
        let mut full = vec![0f32; 64];
        whole.fill_normal(&mut full);
        let mut part = Rng::new(5);
        part.advance(32); // 32 draws = 32 normals in the paired bulk path
        let mut tail = vec![0f32; 32];
        part.fill_normal(&mut tail);
        assert!(full[32..].iter().zip(&tail).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn fill_normal_matches_seed_reconstruction() {
        // the paper's seed-scalar contract: same seed, same z, any client
        let mut z1 = vec![0.0f32; 1001];
        let mut z2 = vec![0.0f32; 1001];
        Rng::new(1234).fill_normal(&mut z1);
        Rng::new(1234).fill_normal(&mut z2);
        assert_eq!(z1, z2);
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let v = r.next_below(8) as usize;
            assert!(v < 8);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mix_avalanches_adjacent_indices() {
        // regression for the sampler-seed fix: seed ^ i gives adjacent
        // clients streams differing in one bit; mix must decorrelate them
        let mut outs = std::collections::HashSet::new();
        for i in 0..256u64 {
            let m = mix(7, i);
            assert!(outs.insert(m), "collision at index {i}");
            // adjacent indices differ in roughly half the output bits
            // (5σ band around 32 — xor-of-index schemes flip 1 bit)
            let dist = (m ^ mix(7, i + 1)).count_ones();
            assert!((12..=52).contains(&dist), "index {i}: hamming {dist}");
        }
        // deterministic, and seed-sensitive
        assert_eq!(mix(7, 3), mix(7, 3));
        assert_ne!(mix(7, 3), mix(8, 3));
    }

    #[test]
    fn mix_decorrelates_the_fixed_call_site_labels() {
        // regression for the rng-hygiene fixes: the dirichlet partition
        // (0xD1B1), the pretrain sampler (0x9E7A) and the subcge dense
        // tail (0x1D1D_1D1D) derive per-purpose seeds from a run seed.
        // Raw `seed ^ label` leaves adjacent run seeds one bit apart;
        // mix must flip about half the bits (same 5σ band as above).
        for label in [0xD1B1u64, 0x9E7A, 0x1D1D_1D1D] {
            for seed in 0..128u64 {
                let dist = (mix(seed, label) ^ mix(seed + 1, label)).count_ones();
                assert!(
                    (12..=52).contains(&dist),
                    "label {label:#x} seed {seed}: hamming {dist}"
                );
            }
        }
        // the hopgrid gossip init derives per-client draws at one seed:
        // adjacent clients must also land in the band
        for i in 0..128u64 {
            let dist = (mix(7, i) ^ mix(7, i + 1)).count_ones();
            assert!((12..=52).contains(&dist), "client {i}: hamming {dist}");
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(11);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<u32>>());
    }
}
