//! Experiment driver: wires topology + network + runtime + data + algorithm
//! together, runs the paper's training protocol (local steps + scheduled
//! communication), evaluates GMP, and records everything in a
//! [`RunRecord`].
//!
//! # The [`Driver`] split (ISSUE 4)
//!
//! What "time" means is a property of the *driver*, selected by
//! `--time-model`:
//!
//! * [`Lockstep`] (default) — the historical shared-step loop, preserved
//!   operation-for-operation by the driver split: `begin_step`
//!   (sequential shared-state hook) →
//!   `local_step_all` (fan-out over a scoped-thread pool, per-client state
//!   isolated in [`crate::algos::ClientState`]) → `communicate`
//!   (sequential, deterministic network rounds). A run's `RunRecord` is
//!   bit-identical for every `--threads` value: local steps are
//!   independent across clients and results are merged in client order
//!   (tested in tests/engine.rs).
//! * [`EventDriven`] (`--time-model event`, [`event`]) — discrete-event
//!   virtual time: each client's local steps complete at times set by a
//!   seeded speed model (`--rates`), flooding methods communicate off the
//!   delivery clock through the [`crate::algos::Algorithm`] async hooks,
//!   and gossip methods run through the barrier adapter. Uniform rates
//!   reproduce the lockstep trajectory exactly
//!   (rust/tests/properties.rs).
//!
//! Both drivers share one crate-internal `RunCtx`: setup, the
//! per-iteration evaluation bookkeeping, the single [`EvalPoint`]
//! construction site, and the final record assembly, so the two time
//! models cannot drift apart.
//!
//! # The [`Env`]/[`EnvCore`] split (ISSUE 5)
//!
//! Environment state is split by what it depends on: [`EnvCore`] holds
//! the heavy pieces that are a pure function of
//! (model, task, clients, artifacts_dir) — backend, dataset, eval
//! batches, the uniform partition — and is cached process-wide
//! ([`shared_core`]), while [`Env`] layers the cheap per-run state on top
//! (config, seeded θ⁰, Dirichlet splits). A sweep or experiment grid
//! builds each core exactly once; a run from a cached core is
//! bit-identical to a from-scratch one (tests/sweep.rs).
//!
//! With `--netcond` set (ISSUE 2), the fault schedule advances
//! ([`Network::set_step`]) before each iteration's hooks run (under the
//! event driver: whenever the nominal iteration clock advances); fault
//! draws come from a dedicated RNG stream on the sequential communication
//! path, so the `--threads` determinism contract extends to faulty runs
//! (tested in tests/netcond.rs).

pub mod event;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

pub use event::EventDriven;

use crate::algos::{self, Algorithm, ClientState, Scratch};
use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, Dataset, Example, TaskSpec, CLASS_TOKENS};
use crate::flood::STALE_BUCKETS;
use crate::metrics::{hist_percentile, EvalPoint, RunRecord};
use crate::model::{checkpoint, Manifest, ParamStore};
use crate::net::Network;
use crate::netcond;
use crate::oracle::{AotBackend, Backend, SyntheticOracle};
use crate::runtime::Arg;
use crate::sched::TimeModel;
use crate::subcge::{CoeffAccum, DeviceBasisCache, SubspaceBasis};
use crate::tensor::ParamVec;
use crate::topology::Topology;
use crate::util::timer::Timer;

/// Fixed seed for the synthetic oracle's token features: the synthetic
/// *task* is the same for every run; `cfg.seed` only drives init/probes.
const SYNTHETIC_ORACLE_SEED: u64 = 0x51_E7_0D_AC;

/// Cache identity of an [`EnvCore`]: everything its contents are a
/// function of. Keeping the key this small is what makes the core safely
/// shareable across sweep cells that differ in seed, method, topology, or
/// fault scenario.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CoreKey {
    pub model: String,
    pub task: String,
    pub clients: usize,
    pub artifacts_dir: String,
}

impl CoreKey {
    pub fn of(cfg: &ExperimentConfig) -> CoreKey {
        CoreKey {
            model: cfg.model.clone(),
            task: cfg.task.clone(),
            clients: cfg.clients,
            artifacts_dir: cfg.artifacts_dir.clone(),
        }
    }
}

/// The heavy, seed-independent part of a run environment: runtime backend,
/// dataset, eval batches, and the uniform client partition — everything
/// that is a pure function of its [`CoreKey`]. Built once per
/// (model, task, clients) group and shared across sweep cells behind an
/// `Arc` ([`shared_core`]); per-run state (seeded θ⁰, Dirichlet splits)
/// lives on [`Env`].
pub struct EnvCore {
    pub key: CoreKey,
    pub manifest: Manifest,
    /// AOT/PJRT artifacts or the pure-rust synthetic oracle.
    pub backend: Backend,
    pub class_tokens: Vec<i32>,
    pub dataset: Dataset,
    /// Seed-independent uniform client split; Dirichlet label-skew splits
    /// depend on the run seed and live on [`Env`].
    pub uniform_partitions: Vec<Vec<Example>>,
    pub test_batches: Vec<(Vec<i32>, Vec<i32>)>,
    pub val_batches: Vec<(Vec<i32>, Vec<i32>)>,
}

/// How many [`EnvCore`]s have been constructed process-wide — the probe
/// behind the harness's exactly-once cache contract (tests/sweep.rs).
static ENV_BUILDS: AtomicU64 = AtomicU64::new(0);

pub fn env_builds() -> u64 {
    ENV_BUILDS.load(Ordering::Relaxed)
}

fn core_cache() -> &'static Mutex<BTreeMap<CoreKey, Arc<EnvCore>>> {
    static CACHE: OnceLock<Mutex<BTreeMap<CoreKey, Arc<EnvCore>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Process-global [`EnvCore`] cache, keyed by [`CoreKey`]. The build runs
/// under the cache lock, so concurrent callers observe exactly one
/// construction per key — this is what lets a 100-cell sweep (and the
/// `experiment` grid loops via [`crate::experiments::run_one`]) build each
/// environment once instead of once per cell. Entries live for the
/// process lifetime.
pub fn shared_core(cfg: &ExperimentConfig) -> Result<Arc<EnvCore>> {
    let key = CoreKey::of(cfg);
    let mut cache = core_cache().lock().unwrap_or_else(|p| p.into_inner());
    if let Some(core) = cache.get(&key) {
        return Ok(core.clone());
    }
    let core = Arc::new(EnvCore::build(key.clone())?);
    cache.insert(key, core.clone());
    Ok(core)
}

impl EnvCore {
    /// Construct the core from scratch (bypassing [`shared_core`]). Every
    /// construction increments the [`env_builds`] probe.
    pub fn build(key: CoreKey) -> Result<EnvCore> {
        ENV_BUILDS.fetch_add(1, Ordering::Relaxed);
        if key.clients == 0 {
            bail!("clients must be >= 1");
        }
        let (manifest, backend) = if key.model == "synthetic" || key.model == "cheap" {
            let manifest = if key.model == "cheap" {
                crate::oracle::cheap_manifest()
            } else {
                crate::oracle::synthetic_manifest()
            };
            let backend =
                Backend::Synthetic(SyntheticOracle::new(&manifest, SYNTHETIC_ORACLE_SEED));
            (manifest, backend)
        } else {
            let manifest_path = format!("{}/{}_manifest.json", key.artifacts_dir, key.model);
            let manifest = Manifest::load(&manifest_path)?;
            let backend = Backend::Aot(AotBackend::load(&key.artifacts_dir, &manifest)?);
            (manifest, backend)
        };
        let spec = TaskSpec::named(&key.task)
            .with_context(|| format!("unknown task {:?}", key.task))?;
        let dataset = if key.model == "cheap" {
            // massive-scale mode: grow the train split with the client
            // count (partition() needs ≥ 1 example per client) and keep
            // the eval splits small so per-eval cost stays trivial
            Dataset::generate_sized(
                &spec,
                manifest.config.vocab,
                manifest.config.seq,
                key.clients.max(1024),
                128,
                256,
            )
        } else {
            Dataset::generate(&spec, manifest.config.vocab, manifest.config.seq)
        };
        let uniform_partitions = dataset.partition(key.clients);
        let b = manifest.config.batch;
        let test_batches = batchify(&dataset.test, b);
        let val_batches = batchify(&dataset.val, b);
        Ok(EnvCore {
            key,
            class_tokens: CLASS_TOKENS.to_vec(),
            manifest,
            backend,
            dataset,
            uniform_partitions,
            test_batches,
            val_batches,
        })
    }
}

/// Everything an algorithm needs from the environment, borrowed immutably
/// on the hot path (the network is threaded separately as `&mut`). `Env`
/// is `Send + Sync`: worker threads call the loss oracle concurrently
/// during the local-step fan-out.
///
/// The heavy state lives in a shared [`EnvCore`]; an `Env` adds only the
/// per-run pieces (config, seeded θ⁰, optional Dirichlet split), so
/// deriving one from a cached core ([`Env::from_core`]) is cheap and
/// bit-identical to a from-scratch [`Env::new`] (tests/sweep.rs).
pub struct Env {
    pub cfg: ExperimentConfig,
    pub core: Arc<EnvCore>,
    /// Per-run Dirichlet label-skew split (`None` = the core's uniform
    /// split; the Dirichlet draw depends on `cfg.seed`).
    dirichlet_partitions: Option<Vec<Vec<Example>>>,
    /// shared θ⁰ — the paper's "pretrained" starting point (checkpoint if
    /// `cfg.init_from` is set, else seeded random init)
    pub init_params: ParamVec,
}

impl Env {
    pub fn new(cfg: ExperimentConfig) -> Result<Env> {
        let core = Arc::new(EnvCore::build(CoreKey::of(&cfg))?);
        Self::from_core(core, cfg)
    }

    /// Artifact-free environment on the synthetic oracle (tests, benches,
    /// images without the `xla` feature).
    pub fn synthetic(mut cfg: ExperimentConfig) -> Result<Env> {
        cfg.model = "synthetic".to_string();
        Self::new(cfg)
    }

    /// Assemble a run environment around a pre-built (typically
    /// [`shared_core`]-cached) core, deriving only the cheap per-run
    /// state. `cfg` must match the core's identity exactly.
    pub fn from_core(core: Arc<EnvCore>, cfg: ExperimentConfig) -> Result<Env> {
        let key = CoreKey::of(&cfg);
        if key != core.key {
            bail!("config identity {key:?} does not match the Env core {:?}", core.key);
        }
        let dirichlet_partitions = if cfg.dirichlet_alpha > 0.0 {
            Some(core.dataset.partition_dirichlet(cfg.clients, cfg.dirichlet_alpha, cfg.seed))
        } else {
            None
        };
        let init_params = if cfg.init_from.is_empty() {
            ParamStore::init(&core.manifest, cfg.seed)
        } else {
            let p = checkpoint::load(&cfg.init_from)?;
            checkpoint::check_compatible(&p, &core.manifest)?;
            p
        };
        Ok(Env { cfg, core, dirichlet_partitions, init_params })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.core.manifest
    }

    /// The per-client example partition: the run's Dirichlet split when
    /// label skew is configured, else the core's shared uniform split.
    pub fn partitions(&self) -> &[Vec<Example>] {
        self.dirichlet_partitions.as_deref().unwrap_or(&self.core.uniform_partitions)
    }

    pub fn n_clients(&self) -> usize {
        self.cfg.clients
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.core.manifest.config.batch, self.core.manifest.config.seq)
    }

    /// Per-client mini-batch samplers over the uniform partition.
    ///
    /// Seeds go through the splitmix mixer ([`crate::rng::mix`]): the
    /// historical `seed ^ (0xBA7C << 8) ^ i` gave adjacent clients seeds
    /// differing in a single bit, which a small-state PRNG turns into
    /// visibly correlated early batch orders. The mixer avalanches every
    /// index bit; each sampler is still a pure function of
    /// `(cfg.seed, client)`, so the threads-determinism contract is
    /// untouched.
    pub fn make_samplers(&self) -> Vec<BatchSampler> {
        self.partitions()
            .iter()
            .enumerate()
            .map(|(i, p)| {
                BatchSampler::new(p.clone(), crate::rng::mix(self.cfg.seed ^ 0xBA7C, i as u64))
            })
            .collect()
    }

    /// (loss, #correct) of `params` on one batch.
    pub fn loss_acc(&self, params: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, f32)> {
        match &self.core.backend {
            Backend::Aot(be) => {
                let (b, s) = self.batch_shape();
                let args = crate::runtime::loss_args(
                    params, ids, vec![b, s], labels, &self.core.class_tokens);
                let out = be.exe_loss.run(&args)?;
                be.rt.count_execution();
                Ok((out[0].data[0], out[1].data[0]))
            }
            Backend::Synthetic(o) => {
                Ok(o.loss_acc(params, ids, labels, self.core.manifest.config.seq))
            }
        }
    }

    /// (loss, grads) — the FO oracle (DSGD/ChocoSGD local step).
    pub fn grad(&self, params: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, ParamVec)> {
        match &self.core.backend {
            Backend::Aot(be) => {
                let (b, s) = self.batch_shape();
                let args = crate::runtime::loss_args(
                    params, ids, vec![b, s], labels, &self.core.class_tokens);
                let out = be.exe_grad.run(&args)?;
                be.rt.count_execution();
                let loss = out[0].data[0];
                let grads = ParamVec::new(params.names.clone(), out[1..].to_vec());
                Ok((loss, grads))
            }
            Backend::Synthetic(o) => {
                Ok(o.grad(params, ids, labels, self.core.manifest.config.seq))
            }
        }
    }

    fn lora_args<'a>(
        &'a self,
        params: &'a ParamVec,
        lora: &'a ParamVec,
        ids: &'a [i32],
        labels: &'a [i32],
    ) -> Vec<Arg<'a>> {
        let (b, s) = self.batch_shape();
        let mut args: Vec<Arg> = params.tensors.iter().map(Arg::F32).collect();
        args.extend(lora.tensors.iter().map(Arg::F32));
        args.push(Arg::I32(ids, vec![b, s]));
        args.push(Arg::I32(labels, vec![b]));
        args.push(Arg::I32(&self.core.class_tokens, vec![2]));
        args
    }

    pub fn loss_acc_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, f32)> {
        match &self.core.backend {
            Backend::Aot(be) => {
                let args = self.lora_args(params, lora, ids, labels);
                let out = be.exe_loss_lora.run(&args)?;
                be.rt.count_execution();
                Ok((out[0].data[0], out[1].data[0]))
            }
            Backend::Synthetic(o) => {
                Ok(o.loss_acc_lora(params, lora, ids, labels, self.core.manifest.config.seq))
            }
        }
    }

    pub fn grad_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, ParamVec)> {
        match &self.core.backend {
            Backend::Aot(be) => {
                let args = self.lora_args(params, lora, ids, labels);
                let out = be.exe_grad_lora.run(&args)?;
                be.rt.count_execution();
                let loss = out[0].data[0];
                let grads = ParamVec::new(lora.names.clone(), out[1..].to_vec());
                Ok((loss, grads))
            }
            Backend::Synthetic(o) => {
                Ok(o.grad_lora(params, lora, ids, labels, self.core.manifest.config.seq))
            }
        }
    }

    /// Apply a client's accumulated SubCGE coefficients to its params —
    /// batched through the AOT pallas artifact on the real backend, the
    /// pure-rust kernel otherwise. `cache` (optional) holds device-resident
    /// basis factors so the dominant host→device upload is skipped.
    pub fn subcge_flush(
        &self,
        basis: &SubspaceBasis,
        accum: &mut CoeffAccum,
        params: &mut ParamVec,
        cache: Option<&mut DeviceBasisCache>,
    ) -> Result<()> {
        match &self.core.backend {
            Backend::Synthetic(_) => {
                accum.flush_rust(basis, params);
                Ok(())
            }
            Backend::Aot(be) => match cache {
                Some(c) => {
                    accum.flush_with_artifact_cached(basis, c, params, &be.exe_subcge, &be.rt)
                }
                None => accum.flush_with_artifact(basis, params, &be.exe_subcge, &be.rt),
            },
        }
    }

    /// Device-resident basis cache for [`Self::subcge_flush`] — `None` on
    /// the synthetic backend (nothing to upload).
    pub fn make_device_cache(&self, basis: &SubspaceBasis) -> Result<Option<DeviceBasisCache>> {
        match &self.core.backend {
            Backend::Aot(be) => Ok(Some(DeviceBasisCache::new(basis, &be.rt)?)),
            Backend::Synthetic(_) => Ok(None),
        }
    }

    /// (mean loss, accuracy) over pre-tokenized eval batches. An empty
    /// batch list yields a zeroed point instead of NaN (datasets smaller
    /// than one batch).
    pub fn eval_full(
        &self,
        params: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        if batches.is_empty() {
            return Ok((0.0, 0.0));
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for (ids, labels) in batches {
            let (l, c) = self.loss_acc(params, ids, labels)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += labels.len();
        }
        let acc = if total == 0 { 0.0 } else { correct / total as f64 };
        Ok((loss_sum / batches.len() as f64, acc))
    }

    pub fn eval_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        if batches.is_empty() {
            return Ok((0.0, 0.0));
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for (ids, labels) in batches {
            let (l, c) = self.loss_acc_lora(params, lora, ids, labels)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += labels.len();
        }
        let acc = if total == 0 { 0.0 } else { correct / total as f64 };
        Ok((loss_sum / batches.len() as f64, acc))
    }

    /// Cheap eval subset used for periodic (non-final) evaluation points.
    pub fn quick_batches(&self) -> &[(Vec<i32>, Vec<i32>)] {
        let k = self.core.val_batches.len().min(8);
        &self.core.val_batches[..k]
    }

    /// Validation batches used for best-checkpoint selection (paper
    /// Table 5: best val loss every tenth of training is evaluated on the
    /// held-out test set).
    pub fn select_batches(&self) -> &[(Vec<i32>, Vec<i32>)] {
        let k = self.core.val_batches.len().min(24);
        &self.core.val_batches[..k]
    }
}

/// Fixed-size batches; the tail that doesn't fill a batch is dropped
/// (artifact shapes are static).
pub fn batchify(examples: &[Example], batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
    examples
        .chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|chunk| {
            let mut ids = Vec::with_capacity(batch * chunk[0].tokens.len());
            let mut labels = Vec::with_capacity(batch);
            for ex in chunk {
                ids.extend_from_slice(&ex.tokens);
                labels.push(ex.label);
            }
            (ids, labels)
        })
        .collect()
}

/// Mean squared per-coordinate distance of client params from their mean —
/// the consensus-error diagnostic (zero ⇒ the paper's "perfect consensus").
pub fn consensus_error_refs(clients: &[&ParamVec]) -> f64 {
    if clients.len() < 2 {
        return 0.0;
    }
    let mean = ParamVec::average(clients);
    let d = mean.num_elements() as f64;
    clients.iter().map(|c| c.sq_dist(&mean)).sum::<f64>() / (clients.len() as f64 * d)
}

/// Owned-slice convenience wrapper over [`consensus_error_refs`].
pub fn consensus_error(clients: &[ParamVec]) -> f64 {
    consensus_error_refs(&clients.iter().collect::<Vec<_>>())
}

/// Run one full experiment: the paper's protocol of `steps` local
/// iterations with communication scheduled by the algorithm itself.
pub fn run_experiment(cfg: ExperimentConfig) -> Result<RunRecord> {
    let env = Env::new(cfg.clone())?;
    run_with_env(&env)
}

/// Run with a pre-built Env (lets experiment harnesses share the runtime
/// and dataset across runs). Dispatches to the configured [`Driver`].
pub fn run_with_env(env: &Env) -> Result<RunRecord> {
    env.cfg.validate()?; // TOML/programmatic configs skip from_args
    match env.cfg.time_model {
        TimeModel::Lockstep => Lockstep.run(env),
        TimeModel::Event => EventDriven.run(env),
    }
}

/// An execution engine for the training protocol: owns the definition of
/// "time" (shared step index vs discrete-event virtual time) and drives
/// the [`Algorithm`] through its lifecycle. Both implementations share
/// the crate-internal `RunCtx` so setup, evaluation bookkeeping, and
/// record assembly stay identical.
pub trait Driver {
    fn run(&mut self, env: &Env) -> Result<RunRecord>;
}

/// The historical shared-step engine (`--time-model lockstep`, default):
/// every client computes one local step per iteration, communication
/// happens at the global barrier. The driver split preserves the loop
/// operation-for-operation — within a version, `--time-model event
/// --rates uniform` and every `--threads` value reproduce it exactly.
/// (Trajectories DO differ from releases before the sampler-seed fix in
/// [`Env::make_samplers`] — that change was deliberate.)
pub struct Lockstep;

impl Driver for Lockstep {
    fn run(&mut self, env: &Env) -> Result<RunRecord> {
        let mut ctx = RunCtx::setup(env)?;
        for t in 0..env.cfg.steps {
            ctx.lockstep_iteration(t)?;
        }
        ctx.finalize()
    }
}

/// Shared per-run state and bookkeeping for both [`Driver`]s.
pub(crate) struct RunCtx<'e> {
    pub(crate) env: &'e Env,
    pub(crate) algo: Box<dyn Algorithm>,
    pub(crate) states: Vec<ClientState>,
    pub(crate) net: Network,
    pub(crate) record: RunRecord,
    timer: Timer,
    /// best-validation cadence (paper Table 5): validate every tenth of
    /// training, keep the snapshot with the lowest val loss
    val_every: usize,
    best: (f64, Option<Vec<ParamVec>>),
}

impl<'e> RunCtx<'e> {
    pub(crate) fn setup(env: &'e Env) -> Result<RunCtx<'e>> {
        let cfg = &env.cfg;
        // netcond: a preset name pins the topology it is named after; a
        // raw spec string leaves the configured topology alone; empty =
        // the reliable static graph, bit-for-bit identical to the
        // pre-netcond simulator (no fault state is installed at all)
        let (kind_override, cond) = if cfg.netcond.is_empty() {
            (None, None)
        } else {
            let (k, c) = netcond::resolve(&cfg.netcond, cfg.clients, cfg.steps)?;
            (k, Some(c))
        };
        let kind = kind_override.unwrap_or(cfg.topology);
        let topo = Topology::build(kind, cfg.clients, cfg.topology_seed);
        let (algo, states) = algos::build(env, &topo)?;
        let mut net = Network::new(topo);
        if let Some(c) = &cond {
            net.install(c)?;
        }
        let record = RunRecord {
            method: cfg.method.name().to_string(),
            task: cfg.task.clone(),
            model: cfg.model.clone(),
            topology: net.topology().kind.clone(),
            clients: cfg.clients,
            steps: cfg.steps,
            // provenance (ISSUE 5): the configured values, recorded so two
            // runs differing only in seed (or two fig6/fig7 grid cells)
            // stay distinguishable in saved JSON
            seed: cfg.seed,
            rank: cfg.rank,
            refresh: cfg.refresh,
            flood_steps: cfg.flood_steps,
            netcond: cfg.netcond.clone(),
            time_model: cfg.time_model.name().to_string(),
            rates: cfg.rates.clone(),
            ..Default::default()
        };
        Ok(RunCtx {
            env,
            algo,
            states,
            net,
            record,
            timer: Timer::start(),
            val_every: (cfg.steps / 10).max(1),
            best: (f64::INFINITY, None),
        })
    }

    /// One full lockstep iteration — the body of the [`Lockstep`] driver,
    /// reused verbatim by the event driver's barrier adapter (so a
    /// barrier method under `--time-model event` reproduces lockstep
    /// results exactly, for *any* speed model).
    pub(crate) fn lockstep_iteration(&mut self, t: usize) -> Result<()> {
        self.net.set_step(t); // advance the fault schedule (no-op when reliable)
        self.algo.begin_step(&mut self.states, t, self.env)?;
        let threads = self.env.cfg.threads;
        let losses = algos::local_step_all(&*self.algo, &mut self.states, t, self.env, threads)?;
        // merged in client order: the mean is identical for any thread count
        self.push_train_loss(&losses);
        self.algo.communicate(&mut self.states, t, self.env, &mut self.net)?;
        self.after_step(t)
    }

    /// Record the iteration's mean train loss (client-order sum, so the
    /// float result is identical for every thread count).
    pub(crate) fn push_train_loss(&mut self, losses: &[f32]) {
        let step_loss: f64 = losses.iter().map(|&l| l as f64).sum();
        self.record.train_losses.push(step_loss / self.env.cfg.clients as f64);
    }

    /// One evaluation point at `step` over `batches` — the single
    /// construction site for the periodic, final, and event-driven eval
    /// paths (this used to be two hand-maintained copies).
    pub(crate) fn eval_point(
        &mut self,
        step: usize,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<EvalPoint> {
        let (loss, accuracy) = self.algo.eval_gmp(&self.states, self.env, batches)?;
        Ok(EvalPoint {
            step,
            loss,
            accuracy,
            total_bytes: self.net.acct.total_bytes,
            per_edge_bytes: self.net.per_edge_bytes(),
            consensus_error: self.algo.consensus_error(&self.states),
        })
    }

    /// Post-iteration evaluation bookkeeping: the best-validation
    /// snapshot (every tenth of training + the final step) and the
    /// periodic `eval_every` [`EvalPoint`]. Called after iteration `t`'s
    /// communication has settled, by both drivers.
    pub(crate) fn after_step(&mut self, t: usize) -> Result<()> {
        let cfg = &self.env.cfg;
        if (t + 1) % self.val_every == 0 || t + 1 == cfg.steps {
            let (vl, _) = self.algo.eval_gmp(&self.states, self.env, self.env.select_batches())?;
            if vl < self.best.0 {
                self.best = (vl, Some(self.algo.snapshot(&self.states)));
            }
        }
        if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 && t + 1 < cfg.steps {
            let point = self.eval_point(t + 1, self.env.quick_batches())?;
            log::info!(
                "[{}] step {} loss {:.4} acc {:.3} bytes {}",
                self.record.method, t + 1, point.loss, point.accuracy, point.total_bytes
            );
            self.record.evals.push(point);
        }
        Ok(())
    }

    /// Restore the best-validation snapshot, run the final test-set
    /// evaluation, and assemble the [`RunRecord`] (byte accounting, fault
    /// metrics, flooding staleness distribution, wall clock). Timing
    /// fields (`virtual_makespan`, `idle_frac`, `client_steps`) are the
    /// drivers' responsibility and are left as set.
    pub(crate) fn finalize(mut self) -> Result<RunRecord> {
        if let Some(snap) = self.best.1.take() {
            self.algo.restore(&mut self.states, snap);
        }
        let point = self.eval_point(self.env.cfg.steps, &self.env.core.test_batches)?;
        self.record.gmp = point.accuracy;
        self.record.final_loss = point.loss;
        self.record.evals.push(point);
        self.record.total_bytes = self.net.acct.total_bytes;
        self.record.per_edge_bytes = self.net.per_edge_bytes();
        self.record.dropped_messages = self.net.acct.dropped_messages;
        self.record.delivery_ratio = self.net.acct.delivery_ratio();
        self.record.repair_bytes = self.net.acct.repair_bytes;
        self.record.repair_messages = self.net.acct.repair_messages;
        self.record.peak_in_flight_bytes = self.net.acct.peak_in_flight_bytes;
        let mut stale_hist = vec![0u64; STALE_BUCKETS];
        for s in &self.states {
            if let Scratch::Flood { flood, .. } = &s.scratch {
                self.record.flood_duplicates += flood.duplicates;
                self.record.max_staleness =
                    self.record.max_staleness.max(flood.max_staleness);
                self.record.repair_gap_misses += flood.gap_misses;
                self.record.flood_retained =
                    self.record.flood_retained.max(flood.retained_entries() as u64);
                self.record.flood_dedup_bytes =
                    self.record.flood_dedup_bytes.max(flood.seen.mem_bytes() as u64);
                for (b, &c) in flood.stale_hist.iter().enumerate() {
                    stale_hist[b] += c;
                }
            }
        }
        self.record.staleness_p50 = hist_percentile(&stale_hist, 50.0);
        self.record.staleness_p90 = hist_percentile(&stale_hist, 90.0);
        self.record.staleness_p99 = hist_percentile(&stale_hist, 99.0);
        self.record.wall_secs = self.timer.elapsed().as_secs_f64();
        self.record.phase_ms = self.algo.phase_ms();
        Ok(self.record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn batchify_drops_ragged_tail() {
        let exs: Vec<Example> = (0..10)
            .map(|i| Example { tokens: vec![i; 4], label: (i % 2) as i32 })
            .collect();
        let b = batchify(&exs, 4);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].0.len(), 16);
        assert_eq!(b[0].1.len(), 4);
    }

    #[test]
    fn consensus_error_zero_iff_identical() {
        let mk = |v: f32| {
            ParamVec::new(vec!["w".into()], vec![Tensor::from_vec(&[2], vec![v, v])])
        };
        assert_eq!(consensus_error(&[mk(1.0), mk(1.0)]), 0.0);
        assert!(consensus_error(&[mk(1.0), mk(2.0)]) > 0.0);
        assert_eq!(consensus_error(&[mk(5.0)]), 0.0);
    }

    #[test]
    fn eval_full_empty_batches_is_zero_not_nan() {
        let env = Env::synthetic(ExperimentConfig {
            clients: 2,
            steps: 1,
            ..Default::default()
        })
        .unwrap();
        let (loss, acc) = env.eval_full(&env.init_params, &[]).unwrap();
        assert_eq!(loss, 0.0);
        assert_eq!(acc, 0.0);
        assert!(!loss.is_nan() && !acc.is_nan());
    }

    #[test]
    fn synthetic_env_builds_and_evaluates() {
        let env = Env::synthetic(ExperimentConfig {
            clients: 4,
            steps: 1,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(env.partitions().len(), 4);
        let (loss, acc) = env.eval_full(&env.init_params, env.quick_batches()).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
    }
}
