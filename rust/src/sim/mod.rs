//! Experiment driver: wires topology + network + runtime + data + algorithm
//! together, runs the paper's training protocol (local steps + scheduled
//! communication), evaluates GMP, and records everything in a
//! [`RunRecord`].

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::algos;
use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, Dataset, Example, TaskSpec, CLASS_TOKENS};
use crate::metrics::{EvalPoint, RunRecord};
use crate::model::{checkpoint, Manifest, ParamStore};
use crate::net::Network;
use crate::runtime::{Arg, Executable, Runtime};
use crate::tensor::ParamVec;
use crate::topology::Topology;
use crate::util::timer::Timer;

/// Everything an algorithm needs from the environment, borrowed immutably
/// on the hot path (the network is threaded separately as `&mut`).
pub struct Env {
    pub cfg: ExperimentConfig,
    pub manifest: Manifest,
    pub rt: Runtime,
    pub exe_loss: Arc<Executable>,
    pub exe_grad: Arc<Executable>,
    pub exe_loss_lora: Arc<Executable>,
    pub exe_grad_lora: Arc<Executable>,
    pub exe_subcge: Arc<Executable>,
    pub class_tokens: Vec<i32>,
    pub dataset: Dataset,
    pub partitions: Vec<Vec<Example>>,
    pub test_batches: Vec<(Vec<i32>, Vec<i32>)>,
    pub val_batches: Vec<(Vec<i32>, Vec<i32>)>,
    /// shared θ⁰ — the paper's "pretrained" starting point (checkpoint if
    /// `cfg.init_from` is set, else seeded random init)
    pub init_params: ParamVec,
}

impl Env {
    pub fn new(cfg: ExperimentConfig) -> Result<Env> {
        let manifest_path =
            format!("{}/{}_manifest.json", cfg.artifacts_dir, cfg.model);
        let manifest = Manifest::load(&manifest_path)?;
        let rt = Runtime::cpu(&cfg.artifacts_dir)?;
        let exe_loss = rt.load(&manifest, "loss")?;
        let exe_grad = rt.load(&manifest, "grad")?;
        let exe_loss_lora = rt.load(&manifest, "loss_lora")?;
        let exe_grad_lora = rt.load(&manifest, "grad_lora")?;
        let exe_subcge = rt.load(&manifest, "subcge")?;

        let spec = TaskSpec::named(&cfg.task)
            .with_context(|| format!("unknown task {:?}", cfg.task))?;
        let dataset = Dataset::generate(&spec, manifest.config.vocab, manifest.config.seq);
        let partitions = if cfg.dirichlet_alpha > 0.0 {
            dataset.partition_dirichlet(cfg.clients, cfg.dirichlet_alpha, cfg.seed)
        } else {
            dataset.partition(cfg.clients)
        };
        let b = manifest.config.batch;
        let test_batches = batchify(&dataset.test, b);
        let val_batches = batchify(&dataset.val, b);
        let init_params = if cfg.init_from.is_empty() {
            ParamStore::init(&manifest, cfg.seed)
        } else {
            let p = checkpoint::load(&cfg.init_from)?;
            checkpoint::check_compatible(&p, &manifest)?;
            p
        };

        Ok(Env {
            cfg,
            class_tokens: CLASS_TOKENS.to_vec(),
            manifest,
            rt,
            exe_loss,
            exe_grad,
            exe_loss_lora,
            exe_grad_lora,
            exe_subcge,
            dataset,
            partitions,
            test_batches,
            val_batches,
            init_params,
        })
    }

    pub fn n_clients(&self) -> usize {
        self.cfg.clients
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.manifest.config.batch, self.manifest.config.seq)
    }

    /// Per-client mini-batch samplers over the uniform partition.
    pub fn make_samplers(&self) -> Vec<BatchSampler> {
        self.partitions
            .iter()
            .enumerate()
            .map(|(i, p)| BatchSampler::new(p.clone(), self.cfg.seed ^ (0xBA7C << 8) ^ i as u64))
            .collect()
    }

    /// (loss, #correct) of `params` on one batch, via the AOT loss graph.
    pub fn loss_acc(&self, params: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, f32)> {
        let (b, s) = self.batch_shape();
        let args =
            crate::runtime::loss_args(params, ids, vec![b, s], labels, &self.class_tokens);
        let out = self.exe_loss.run(&args)?;
        self.rt.count_execution();
        Ok((out[0].data[0], out[1].data[0]))
    }

    /// (loss, grads) — the FO oracle (DSGD/ChocoSGD local step).
    pub fn grad(&self, params: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, ParamVec)> {
        let (b, s) = self.batch_shape();
        let args =
            crate::runtime::loss_args(params, ids, vec![b, s], labels, &self.class_tokens);
        let out = self.exe_grad.run(&args)?;
        self.rt.count_execution();
        let loss = out[0].data[0];
        let grads = ParamVec::new(params.names.clone(), out[1..].to_vec());
        Ok((loss, grads))
    }

    fn lora_args<'a>(
        &'a self,
        params: &'a ParamVec,
        lora: &'a ParamVec,
        ids: &'a [i32],
        labels: &'a [i32],
    ) -> Vec<Arg<'a>> {
        let (b, s) = self.batch_shape();
        let mut args: Vec<Arg> = params.tensors.iter().map(Arg::F32).collect();
        args.extend(lora.tensors.iter().map(Arg::F32));
        args.push(Arg::I32(ids, vec![b, s]));
        args.push(Arg::I32(labels, vec![b]));
        args.push(Arg::I32(&self.class_tokens, vec![2]));
        args
    }

    pub fn loss_acc_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, f32)> {
        let args = self.lora_args(params, lora, ids, labels);
        let out = self.exe_loss_lora.run(&args)?;
        self.rt.count_execution();
        Ok((out[0].data[0], out[1].data[0]))
    }

    pub fn grad_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, ParamVec)> {
        let args = self.lora_args(params, lora, ids, labels);
        let out = self.exe_grad_lora.run(&args)?;
        self.rt.count_execution();
        let loss = out[0].data[0];
        let grads = ParamVec::new(lora.names.clone(), out[1..].to_vec());
        Ok((loss, grads))
    }

    /// (mean loss, accuracy) over pre-tokenized eval batches.
    pub fn eval_full(&self, params: &ParamVec, batches: &[(Vec<i32>, Vec<i32>)]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for (ids, labels) in batches {
            let (l, c) = self.loss_acc(params, ids, labels)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += labels.len();
        }
        Ok((loss_sum / batches.len() as f64, correct / total as f64))
    }

    pub fn eval_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for (ids, labels) in batches {
            let (l, c) = self.loss_acc_lora(params, lora, ids, labels)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += labels.len();
        }
        Ok((loss_sum / batches.len() as f64, correct / total as f64))
    }

    /// Cheap eval subset used for periodic (non-final) evaluation points.
    pub fn quick_batches(&self) -> &[(Vec<i32>, Vec<i32>)] {
        let k = self.val_batches.len().min(8);
        &self.val_batches[..k]
    }

    /// Validation batches used for best-checkpoint selection (paper
    /// Table 5: best val loss every tenth of training is evaluated on the
    /// held-out test set).
    pub fn select_batches(&self) -> &[(Vec<i32>, Vec<i32>)] {
        let k = self.val_batches.len().min(24);
        &self.val_batches[..k]
    }
}

/// Fixed-size batches; the tail that doesn't fill a batch is dropped
/// (artifact shapes are static).
pub fn batchify(examples: &[Example], batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
    examples
        .chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|chunk| {
            let mut ids = Vec::with_capacity(batch * chunk[0].tokens.len());
            let mut labels = Vec::with_capacity(batch);
            for ex in chunk {
                ids.extend_from_slice(&ex.tokens);
                labels.push(ex.label);
            }
            (ids, labels)
        })
        .collect()
}

/// Mean squared per-coordinate distance of client params from their mean —
/// the consensus-error diagnostic (zero ⇒ the paper's "perfect consensus").
pub fn consensus_error(clients: &[ParamVec]) -> f64 {
    if clients.len() < 2 {
        return 0.0;
    }
    let refs: Vec<&ParamVec> = clients.iter().collect();
    let mean = ParamVec::average(&refs);
    let d = mean.num_elements() as f64;
    clients.iter().map(|c| c.sq_dist(&mean)).sum::<f64>() / (clients.len() as f64 * d)
}

/// Run one full experiment: the paper's protocol of `steps` local
/// iterations with communication scheduled by the algorithm itself.
pub fn run_experiment(cfg: ExperimentConfig) -> Result<RunRecord> {
    let env = Env::new(cfg.clone())?;
    run_with_env(&env)
}

/// Run with a pre-built Env (lets experiment harnesses share the runtime
/// and dataset across runs).
pub fn run_with_env(env: &Env) -> Result<RunRecord> {
    let cfg = &env.cfg;
    let topo = Topology::build(cfg.topology, cfg.clients, cfg.topology_seed);
    let mut algo = algos::build(env, &topo)?;
    let mut net = Network::new(topo);
    let timer = Timer::start();

    let mut record = RunRecord {
        method: cfg.method.name().to_string(),
        task: cfg.task.clone(),
        model: cfg.model.clone(),
        topology: net.topology().kind.clone(),
        clients: cfg.clients,
        steps: cfg.steps,
        ..Default::default()
    };

    // best-validation checkpoint selection (paper Table 5): validate every
    // tenth of training, keep the snapshot with the lowest val loss
    let val_every = (cfg.steps / 10).max(1);
    let mut best: (f64, Option<Vec<crate::tensor::ParamVec>>) = (f64::INFINITY, None);

    for t in 0..cfg.steps {
        let mut step_loss = 0.0f64;
        for i in 0..cfg.clients {
            step_loss += algo.local_step(i, t, env)? as f64;
        }
        record.train_losses.push(step_loss / cfg.clients as f64);
        algo.communicate(t, env, &mut net)?;

        if (t + 1) % val_every == 0 || t + 1 == cfg.steps {
            let (vl, _) = algo.eval_gmp(env, env.select_batches())?;
            if vl < best.0 {
                best = (vl, Some(algo.snapshot()));
            }
        }

        if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 && t + 1 < cfg.steps {
            let (loss, acc) = algo.eval_gmp(env, env.quick_batches())?;
            record.evals.push(EvalPoint {
                step: t + 1,
                loss,
                accuracy: acc,
                total_bytes: net.acct.total_bytes,
                per_edge_bytes: net.per_edge_bytes(),
                consensus_error: algo.consensus_error(),
            });
            log::info!(
                "[{}] step {} loss {:.4} acc {:.3} bytes {}",
                record.method, t + 1, loss, acc, net.acct.total_bytes
            );
        }
    }

    if let Some(snap) = best.1.take() {
        algo.restore(snap);
    }
    let (final_loss, gmp) = algo.eval_gmp(env, &env.test_batches)?;
    record.evals.push(EvalPoint {
        step: cfg.steps,
        loss: final_loss,
        accuracy: gmp,
        total_bytes: net.acct.total_bytes,
        per_edge_bytes: net.per_edge_bytes(),
        consensus_error: algo.consensus_error(),
    });
    record.gmp = gmp;
    record.final_loss = final_loss;
    record.total_bytes = net.acct.total_bytes;
    record.per_edge_bytes = net.per_edge_bytes();
    record.wall_secs = timer.elapsed().as_secs_f64();
    record.phase_ms = algo.phase_ms();
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn batchify_drops_ragged_tail() {
        let exs: Vec<Example> = (0..10)
            .map(|i| Example { tokens: vec![i; 4], label: (i % 2) as i32 })
            .collect();
        let b = batchify(&exs, 4);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].0.len(), 16);
        assert_eq!(b[0].1.len(), 4);
    }

    #[test]
    fn consensus_error_zero_iff_identical() {
        let mk = |v: f32| {
            ParamVec::new(vec!["w".into()], vec![Tensor::from_vec(&[2], vec![v, v])])
        };
        assert_eq!(consensus_error(&[mk(1.0), mk(1.0)]), 0.0);
        assert!(consensus_error(&[mk(1.0), mk(2.0)]) > 0.0);
        assert_eq!(consensus_error(&[mk(5.0)]), 0.0);
    }
}
