//! Experiment driver: wires topology + network + runtime + data + algorithm
//! together, runs the paper's training protocol (local steps + scheduled
//! communication), evaluates GMP, and records everything in a
//! [`RunRecord`].
//!
//! Since the parallel-engine refactor (ISSUE 1) the iteration loop is:
//! `begin_step` (sequential shared-state hook) → `local_step_all` (fan-out
//! over a scoped-thread pool, per-client state isolated in
//! [`crate::algos::ClientState`]) → `communicate` (sequential,
//! deterministic network rounds). A run's `RunRecord` is bit-identical for
//! every `--threads` value: local steps are independent across clients and
//! results are merged in client order (tested in tests/engine.rs).
//!
//! With `--netcond` set (ISSUE 2), each iteration first advances the fault
//! schedule ([`Network::set_step`]) before the hooks run; fault draws come
//! from a dedicated RNG stream on the sequential communication path, so
//! the `--threads` determinism contract extends to faulty runs (tested in
//! tests/netcond.rs).

use anyhow::{bail, Context, Result};

use crate::algos::{self, Scratch};
use crate::config::ExperimentConfig;
use crate::data::{BatchSampler, Dataset, Example, TaskSpec, CLASS_TOKENS};
use crate::metrics::{EvalPoint, RunRecord};
use crate::model::{checkpoint, Manifest, ParamStore};
use crate::net::Network;
use crate::netcond;
use crate::oracle::{AotBackend, Backend, SyntheticOracle};
use crate::runtime::Arg;
use crate::subcge::{CoeffAccum, DeviceBasisCache, SubspaceBasis};
use crate::tensor::ParamVec;
use crate::topology::Topology;
use crate::util::timer::Timer;

/// Fixed seed for the synthetic oracle's token features: the synthetic
/// *task* is the same for every run; `cfg.seed` only drives init/probes.
const SYNTHETIC_ORACLE_SEED: u64 = 0x51_E7_0D_AC;

/// Everything an algorithm needs from the environment, borrowed immutably
/// on the hot path (the network is threaded separately as `&mut`). `Env`
/// is `Send + Sync`: worker threads call the loss oracle concurrently
/// during the local-step fan-out.
pub struct Env {
    pub cfg: ExperimentConfig,
    pub manifest: Manifest,
    /// AOT/PJRT artifacts or the pure-rust synthetic oracle.
    pub backend: Backend,
    pub class_tokens: Vec<i32>,
    pub dataset: Dataset,
    pub partitions: Vec<Vec<Example>>,
    pub test_batches: Vec<(Vec<i32>, Vec<i32>)>,
    pub val_batches: Vec<(Vec<i32>, Vec<i32>)>,
    /// shared θ⁰ — the paper's "pretrained" starting point (checkpoint if
    /// `cfg.init_from` is set, else seeded random init)
    pub init_params: ParamVec,
}

impl Env {
    pub fn new(cfg: ExperimentConfig) -> Result<Env> {
        if cfg.model == "synthetic" {
            let manifest = crate::oracle::synthetic_manifest();
            let backend =
                Backend::Synthetic(SyntheticOracle::new(&manifest, SYNTHETIC_ORACLE_SEED));
            return Self::assemble(cfg, manifest, backend);
        }
        let manifest_path = format!("{}/{}_manifest.json", cfg.artifacts_dir, cfg.model);
        let manifest = Manifest::load(&manifest_path)?;
        let backend = Backend::Aot(AotBackend::load(&cfg.artifacts_dir, &manifest)?);
        Self::assemble(cfg, manifest, backend)
    }

    /// Artifact-free environment on the synthetic oracle (tests, benches,
    /// images without the `xla` feature).
    pub fn synthetic(mut cfg: ExperimentConfig) -> Result<Env> {
        cfg.model = "synthetic".to_string();
        Self::new(cfg)
    }

    fn assemble(cfg: ExperimentConfig, manifest: Manifest, backend: Backend) -> Result<Env> {
        let spec = TaskSpec::named(&cfg.task)
            .with_context(|| format!("unknown task {:?}", cfg.task))?;
        let dataset = Dataset::generate(&spec, manifest.config.vocab, manifest.config.seq);
        if cfg.clients == 0 {
            bail!("clients must be >= 1");
        }
        let partitions = if cfg.dirichlet_alpha > 0.0 {
            dataset.partition_dirichlet(cfg.clients, cfg.dirichlet_alpha, cfg.seed)
        } else {
            dataset.partition(cfg.clients)
        };
        let b = manifest.config.batch;
        let test_batches = batchify(&dataset.test, b);
        let val_batches = batchify(&dataset.val, b);
        let init_params = if cfg.init_from.is_empty() {
            ParamStore::init(&manifest, cfg.seed)
        } else {
            let p = checkpoint::load(&cfg.init_from)?;
            checkpoint::check_compatible(&p, &manifest)?;
            p
        };

        Ok(Env {
            cfg,
            class_tokens: CLASS_TOKENS.to_vec(),
            manifest,
            backend,
            dataset,
            partitions,
            test_batches,
            val_batches,
            init_params,
        })
    }

    pub fn n_clients(&self) -> usize {
        self.cfg.clients
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.manifest.config.batch, self.manifest.config.seq)
    }

    /// Per-client mini-batch samplers over the uniform partition.
    pub fn make_samplers(&self) -> Vec<BatchSampler> {
        self.partitions
            .iter()
            .enumerate()
            .map(|(i, p)| BatchSampler::new(p.clone(), self.cfg.seed ^ (0xBA7C << 8) ^ i as u64))
            .collect()
    }

    /// (loss, #correct) of `params` on one batch.
    pub fn loss_acc(&self, params: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, f32)> {
        match &self.backend {
            Backend::Aot(be) => {
                let (b, s) = self.batch_shape();
                let args =
                    crate::runtime::loss_args(params, ids, vec![b, s], labels, &self.class_tokens);
                let out = be.exe_loss.run(&args)?;
                be.rt.count_execution();
                Ok((out[0].data[0], out[1].data[0]))
            }
            Backend::Synthetic(o) => {
                Ok(o.loss_acc(params, ids, labels, self.manifest.config.seq))
            }
        }
    }

    /// (loss, grads) — the FO oracle (DSGD/ChocoSGD local step).
    pub fn grad(&self, params: &ParamVec, ids: &[i32], labels: &[i32]) -> Result<(f32, ParamVec)> {
        match &self.backend {
            Backend::Aot(be) => {
                let (b, s) = self.batch_shape();
                let args =
                    crate::runtime::loss_args(params, ids, vec![b, s], labels, &self.class_tokens);
                let out = be.exe_grad.run(&args)?;
                be.rt.count_execution();
                let loss = out[0].data[0];
                let grads = ParamVec::new(params.names.clone(), out[1..].to_vec());
                Ok((loss, grads))
            }
            Backend::Synthetic(o) => Ok(o.grad(params, ids, labels, self.manifest.config.seq)),
        }
    }

    fn lora_args<'a>(
        &'a self,
        params: &'a ParamVec,
        lora: &'a ParamVec,
        ids: &'a [i32],
        labels: &'a [i32],
    ) -> Vec<Arg<'a>> {
        let (b, s) = self.batch_shape();
        let mut args: Vec<Arg> = params.tensors.iter().map(Arg::F32).collect();
        args.extend(lora.tensors.iter().map(Arg::F32));
        args.push(Arg::I32(ids, vec![b, s]));
        args.push(Arg::I32(labels, vec![b]));
        args.push(Arg::I32(&self.class_tokens, vec![2]));
        args
    }

    pub fn loss_acc_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, f32)> {
        match &self.backend {
            Backend::Aot(be) => {
                let args = self.lora_args(params, lora, ids, labels);
                let out = be.exe_loss_lora.run(&args)?;
                be.rt.count_execution();
                Ok((out[0].data[0], out[1].data[0]))
            }
            Backend::Synthetic(o) => {
                Ok(o.loss_acc_lora(params, lora, ids, labels, self.manifest.config.seq))
            }
        }
    }

    pub fn grad_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        ids: &[i32],
        labels: &[i32],
    ) -> Result<(f32, ParamVec)> {
        match &self.backend {
            Backend::Aot(be) => {
                let args = self.lora_args(params, lora, ids, labels);
                let out = be.exe_grad_lora.run(&args)?;
                be.rt.count_execution();
                let loss = out[0].data[0];
                let grads = ParamVec::new(lora.names.clone(), out[1..].to_vec());
                Ok((loss, grads))
            }
            Backend::Synthetic(o) => {
                Ok(o.grad_lora(params, lora, ids, labels, self.manifest.config.seq))
            }
        }
    }

    /// Apply a client's accumulated SubCGE coefficients to its params —
    /// batched through the AOT pallas artifact on the real backend, the
    /// pure-rust kernel otherwise. `cache` (optional) holds device-resident
    /// basis factors so the dominant host→device upload is skipped.
    pub fn subcge_flush(
        &self,
        basis: &SubspaceBasis,
        accum: &mut CoeffAccum,
        params: &mut ParamVec,
        cache: Option<&mut DeviceBasisCache>,
    ) -> Result<()> {
        match &self.backend {
            Backend::Synthetic(_) => {
                accum.flush_rust(basis, params);
                Ok(())
            }
            Backend::Aot(be) => match cache {
                Some(c) => {
                    accum.flush_with_artifact_cached(basis, c, params, &be.exe_subcge, &be.rt)
                }
                None => accum.flush_with_artifact(basis, params, &be.exe_subcge, &be.rt),
            },
        }
    }

    /// Device-resident basis cache for [`Self::subcge_flush`] — `None` on
    /// the synthetic backend (nothing to upload).
    pub fn make_device_cache(&self, basis: &SubspaceBasis) -> Result<Option<DeviceBasisCache>> {
        match &self.backend {
            Backend::Aot(be) => Ok(Some(DeviceBasisCache::new(basis, &be.rt)?)),
            Backend::Synthetic(_) => Ok(None),
        }
    }

    /// (mean loss, accuracy) over pre-tokenized eval batches. An empty
    /// batch list yields a zeroed point instead of NaN (datasets smaller
    /// than one batch).
    pub fn eval_full(
        &self,
        params: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        if batches.is_empty() {
            return Ok((0.0, 0.0));
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for (ids, labels) in batches {
            let (l, c) = self.loss_acc(params, ids, labels)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += labels.len();
        }
        let acc = if total == 0 { 0.0 } else { correct / total as f64 };
        Ok((loss_sum / batches.len() as f64, acc))
    }

    pub fn eval_lora(
        &self,
        params: &ParamVec,
        lora: &ParamVec,
        batches: &[(Vec<i32>, Vec<i32>)],
    ) -> Result<(f64, f64)> {
        if batches.is_empty() {
            return Ok((0.0, 0.0));
        }
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut total = 0usize;
        for (ids, labels) in batches {
            let (l, c) = self.loss_acc_lora(params, lora, ids, labels)?;
            loss_sum += l as f64;
            correct += c as f64;
            total += labels.len();
        }
        let acc = if total == 0 { 0.0 } else { correct / total as f64 };
        Ok((loss_sum / batches.len() as f64, acc))
    }

    /// Cheap eval subset used for periodic (non-final) evaluation points.
    pub fn quick_batches(&self) -> &[(Vec<i32>, Vec<i32>)] {
        let k = self.val_batches.len().min(8);
        &self.val_batches[..k]
    }

    /// Validation batches used for best-checkpoint selection (paper
    /// Table 5: best val loss every tenth of training is evaluated on the
    /// held-out test set).
    pub fn select_batches(&self) -> &[(Vec<i32>, Vec<i32>)] {
        let k = self.val_batches.len().min(24);
        &self.val_batches[..k]
    }
}

/// Fixed-size batches; the tail that doesn't fill a batch is dropped
/// (artifact shapes are static).
pub fn batchify(examples: &[Example], batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
    examples
        .chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|chunk| {
            let mut ids = Vec::with_capacity(batch * chunk[0].tokens.len());
            let mut labels = Vec::with_capacity(batch);
            for ex in chunk {
                ids.extend_from_slice(&ex.tokens);
                labels.push(ex.label);
            }
            (ids, labels)
        })
        .collect()
}

/// Mean squared per-coordinate distance of client params from their mean —
/// the consensus-error diagnostic (zero ⇒ the paper's "perfect consensus").
pub fn consensus_error_refs(clients: &[&ParamVec]) -> f64 {
    if clients.len() < 2 {
        return 0.0;
    }
    let mean = ParamVec::average(clients);
    let d = mean.num_elements() as f64;
    clients.iter().map(|c| c.sq_dist(&mean)).sum::<f64>() / (clients.len() as f64 * d)
}

/// Owned-slice convenience wrapper over [`consensus_error_refs`].
pub fn consensus_error(clients: &[ParamVec]) -> f64 {
    consensus_error_refs(&clients.iter().collect::<Vec<_>>())
}

/// Run one full experiment: the paper's protocol of `steps` local
/// iterations with communication scheduled by the algorithm itself.
pub fn run_experiment(cfg: ExperimentConfig) -> Result<RunRecord> {
    let env = Env::new(cfg.clone())?;
    run_with_env(&env)
}

/// Run with a pre-built Env (lets experiment harnesses share the runtime
/// and dataset across runs).
pub fn run_with_env(env: &Env) -> Result<RunRecord> {
    let cfg = &env.cfg;
    // netcond: a preset name pins the topology it is named after; a raw
    // spec string leaves the configured topology alone; empty = the
    // reliable static graph, bit-for-bit identical to the pre-netcond
    // simulator (no fault state is installed at all)
    let (kind_override, cond) = if cfg.netcond.is_empty() {
        (None, None)
    } else {
        let (k, c) = netcond::resolve(&cfg.netcond, cfg.clients, cfg.steps)?;
        (k, Some(c))
    };
    let kind = kind_override.unwrap_or(cfg.topology);
    let topo = Topology::build(kind, cfg.clients, cfg.topology_seed);
    let (mut algo, mut states) = algos::build(env, &topo)?;
    let mut net = Network::new(topo);
    if let Some(c) = &cond {
        net.install(c)?;
    }
    let timer = Timer::start();

    let mut record = RunRecord {
        method: cfg.method.name().to_string(),
        task: cfg.task.clone(),
        model: cfg.model.clone(),
        topology: net.topology().kind.clone(),
        clients: cfg.clients,
        steps: cfg.steps,
        netcond: cfg.netcond.clone(),
        ..Default::default()
    };

    // best-validation checkpoint selection (paper Table 5): validate every
    // tenth of training, keep the snapshot with the lowest val loss
    let val_every = (cfg.steps / 10).max(1);
    let mut best: (f64, Option<Vec<ParamVec>>) = (f64::INFINITY, None);

    for t in 0..cfg.steps {
        net.set_step(t); // advance the fault schedule (no-op when reliable)
        algo.begin_step(t, env)?;
        let losses = algos::local_step_all(&*algo, &mut states, t, env, cfg.threads)?;
        // merged in client order: the mean is identical for any thread count
        let step_loss: f64 = losses.iter().map(|&l| l as f64).sum();
        record.train_losses.push(step_loss / cfg.clients as f64);
        algo.communicate(&mut states, t, env, &mut net)?;

        if (t + 1) % val_every == 0 || t + 1 == cfg.steps {
            let (vl, _) = algo.eval_gmp(&states, env, env.select_batches())?;
            if vl < best.0 {
                best = (vl, Some(algo.snapshot(&states)));
            }
        }

        if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 && t + 1 < cfg.steps {
            let (loss, acc) = algo.eval_gmp(&states, env, env.quick_batches())?;
            record.evals.push(EvalPoint {
                step: t + 1,
                loss,
                accuracy: acc,
                total_bytes: net.acct.total_bytes,
                per_edge_bytes: net.per_edge_bytes(),
                consensus_error: algo.consensus_error(&states),
            });
            log::info!(
                "[{}] step {} loss {:.4} acc {:.3} bytes {}",
                record.method, t + 1, loss, acc, net.acct.total_bytes
            );
        }
    }

    if let Some(snap) = best.1.take() {
        algo.restore(&mut states, snap);
    }
    let (final_loss, gmp) = algo.eval_gmp(&states, env, &env.test_batches)?;
    record.evals.push(EvalPoint {
        step: cfg.steps,
        loss: final_loss,
        accuracy: gmp,
        total_bytes: net.acct.total_bytes,
        per_edge_bytes: net.per_edge_bytes(),
        consensus_error: algo.consensus_error(&states),
    });
    record.gmp = gmp;
    record.final_loss = final_loss;
    record.total_bytes = net.acct.total_bytes;
    record.per_edge_bytes = net.per_edge_bytes();
    record.dropped_messages = net.acct.dropped_messages;
    record.delivery_ratio = net.acct.delivery_ratio();
    record.repair_bytes = net.acct.repair_bytes;
    record.repair_messages = net.acct.repair_messages;
    for s in &states {
        if let Scratch::Flood { flood, .. } = &s.scratch {
            record.flood_duplicates += flood.duplicates;
            record.max_staleness = record.max_staleness.max(flood.max_staleness);
            record.repair_gap_misses += flood.gap_misses;
            record.flood_retained =
                record.flood_retained.max(flood.retained_entries() as u64);
        }
    }
    record.wall_secs = timer.elapsed().as_secs_f64();
    record.phase_ms = algo.phase_ms();
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn batchify_drops_ragged_tail() {
        let exs: Vec<Example> = (0..10)
            .map(|i| Example { tokens: vec![i; 4], label: (i % 2) as i32 })
            .collect();
        let b = batchify(&exs, 4);
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].0.len(), 16);
        assert_eq!(b[0].1.len(), 4);
    }

    #[test]
    fn consensus_error_zero_iff_identical() {
        let mk = |v: f32| {
            ParamVec::new(vec!["w".into()], vec![Tensor::from_vec(&[2], vec![v, v])])
        };
        assert_eq!(consensus_error(&[mk(1.0), mk(1.0)]), 0.0);
        assert!(consensus_error(&[mk(1.0), mk(2.0)]) > 0.0);
        assert_eq!(consensus_error(&[mk(5.0)]), 0.0);
    }

    #[test]
    fn eval_full_empty_batches_is_zero_not_nan() {
        let env = Env::synthetic(ExperimentConfig {
            clients: 2,
            steps: 1,
            ..Default::default()
        })
        .unwrap();
        let (loss, acc) = env.eval_full(&env.init_params, &[]).unwrap();
        assert_eq!(loss, 0.0);
        assert_eq!(acc, 0.0);
        assert!(!loss.is_nan() && !acc.is_nan());
    }

    #[test]
    fn synthetic_env_builds_and_evaluates() {
        let env = Env::synthetic(ExperimentConfig {
            clients: 4,
            steps: 1,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(env.partitions.len(), 4);
        let (loss, acc) = env.eval_full(&env.init_params, env.quick_batches()).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=1.0).contains(&acc));
    }
}
