//! The event-driven virtual-time execution engine (`--time-model event`,
//! ISSUE 4 tentpole).
//!
//! Each client has a compute rate drawn from the seeded speed model
//! (`--rates`, [`SpeedModel`]); its local steps complete at virtual times
//! instead of a shared step index. Communication runs off the delivery
//! clock: one [`crate::net::Network::tick`] round every
//! [`TICKS_PER_ROUND`] virtual ticks (a nominal local step spans
//! `flood_steps` rounds, matching the lockstep cadence), so netcond
//! delays and down-windows keep their round/iteration units — re-keyed to
//! virtual time rather than the barrier loop.
//!
//! The driver honors each algorithm's [`TimePolicy`]:
//!
//! * **Async** (SeedFlood, the single-client baselines) — a deterministic
//!   event queue interleaves three event kinds: `Step` (a client's local
//!   step completes: catch-up flush → `local_step` → flood immediately
//!   via `on_step_complete`), `Round` (delivery-clock round: every online
//!   client's `on_send` then `on_deliver`), and `Barrier` (every client
//!   has completed step `t`: settle via `on_barrier`, record the train
//!   loss, run the evaluation bookkeeping). The nominal schedule clock
//!   (`virtual time / step span`) drives [`crate::net::Network::set_step`]
//!   and the netcond repair triggers. Step events sharing one virtual
//!   instant form a **cohort** whose compute phase fans out over the
//!   `--threads` worker pool while every network-visible effect replays
//!   sequentially in canonical order — see [`run_async`] for why the
//!   trajectory is thread-count-invariant, and ARCHITECTURE.md for the
//!   full determinism argument.
//! * **Barrier** (DSGD, ChocoSGD, DZSGD and the LoRA variants) — the
//!   lockstep adapter: dense/sparse gossip mixes simultaneous snapshots
//!   of all clients and has no barrier-free formulation, so the driver
//!   reuses the shared `RunCtx::lockstep_iteration` verbatim and heterogeneous
//!   speeds surface only as the timing metrics. Results are identical to
//!   `--time-model lockstep` for *any* `--rates` — the honest semantics
//!   of a method that must wait for its slowest participant.
//!
//! # The reduction contract
//!
//! With uniform rates every step-completion cohort lands on one virtual
//! instant, the queue's `(time, priority, insertion)` order degenerates
//! to the lockstep order (completions → k rounds → barrier), completion
//! sends coincide with what the first lockstep round would have sent, and
//! the barrier flush sits exactly where the lockstep iteration flush sat —
//! so `--time-model event --rates uniform` reproduces the lockstep
//! trajectory bit-for-bit, for async and barrier methods alike
//! (property-tested in rust/tests/properties.rs). Non-uniform rates are
//! then the *only* source of divergence, which is what makes the
//! straggler experiments attributable.
//!
//! # Timing metrics
//!
//! The run's `RunRecord` gains `virtual_makespan` (nominal-step units:
//! `Σ_t max_i dur` for barrier methods, `max_i Σ_t dur` for async — the
//! gap between the two is the straggler tax), `idle_frac`
//! (1 − compute / (n · makespan)), `client_steps`, and the flooding
//! staleness percentiles (`staleness_p50/p90/p99`), measured on the
//! nominal iteration clock.

use std::collections::VecDeque;

use anyhow::Result;

use super::{Driver, Env, RunCtx};
use crate::algos::TimePolicy;
use crate::metrics::RunRecord;
use crate::sched::{Event, EventQueue, RateSpec, SpeedModel, TICKS_PER_ROUND};
use crate::util::par::par_map_mut_idx;

/// Event kinds of the async engine; the listed order is also the
/// same-tick priority (completions before the round that forwards them,
/// rounds before the barrier that evaluates their effect).
enum Ev {
    /// Client `client` completes local step `step`.
    Step { client: usize, step: usize },
    /// One delivery-clock communication round.
    Round,
    /// Every client has completed local step `step`.
    Barrier { step: usize },
}

const PRIO_STEP: u8 = 0;
const PRIO_ROUND: u8 = 1;
const PRIO_BARRIER: u8 = 2;

/// The `--time-model event` driver. See the module docs.
pub struct EventDriven;

impl Driver for EventDriven {
    fn run(&mut self, env: &Env) -> Result<RunRecord> {
        let ctx = RunCtx::setup(env)?;
        let spec = RateSpec::parse(&env.cfg.rates)?;
        let speed = SpeedModel::build(&spec, env.cfg.clients, env.cfg.seed);
        match ctx.algo.time_policy() {
            TimePolicy::Barrier => run_barrier(ctx, &speed),
            TimePolicy::Async => run_async(ctx, &speed),
        }
    }
}

/// Virtual-time span of one nominal local step: `flood_steps` delivery
/// rounds (the lockstep cadence — k rounds per iteration), resolving the
/// `0 = topology diameter` default exactly as SeedFlood does.
fn step_ticks(ctx: &RunCtx<'_>) -> u64 {
    let k = if ctx.env.cfg.flood_steps == 0 {
        ctx.net.topology().diameter().max(1)
    } else {
        ctx.env.cfg.flood_steps
    };
    k as u64 * TICKS_PER_ROUND
}

/// Fill the driver-owned timing fields of the record.
fn time_metrics(
    record: &mut RunRecord,
    makespan_ticks: u64,
    compute_ticks: u64,
    ticks_per_step: u64,
    n: usize,
    steps: usize,
) {
    record.virtual_makespan = makespan_ticks as f64 / ticks_per_step as f64;
    record.idle_frac = if makespan_ticks == 0 {
        0.0
    } else {
        1.0 - compute_ticks as f64 / (n as u64 * makespan_ticks) as f64
    };
    record.client_steps = vec![steps as u64; n];
}

/// The lockstep adapter: reuse the exact lockstep iteration for barrier
/// methods and account virtual time around it — each iteration costs the
/// cohort maximum (everyone waits for the slowest), which is where the
/// `Σ_t max_i` straggler tax comes from.
fn run_barrier(mut ctx: RunCtx<'_>, speed: &SpeedModel) -> Result<RunRecord> {
    let steps = ctx.env.cfg.steps;
    let n = ctx.env.cfg.clients;
    let s = step_ticks(&ctx);
    let (mut now, mut compute) = (0u64, 0u64);
    for t in 0..steps {
        ctx.lockstep_iteration(t)?;
        // single accumulation pass, no per-iteration buffer — steady-state
        // event stepping allocates nothing (the net/flood contract)
        let (mut slowest, mut total) = (0u64, 0u64);
        for i in 0..n {
            let d = speed.duration(i, t, s);
            slowest = slowest.max(d);
            total += d;
        }
        now += slowest;
        compute += total;
    }
    time_metrics(&mut ctx.record, now, compute, s, n, steps);
    ctx.finalize()
}

/// Rolling per-(step, client) loss rows for the async engine: only steps
/// that some client has completed but whose barrier has not yet settled
/// are resident (bounded by the fastest–slowest step spread), replacing
/// the up-front dense `steps × n` matrix (400 MB at n = 100k,
/// steps = 1000). Retired rows recycle through a free pool, so once the
/// spread peaks, steady-state stepping allocates nothing.
struct LossWindow {
    n: usize,
    /// lowest un-settled step — `rows[0]` is its row
    base: usize,
    rows: VecDeque<Vec<f32>>,
    pool: Vec<Vec<f32>>,
}

impl LossWindow {
    fn new(n: usize) -> LossWindow {
        LossWindow { n, base: 0, rows: VecDeque::new(), pool: vec![] }
    }

    /// Record client's step loss, growing the window as needed. A write
    /// below `base` would mean an unsettled row was evicted — impossible
    /// by construction (a barrier settles step t only after all n clients
    /// completed it, so no step-t write can follow it), and asserted.
    fn set(&mut self, step: usize, client: usize, loss: f32) {
        assert!(step >= self.base, "loss write to step {step} after its barrier settled");
        let idx = step - self.base;
        while self.rows.len() <= idx {
            let mut row = self.pool.pop().unwrap_or_default();
            row.clear();
            row.resize(self.n, 0.0);
            self.rows.push_back(row);
        }
        self.rows[idx][client] = loss;
    }

    /// The complete row for the settling barrier (client-order mean).
    fn row(&self, step: usize) -> &[f32] {
        assert_eq!(step, self.base, "barriers must settle in step order");
        &self.rows[0]
    }

    /// Retire the settled row into the recycle pool and advance the base.
    fn retire(&mut self, step: usize) {
        assert_eq!(step, self.base, "barriers must settle in step order");
        if let Some(row) = self.rows.pop_front() {
            self.pool.push(row);
        }
        self.base += 1;
    }
}

/// The fully asynchronous engine for [`TimePolicy::Async`] methods.
///
/// Local steps execute lazily at their completion events. Every `Ev::Step`
/// sharing one `(time, priority)` instant is drained into a **cohort**
/// ([`EventQueue::pop_cohort`]) and canonicalized to (step, client) order;
/// each step group then runs `on_step_begin` + `local_step` for all its
/// clients through the worker pool ([`par_map_mut_idx`]) and replays the
/// per-client completion effects (`on_step_complete` flood sends, counts,
/// next-event pushes) sequentially in client-id order. The fan-out is
/// sound because `local_step` touches only its own `ClientState` (never
/// the network) and the replay reproduces the sequential message order,
/// so accounting and trajectories are independent of the thread count —
/// and under uniform rates every instant holds all n clients, recovering
/// lockstep's thread scaling exactly. The schedule clock, `begin_step`,
/// and the repair triggers advance with the nominal iteration
/// (`virtual time / step span`), mirroring their lockstep positions.
fn run_async(mut ctx: RunCtx<'_>, speed: &SpeedModel) -> Result<RunRecord> {
    let steps = ctx.env.cfg.steps;
    let n = ctx.env.cfg.clients;
    let s = step_ticks(&ctx);
    let threads = ctx.env.cfg.threads;
    if steps == 0 || n == 0 {
        return ctx.finalize();
    }

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut compute = 0u64;
    for i in 0..n {
        let d = speed.duration(i, 0, s);
        compute += d;
        q.push(d, PRIO_STEP, Ev::Step { client: i, step: 0 });
    }
    q.push(TICKS_PER_ROUND, PRIO_ROUND, Ev::Round);

    // per-step completion counts and the rolling loss window; the window
    // keeps the barrier's mean a client-order sum regardless of the
    // completion order, preserving the reduction contract
    let mut completed = vec![0usize; steps];
    let mut losses = LossWindow::new(n);
    let mut finish = vec![0u64; n];
    let mut begun: Option<usize> = None; // highest step begin_step has seen
    let mut sched: Option<usize> = None; // last Network::set_step argument
    let mut barriers = 0usize;
    let mut clock = 0u64; // delivery rounds ticked so far
    // false ⇔ provably quiescent: the last round moved nothing, nothing
    // is in flight, and no step/barrier/schedule event happened since —
    // lets Round events skip their O(n·deg) scans while stragglers crawl
    let mut active = true;
    // reusable cohort scratch — steady-state stepping allocates nothing
    let mut cohort: Vec<Event<Ev>> = Vec::new();
    let mut order: Vec<(usize, usize)> = Vec::new(); // canonical (step, client)
    let mut group: Vec<usize> = Vec::new(); // one step group's client ids

    while let Some((now, prio)) = q.peek_key() {
        // delivery clock: one round per TICKS_PER_ROUND of virtual time,
        // advanced *before* any event at this instant. A completion's
        // send and the coincident round's sends therefore stamp the same
        // round, so a netcond `delay=K` costs exactly K delivery rounds
        // on every hop — the same relative timing as lockstep's
        // tick-then-send order (absolute clock values differ only by a
        // constant offset, which no stamp comparison can observe).
        while clock < now / TICKS_PER_ROUND {
            ctx.net.tick();
            clock += 1;
        }
        // nominal iteration: events in [(t+1)·s, (t+2)·s) belong to
        // iteration t — under uniform rates exactly the window from the
        // step-t completions up to (excluding) the step-t+1 completions,
        // aligning the schedule clock and staleness accounting with
        // lockstep. The clock keeps running past `steps` while stragglers
        // catch up (anti-entropy heartbeats continue; every scheduled
        // down-window is over by then). Both advance loops are monotone
        // guards, so running them once per *instant* (here) is identical
        // to the historical once per *event*.
        let nominal = ((now / s).saturating_sub(1)) as usize;
        while sched.map_or(true, |g| g < nominal) {
            let g = sched.map_or(0, |g| g + 1);
            ctx.net.set_step(g);
            ctx.algo.on_iteration_start(&mut ctx.states, g, ctx.env, &mut ctx.net)?;
            sched = Some(g);
            active = true; // churn flips, repair arming: rounds matter again
        }

        if prio == PRIO_STEP {
            // --- the same-instant step cohort (see the fn docs) ---
            q.pop_cohort(&mut cohort);
            order.clear();
            for e in &cohort {
                match &e.payload {
                    Ev::Step { client, step } => order.push((*step, *client)),
                    _ => unreachable!("PRIO_STEP cohort holds only step events"),
                }
            }
            // canonical replay order: ascending step, then client id.
            // Under uniform rates (the bit-for-bit reduction case) a
            // cohort is exactly one full step group already in client ==
            // insertion order, so this sort is the identity permutation.
            order.sort_unstable();
            let mut lo = 0usize;
            while lo < order.len() {
                let step = order[lo].0;
                group.clear();
                let mut hi = lo;
                while hi < order.len() && order[hi].0 == step {
                    group.push(order[hi].1);
                    hi += 1;
                }
                lo = hi;
                if begun.map_or(true, |b| step > b) {
                    // shared-state hook (e.g. the τ-periodic basis
                    // refresh) follows the most advanced client; it
                    // settles any basis-relative pending state across
                    // all clients before mutating (stragglers can hold
                    // accumulated coefficients at a refresh boundary)
                    ctx.algo.begin_step(&mut ctx.states, step, ctx.env)?;
                    begun = Some(step);
                }
                // compute phase: on_step_begin + local_step touch only
                // their own ClientState (never the network), so the whole
                // group fans out over the worker pool; a singleton group
                // (the heterogeneous steady state) runs inline with zero
                // fan-out overhead. Losses land in client order and the
                // lowest-client error wins — exactly the sequential
                // outcome for every thread count.
                if group.len() == 1 {
                    let c = group[0];
                    ctx.algo.on_step_begin(&mut ctx.states[c], c, step, ctx.env)?;
                    let loss =
                        ctx.algo.local_step(&mut ctx.states[c], c, step, ctx.env)?;
                    losses.set(step, c, loss);
                } else {
                    let algo = &ctx.algo;
                    let env = ctx.env;
                    let results = par_map_mut_idx(&mut ctx.states, &group, threads, |c, st| {
                        algo.on_step_begin(st, c, step, env)?;
                        algo.local_step(st, c, step, env)
                    });
                    for (j, res) in results.into_iter().enumerate() {
                        losses.set(step, group[j], res?);
                    }
                }
                // replay phase: per-client completion effects in client-id
                // order — flood sends hit the network in the sequential
                // order, and next-step events get the sequential insertion
                // (seq) order, keeping accounting and trajectories intact
                for &c in group.iter() {
                    if ctx.net.is_online(c) {
                        ctx.algo.on_step_complete(
                            &mut ctx.states[c],
                            c,
                            step,
                            ctx.env,
                            &mut ctx.net,
                        )?;
                    }
                    completed[step] += 1;
                    if step + 1 < steps {
                        let d = speed.duration(c, step + 1, s);
                        compute += d;
                        q.push(now + d, PRIO_STEP, Ev::Step { client: c, step: step + 1 });
                    } else {
                        finish[c] = now;
                    }
                    if completed[step] == n {
                        // settle after the remaining rounds of this
                        // nominal step (k rounds total follow a full
                        // cohort — the lockstep communication depth)
                        let settle = (s / TICKS_PER_ROUND - 1) * TICKS_PER_ROUND;
                        q.push(now + settle, PRIO_BARRIER, Ev::Barrier { step });
                    }
                }
            }
            active = true;
            continue;
        }

        let ev = q.pop().expect("peeked event vanished");
        match ev.payload {
            Ev::Step { .. } => unreachable!("PRIO_STEP events take the cohort path"),
            Ev::Round => {
                // scans are skipped while provably quiescent: an idle
                // round's send_round/collect cannot change any state, so
                // skipping is invisible to the trajectory — it only
                // avoids O(n·deg) no-op work on long straggler tails
                if active {
                    let bytes0 = ctx.net.acct.total_bytes;
                    let deliv0 = ctx.net.acct.delivered_messages;
                    for i in 0..n {
                        if ctx.net.is_online(i) {
                            ctx.algo.on_send(&mut ctx.states[i], i, ctx.env, &mut ctx.net)?;
                        }
                    }
                    for i in 0..n {
                        if ctx.net.is_online(i) {
                            ctx.algo.on_deliver(
                                &mut ctx.states[i],
                                i,
                                nominal,
                                ctx.env,
                                &mut ctx.net,
                            )?;
                        }
                    }
                    active = ctx.net.acct.total_bytes != bytes0
                        || ctx.net.acct.delivered_messages != deliv0
                        || ctx.net.in_flight() > 0;
                }
                q.push(now + TICKS_PER_ROUND, PRIO_ROUND, Ev::Round);
            }
            Ev::Barrier { step } => {
                debug_assert_eq!(step, barriers, "barriers must settle in step order");
                ctx.push_train_loss(losses.row(step));
                ctx.algo.on_barrier(&mut ctx.states, step, ctx.env, &mut ctx.net)?;
                ctx.after_step(step)?;
                losses.retire(step);
                barriers += 1;
                if barriers == steps {
                    break;
                }
                active = true; // an on_barrier override may have sent
            }
        }
    }

    let makespan = finish.iter().copied().max().unwrap_or(0);
    time_metrics(&mut ctx.record, makespan, compute, s, n, steps);
    ctx.finalize()
}
