//! The event-driven virtual-time execution engine (`--time-model event`,
//! ISSUE 4 tentpole).
//!
//! Each client has a compute rate drawn from the seeded speed model
//! (`--rates`, [`SpeedModel`]); its local steps complete at virtual times
//! instead of a shared step index. Communication runs off the delivery
//! clock: one [`crate::net::Network::tick`] round every
//! [`TICKS_PER_ROUND`] virtual ticks (a nominal local step spans
//! `flood_steps` rounds, matching the lockstep cadence), so netcond
//! delays and down-windows keep their round/iteration units — re-keyed to
//! virtual time rather than the barrier loop.
//!
//! The driver honors each algorithm's [`TimePolicy`]:
//!
//! * **Async** (SeedFlood, the single-client baselines) — a deterministic
//!   event queue interleaves three event kinds: `Step` (a client's local
//!   step completes: catch-up flush → `local_step` → flood immediately
//!   via `on_step_complete`), `Round` (delivery-clock round: every online
//!   client's `on_send` then `on_deliver`), and `Barrier` (every client
//!   has completed step `t`: settle via `on_barrier`, record the train
//!   loss, run the evaluation bookkeeping). The nominal schedule clock
//!   (`virtual time / step span`) drives [`crate::net::Network::set_step`]
//!   and the netcond repair triggers.
//! * **Barrier** (DSGD, ChocoSGD, DZSGD and the LoRA variants) — the
//!   lockstep adapter: dense/sparse gossip mixes simultaneous snapshots
//!   of all clients and has no barrier-free formulation, so the driver
//!   reuses the shared `RunCtx::lockstep_iteration` verbatim and heterogeneous
//!   speeds surface only as the timing metrics. Results are identical to
//!   `--time-model lockstep` for *any* `--rates` — the honest semantics
//!   of a method that must wait for its slowest participant.
//!
//! # The reduction contract
//!
//! With uniform rates every step-completion cohort lands on one virtual
//! instant, the queue's `(time, priority, insertion)` order degenerates
//! to the lockstep order (completions → k rounds → barrier), completion
//! sends coincide with what the first lockstep round would have sent, and
//! the barrier flush sits exactly where the lockstep iteration flush sat —
//! so `--time-model event --rates uniform` reproduces the lockstep
//! trajectory bit-for-bit, for async and barrier methods alike
//! (property-tested in rust/tests/properties.rs). Non-uniform rates are
//! then the *only* source of divergence, which is what makes the
//! straggler experiments attributable.
//!
//! # Timing metrics
//!
//! The run's `RunRecord` gains `virtual_makespan` (nominal-step units:
//! `Σ_t max_i dur` for barrier methods, `max_i Σ_t dur` for async — the
//! gap between the two is the straggler tax), `idle_frac`
//! (1 − compute / (n · makespan)), `client_steps`, and the flooding
//! staleness percentiles (`staleness_p50/p90/p99`), measured on the
//! nominal iteration clock.

use anyhow::Result;

use super::{Driver, Env, RunCtx};
use crate::algos::TimePolicy;
use crate::metrics::RunRecord;
use crate::sched::{EventQueue, RateSpec, SpeedModel, TICKS_PER_ROUND};

/// Event kinds of the async engine; the listed order is also the
/// same-tick priority (completions before the round that forwards them,
/// rounds before the barrier that evaluates their effect).
enum Ev {
    /// Client `client` completes local step `step`.
    Step { client: usize, step: usize },
    /// One delivery-clock communication round.
    Round,
    /// Every client has completed local step `step`.
    Barrier { step: usize },
}

const PRIO_STEP: u8 = 0;
const PRIO_ROUND: u8 = 1;
const PRIO_BARRIER: u8 = 2;

/// The `--time-model event` driver. See the module docs.
pub struct EventDriven;

impl Driver for EventDriven {
    fn run(&mut self, env: &Env) -> Result<RunRecord> {
        let ctx = RunCtx::setup(env)?;
        let spec = RateSpec::parse(&env.cfg.rates)?;
        let speed = SpeedModel::build(&spec, env.cfg.clients, env.cfg.seed);
        match ctx.algo.time_policy() {
            TimePolicy::Barrier => run_barrier(ctx, &speed),
            TimePolicy::Async => run_async(ctx, &speed),
        }
    }
}

/// Virtual-time span of one nominal local step: `flood_steps` delivery
/// rounds (the lockstep cadence — k rounds per iteration), resolving the
/// `0 = topology diameter` default exactly as SeedFlood does.
fn step_ticks(ctx: &RunCtx<'_>) -> u64 {
    let k = if ctx.env.cfg.flood_steps == 0 {
        ctx.net.topology().diameter().max(1)
    } else {
        ctx.env.cfg.flood_steps
    };
    k as u64 * TICKS_PER_ROUND
}

/// Fill the driver-owned timing fields of the record.
fn time_metrics(
    record: &mut RunRecord,
    makespan_ticks: u64,
    compute_ticks: u64,
    ticks_per_step: u64,
    n: usize,
    steps: usize,
) {
    record.virtual_makespan = makespan_ticks as f64 / ticks_per_step as f64;
    record.idle_frac = if makespan_ticks == 0 {
        0.0
    } else {
        1.0 - compute_ticks as f64 / (n as u64 * makespan_ticks) as f64
    };
    record.client_steps = vec![steps as u64; n];
}

/// The lockstep adapter: reuse the exact lockstep iteration for barrier
/// methods and account virtual time around it — each iteration costs the
/// cohort maximum (everyone waits for the slowest), which is where the
/// `Σ_t max_i` straggler tax comes from.
fn run_barrier(mut ctx: RunCtx<'_>, speed: &SpeedModel) -> Result<RunRecord> {
    let steps = ctx.env.cfg.steps;
    let n = ctx.env.cfg.clients;
    let s = step_ticks(&ctx);
    let (mut now, mut compute) = (0u64, 0u64);
    for t in 0..steps {
        ctx.lockstep_iteration(t)?;
        let durs: Vec<u64> = (0..n).map(|i| speed.duration(i, t, s)).collect();
        now += durs.iter().copied().max().unwrap_or(0);
        compute += durs.iter().sum::<u64>();
    }
    time_metrics(&mut ctx.record, now, compute, s, n, steps);
    ctx.finalize()
}

/// The fully asynchronous engine for [`TimePolicy::Async`] methods.
///
/// Local steps execute lazily at their completion events (sequentially —
/// event interleavings are inherently serial; per-client results are
/// independent of execution order by the engine's determinism contract,
/// so this agrees with the threaded lockstep fan-out). The schedule
/// clock, `begin_step`, and the repair triggers advance with the nominal
/// iteration (`virtual time / step span`), mirroring their lockstep
/// positions.
fn run_async(mut ctx: RunCtx<'_>, speed: &SpeedModel) -> Result<RunRecord> {
    let steps = ctx.env.cfg.steps;
    let n = ctx.env.cfg.clients;
    let s = step_ticks(&ctx);
    if steps == 0 || n == 0 {
        return ctx.finalize();
    }

    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut compute = 0u64;
    for i in 0..n {
        let d = speed.duration(i, 0, s);
        compute += d;
        q.push(d, PRIO_STEP, Ev::Step { client: i, step: 0 });
    }
    q.push(TICKS_PER_ROUND, PRIO_ROUND, Ev::Round);

    // per-step completion counts and per-(step, client) losses; the loss
    // matrix keeps the barrier's mean a client-order sum regardless of
    // the completion order, preserving the reduction contract
    let mut completed = vec![0usize; steps];
    let mut losses = vec![0f32; steps * n];
    let mut finish = vec![0u64; n];
    let mut begun: Option<usize> = None; // highest step begin_step has seen
    let mut sched: Option<usize> = None; // last Network::set_step argument
    let mut barriers = 0usize;
    let mut clock = 0u64; // delivery rounds ticked so far
    // false ⇔ provably quiescent: the last round moved nothing, nothing
    // is in flight, and no step/barrier/schedule event happened since —
    // lets Round events skip their O(n·deg) scans while stragglers crawl
    let mut active = true;

    while let Some(ev) = q.pop() {
        let now = ev.time;
        // delivery clock: one round per TICKS_PER_ROUND of virtual time,
        // advanced *before* any event at this instant. A completion's
        // send and the coincident round's sends therefore stamp the same
        // round, so a netcond `delay=K` costs exactly K delivery rounds
        // on every hop — the same relative timing as lockstep's
        // tick-then-send order (absolute clock values differ only by a
        // constant offset, which no stamp comparison can observe).
        while clock < now / TICKS_PER_ROUND {
            ctx.net.tick();
            clock += 1;
        }
        // nominal iteration: events in [(t+1)·s, (t+2)·s) belong to
        // iteration t — under uniform rates exactly the window from the
        // step-t completions up to (excluding) the step-t+1 completions,
        // aligning the schedule clock and staleness accounting with
        // lockstep. The clock keeps running past `steps` while stragglers
        // catch up (anti-entropy heartbeats continue; every scheduled
        // down-window is over by then).
        let nominal = ((now / s).saturating_sub(1)) as usize;
        while sched.map_or(true, |g| g < nominal) {
            let g = sched.map_or(0, |g| g + 1);
            ctx.net.set_step(g);
            ctx.algo.on_iteration_start(&mut ctx.states, g, ctx.env, &mut ctx.net)?;
            sched = Some(g);
            active = true; // churn flips, repair arming: rounds matter again
        }

        match ev.payload {
            Ev::Step { client, step } => {
                if begun.map_or(true, |b| step > b) {
                    // shared-state hook (e.g. the τ-periodic basis
                    // refresh) follows the most advanced client; it
                    // settles any basis-relative pending state across
                    // all clients before mutating (stragglers can hold
                    // accumulated coefficients at a refresh boundary)
                    ctx.algo.begin_step(&mut ctx.states, step, ctx.env)?;
                    begun = Some(step);
                }
                ctx.algo.on_step_begin(&mut ctx.states[client], client, step, ctx.env)?;
                let loss = ctx.algo.local_step(&mut ctx.states[client], client, step, ctx.env)?;
                losses[step * n + client] = loss;
                if ctx.net.is_online(client) {
                    ctx.algo.on_step_complete(
                        &mut ctx.states[client],
                        client,
                        step,
                        ctx.env,
                        &mut ctx.net,
                    )?;
                }
                completed[step] += 1;
                if step + 1 < steps {
                    let d = speed.duration(client, step + 1, s);
                    compute += d;
                    q.push(now + d, PRIO_STEP, Ev::Step { client, step: step + 1 });
                } else {
                    finish[client] = now;
                }
                if completed[step] == n {
                    // settle after the remaining rounds of this nominal
                    // step (k rounds total follow a full cohort — the
                    // lockstep iteration's communication depth)
                    let settle = (s / TICKS_PER_ROUND - 1) * TICKS_PER_ROUND;
                    q.push(now + settle, PRIO_BARRIER, Ev::Barrier { step });
                }
                active = true;
            }
            Ev::Round => {
                // scans are skipped while provably quiescent: an idle
                // round's send_round/collect cannot change any state, so
                // skipping is invisible to the trajectory — it only
                // avoids O(n·deg) no-op work on long straggler tails
                if active {
                    let bytes0 = ctx.net.acct.total_bytes;
                    let deliv0 = ctx.net.acct.delivered_messages;
                    for i in 0..n {
                        if ctx.net.is_online(i) {
                            ctx.algo.on_send(&mut ctx.states[i], i, ctx.env, &mut ctx.net)?;
                        }
                    }
                    for i in 0..n {
                        if ctx.net.is_online(i) {
                            ctx.algo.on_deliver(
                                &mut ctx.states[i],
                                i,
                                nominal,
                                ctx.env,
                                &mut ctx.net,
                            )?;
                        }
                    }
                    active = ctx.net.acct.total_bytes != bytes0
                        || ctx.net.acct.delivered_messages != deliv0
                        || ctx.net.in_flight() > 0;
                }
                q.push(now + TICKS_PER_ROUND, PRIO_ROUND, Ev::Round);
            }
            Ev::Barrier { step } => {
                debug_assert_eq!(step, barriers, "barriers must settle in step order");
                let row: Vec<f32> = losses[step * n..(step + 1) * n].to_vec();
                ctx.push_train_loss(&row);
                ctx.algo.on_barrier(&mut ctx.states, step, ctx.env, &mut ctx.net)?;
                ctx.after_step(step)?;
                barriers += 1;
                if barriers == steps {
                    break;
                }
                active = true; // an on_barrier override may have sent
            }
        }
    }

    let makespan = finish.iter().copied().max().unwrap_or(0);
    time_metrics(&mut ctx.record, makespan, compute, s, n, steps);
    ctx.finalize()
}
