//! Synthetic task family + client partitioning (paper §4.1 substitution).
//!
//! The paper fine-tunes OPT on SuperGLUE/SST-2 with 1,024 training samples
//! uniformly partitioned across clients, 500 validation, 1,000 test.  We
//! keep the exact split sizes and partitioning but substitute a *planted
//! token-motif* classification family (DESIGN.md#Substitutions): a task
//! plants disjoint positive/negative lexicons; each example is a fixed-
//! length token sequence containing lexicon tokens amid filler, rendered
//! MeZO-prompt style — the final position is a task "query" token and the
//! model scores C verbalizer tokens there.  The label is which lexicon
//! dominates, flipped with a per-task noise rate (task difficulty knob).
//!
//! Six named instances mirror the paper's task list (sst2, rte, boolq,
//! wic, multirc, record) with increasing difficulty.

use crate::rng::Rng;

/// Reserved token ids (must stay below every config's vocab of >= 256).
pub const PAD: i32 = 0;
pub const QUERY: i32 = 1;
pub const CLASS_TOKENS: [i32; 2] = [2, 3];
const RESERVED: i32 = 4;
/// Each task owns a disjoint block of `LEX_BLOCK` token ids for its two
/// lexicons (so tasks never assign conflicting labels to the same token —
/// words keep stable meanings across the corpus, like real text); ids from
/// `FILLER_BASE` up are the shared neutral filler pool.
pub const LEX_BLOCK: i32 = 20;
pub const MAX_TASKS: i32 = 6;
pub const FILLER_BASE: i32 = RESERVED + MAX_TASKS * LEX_BLOCK; // = 124

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: String,
    /// per-side lexicon size (<= LEX_BLOCK/2)
    pub lexicon: usize,
    /// how many lexicon tokens are planted per sequence
    pub planted: usize,
    /// label-noise rate (difficulty)
    pub noise: f64,
    /// task seed (determines example sampling)
    pub seed: u64,
    /// base token id of this task's lexicon block (disjoint across tasks)
    pub lex_base: i32,
}

impl TaskSpec {
    /// The six SuperGLUE/SST-2 analogues, ordered easy → hard like the
    /// paper's observed accuracy spread.
    pub fn named(name: &str) -> Option<TaskSpec> {
        let (idx, lexicon, planted, noise, seed) = match name {
            "sst2" => (0, 8, 6, 0.02, 101),
            "rte" => (1, 6, 4, 0.12, 102),
            "boolq" => (2, 6, 4, 0.10, 103),
            "wic" => (3, 4, 4, 0.16, 104),
            "multirc" => (4, 6, 4, 0.08, 105),
            "record" => (5, 10, 6, 0.05, 106),
            _ => return None,
        };
        Some(TaskSpec {
            name: name.to_string(),
            lexicon,
            planted,
            noise,
            seed,
            lex_base: RESERVED + idx * LEX_BLOCK,
        })
    }

    /// This task's positive / negative lexicons (disjoint id ranges).
    pub fn lexicons(&self) -> (Vec<i32>, Vec<i32>) {
        assert!(2 * self.lexicon as i32 <= LEX_BLOCK);
        let pos = (self.lex_base..self.lex_base + self.lexicon as i32).collect();
        let neg = (self.lex_base + self.lexicon as i32
            ..self.lex_base + 2 * self.lexicon as i32)
            .collect();
        (pos, neg)
    }

    pub fn all_names() -> [&'static str; 6] {
        ["sst2", "rte", "boolq", "wic", "multirc", "record"]
    }
}

/// One classification example: fixed-length token sequence + binary label.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub label: i32, // 0 or 1
}

/// A fully materialized task: train/val/test splits (paper sizes).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub spec: TaskSpec,
    pub train: Vec<Example>,
    pub val: Vec<Example>,
    pub test: Vec<Example>,
    pub seq: usize,
    pub vocab: usize,
}

impl Dataset {
    /// Paper split sizes: 1,024 / 500 / 1,000.
    pub fn generate(spec: &TaskSpec, vocab: usize, seq: usize) -> Dataset {
        Self::generate_sized(spec, vocab, seq, 1024, 500, 1000)
    }

    pub fn generate_sized(
        spec: &TaskSpec,
        vocab: usize,
        seq: usize,
        n_train: usize,
        n_val: usize,
        n_test: usize,
    ) -> Dataset {
        assert!(vocab as i32 > FILLER_BASE + 16,
                "vocab {vocab} too small (need > {})", FILLER_BASE + 16);
        let (pos, neg) = spec.lexicons();
        let filler: Vec<i32> = (FILLER_BASE..vocab as i32).collect();

        let gen_split = |n: usize, stream: u64| -> Vec<Example> {
            let mut r = Rng::fold_in(spec.seed, stream);
            (0..n).map(|_| Self::gen_example(spec, &pos, &neg, &filler, seq, &mut r)).collect()
        };
        Dataset {
            spec: spec.clone(),
            train: gen_split(n_train, 1),
            val: gen_split(n_val, 2),
            test: gen_split(n_test, 3),
            seq,
            vocab,
        }
    }

    fn gen_example(
        spec: &TaskSpec,
        pos: &[i32],
        neg: &[i32],
        filler: &[i32],
        seq: usize,
        rng: &mut Rng,
    ) -> Example {
        let mut tokens: Vec<i32> = (0..seq - 1)
            .map(|_| filler[rng.next_below(filler.len() as u64) as usize])
            .collect();
        // plant `planted` lexicon tokens with a majority from one side
        let label = (rng.next_u64() & 1) as i32;
        let majority = spec.planted / 2 + 1;
        let minority = spec.planted - majority;
        let (maj_lex, min_lex) = if label == 1 { (pos, neg) } else { (neg, pos) };
        let positions = rng.permutation(seq - 1);
        for (k, &p) in positions.iter().take(spec.planted).enumerate() {
            let lex = if k < majority { maj_lex } else { min_lex };
            let _ = minority;
            tokens[p as usize] = lex[rng.next_below(lex.len() as u64) as usize];
        }
        tokens.push(QUERY); // prediction position
        let label = if rng.next_f64() < spec.noise { 1 - label } else { label };
        Example { tokens, label }
    }

    /// Extra examples from the same task distribution on a stream disjoint
    /// from train/val/test — used by the pretraining corpus (the paper's
    /// OPT pretraining makes SuperGLUE zero-shot feasible; this split plays
    /// that role, see DESIGN.md#Substitutions).
    pub fn pretrain_split(spec: &TaskSpec, vocab: usize, seq: usize, n: usize) -> Vec<Example> {
        let (pos, neg) = spec.lexicons();
        let filler: Vec<i32> = (FILLER_BASE..vocab as i32).collect();
        let mut r = Rng::fold_in(spec.seed, 4);
        (0..n).map(|_| Self::gen_example(spec, &pos, &neg, &filler, seq, &mut r)).collect()
    }

    /// Uniform partition of the training split across `n` clients
    /// (paper §4.1: "1,024 training samples uniformly partitioned").
    pub fn partition(&self, n: usize) -> Vec<Vec<Example>> {
        let per = self.train.len() / n;
        assert!(per > 0, "more clients ({n}) than examples ({})", self.train.len());
        (0..n).map(|i| self.train[i * per..(i + 1) * per].to_vec()).collect()
    }

    /// Label-skewed (non-IID) partition: each client draws its label
    /// proportions from a symmetric Dirichlet(α). Small α ⇒ clients see
    /// mostly one class — the standard heterogeneity stressor for
    /// decentralized methods (the paper's uniform split is α → ∞). Every
    /// client is guaranteed at least one example of some class.
    pub fn partition_dirichlet(&self, n: usize, alpha: f64, seed: u64) -> Vec<Vec<Example>> {
        assert!(n <= self.train.len());
        let mut rng = Rng::new(crate::rng::mix(seed, 0xD1B1));
        // split train pool by label
        let mut by_label: [Vec<&Example>; 2] = [vec![], vec![]];
        for ex in &self.train {
            by_label[ex.label as usize].push(ex);
        }
        // per-client Dirichlet(α, α) over the two labels via Gamma draws
        let gamma = |rng: &mut Rng| -> f64 {
            // Marsaglia–Tsang for shape α (<1 handled by boost)
            let boost = if alpha < 1.0 { rng.next_f64().powf(1.0 / alpha) } else { 1.0 };
            let d = alpha.max(1.0) - 1.0 / 3.0;
            let c = 1.0 / (9.0 * d).sqrt();
            loop {
                let x = {
                    // one normal draw
                    let u1 = rng.next_f64().max(1e-300);
                    let u2 = rng.next_f64();
                    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
                };
                let v = (1.0 + c * x).powi(3);
                if v <= 0.0 {
                    continue;
                }
                let u = rng.next_f64();
                if u < 1.0 - 0.0331 * x.powi(4)
                    || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
                {
                    return d * v * boost;
                }
            }
        };
        let props: Vec<[f64; 2]> = (0..n)
            .map(|_| {
                let (a, b) = (gamma(&mut rng).max(1e-9), gamma(&mut rng).max(1e-9));
                [a / (a + b), b / (a + b)]
            })
            .collect();
        // deal examples: walk each label pool, assigning to clients in
        // proportion to their normalized share of that label
        let mut out: Vec<Vec<Example>> = vec![vec![]; n];
        for label in 0..2 {
            let pool = &by_label[label];
            let total: f64 = props.iter().map(|p| p[label]).sum();
            let mut cursor = 0usize;
            for (i, p) in props.iter().enumerate() {
                let want = ((p[label] / total) * pool.len() as f64).round() as usize;
                let end = (cursor + want).min(pool.len());
                for ex in &pool[cursor..end] {
                    out[i].push((*ex).clone());
                }
                cursor = end;
            }
            // leftovers to the last clients round-robin
            let mut i = 0;
            while cursor < pool.len() {
                out[i % n].push(pool[cursor].clone());
                cursor += 1;
                i += 1;
            }
        }
        // nobody may be empty (samplers need >= 1 example)
        for i in 0..n {
            if out[i].is_empty() {
                let donor = (0..n).max_by_key(|&j| out[j].len()).unwrap();
                let ex = out[donor].pop().unwrap();
                out[i].push(ex);
            }
        }
        out
    }
}

/// Mini-batch iterator over a client's local shard: shuffled, wrapping.
pub struct BatchSampler {
    examples: Vec<Example>,
    order: Vec<u32>,
    cursor: usize,
    rng: Rng,
}

impl BatchSampler {
    pub fn new(examples: Vec<Example>, seed: u64) -> BatchSampler {
        assert!(!examples.is_empty());
        let mut rng = Rng::new(seed);
        let order = rng.permutation(examples.len());
        BatchSampler { examples, order, cursor: 0, rng }
    }

    /// Next batch of (input_ids flat, labels), re-shuffling per epoch.
    pub fn next_batch(&mut self, batch: usize) -> (Vec<i32>, Vec<i32>) {
        let mut ids = Vec::with_capacity(batch * self.examples[0].tokens.len());
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.cursor >= self.order.len() {
                self.order = self.rng.permutation(self.examples.len());
                self.cursor = 0;
            }
            let ex = &self.examples[self.order[self.cursor] as usize];
            self.cursor += 1;
            ids.extend_from_slice(&ex.tokens);
            labels.push(ex.label);
        }
        (ids, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexicon_blocks_disjoint_across_tasks() {
        let mut seen = std::collections::HashSet::new();
        for name in TaskSpec::all_names() {
            let (pos, neg) = TaskSpec::named(name).unwrap().lexicons();
            for t in pos.iter().chain(neg.iter()) {
                assert!(*t >= RESERVED && *t < FILLER_BASE);
                assert!(seen.insert(*t), "token {t} reused across tasks");
            }
        }
    }

    fn ds() -> Dataset {
        Dataset::generate_sized(&TaskSpec::named("sst2").unwrap(), 256, 32, 128, 50, 100)
    }

    #[test]
    fn split_sizes_and_shapes() {
        let d = ds();
        assert_eq!(d.train.len(), 128);
        assert_eq!(d.val.len(), 50);
        assert_eq!(d.test.len(), 100);
        for ex in d.train.iter().chain(&d.val).chain(&d.test) {
            assert_eq!(ex.tokens.len(), 32);
            assert_eq!(*ex.tokens.last().unwrap(), QUERY);
            assert!(ex.label == 0 || ex.label == 1);
            assert!(ex.tokens.iter().all(|&t| t >= 0 && (t as usize) < 256));
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = ds();
        let b = ds();
        assert_eq!(a.train[0].tokens, b.train[0].tokens);
        assert_eq!(a.test[9].label, b.test[9].label);
    }

    #[test]
    fn labels_roughly_balanced() {
        let d = ds();
        let ones: usize = d.train.iter().filter(|e| e.label == 1).count();
        assert!(ones > 128 / 4 && ones < 128 * 3 / 4, "ones={ones}");
    }

    #[test]
    fn tasks_all_construct() {
        for name in TaskSpec::all_names() {
            let spec = TaskSpec::named(name).unwrap();
            let d = Dataset::generate_sized(&spec, 256, 16, 32, 8, 8);
            assert_eq!(d.train.len(), 32, "{name}");
        }
        assert!(TaskSpec::named("nope").is_none());
    }

    #[test]
    fn dirichlet_partition_covers_all_and_skews() {
        let d = Dataset::generate_sized(&TaskSpec::named("sst2").unwrap(), 256, 16, 512, 8, 8);
        let parts = d.partition_dirichlet(8, 0.3, 7);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 512);
        assert!(parts.iter().all(|p| !p.is_empty()));
        // at α=0.3 at least one client should be heavily label-skewed
        let max_skew = parts
            .iter()
            .map(|p| {
                let ones = p.iter().filter(|e| e.label == 1).count() as f64;
                (ones / p.len() as f64 - 0.5).abs()
            })
            .fold(0.0, f64::max);
        assert!(max_skew > 0.2, "no skew at alpha=0.3: {max_skew}");
        // determinism
        let parts2 = d.partition_dirichlet(8, 0.3, 7);
        assert_eq!(parts[0].len(), parts2[0].len());
    }

    #[test]
    fn partition_uniform_disjoint() {
        let d = ds();
        let parts = d.partition(8);
        assert_eq!(parts.len(), 8);
        assert!(parts.iter().all(|p| p.len() == 16));
    }

    #[test]
    fn sampler_wraps_and_shuffles() {
        let d = ds();
        let mut s = BatchSampler::new(d.partition(8)[0].clone(), 7);
        let seq = d.seq;
        for _ in 0..10 {
            let (ids, labels) = s.next_batch(8);
            assert_eq!(ids.len(), 8 * seq);
            assert_eq!(labels.len(), 8);
        }
    }

    #[test]
    fn majority_signal_exists() {
        // count pos-lexicon occurrences correlate with label (pre-noise)
        let spec = TaskSpec { noise: 0.0, ..TaskSpec::named("sst2").unwrap() };
        let d = Dataset::generate_sized(&spec, 256, 32, 256, 8, 8);
        // a simple count-based classifier must beat chance comfortably
        let (pos, neg) = spec.lexicons();
        let mut correct = 0;
        for ex in &d.train {
            let p = ex.tokens.iter().filter(|t| pos.contains(t)).count();
            let n = ex.tokens.iter().filter(|t| neg.contains(t)).count();
            let pred = (p > n) as i32;
            correct += (pred == ex.label) as usize;
        }
        assert!(correct as f64 / d.train.len() as f64 > 0.95,
                "planted rule not recoverable: {correct}/256");
    }
}
