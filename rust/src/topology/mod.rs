//! Network topologies for decentralized training (paper §2.1, §4.1).
//!
//! The communication structure is an undirected, connected, static graph
//! `G = (V, E)`; clients talk only to `N(i)`.  The paper evaluates ring and
//! meshgrid; we additionally provide torus, complete, star, Erdős–Rényi and
//! Watts–Strogatz small-world graphs for ablations, plus the graph
//! quantities the algorithms need: BFS diameter, Metropolis–Hastings mixing
//! weights (doubly-stochastic, the `w_ij` of Eq. 2) and a spectral-gap
//! estimate (consensus-rate diagnostic).
//!
//! ```
//! use seedflood::topology::Topology;
//!
//! let mesh = Topology::meshgrid(16); // the paper's 4×4 grid
//! assert!(mesh.is_connected());
//! assert_eq!(mesh.diameter(), 6); // flooding depth D for full consensus
//! // denser graphs gossip faster: complete ≻ meshgrid ≻ ring
//! assert!(Topology::complete(16).spectral_gap() > mesh.spectral_gap());
//! assert!(mesh.spectral_gap() > Topology::ring(16).spectral_gap());
//! ```

use crate::rng::Rng;

/// Undirected graph in adjacency-list form. Nodes are `0..n`.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    pub adj: Vec<Vec<usize>>,
    pub kind: String,
}

/// Named topology kinds accepted by configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ring,
    Meshgrid,
    Torus,
    Complete,
    Star,
    ErdosRenyi,
    SmallWorld,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "ring" => Kind::Ring,
            "meshgrid" | "mesh" | "grid" => Kind::Meshgrid,
            "torus" => Kind::Torus,
            "complete" | "full" => Kind::Complete,
            "star" => Kind::Star,
            "erdos" | "erdos-renyi" | "er" => Kind::ErdosRenyi,
            "smallworld" | "small-world" | "ws" => Kind::SmallWorld,
            _ => return None,
        })
    }

    /// Canonical name: a string [`Kind::parse`] accepts back. This is the
    /// *requested* kind — [`Topology::build`] may still report a different
    /// `Topology::kind` (n = 1 degenerates to "singleton").
    pub fn name(&self) -> &'static str {
        match self {
            Kind::Ring => "ring",
            Kind::Meshgrid => "meshgrid",
            Kind::Torus => "torus",
            Kind::Complete => "complete",
            Kind::Star => "star",
            Kind::ErdosRenyi => "erdos-renyi",
            Kind::SmallWorld => "small-world",
        }
    }
}

impl Topology {
    pub fn build(kind: Kind, n: usize, seed: u64) -> Topology {
        if n == 1 {
            // single-client degenerate graph (Table 3 baselines)
            return Topology { n: 1, adj: vec![vec![]], kind: "singleton".into() };
        }
        match kind {
            Kind::Ring => Self::ring(n),
            Kind::Meshgrid => Self::meshgrid(n),
            Kind::Torus => Self::torus(n),
            Kind::Complete => Self::complete(n),
            Kind::Star => Self::star(n),
            Kind::ErdosRenyi => Self::erdos_renyi(n, seed),
            Kind::SmallWorld => Self::small_world(n, 4, 0.1, seed),
        }
    }

    fn from_edges(n: usize, edges: &[(usize, usize)], kind: &str) -> Topology {
        let mut adj = vec![vec![]; n];
        for &(a, b) in edges {
            assert!(a != b && a < n && b < n, "bad edge ({a},{b}) of {n}");
            if !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        }
        for l in &mut adj {
            l.sort_unstable();
        }
        Topology { n, adj, kind: kind.to_string() }
    }

    /// Cycle over n nodes (the paper's sparsest benchmark topology).
    pub fn ring(n: usize) -> Topology {
        assert!(n >= 2);
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Self::from_edges(n, &edges, "ring")
    }

    /// √n × √n grid without wraparound (paper's "meshgrid"); n must be a
    /// perfect square (all paper sizes 16/32/64/128 → we use the most
    /// square factorization r×c with r·c = n).
    pub fn meshgrid(n: usize) -> Topology {
        let (rows, cols) = most_square_factors(n);
        let mut edges = vec![];
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                if c + 1 < cols {
                    edges.push((i, i + 1));
                }
                if r + 1 < rows {
                    edges.push((i, i + cols));
                }
            }
        }
        Self::from_edges(n, &edges, "meshgrid")
    }

    /// Grid with wraparound.
    pub fn torus(n: usize) -> Topology {
        let (rows, cols) = most_square_factors(n);
        let mut edges = vec![];
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                if cols > 2 || c + 1 < cols {
                    edges.push((i, r * cols + (c + 1) % cols));
                }
                if rows > 2 || r + 1 < rows {
                    edges.push((i, ((r + 1) % rows) * cols + c));
                }
            }
        }
        Self::from_edges(n, &edges, "torus")
    }

    pub fn complete(n: usize) -> Topology {
        let mut edges = vec![];
        for a in 0..n {
            for b in a + 1..n {
                edges.push((a, b));
            }
        }
        Self::from_edges(n, &edges, "complete")
    }

    pub fn star(n: usize) -> Topology {
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Self::from_edges(n, &edges, "star")
    }

    /// G(n, p) with p chosen ≈ 2 ln n / n, re-sampled until connected.
    pub fn erdos_renyi(n: usize, seed: u64) -> Topology {
        let p = (2.0 * (n as f64).ln() / n as f64).min(1.0);
        let mut rng = Rng::new(seed);
        loop {
            let mut edges = vec![];
            for a in 0..n {
                for b in a + 1..n {
                    if rng.next_f64() < p {
                        edges.push((a, b));
                    }
                }
            }
            let t = Self::from_edges(n, &edges, "erdos-renyi");
            if t.is_connected() {
                return t;
            }
        }
    }

    /// Watts–Strogatz: ring lattice with k nearest neighbours, rewired with
    /// probability beta (kept connected by retrying).
    pub fn small_world(n: usize, k: usize, beta: f64, seed: u64) -> Topology {
        let mut rng = Rng::new(seed);
        loop {
            let mut edges = vec![];
            for i in 0..n {
                for d in 1..=k / 2 {
                    let j = (i + d) % n;
                    if rng.next_f64() < beta {
                        // rewire to a uniform non-self target
                        let mut t = rng.next_below(n as u64) as usize;
                        while t == i {
                            t = rng.next_below(n as u64) as usize;
                        }
                        edges.push((i, t));
                    } else {
                        edges.push((i, j));
                    }
                }
            }
            let t = Self::from_edges(n, &edges, "small-world");
            if t.is_connected() {
                return t;
            }
        }
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Whether `a`–`b` is an (undirected) edge. Adjacency lists are kept
    /// sorted, so this is a binary search — used by
    /// [`crate::netcond::NetCond::validate`] to reject fault schedules
    /// that reference links the graph does not have.
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        a < self.n && b < self.n && self.adj[a].binary_search(&b).is_ok()
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// BFS distances from `src`; usize::MAX for unreachable.
    pub fn bfs(&self, src: usize) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.n];
        let mut q = std::collections::VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            for &v in &self.adj[u] {
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }

    pub fn is_connected(&self) -> bool {
        self.n == 0 || self.bfs(0).iter().all(|&d| d != usize::MAX)
    }

    /// Exact diameter (max over all-pairs BFS). Paper: flooding runs for
    /// `D` steps so every message reaches every client within an iteration.
    pub fn diameter(&self) -> usize {
        (0..self.n)
            .map(|s| self.bfs(s).into_iter().max().unwrap())
            .max()
            .unwrap_or(0)
    }

    /// Metropolis–Hastings mixing weights: symmetric, doubly stochastic —
    /// the standard `w_ij` for DSGD/ChocoSGD (Eq. 2). Row i: weight for
    /// each neighbor j is 1/(1+max(deg_i,deg_j)); self-weight is the rest.
    pub fn mixing_weights(&self) -> Vec<Vec<(usize, f32)>> {
        (0..self.n)
            .map(|i| {
                let mut row: Vec<(usize, f32)> = self.adj[i]
                    .iter()
                    .map(|&j| {
                        (j, 1.0 / (1 + self.degree(i).max(self.degree(j))) as f32)
                    })
                    .collect();
                let others: f32 = row.iter().map(|&(_, w)| w).sum();
                row.push((i, 1.0 - others));
                row.sort_unstable_by_key(|&(j, _)| j);
                row
            })
            .collect()
    }

    /// Spectral gap `1 - λ₂(W)` of the mixing matrix, estimated by power
    /// iteration on the space orthogonal to 𝟙. Larger gap ⇒ faster gossip
    /// consensus; the paper's information-decay argument is about this
    /// quantity shrinking on large/sparse graphs.
    pub fn spectral_gap(&self) -> f64 {
        let w = self.mixing_weights();
        let n = self.n;
        if n < 2 {
            return 1.0;
        }
        let mut x: Vec<f64> =
            (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 - 0.5).collect();
        let mut lambda = 0.0;
        for _ in 0..500 {
            // project out the all-ones direction
            let mean = x.iter().sum::<f64>() / n as f64;
            for v in &mut x {
                *v -= mean;
            }
            let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm < 1e-12 {
                return 1.0;
            }
            for v in &mut x {
                *v /= norm;
            }
            let mut y = vec![0.0; n];
            for i in 0..n {
                for &(j, wij) in &w[i] {
                    y[i] += wij as f64 * x[j];
                }
            }
            lambda = x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>();
            x = y;
        }
        1.0 - lambda.abs()
    }
}

/// Factor n as r×c with r ≤ c and r as large as possible.
fn most_square_factors(n: usize) -> (usize, usize) {
    let mut r = (n as f64).sqrt() as usize;
    while r > 1 && n % r != 0 {
        r -= 1;
    }
    (r.max(1), n / r.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let t = Topology::ring(8);
        assert_eq!(t.num_edges(), 8);
        assert!(t.adj.iter().all(|l| l.len() == 2));
        assert_eq!(t.diameter(), 4);
        assert!(t.is_connected());
    }

    #[test]
    fn ring_n2() {
        let t = Topology::ring(2);
        assert_eq!(t.num_edges(), 1);
        assert_eq!(t.diameter(), 1);
    }

    #[test]
    fn meshgrid_16_is_4x4() {
        let t = Topology::meshgrid(16);
        assert_eq!(t.num_edges(), 2 * 4 * 3);
        assert_eq!(t.diameter(), 6);
    }

    #[test]
    fn meshgrid_non_square() {
        // 32 -> 4x8 grid
        let t = Topology::meshgrid(32);
        assert!(t.is_connected());
        assert_eq!(t.diameter(), 3 + 7);
    }

    #[test]
    fn torus_diameter_smaller_than_grid() {
        assert!(Topology::torus(16).diameter() < Topology::meshgrid(16).diameter());
    }

    #[test]
    fn complete_diameter_1() {
        let t = Topology::complete(10);
        assert_eq!(t.diameter(), 1);
        assert_eq!(t.num_edges(), 45);
    }

    #[test]
    fn star_diameter_2() {
        assert_eq!(Topology::star(9).diameter(), 2);
    }

    #[test]
    fn erdos_connected() {
        for seed in 0..5 {
            assert!(Topology::erdos_renyi(24, seed).is_connected());
        }
    }

    #[test]
    fn small_world_connected() {
        assert!(Topology::small_world(32, 4, 0.1, 1).is_connected());
    }

    #[test]
    fn mh_weights_doubly_stochastic() {
        for t in [Topology::ring(8), Topology::meshgrid(16), Topology::star(6)] {
            let w = t.mixing_weights();
            // rows sum to 1
            for row in &w {
                let s: f32 = row.iter().map(|&(_, x)| x).sum();
                assert!((s - 1.0).abs() < 1e-6);
                assert!(row.iter().all(|&(_, x)| x >= -1e-7));
            }
            // symmetry w_ij == w_ji
            for (i, row) in w.iter().enumerate() {
                for &(j, wij) in row {
                    let wji = w[j].iter().find(|&&(k, _)| k == i).unwrap().1;
                    assert!((wij - wji).abs() < 1e-7);
                }
            }
        }
    }

    #[test]
    fn spectral_gap_ordering() {
        // complete graph mixes faster than meshgrid, which beats ring
        let ring = Topology::ring(16).spectral_gap();
        let mesh = Topology::meshgrid(16).spectral_gap();
        let full = Topology::complete(16).spectral_gap();
        assert!(full > mesh && mesh > ring, "{full} {mesh} {ring}");
    }

    #[test]
    fn singleton_for_one_client() {
        let t = Topology::build(Kind::Ring, 1, 0);
        assert_eq!(t.n, 1);
        assert_eq!(t.num_edges(), 0);
        assert_eq!(t.diameter(), 0);
        assert!(t.is_connected());
    }

    #[test]
    fn has_edge_matches_adjacency() {
        let t = Topology::meshgrid(9);
        for a in 0..9 {
            for b in 0..9 {
                assert_eq!(t.has_edge(a, b), t.neighbors(a).contains(&b), "({a},{b})");
            }
        }
        assert!(!t.has_edge(0, 12)); // out of range is false, not a panic
    }

    #[test]
    fn kind_parse() {
        assert_eq!(Kind::parse("ring"), Some(Kind::Ring));
        assert_eq!(Kind::parse("mesh"), Some(Kind::Meshgrid));
        assert_eq!(Kind::parse("nope"), None);
    }
}
