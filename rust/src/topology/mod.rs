//! Network topologies for decentralized training (paper §2.1, §4.1).
//!
//! The communication structure is an undirected, connected, static graph
//! `G = (V, E)`; clients talk only to `N(i)`.  The paper evaluates ring and
//! meshgrid; we additionally provide torus, complete, star, Erdős–Rényi,
//! Watts–Strogatz small-world, Barabási–Albert scale-free, hierarchical
//! cluster-of-rings and hub-and-spoke graphs for ablations and
//! massive-scale runs, plus the graph quantities the algorithms need: BFS
//! diameter, Metropolis–Hastings mixing weights (doubly-stochastic, the
//! `w_ij` of Eq. 2) and a spectral-gap estimate (consensus-rate
//! diagnostic).
//!
//! Construction is O(m): edge lists are deduplicated by sort+dedup, G(n,p)
//! uses Batagelj–Brandes geometric skip sampling, and preferential
//! attachment uses the repeated-nodes target list.  [`Topology::diameter`]
//! is exact all-pairs BFS up to [`EXACT_DIAMETER_LIMIT`] nodes and a
//! certified double-sweep/iFUB-style upper bound beyond that (never an
//! underestimate, so flooding still covers the graph).
//!
//! ```
//! use seedflood::topology::Topology;
//!
//! let mesh = Topology::meshgrid(16); // the paper's 4×4 grid
//! assert!(mesh.is_connected());
//! assert_eq!(mesh.diameter(), 6); // flooding depth D for full consensus
//! // denser graphs gossip faster: complete ≻ meshgrid ≻ ring
//! assert!(Topology::complete(16).spectral_gap() > mesh.spectral_gap());
//! assert!(mesh.spectral_gap() > Topology::ring(16).spectral_gap());
//! ```

use crate::rng::Rng;

/// Undirected graph in adjacency-list form. Nodes are `0..n`.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    pub adj: Vec<Vec<usize>>,
    pub kind: String,
}

/// Named topology kinds accepted by configs / CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ring,
    Meshgrid,
    Torus,
    Complete,
    Star,
    ErdosRenyi,
    SmallWorld,
    ScaleFree,
    Hierarchical,
    HubSpoke,
}

impl Kind {
    pub fn parse(s: &str) -> Option<Kind> {
        Some(match s {
            "ring" => Kind::Ring,
            "meshgrid" | "mesh" | "grid" => Kind::Meshgrid,
            "torus" => Kind::Torus,
            "complete" | "full" => Kind::Complete,
            "star" => Kind::Star,
            "erdos" | "erdos-renyi" | "er" => Kind::ErdosRenyi,
            "smallworld" | "small-world" | "ws" => Kind::SmallWorld,
            "scalefree" | "scale-free" | "ba" => Kind::ScaleFree,
            "hierarchical" | "hier" | "clusters" => Kind::Hierarchical,
            "hubspoke" | "hub-spoke" | "hub" => Kind::HubSpoke,
            _ => return None,
        })
    }

    /// Canonical name: a string [`Kind::parse`] accepts back. This is the
    /// *requested* kind — [`Topology::build`] may still report a different
    /// `Topology::kind` (n = 1 degenerates to "singleton").
    pub fn name(&self) -> &'static str {
        match self {
            Kind::Ring => "ring",
            Kind::Meshgrid => "meshgrid",
            Kind::Torus => "torus",
            Kind::Complete => "complete",
            Kind::Star => "star",
            Kind::ErdosRenyi => "erdos-renyi",
            Kind::SmallWorld => "small-world",
            Kind::ScaleFree => "scale-free",
            Kind::Hierarchical => "hierarchical",
            Kind::HubSpoke => "hub-spoke",
        }
    }
}

/// Largest n for which [`Topology::diameter`] computes the exact all-pairs
/// BFS diameter; beyond it the certified upper bound from
/// [`Topology::diameter_bounds`] is used.
pub const EXACT_DIAMETER_LIMIT: usize = 1024;

impl Topology {
    pub fn build(kind: Kind, n: usize, seed: u64) -> Topology {
        if n == 1 {
            // single-client degenerate graph (Table 3 baselines)
            return Topology { n: 1, adj: vec![vec![]], kind: "singleton".into() };
        }
        match kind {
            Kind::Ring => Self::ring(n),
            Kind::Meshgrid => Self::meshgrid(n),
            Kind::Torus => Self::torus(n),
            Kind::Complete => Self::complete(n),
            Kind::Star => Self::star(n),
            Kind::ErdosRenyi => Self::erdos_renyi(n, seed),
            Kind::SmallWorld => Self::small_world(n, 4, 0.1, seed),
            Kind::ScaleFree => Self::scale_free(n, 2, seed),
            Kind::Hierarchical => Self::hierarchical(n),
            Kind::HubSpoke => Self::hub_spoke(n),
        }
    }

    /// Build from an undirected edge list, deduplicating repeats in either
    /// orientation. Sort+dedup over normalized pairs — O(m log m), with no
    /// per-edge `contains` scan (which made dense generators O(m·deg)).
    fn from_edges(n: usize, edges: &[(usize, usize)], kind: &str) -> Topology {
        let mut norm: Vec<(usize, usize)> = edges
            .iter()
            .map(|&(a, b)| {
                assert!(a != b && a < n && b < n, "bad edge ({a},{b}) of {n}");
                (a.min(b), a.max(b))
            })
            .collect();
        norm.sort_unstable();
        norm.dedup();
        let mut deg = vec![0usize; n];
        for &(a, b) in &norm {
            deg[a] += 1;
            deg[b] += 1;
        }
        let mut adj: Vec<Vec<usize>> = deg.iter().map(|&d| Vec::with_capacity(d)).collect();
        for &(a, b) in &norm {
            adj[a].push(b);
            adj[b].push(a);
        }
        for l in &mut adj {
            l.sort_unstable();
        }
        Topology { n, adj, kind: kind.to_string() }
    }

    /// Cycle over n nodes (the paper's sparsest benchmark topology).
    pub fn ring(n: usize) -> Topology {
        assert!(n >= 2);
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Self::from_edges(n, &edges, "ring")
    }

    /// √n × √n grid without wraparound (paper's "meshgrid"); n must be a
    /// perfect square (all paper sizes 16/32/64/128 → we use the most
    /// square factorization r×c with r·c = n).
    pub fn meshgrid(n: usize) -> Topology {
        let (rows, cols) = most_square_factors(n);
        let mut edges = vec![];
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                if c + 1 < cols {
                    edges.push((i, i + 1));
                }
                if r + 1 < rows {
                    edges.push((i, i + cols));
                }
            }
        }
        Self::from_edges(n, &edges, "meshgrid")
    }

    /// Grid with wraparound.
    pub fn torus(n: usize) -> Topology {
        let (rows, cols) = most_square_factors(n);
        let mut edges = vec![];
        for r in 0..rows {
            for c in 0..cols {
                let i = r * cols + c;
                if cols > 2 || c + 1 < cols {
                    edges.push((i, r * cols + (c + 1) % cols));
                }
                if rows > 2 || r + 1 < rows {
                    edges.push((i, ((r + 1) % rows) * cols + c));
                }
            }
        }
        Self::from_edges(n, &edges, "torus")
    }

    pub fn complete(n: usize) -> Topology {
        let mut edges = vec![];
        for a in 0..n {
            for b in a + 1..n {
                edges.push((a, b));
            }
        }
        Self::from_edges(n, &edges, "complete")
    }

    pub fn star(n: usize) -> Topology {
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Self::from_edges(n, &edges, "star")
    }

    /// G(n, p) with p chosen ≈ 2 ln n / n, re-sampled until connected.
    /// Batagelj–Brandes geometric skip sampling: O(n + m) expected draws
    /// instead of the n(n−1)/2 Bernoulli trials of the naive sampler.
    pub fn erdos_renyi(n: usize, seed: u64) -> Topology {
        let p = (2.0 * (n as f64).ln() / n as f64).min(1.0);
        let mut rng = Rng::new(seed);
        loop {
            let t = Self::from_edges(n, &gnp_edges(n, p, &mut rng), "erdos-renyi");
            if t.is_connected() {
                return t;
            }
        }
    }

    /// Barabási–Albert scale-free graph: each new node attaches `m` edges
    /// preferentially (P ∝ degree) via the repeated-nodes target list —
    /// every node appears once per unit of degree, so a uniform draw from
    /// the list is degree-proportional. O(m·n) total; connected by
    /// construction (growth from an (m+1)-clique), power-law degree tail.
    pub fn scale_free(n: usize, m: usize, seed: u64) -> Topology {
        assert!(n >= 2);
        let m = m.clamp(1, n - 1);
        let mut rng = Rng::new(seed);
        let mut edges: Vec<(usize, usize)> = Vec::with_capacity(m * n);
        let mut targets: Vec<u32> = Vec::with_capacity(2 * m * n);
        for a in 0..=m {
            for b in a + 1..=m {
                edges.push((a, b));
                targets.push(a as u32);
                targets.push(b as u32);
            }
        }
        let mut picked: Vec<usize> = Vec::with_capacity(m);
        for v in m + 1..n {
            // m distinct degree-proportional targets; rejection of repeats
            // is O(1) expected since m ≪ Σdeg
            picked.clear();
            while picked.len() < m {
                let t = targets[rng.next_below(targets.len() as u64) as usize] as usize;
                if !picked.contains(&t) {
                    picked.push(t);
                }
            }
            for &t in &picked {
                edges.push((t, v));
                targets.push(t as u32);
                targets.push(v as u32);
            }
        }
        Self::from_edges(n, &edges, "scale-free")
    }

    /// Hierarchical cluster-of-rings: ~√n local rings of ~√n clients whose
    /// gateway nodes (each cluster's first member) form a top-level ring —
    /// the shape of region/rack-organized deployments. Deterministic,
    /// O(n) edges, diameter Θ(√n).
    pub fn hierarchical(n: usize) -> Topology {
        assert!(n >= 2);
        let clusters = (n as f64).sqrt().ceil() as usize;
        let base = n / clusters;
        let extra = n % clusters; // first `extra` clusters get one more node
        let mut edges = Vec::with_capacity(n + clusters);
        let mut gateways = Vec::with_capacity(clusters);
        let mut start = 0;
        for c in 0..clusters {
            let size = base + usize::from(c < extra);
            gateways.push(start);
            for k in 0..size {
                // ring within the cluster (a 2-ring is a single edge)
                if size >= 2 && (size > 2 || k == 0) {
                    edges.push((start + k, start + (k + 1) % size));
                }
            }
            start += size;
        }
        for (c, &g) in gateways.iter().enumerate() {
            if clusters >= 2 && (clusters > 2 || c == 0) {
                edges.push((g, gateways[(c + 1) % clusters]));
            }
        }
        Self::from_edges(n, &edges, "hierarchical")
    }

    /// Hub-and-spoke: ~√n hubs in a clique, every other node a leaf
    /// attached round-robin to one hub — the centralized extreme of the
    /// family. Deterministic, O(n) edges, diameter ≤ 3 at any scale.
    pub fn hub_spoke(n: usize) -> Topology {
        assert!(n >= 2);
        let hubs = ((n as f64).sqrt().ceil() as usize).min(n);
        let mut edges = Vec::with_capacity(hubs * hubs / 2 + n);
        for a in 0..hubs {
            for b in a + 1..hubs {
                edges.push((a, b));
            }
        }
        for v in hubs..n {
            edges.push(((v - hubs) % hubs, v));
        }
        Self::from_edges(n, &edges, "hub-spoke")
    }

    /// Watts–Strogatz: ring lattice with k nearest neighbours, rewired with
    /// probability beta (kept connected by retrying).
    pub fn small_world(n: usize, k: usize, beta: f64, seed: u64) -> Topology {
        let mut rng = Rng::new(seed);
        loop {
            let mut edges = vec![];
            for i in 0..n {
                for d in 1..=k / 2 {
                    let j = (i + d) % n;
                    if rng.next_f64() < beta {
                        // rewire to a uniform non-self target
                        let mut t = rng.next_below(n as u64) as usize;
                        while t == i {
                            t = rng.next_below(n as u64) as usize;
                        }
                        edges.push((i, t));
                    } else {
                        edges.push((i, j));
                    }
                }
            }
            let t = Self::from_edges(n, &edges, "small-world");
            if t.is_connected() {
                return t;
            }
        }
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Whether `a`–`b` is an (undirected) edge. Adjacency lists are kept
    /// sorted, so this is a binary search — used by
    /// [`crate::netcond::NetCond::validate`] to reject fault schedules
    /// that reference links the graph does not have.
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        a < self.n && b < self.n && self.adj[a].binary_search(&b).is_ok()
    }

    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// BFS distances from `src`; usize::MAX for unreachable.
    pub fn bfs(&self, src: usize) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.n];
        let mut q = std::collections::VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            for &v in &self.adj[u] {
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }

    pub fn is_connected(&self) -> bool {
        self.n == 0 || self.bfs(0).iter().all(|&d| d != usize::MAX)
    }

    /// Flood depth D (paper: flooding runs for `D` steps so every message
    /// reaches every client within an iteration). Exact all-pairs BFS for
    /// n ≤ [`EXACT_DIAMETER_LIMIT`]; beyond that, the certified upper
    /// bound from [`Topology::diameter_bounds`] — an overestimate at worst
    /// (never under-floods), computed in O(k·(n+m)) for a small sweep
    /// budget k instead of O(n·(n+m)).
    pub fn diameter(&self) -> usize {
        if self.n <= EXACT_DIAMETER_LIMIT {
            self.diameter_exact()
        } else {
            self.diameter_bounds().1
        }
    }

    /// Exact diameter (max over all-pairs BFS), O(n·(n+m)) — ground truth
    /// for [`Topology::diameter_bounds`] and small graphs.
    pub fn diameter_exact(&self) -> usize {
        (0..self.n)
            .map(|s| self.bfs(s).into_iter().max().unwrap())
            .max()
            .unwrap_or(0)
    }

    /// Certified diameter bounds `(lb, ub)` with `lb ≤ D ≤ ub`, from a
    /// constant number of BFS sweeps (double-sweep / iFUB style):
    /// eccentricities of sweep endpoints lower-bound D; twice the
    /// eccentricity of a shortest-path midpoint upper-bounds it
    /// (`d(x,y) ≤ d(x,mid) + d(mid,y) ≤ 2·ecc(mid)`). Panics on a
    /// disconnected graph (eccentricities are infinite there).
    pub fn diameter_bounds(&self) -> (usize, usize) {
        if self.n <= 1 {
            return (0, 0);
        }
        let bfs_ecc = |s: usize| -> (Vec<usize>, usize, usize) {
            let d = self.bfs(s);
            let (mut e, mut far) = (0, s);
            for (v, &dv) in d.iter().enumerate() {
                assert!(dv != usize::MAX, "diameter_bounds on a disconnected graph");
                if dv > e {
                    e = dv;
                    far = v;
                }
            }
            (d, e, far)
        };
        // iFUB's heuristic root: sweeps from a max-degree vertex land on
        // peripheral vertices fast
        let root = (0..self.n).max_by_key(|&v| self.adj[v].len()).unwrap();
        let (_, e_root, mut a) = bfs_ecc(root);
        let mut lb = e_root;
        let mut ub = 2 * e_root;
        for _ in 0..3 {
            let (da, ea, b) = bfs_ecc(a);
            lb = lb.max(ea);
            let (db, eb, _) = bfs_ecc(b);
            lb = lb.max(eb);
            // midpoint: a vertex on a shortest a–b path (d_a + d_b = d(a,b))
            // as close to halfway as possible
            let mut mid = a;
            let mut best = usize::MAX;
            for (v, (&dav, &dbv)) in da.iter().zip(&db).enumerate() {
                if dav + dbv == ea {
                    let off = dav.abs_diff(ea / 2);
                    if off < best {
                        best = off;
                        mid = v;
                    }
                }
            }
            let (_, em, next) = bfs_ecc(mid);
            lb = lb.max(em);
            ub = ub.min(2 * em);
            if lb == ub {
                break;
            }
            a = next; // restart the sweep from the midpoint's periphery
        }
        (lb, ub)
    }

    /// Metropolis–Hastings mixing weights: symmetric, doubly stochastic —
    /// the standard `w_ij` for DSGD/ChocoSGD (Eq. 2). Row i: weight for
    /// each neighbor j is 1/(1+max(deg_i,deg_j)); self-weight is the rest.
    pub fn mixing_weights(&self) -> Vec<Vec<(usize, f32)>> {
        (0..self.n)
            .map(|i| {
                let mut row: Vec<(usize, f32)> = self.adj[i]
                    .iter()
                    .map(|&j| {
                        (j, 1.0 / (1 + self.degree(i).max(self.degree(j))) as f32)
                    })
                    .collect();
                let others: f32 = row.iter().map(|&(_, w)| w).sum();
                row.push((i, 1.0 - others));
                row.sort_unstable_by_key(|&(j, _)| j);
                row
            })
            .collect()
    }

    /// Spectral gap `1 - λ₂(W)` of the mixing matrix, estimated by power
    /// iteration on the space orthogonal to 𝟙. Larger gap ⇒ faster gossip
    /// consensus; the paper's information-decay argument is about this
    /// quantity shrinking on large/sparse graphs.
    pub fn spectral_gap(&self) -> f64 {
        let w = self.mixing_weights();
        let n = self.n;
        if n < 2 {
            return 1.0;
        }
        let mut x: Vec<f64> =
            (0..n).map(|i| ((i * 2654435761) % 1000) as f64 / 1000.0 - 0.5).collect();
        let mut lambda = 0.0;
        for _ in 0..500 {
            // project out the all-ones direction
            let mean = x.iter().sum::<f64>() / n as f64;
            for v in &mut x {
                *v -= mean;
            }
            let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm < 1e-12 {
                return 1.0;
            }
            for v in &mut x {
                *v /= norm;
            }
            let mut y = vec![0.0; n];
            for i in 0..n {
                for &(j, wij) in &w[i] {
                    y[i] += wij as f64 * x[j];
                }
            }
            lambda = x.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>();
            x = y;
        }
        1.0 - lambda.abs()
    }
}

/// Sample the edge set of G(n, p) by Batagelj–Brandes geometric skip
/// sampling: walk the linearized upper triangle jumping `1 + ⌊ln(1−r) /
/// ln(1−p)⌋` cells per draw — one RNG draw per *edge* (plus O(n) row
/// crossings), not per pair.
fn gnp_edges(n: usize, p: f64, rng: &mut Rng) -> Vec<(usize, usize)> {
    if p <= 0.0 || n < 2 {
        return vec![];
    }
    if p >= 1.0 {
        return (0..n).flat_map(|a| (a + 1..n).map(move |b| (a, b))).collect();
    }
    let lq = (1.0 - p).ln(); // < 0
    let expect = (p * (n * (n - 1) / 2) as f64) as usize;
    let mut edges = Vec::with_capacity(expect + expect / 8 + 16);
    let mut v: usize = 1;
    let mut w: i64 = -1;
    while v < n {
        // 1 − r ∈ (0, 1], so the skip is a non-negative integer
        let skip = ((1.0 - rng.next_f64()).ln() / lq) as i64;
        w += 1 + skip;
        while w >= v as i64 && v < n {
            w -= v as i64;
            v += 1;
        }
        if v < n {
            edges.push((w as usize, v));
        }
    }
    edges
}

/// Factor n as r×c with r ≤ c and r as large as possible.
fn most_square_factors(n: usize) -> (usize, usize) {
    let mut r = (n as f64).sqrt() as usize;
    while r > 1 && n % r != 0 {
        r -= 1;
    }
    (r.max(1), n / r.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let t = Topology::ring(8);
        assert_eq!(t.num_edges(), 8);
        assert!(t.adj.iter().all(|l| l.len() == 2));
        assert_eq!(t.diameter(), 4);
        assert!(t.is_connected());
    }

    #[test]
    fn ring_n2() {
        let t = Topology::ring(2);
        assert_eq!(t.num_edges(), 1);
        assert_eq!(t.diameter(), 1);
    }

    #[test]
    fn meshgrid_16_is_4x4() {
        let t = Topology::meshgrid(16);
        assert_eq!(t.num_edges(), 2 * 4 * 3);
        assert_eq!(t.diameter(), 6);
    }

    #[test]
    fn meshgrid_non_square() {
        // 32 -> 4x8 grid
        let t = Topology::meshgrid(32);
        assert!(t.is_connected());
        assert_eq!(t.diameter(), 3 + 7);
    }

    #[test]
    fn torus_diameter_smaller_than_grid() {
        assert!(Topology::torus(16).diameter() < Topology::meshgrid(16).diameter());
    }

    #[test]
    fn complete_diameter_1() {
        let t = Topology::complete(10);
        assert_eq!(t.diameter(), 1);
        assert_eq!(t.num_edges(), 45);
    }

    #[test]
    fn star_diameter_2() {
        assert_eq!(Topology::star(9).diameter(), 2);
    }

    #[test]
    fn erdos_connected() {
        for seed in 0..5 {
            assert!(Topology::erdos_renyi(24, seed).is_connected());
        }
    }

    #[test]
    fn small_world_connected() {
        assert!(Topology::small_world(32, 4, 0.1, 1).is_connected());
    }

    #[test]
    fn mh_weights_doubly_stochastic() {
        for t in [Topology::ring(8), Topology::meshgrid(16), Topology::star(6)] {
            let w = t.mixing_weights();
            // rows sum to 1
            for row in &w {
                let s: f32 = row.iter().map(|&(_, x)| x).sum();
                assert!((s - 1.0).abs() < 1e-6);
                assert!(row.iter().all(|&(_, x)| x >= -1e-7));
            }
            // symmetry w_ij == w_ji
            for (i, row) in w.iter().enumerate() {
                for &(j, wij) in row {
                    let wji = w[j].iter().find(|&&(k, _)| k == i).unwrap().1;
                    assert!((wij - wji).abs() < 1e-7);
                }
            }
        }
    }

    #[test]
    fn spectral_gap_ordering() {
        // complete graph mixes faster than meshgrid, which beats ring
        let ring = Topology::ring(16).spectral_gap();
        let mesh = Topology::meshgrid(16).spectral_gap();
        let full = Topology::complete(16).spectral_gap();
        assert!(full > mesh && mesh > ring, "{full} {mesh} {ring}");
    }

    #[test]
    fn singleton_for_one_client() {
        let t = Topology::build(Kind::Ring, 1, 0);
        assert_eq!(t.n, 1);
        assert_eq!(t.num_edges(), 0);
        assert_eq!(t.diameter(), 0);
        assert!(t.is_connected());
    }

    #[test]
    fn has_edge_matches_adjacency() {
        let t = Topology::meshgrid(9);
        for a in 0..9 {
            for b in 0..9 {
                assert_eq!(t.has_edge(a, b), t.neighbors(a).contains(&b), "({a},{b})");
            }
        }
        assert!(!t.has_edge(0, 12)); // out of range is false, not a panic
    }

    #[test]
    fn kind_parse() {
        assert_eq!(Kind::parse("ring"), Some(Kind::Ring));
        assert_eq!(Kind::parse("mesh"), Some(Kind::Meshgrid));
        assert_eq!(Kind::parse("scale-free"), Some(Kind::ScaleFree));
        assert_eq!(Kind::parse("ba"), Some(Kind::ScaleFree));
        assert_eq!(Kind::parse("hierarchical"), Some(Kind::Hierarchical));
        assert_eq!(Kind::parse("hub-spoke"), Some(Kind::HubSpoke));
        assert_eq!(Kind::parse("nope"), None);
    }

    #[test]
    fn kind_name_roundtrips_through_parse() {
        for k in [
            Kind::Ring, Kind::Meshgrid, Kind::Torus, Kind::Complete, Kind::Star,
            Kind::ErdosRenyi, Kind::SmallWorld, Kind::ScaleFree, Kind::Hierarchical,
            Kind::HubSpoke,
        ] {
            assert_eq!(Kind::parse(k.name()), Some(k), "{}", k.name());
        }
    }

    #[test]
    fn from_edges_dedups_both_orientations() {
        let t = Topology::from_edges(3, &[(0, 1), (1, 0), (0, 1), (1, 2)], "t");
        assert_eq!(t.num_edges(), 2);
        assert_eq!(t.neighbors(1), &[0, 2]);
    }

    #[test]
    fn scale_free_connected_heavy_tail_and_deterministic() {
        let t = Topology::scale_free(2000, 2, 7);
        assert!(t.is_connected());
        // m edges per new node (minus clique overlap dedup is impossible:
        // targets are distinct), so |E| = C(3,2) + 2·(n-3)
        assert_eq!(t.num_edges(), 3 + 2 * (2000 - 3));
        // preferential attachment concentrates degree: the hub's degree
        // dwarfs the mean (≈ 4) — the power-law tail in one number
        let mean = 2.0 * t.num_edges() as f64 / t.n as f64;
        assert!(
            t.max_degree() as f64 > 8.0 * mean,
            "no heavy tail: max {} mean {mean:.1}",
            t.max_degree()
        );
        let t2 = Topology::scale_free(2000, 2, 7);
        assert_eq!(t.adj, t2.adj);
        assert_ne!(t.adj, Topology::scale_free(2000, 2, 8).adj);
    }

    #[test]
    fn hierarchical_structure() {
        let t = Topology::hierarchical(100);
        assert!(t.is_connected());
        // ring-in-ring: local degree 2, gateways at most 4
        assert!(t.max_degree() <= 4, "max degree {}", t.max_degree());
        // Θ(√n) diameter: two half-rings of ~√n each
        let d = t.diameter();
        assert!(d >= 5 && d <= 30, "diameter {d}");
        assert_eq!(t.adj, Topology::hierarchical(100).adj);
    }

    #[test]
    fn hub_spoke_short_diameter() {
        let t = Topology::hub_spoke(1000);
        assert!(t.is_connected());
        assert!(t.diameter() <= 3, "diameter {}", t.diameter());
        // every leaf has degree 1; hubs carry clique + leaf share
        let hubs = (1000f64).sqrt().ceil() as usize;
        assert!((hubs..1000).all(|v| t.degree(v) == 1));
        assert!(t.max_degree() >= hubs - 1);
    }

    #[test]
    fn small_ns_construct_for_every_kind() {
        for k in [
            Kind::Ring, Kind::Meshgrid, Kind::Torus, Kind::Complete, Kind::Star,
            Kind::ErdosRenyi, Kind::SmallWorld, Kind::ScaleFree, Kind::Hierarchical,
            Kind::HubSpoke,
        ] {
            for n in [1usize, 2, 3, 5, 8] {
                let t = Topology::build(k, n, 3);
                assert!(t.is_connected(), "{} n={n}", k.name());
                assert_eq!(t.n, n);
            }
        }
    }

    #[test]
    fn gnp_skip_sampler_matches_expected_density() {
        let n = 400;
        let p = 0.05;
        let mut rng = Rng::new(11);
        let edges = gnp_edges(n, p, &mut rng);
        let pairs = (n * (n - 1) / 2) as f64;
        let got = edges.len() as f64 / pairs;
        assert!((got - p).abs() < 0.01, "density {got:.4} vs p={p}");
        // all edges in range, upper-triangular, unique
        let mut seen = std::collections::HashSet::new();
        for &(a, b) in &edges {
            assert!(a < b && b < n);
            assert!(seen.insert((a, b)));
        }
        // degenerate ps
        assert!(gnp_edges(10, 0.0, &mut rng).is_empty());
        assert_eq!(gnp_edges(10, 1.0, &mut rng).len(), 45);
    }

    #[test]
    fn diameter_bounds_sandwich_exact_for_every_kind() {
        // the acceptance contract: lb ≤ D ≤ ub on every kind, across sizes
        // up to EXACT_DIAMETER_LIMIT (sparse kinds; dense kinds capped so
        // the exact all-pairs reference stays fast)
        for k in [
            Kind::Ring, Kind::Meshgrid, Kind::Torus, Kind::Star, Kind::ErdosRenyi,
            Kind::SmallWorld, Kind::ScaleFree, Kind::Hierarchical, Kind::HubSpoke,
        ] {
            for n in [2usize, 3, 17, 64, 257, EXACT_DIAMETER_LIMIT] {
                let t = Topology::build(k, n, 5);
                let exact = t.diameter_exact();
                let (lb, ub) = t.diameter_bounds();
                assert!(
                    lb <= exact && exact <= ub,
                    "{} n={n}: bounds [{lb},{ub}] miss exact {exact}",
                    k.name()
                );
                // diameter() takes the exact path at these sizes
                assert_eq!(t.diameter(), exact, "{} n={n}", k.name());
            }
        }
        for n in [2usize, 17, 128] {
            let t = Topology::complete(n);
            let (lb, ub) = t.diameter_bounds();
            assert!(lb <= 1 && ub >= 1 && lb <= ub);
        }
    }

    #[test]
    fn diameter_switches_representation_exactly_at_the_limit() {
        // the n = 1023 / 1024 / 1025 boundary: diameter() must take the
        // exact all-pairs path up to EXACT_DIAMETER_LIMIT inclusive and
        // the certified upper bound strictly above it — on a long-diameter
        // kind (ring: estimate and exact can disagree) and a clustered
        // one (hierarchical: the hopgrid families cross this boundary)
        for build in [Topology::ring, Topology::hierarchical] {
            for n in [EXACT_DIAMETER_LIMIT - 1, EXACT_DIAMETER_LIMIT] {
                let t = build(n);
                assert_eq!(t.diameter(), t.diameter_exact(), "{} n={n}", t.kind);
            }
            let t = build(EXACT_DIAMETER_LIMIT + 1);
            let (lb, ub) = t.diameter_bounds();
            assert_eq!(t.diameter(), ub, "{} above the limit", t.kind);
            let exact = t.diameter_exact();
            assert!(lb <= exact && exact <= ub, "{}: [{lb},{ub}] miss {exact}", t.kind);
        }
        // ring bounds happen to be tight (a sweep endpoint realizes the
        // diameter), so the switch is invisible there — which is the
        // acceptance property: never an underestimate either side
        let r = Topology::ring(EXACT_DIAMETER_LIMIT + 1);
        assert!(r.diameter() >= r.diameter_exact());
    }

    #[test]
    fn diameter_estimate_used_above_exact_limit_is_safe() {
        // above the cutoff, diameter() must return a certified ≥-D value
        let t = Topology::hierarchical(EXACT_DIAMETER_LIMIT + 500);
        let exact = t.diameter_exact(); // still affordable on a sparse graph
        let d = t.diameter();
        assert!(d >= exact, "estimate {d} under-floods exact {exact}");
        let (lb, ub) = t.diameter_bounds();
        assert!(lb <= exact && exact <= ub);
    }
}
