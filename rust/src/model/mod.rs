//! Rust-side model description: the AOT manifest and the parameter store.
//!
//! `python -m compile.aot` emits `<cfg>_manifest.json` describing the model
//! config, the canonical parameter order (the python↔rust ABI) and every
//! artifact's input/output signature. This module parses it and provides
//! [`ParamStore`]: initialization, LoRA adapter vectors, and the 2D-subset
//! view SubCGE operates on.

use anyhow::{bail, Context, Result};

use crate::rng::Rng;
use crate::tensor::{ParamVec, Tensor};
use crate::util::json::Json;

/// One named tensor signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Input/output signature entry of one artifact.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

/// One HLO artifact as described by the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub tag: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The model configuration the artifacts were lowered for.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub batch: usize,
    pub num_classes: usize,
    pub lora_rank: usize,
    pub subcge_rank: usize,
    pub num_params: usize,
}

/// Parsed `<cfg>_manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub params: Vec<TensorSpec>,
    pub lora_params: Vec<TensorSpec>,
    /// names of 2D params, in subcge-artifact order
    pub params2d: Vec<String>,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path}"))?;
        Self::parse(&text).with_context(|| format!("parsing manifest {path}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let c = j.get("config")?;
        let config = ModelConfig {
            name: c.get("name")?.as_str()?.to_string(),
            vocab: c.get("vocab")?.as_usize()?,
            seq: c.get("seq")?.as_usize()?,
            dim: c.get("dim")?.as_usize()?,
            layers: c.get("layers")?.as_usize()?,
            heads: c.get("heads")?.as_usize()?,
            batch: c.get("batch")?.as_usize()?,
            num_classes: c.get("num_classes")?.as_usize()?,
            lora_rank: c.get("lora_rank")?.as_usize()?,
            subcge_rank: c.get("subcge_rank")?.as_usize()?,
            num_params: c.get("num_params")?.as_usize()?,
        };
        let tensor_specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(TensorSpec {
                        name: e.get("name")?.as_str()?.to_string(),
                        shape: e
                            .get("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<_>>()?,
                    })
                })
                .collect()
        };
        let params = tensor_specs("params")?;
        let lora_params = tensor_specs("lora_params")?;
        let params2d = j
            .get("params2d")?
            .as_arr()?
            .iter()
            .map(|e| Ok(e.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        let io = |e: &Json| -> Result<IoSpec> {
            Ok(IoSpec {
                name: e.get("name")?.as_str()?.to_string(),
                dtype: e.get("dtype")?.as_str()?.to_string(),
                shape: e
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        };
        let mut artifacts = vec![];
        for (tag, a) in j.get("artifacts")?.as_obj()? {
            artifacts.push(ArtifactSpec {
                tag: tag.clone(),
                file: a.get("file")?.as_str()?.to_string(),
                inputs: a.get("inputs")?.as_arr()?.iter().map(io).collect::<Result<_>>()?,
                outputs: a.get("outputs")?.as_arr()?.iter().map(io).collect::<Result<_>>()?,
            });
        }
        // sanity: params2d must all exist and be 2D
        for n in &params2d {
            let Some(spec) = params.iter().find(|s| &s.name == n) else {
                bail!("params2d entry {n:?} not in params");
            };
            if spec.shape.len() != 2 {
                bail!("params2d entry {n:?} has shape {:?}", spec.shape);
            }
        }
        Ok(Manifest { config, params, lora_params, params2d, artifacts })
    }

    pub fn artifact(&self, tag: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.tag == tag)
            .ok_or_else(|| anyhow::anyhow!("artifact {tag:?} not in manifest"))
    }

    /// Indices (into `params`) of the 2D parameters, in params2d order.
    pub fn param2d_indices(&self) -> Vec<usize> {
        self.params2d
            .iter()
            .map(|n| self.params.iter().position(|s| &s.name == n).unwrap())
            .collect()
    }
}

/// Parameter initialization + views, mirroring python `model.init_params`
/// conventions (ones for LN scales, zeros for biases, scaled normals for
/// weight matrices). The exact values need not match python — only the
/// *order and shapes* are the ABI — but all clients must share θ⁰, which
/// this guarantees via the seed.
pub struct ParamStore;

impl ParamStore {
    pub fn init(manifest: &Manifest, seed: u64) -> ParamVec {
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut names = Vec::with_capacity(manifest.params.len());
        for (i, spec) in manifest.params.iter().enumerate() {
            let mut t = Tensor::zeros(&spec.shape);
            if spec.name.ends_with(".scale") {
                t.data.fill(1.0);
            } else if is_bias(&spec.name) {
                // zeros
            } else {
                let fan_in = if spec.shape.len() == 2 { spec.shape[0] } else { spec.shape[0] };
                let std = if spec.name.starts_with("embed") {
                    0.02
                } else {
                    (fan_in as f32).powf(-0.5)
                };
                let mut rng = Rng::fold_in(seed, i as u64);
                rng.fill_normal(&mut t.data);
                t.scale(std);
            }
            names.push(spec.name.clone());
            tensors.push(t);
        }
        ParamVec::new(names, tensors)
    }

    /// LoRA adapters: A ~ small normal, B = 0 (standard init — adapter
    /// starts as identity; verified against python in test_model.py).
    pub fn init_lora(manifest: &Manifest, seed: u64) -> ParamVec {
        let mut tensors = vec![];
        let mut names = vec![];
        for (i, spec) in manifest.lora_params.iter().enumerate() {
            let mut t = Tensor::zeros(&spec.shape);
            if spec.name.ends_with("lora_a") {
                let mut rng = Rng::fold_in(seed ^ 0x10AA, i as u64);
                rng.fill_normal(&mut t.data);
                t.scale(0.02);
            }
            names.push(spec.name.clone());
            tensors.push(t);
        }
        ParamVec::new(names, tensors)
    }
}

/// Checkpoint I/O: a minimal self-describing binary format
/// (`SFCK` magic, u32 tensor count, then per tensor: name len/bytes,
/// u32 ndim, u64 dims, raw f32 LE data). Used to persist the shared
/// "pretrained" θ⁰ that stands in for the paper's OPT checkpoints.
pub mod checkpoint {
    use anyhow::{bail, Context, Result};

    use crate::tensor::{ParamVec, Tensor};

    const MAGIC: &[u8; 4] = b"SFCK";

    pub fn save(params: &ParamVec, path: &str) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(params.tensors.len() as u32).to_le_bytes());
        for (name, t) in params.names.iter().zip(params.tensors.iter()) {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, buf).with_context(|| format!("writing checkpoint {path}"))
    }

    pub fn load(path: &str) -> Result<ParamVec> {
        let buf = std::fs::read(path).with_context(|| format!("reading checkpoint {path}"))?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("checkpoint truncated at byte {pos}", pos = *pos);
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("not a SFCK checkpoint: {path}");
        }
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            names.push(String::from_utf8(take(&mut pos, nlen)?.to_vec())?);
            let ndim = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?) as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize);
            }
            let numel: usize = shape.iter().product();
            let raw = take(&mut pos, 4 * numel)?;
            let data: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.push(Tensor::from_vec(&shape, data));
        }
        Ok(ParamVec::new(names, tensors))
    }

    /// Verify a checkpoint matches a manifest's parameter signature.
    pub fn check_compatible(p: &ParamVec, m: &super::Manifest) -> Result<()> {
        if p.names.len() != m.params.len() {
            bail!("checkpoint has {} tensors, manifest {}", p.names.len(), m.params.len());
        }
        for ((n, t), spec) in p.names.iter().zip(p.tensors.iter()).zip(m.params.iter()) {
            if n != &spec.name || t.shape != spec.shape {
                bail!(
                    "checkpoint tensor {n} {:?} != manifest {} {:?}",
                    t.shape, spec.name, spec.shape
                );
            }
        }
        Ok(())
    }
}

fn is_bias(name: &str) -> bool {
    name.ends_with(".bias")
        || name.ends_with(".bq")
        || name.ends_with(".bk")
        || name.ends_with(".bv")
        || name.ends_with(".bo")
        || name.ends_with(".b1")
        || name.ends_with(".b2")
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "config": {"name":"t","vocab":16,"seq":4,"dim":8,"layers":1,"heads":2,
                 "mlp_ratio":4,"batch":2,"num_classes":2,"lora_rank":2,
                 "subcge_rank":4,"num_params":200},
      "params": [{"name":"embed.tok","shape":[16,8]},
                 {"name":"block0.ln1.scale","shape":[8]},
                 {"name":"block0.ln1.bias","shape":[8]},
                 {"name":"block0.attn.wq","shape":[8,8]}],
      "lora_params": [{"name":"block0.attn.wq.lora_a","shape":[8,2]},
                      {"name":"block0.attn.wq.lora_b","shape":[2,8]}],
      "params2d": ["embed.tok","block0.attn.wq"],
      "artifacts": {"loss": {"file":"t_loss.hlo.txt",
        "inputs":[{"name":"embed.tok","dtype":"f32","shape":[16,8]}],
        "outputs":[{"name":"loss","dtype":"f32","shape":[]}]}}
    }"#;

    #[test]
    fn parse_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.config.vocab, 16);
        assert_eq!(m.params.len(), 4);
        assert_eq!(m.params2d, vec!["embed.tok", "block0.attn.wq"]);
        assert_eq!(m.param2d_indices(), vec![0, 3]);
        let a = m.artifact("loss").unwrap();
        assert_eq!(a.file, "t_loss.hlo.txt");
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn init_params_shapes_and_conventions() {
        let m = Manifest::parse(MINI).unwrap();
        let p = ParamStore::init(&m, 0);
        assert_eq!(p.tensors[0].shape, vec![16, 8]);
        assert!(p.tensors[1].data.iter().all(|&x| x == 1.0)); // ln scale
        assert!(p.tensors[2].data.iter().all(|&x| x == 0.0)); // ln bias
        assert!(p.tensors[3].l2_norm() > 0.0); // weight is random
        // deterministic
        let p2 = ParamStore::init(&m, 0);
        assert_eq!(p.tensors[3].data, p2.tensors[3].data);
        let p3 = ParamStore::init(&m, 1);
        assert_ne!(p.tensors[3].data, p3.tensors[3].data);
    }

    #[test]
    fn init_lora_b_zero() {
        let m = Manifest::parse(MINI).unwrap();
        let l = ParamStore::init_lora(&m, 0);
        assert!(l.tensors[0].l2_norm() > 0.0);
        assert!(l.tensors[1].data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn rejects_bad_params2d() {
        let bad = MINI.replace("\"embed.tok\",\"block0.attn.wq\"", "\"missing\"");
        assert!(Manifest::parse(&bad).is_err());
    }
}
