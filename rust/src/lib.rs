//! # SeedFlood — scalable decentralized training via flooded seed-reconstructible updates
//!
//! Reproduction of *“SeedFlood: A Step Toward Scalable Decentralized Training
//! of LLMs”* (Kim & Lee, 2026). The library is the L3 layer of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized-training coordinator: network
//!   topologies ([`topology`]), a simulated message-passing network with
//!   exact per-edge byte accounting ([`net`]) plus a deterministic
//!   unreliable-network & churn fault model ([`netcond`]), the flooding
//!   consensus primitive ([`flood`]), the SubCGE subspace state
//!   ([`subcge`]), zeroth-order estimation ([`zo`]), and all paper
//!   baselines (DSGD, ChocoSGD, DZSGD, LoRA variants) behind one
//!   [`algos::Algorithm`] trait, driven by the [`sim`] experiment runner
//!   under either execution engine ([`sim::Driver`]): the lockstep
//!   shared-step loop or the event-driven virtual-time engine
//!   ([`sched`], `--time-model event` — heterogeneous client speeds,
//!   asynchronous flooding).
//! * **L2** — a jax transformer LM (`python/compile/model.py`), AOT-lowered
//!   to HLO text artifacts loaded by [`runtime`] through PJRT.
//! * **L1** — pallas kernels (`python/compile/kernels/`): the SubCGE
//!   aggregation `θ ← θ − U A Vᵀ` and a blocked matmul, lowered into the L2
//!   HLO.
//!
//! Python never runs at request time: `make artifacts` is the only python
//! step; afterwards the `seedflood` binary is self-contained.
//!
//! See `ARCHITECTURE.md` for the module map and a message-lifecycle
//! walkthrough, and `EXPERIMENTS.md` for the measurement conventions
//! behind every number the binary reports. The determinism guarantees
//! those documents claim are statically enforced by the in-repo
//! [`lint`] pass (`seedflood lint`, CI-enforcing).
//!
//! ## Quick start (synthetic backend, no artifacts)
//!
//! The pure-rust synthetic oracle ([`oracle`]) makes the whole simulator
//! runnable without AOT artifacts — this is what tier-1 tests and benches
//! use:
//!
//! ```
//! use seedflood::config::ExperimentConfig;
//! use seedflood::sim::{self, Env};
//!
//! let env = Env::synthetic(ExperimentConfig {
//!     clients: 4,
//!     steps: 2,
//!     ..Default::default()
//! })
//! .unwrap();
//! let record = sim::run_with_env(&env).unwrap();
//! assert!(record.total_bytes > 0); // seeds were flooded
//! assert_eq!(record.delivery_ratio, 1.0); // reliable network by default
//! ```
//!
//! To stress the same run under packet loss and churn, set
//! `netcond: "churn-er".into()` (or any [`netcond`] spec string) in the
//! config — nothing else changes.

pub mod algos;
pub mod config;
pub mod data;
pub mod experiments;
pub mod flood;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod net;
pub mod netcond;
pub mod oracle;
pub mod rng;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod subcge;
pub mod tensor;
pub mod topology;
pub mod util;
pub mod zo;

// `crate::xla` is an in-repo stub of the PJRT bindings (same type surface,
// clear runtime errors) — the offline image cannot resolve or link the
// real xla-rs crate, and the synthetic oracle covers everything that does
// not touch AOT artifacts. To run artifacts, add the real `xla` dependency
// and replace this declaration with `pub use ::xla;`.
pub mod xla;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
