//! # SeedFlood — scalable decentralized training via flooded seed-reconstructible updates
//!
//! Reproduction of *“SeedFlood: A Step Toward Scalable Decentralized Training
//! of LLMs”* (Kim & Lee, 2026). The library is the L3 layer of a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized-training coordinator: network
//!   topologies, a simulated reliable message-passing network with exact
//!   per-edge byte accounting, the flooding consensus primitive, the SubCGE
//!   subspace state, zeroth-order estimation, and all paper baselines
//!   (DSGD, ChocoSGD, DZSGD, LoRA variants) behind one [`algos::Algorithm`]
//!   trait, driven by the [`sim`] experiment runner.
//! * **L2** — a jax transformer LM (`python/compile/model.py`), AOT-lowered
//!   to HLO text artifacts loaded by [`runtime`] through PJRT.
//! * **L1** — pallas kernels (`python/compile/kernels/`): the SubCGE
//!   aggregation `θ ← θ − U A Vᵀ` and a blocked matmul, lowered into the L2
//!   HLO.
//!
//! Python never runs at request time: `make artifacts` is the only python
//! step; afterwards the `seedflood` binary is self-contained.

pub mod algos;
pub mod config;
pub mod data;
pub mod experiments;
pub mod flood;
pub mod metrics;
pub mod model;
pub mod net;
pub mod oracle;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod subcge;
pub mod tensor;
pub mod topology;
pub mod util;
pub mod zo;

// `crate::xla` is an in-repo stub of the PJRT bindings (same type surface,
// clear runtime errors) — the offline image cannot resolve or link the
// real xla-rs crate, and the synthetic oracle covers everything that does
// not touch AOT artifacts. To run artifacts, add the real `xla` dependency
// and replace this declaration with `pub use ::xla;`.
pub mod xla;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
