//! Standalone entry point for the determinism & accounting lint pass —
//! identical to `seedflood lint`, for CI steps and editors that want the
//! linter without the full CLI. See `seedflood::lint` for the rules.

use seedflood::lint;
use seedflood::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    lint::cli_main(&args)
}
