//! Deterministic discrete-event scheduling for the virtual-time execution
//! engine (ISSUE 4 tentpole).
//!
//! The lockstep simulator advances one *shared* step index: every client
//! computes at the same speed and communication happens at a global
//! barrier. That hides exactly the straggler/asynchrony regime where
//! decentralized methods are argued to win. This module provides the
//! primitives the event-driven driver ([`crate::sim`], `--time-model
//! event`) is built on:
//!
//! * a **virtual clock** in integer ticks ([`TICKS_PER_ROUND`] ticks per
//!   communication round, `flood_steps × TICKS_PER_ROUND` per nominal
//!   local step), so all scheduling is pure integer arithmetic and runs
//!   are bit-for-bit reproducible;
//! * a **deterministic event queue** ([`EventQueue`]): a binary heap
//!   ordered by `(time, priority, insertion sequence)` — ties between
//!   simultaneous events always break the same way, independent of
//!   platform or allocation order;
//! * a **seeded speed model** ([`SpeedModel`], parsed from [`RateSpec`]):
//!   per-client compute rates (`uniform`, `lognormal:<sigma>`,
//!   `stragglers:<frac>,<slowdown>`) plus per-step duration jitter
//!   (`jitter:<sigma>`), all drawn from streams derived with the splitmix
//!   mixer ([`crate::rng::mix`]) so durations are pure functions of
//!   `(seed, client, step)`.
//!
//! The module is deliberately self-contained (it depends only on
//! [`crate::rng`]): the drivers in `sim` own all simulation semantics.
//!
//! ```
//! use seedflood::sched::{EventQueue, RateSpec, SpeedModel, TICKS_PER_ROUND};
//!
//! // uniform rates: every step takes exactly the nominal duration
//! let spec = RateSpec::parse("uniform").unwrap();
//! let model = SpeedModel::build(&spec, 4, 0);
//! assert_eq!(model.duration(2, 7, 4 * TICKS_PER_ROUND), 4 * TICKS_PER_ROUND);
//!
//! // events at the same tick pop by priority, then insertion order
//! let mut q: EventQueue<&str> = EventQueue::new();
//! q.push(5, 1, "round");
//! q.push(5, 0, "step-complete");
//! q.push(3, 2, "early");
//! assert_eq!(q.pop().unwrap().payload, "early");
//! assert_eq!(q.pop().unwrap().payload, "step-complete");
//! assert_eq!(q.pop().unwrap().payload, "round");
//! ```

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::{bail, ensure, Result};

use crate::rng::{mix, Rng};

/// Virtual-time ticks per communication round. The delivery clock
/// ([`crate::net::Network::tick`]) advances once per round in both time
/// models, so a netcond `delay=K` means the same K rounds either way; the
/// sub-round tick resolution only exists so heterogeneous step durations
/// can interleave at finer granularity than a whole round (rate
/// granularity is 1/256 of a round).
pub const TICKS_PER_ROUND: u64 = 256;

/// Which execution engine drives the training loop (`--time-model`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TimeModel {
    /// The historical shared-step loop: every client computes at the same
    /// speed, communication happens at a global barrier — the reference
    /// trajectory the event engine must reproduce under uniform rates.
    #[default]
    Lockstep,
    /// Discrete-event virtual time: each client's local steps complete at
    /// times set by its compute rate; flooding methods communicate off
    /// the delivery clock without a step barrier, gossip methods run
    /// through the barrier adapter (same results, honest timing metrics).
    Event,
}

impl TimeModel {
    pub fn parse(s: &str) -> Option<TimeModel> {
        match s.to_ascii_lowercase().as_str() {
            "lockstep" => Some(TimeModel::Lockstep),
            "event" => Some(TimeModel::Event),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TimeModel::Lockstep => "lockstep",
            TimeModel::Event => "event",
        }
    }
}

/// Parsed `--rates` spec: how per-client compute speeds are drawn.
#[derive(Clone, Debug, PartialEq)]
pub enum RateSpec {
    /// Every client computes at the nominal rate (1.0) — the event engine
    /// reproduces the lockstep trajectory exactly.
    Uniform,
    /// Per-client rate `exp(sigma · z_i)`, `z_i` standard normal: a
    /// heavy-tailed mix of fast and slow clients (median rate 1).
    LogNormal { sigma: f64 },
    /// `floor(frac · n)` seeded-randomly chosen clients run `slowdown`×
    /// slower than the rest — the classic straggler regime.
    Stragglers { frac: f64, slowdown: f64 },
    /// Per-client *per-step* lognormal duration jitter (mean rate 1):
    /// models stochastic stalls rather than persistently slow hardware —
    /// this is where barrier methods pay the `Σ_t max_i` straggler tax
    /// while asynchronous flooding pays only `max_i Σ_t`.
    Jitter { sigma: f64 },
}

impl RateSpec {
    /// Parse `uniform | lognormal:<sigma> | stragglers:<frac>,<slowdown>
    /// | jitter:<sigma>`.
    pub fn parse(s: &str) -> Result<RateSpec> {
        let s = s.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("uniform") {
            return Ok(RateSpec::Uniform);
        }
        let (kind, params) = s.split_once(':').unwrap_or((s, ""));
        match kind.to_ascii_lowercase().as_str() {
            "lognormal" => {
                let sigma: f64 = parse_f64(params, "lognormal sigma")?;
                ensure!(sigma >= 0.0, "lognormal sigma {sigma} must be >= 0");
                Ok(RateSpec::LogNormal { sigma })
            }
            "jitter" => {
                let sigma: f64 = parse_f64(params, "jitter sigma")?;
                ensure!(sigma >= 0.0, "jitter sigma {sigma} must be >= 0");
                Ok(RateSpec::Jitter { sigma })
            }
            "stragglers" => {
                let (frac, slow) = params.split_once(',').ok_or_else(|| {
                    anyhow::anyhow!("stragglers needs <frac>,<slowdown>, got {params:?}")
                })?;
                let frac = parse_f64(frac, "straggler fraction")?;
                let slowdown = parse_f64(slow, "straggler slowdown")?;
                ensure!((0.0..=1.0).contains(&frac), "straggler frac {frac} outside [0, 1]");
                ensure!(slowdown >= 1.0, "straggler slowdown {slowdown} must be >= 1");
                Ok(RateSpec::Stragglers { frac, slowdown })
            }
            other => bail!(
                "unknown rate spec {other:?} (have uniform, lognormal:<sigma>, \
                 stragglers:<frac>,<slowdown>, jitter:<sigma>)"
            ),
        }
    }

    /// True iff this spec cannot produce any non-nominal duration (the
    /// event engine then reproduces lockstep exactly).
    pub fn is_uniform(&self) -> bool {
        match self {
            RateSpec::Uniform => true,
            RateSpec::LogNormal { sigma } | RateSpec::Jitter { sigma } => *sigma == 0.0,
            RateSpec::Stragglers { frac, slowdown } => *frac == 0.0 || *slowdown == 1.0,
        }
    }
}

fn parse_f64(s: &str, what: &str) -> Result<f64> {
    s.trim()
        .parse::<f64>()
        .map_err(|e| anyhow::anyhow!("bad {what} {s:?}: {e}"))
}

/// Seed salt for the speed-model streams (independent of probe/sampler
/// randomness; combined with the experiment seed via [`mix`]).
const SPEED_SALT: u64 = 0x5_BEED_4A7E;

/// Compiled per-client compute speeds: base rate per client plus optional
/// per-step jitter. [`Self::duration`] is a pure function of
/// `(seed, client, step)` — durations never depend on simulation order,
/// which keeps the event engine deterministic and lets both drivers share
/// one model.
#[derive(Clone, Debug)]
pub struct SpeedModel {
    rates: Vec<f64>,
    jitter_sigma: f64,
    seed: u64,
    uniform: bool,
}

impl SpeedModel {
    /// Draw per-client rates from the spec on a stream derived from
    /// `seed` (the experiment seed; the salt keeps it disjoint from probe
    /// and sampler streams).
    pub fn build(spec: &RateSpec, n: usize, seed: u64) -> SpeedModel {
        let seed = mix(seed, SPEED_SALT);
        let mut rng = Rng::new(seed);
        let (rates, jitter_sigma) = match spec {
            RateSpec::Uniform => (vec![1.0; n], 0.0),
            RateSpec::Jitter { sigma } => (vec![1.0; n], *sigma),
            RateSpec::LogNormal { sigma } => {
                ((0..n).map(|_| (sigma * rng.next_normal() as f64).exp()).collect(), 0.0)
            }
            RateSpec::Stragglers { frac, slowdown } => {
                let k = (frac * n as f64).floor() as usize;
                let perm = rng.permutation(n);
                let mut rates = vec![1.0; n];
                for &i in perm.iter().take(k) {
                    rates[i as usize] = 1.0 / slowdown;
                }
                (rates, 0.0)
            }
        };
        SpeedModel { rates, jitter_sigma, seed, uniform: spec.is_uniform() }
    }

    /// This client's base compute rate (1.0 = nominal).
    pub fn rate(&self, client: usize) -> f64 {
        self.rates[client]
    }

    pub fn n(&self) -> usize {
        self.rates.len()
    }

    /// True iff every duration equals `step_ticks` exactly.
    pub fn is_uniform(&self) -> bool {
        self.uniform
    }

    /// Virtual-time duration of `client`'s local step number `step`, where
    /// a nominal client takes `step_ticks`. Uniform models return
    /// `step_ticks` *exactly* (no float round-trip) — the bitwise
    /// reduction contract of the event engine hangs on this.
    pub fn duration(&self, client: usize, step: usize, step_ticks: u64) -> u64 {
        if self.uniform {
            return step_ticks;
        }
        let mut rate = self.rates[client];
        if self.jitter_sigma > 0.0 {
            let mut r = Rng::new(mix(mix(self.seed, client as u64), step as u64));
            rate *= (self.jitter_sigma * r.next_normal() as f64).exp();
        }
        ((step_ticks as f64 / rate).round() as u64).max(1)
    }
}

/// One scheduled event: fires at `time`, with `prio` breaking ties at the
/// same tick (lower first) and insertion order breaking ties within a
/// priority class.
#[derive(Clone, Debug)]
pub struct Event<T> {
    pub time: u64,
    pub prio: u8,
    seq: u64,
    pub payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl<T> Eq for Event<T> {}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Event<T> {
    /// Reversed key order: `BinaryHeap` is a max-heap, so "greatest" must
    /// mean "earliest" for `pop` to return events in causal order.
    fn cmp(&self, other: &Self) -> Ordering {
        other.key().cmp(&self.key())
    }
}

impl<T> Event<T> {
    fn key(&self) -> (u64, u8, u64) {
        (self.time, self.prio, self.seq)
    }
}

/// Deterministic event queue: pops in ascending `(time, prio, seq)` order.
/// Determinism does not depend on the payload type — simultaneous events
/// of equal priority fire in insertion order, always.
#[derive(Debug, Default)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn push(&mut self, time: u64, prio: u8, payload: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Event { time, prio, seq, payload });
    }

    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop()
    }

    /// `(time, prio)` of the earliest pending event, without popping it.
    pub fn peek_key(&self) -> Option<(u64, u8)> {
        self.heap.peek().map(|e| (e.time, e.prio))
    }

    /// Drain every event sharing the earliest `(time, prio)` instant into
    /// `out` — the same-instant **cohort** the event engine fans out over
    /// worker threads. `out` is cleared first and filled in pop order
    /// (ascending `seq`, i.e. insertion order), so a caller replaying the
    /// cohort sequentially sees exactly the order `pop` would have
    /// produced. Returns the number of events drained (0 on empty queue).
    pub fn pop_cohort(&mut self, out: &mut Vec<Event<T>>) -> usize {
        out.clear();
        let Some(key) = self.peek_key() else {
            return 0;
        };
        while self.peek_key() == Some(key) {
            out.push(self.heap.pop().expect("peeked event vanished"));
        }
        out.len()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_orders_by_time_prio_seq() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push(10, 0, 1);
        q.push(5, 1, 2);
        q.push(5, 0, 3);
        q.push(5, 0, 4); // same (time, prio) as 3: insertion order wins
        q.push(7, 2, 5);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![3, 4, 2, 5, 1]);
        assert!(q.is_empty());
    }

    #[test]
    fn queue_is_reproducible() {
        let run = || {
            let mut q: EventQueue<usize> = EventQueue::new();
            for i in 0..100 {
                q.push((i * 37) as u64 % 13, (i % 3) as u8, i);
            }
            std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn pop_cohort_drains_exactly_one_instant_in_insertion_order() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push(5, 0, 10);
        q.push(5, 1, 20); // same tick, lower priority class: NOT in the cohort
        q.push(5, 0, 11);
        q.push(9, 0, 30);
        q.push(5, 0, 12);
        let mut cohort = Vec::new();
        assert_eq!(q.peek_key(), Some((5, 0)));
        assert_eq!(q.pop_cohort(&mut cohort), 3);
        assert_eq!(cohort.iter().map(|e| e.payload).collect::<Vec<_>>(), vec![10, 11, 12]);
        assert!(cohort.iter().all(|e| e.time == 5 && e.prio == 0));
        // next instant is the prio-1 event at the same tick
        assert_eq!(q.pop_cohort(&mut cohort), 1);
        assert_eq!(cohort[0].payload, 20);
        assert_eq!(q.pop_cohort(&mut cohort), 1);
        assert_eq!(cohort[0].payload, 30);
        assert_eq!(q.pop_cohort(&mut cohort), 0);
        assert!(cohort.is_empty());
    }

    #[test]
    fn cohort_drain_equals_sequential_pops() {
        let fill = || {
            let mut q: EventQueue<usize> = EventQueue::new();
            for i in 0..200 {
                q.push((i * 37) as u64 % 13, (i % 3) as u8, i);
            }
            q
        };
        let mut seq_q = fill();
        let sequential: Vec<usize> =
            std::iter::from_fn(|| seq_q.pop().map(|e| e.payload)).collect();
        let mut coh_q = fill();
        let mut cohort = Vec::new();
        let mut drained: Vec<usize> = Vec::new();
        while coh_q.pop_cohort(&mut cohort) > 0 {
            drained.extend(cohort.iter().map(|e| e.payload));
        }
        assert_eq!(drained, sequential);
    }

    #[test]
    fn rate_spec_parses() {
        assert_eq!(RateSpec::parse("uniform").unwrap(), RateSpec::Uniform);
        assert_eq!(RateSpec::parse("").unwrap(), RateSpec::Uniform);
        assert_eq!(
            RateSpec::parse("lognormal:0.5").unwrap(),
            RateSpec::LogNormal { sigma: 0.5 }
        );
        assert_eq!(
            RateSpec::parse("stragglers:0.25,4").unwrap(),
            RateSpec::Stragglers { frac: 0.25, slowdown: 4.0 }
        );
        assert_eq!(RateSpec::parse("jitter:0.3").unwrap(), RateSpec::Jitter { sigma: 0.3 });
        for bad in [
            "nope",
            "lognormal",
            "lognormal:-1",
            "stragglers:0.5",
            "stragglers:1.5,2",
            "stragglers:0.5,0.5",
            "jitter:x",
        ] {
            assert!(RateSpec::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn uniform_duration_is_exact() {
        let m = SpeedModel::build(&RateSpec::Uniform, 8, 42);
        for c in 0..8 {
            for t in 0..20 {
                assert_eq!(m.duration(c, t, 1024), 1024);
            }
        }
        assert!(m.is_uniform());
        // degenerate parameterizations collapse to uniform too
        assert!(SpeedModel::build(&RateSpec::LogNormal { sigma: 0.0 }, 4, 0).is_uniform());
        assert!(
            SpeedModel::build(&RateSpec::Stragglers { frac: 0.0, slowdown: 9.0 }, 4, 0)
                .is_uniform()
        );
    }

    #[test]
    fn stragglers_slow_exactly_the_fraction() {
        let m = SpeedModel::build(&RateSpec::Stragglers { frac: 0.25, slowdown: 4.0 }, 16, 7);
        let slow = (0..16).filter(|&i| m.rate(i) < 1.0).count();
        assert_eq!(slow, 4);
        for i in 0..16 {
            let d = m.duration(i, 0, 1000);
            if m.rate(i) < 1.0 {
                assert_eq!(d, 4000, "straggler {i}");
            } else {
                assert_eq!(d, 1000, "fast client {i}");
            }
        }
        // seeded: same seed → same straggler set; different seed → usually not
        let m2 = SpeedModel::build(&RateSpec::Stragglers { frac: 0.25, slowdown: 4.0 }, 16, 7);
        for i in 0..16 {
            assert_eq!(m.rate(i), m2.rate(i));
        }
    }

    #[test]
    fn lognormal_rates_positive_and_seeded() {
        let m = SpeedModel::build(&RateSpec::LogNormal { sigma: 1.0 }, 32, 3);
        assert!((0..32).all(|i| m.rate(i) > 0.0));
        assert!(!m.is_uniform());
        let spread = (0..32).any(|i| (m.rate(i) - 1.0).abs() > 0.1);
        assert!(spread, "sigma=1 must actually spread the rates");
        let m2 = SpeedModel::build(&RateSpec::LogNormal { sigma: 1.0 }, 32, 3);
        for i in 0..32 {
            assert_eq!(m.rate(i), m2.rate(i));
        }
    }

    #[test]
    fn jitter_durations_vary_per_step_but_are_pure() {
        let m = SpeedModel::build(&RateSpec::Jitter { sigma: 0.5 }, 4, 11);
        let d: Vec<u64> = (0..50).map(|t| m.duration(1, t, 1000)).collect();
        assert!(d.iter().any(|&x| x != d[0]), "jitter must vary across steps");
        // pure function of (seed, client, step): re-query in any order
        for t in (0..50).rev() {
            assert_eq!(m.duration(1, t, 1000), d[t]);
        }
        assert!(d.iter().all(|&x| x >= 1));
    }

    #[test]
    fn time_model_parses() {
        assert_eq!(TimeModel::parse("lockstep"), Some(TimeModel::Lockstep));
        assert_eq!(TimeModel::parse("Event"), Some(TimeModel::Event));
        assert_eq!(TimeModel::parse("async"), None);
        assert_eq!(TimeModel::default(), TimeModel::Lockstep);
        assert_eq!(TimeModel::Event.name(), "event");
    }
}
