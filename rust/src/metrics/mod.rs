//! Training metrics: loss curves, GMP, communication cost, consensus
//! error, per-phase wall-clock — everything the paper's tables/figures
//! report, serialized to `results/*.json`.

use crate::util::json::Json;

/// One evaluation point during / after training.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    /// total bytes transmitted network-wide up to this step
    pub total_bytes: u64,
    /// bytes per directed edge (paper's per-edge cost convention)
    pub per_edge_bytes: f64,
    /// mean squared distance of client models from their average
    pub consensus_error: f64,
}

/// Full record of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub method: String,
    pub task: String,
    pub model: String,
    pub topology: String,
    pub clients: usize,
    pub steps: usize,
    /// netcond fault scenario (preset name or spec string; "" = reliable)
    pub netcond: String,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    /// final Global Model Performance (accuracy of averaged model on test)
    pub gmp: f64,
    pub final_loss: f64,
    pub total_bytes: u64,
    pub per_edge_bytes: f64,
    /// messages killed by fault injection (their bytes stay counted)
    pub dropped_messages: u64,
    /// delivered / transmitted messages (1.0 on the reliable network)
    pub delivery_ratio: f64,
    /// duplicate flood receipts filtered by the dedup set (SeedFlood only;
    /// includes the deliberate duplicate traffic of netcond repairs)
    pub flood_duplicates: u64,
    /// worst (apply iteration − origin iteration) over all flooded
    /// messages (SeedFlood only; 0 = everything applied same-iteration)
    pub max_staleness: u64,
    /// bytes of repair traffic: gap-request summaries + gap-fills, or
    /// legacy re-flood broadcasts (subset of `total_bytes`; 0 when no
    /// repair ever triggered)
    pub repair_bytes: u64,
    /// transmissions attributable to repair (same attribution rules)
    pub repair_messages: u64,
    /// gap-fill responses whose oldest requested step was already evicted
    /// from the responder's retention window — history that could not be
    /// replayed. Persistently nonzero ⇒ `flood_retain` is too small for
    /// the scenario's outage lengths (silent-loss warning)
    pub repair_gap_misses: u64,
    /// worst per-client memory retained by the flooding layer at run end:
    /// repair-window entries + out-of-order dedup tail entries — the
    /// O(n + window) bound (SeedFlood only)
    pub flood_retained: u64,
    /// which execution engine drove the loop: "lockstep" or "event"
    pub time_model: String,
    /// the client speed-model spec ("uniform" on the lockstep clock)
    pub rates: String,
    /// total virtual time of the run in nominal-step units (event mode;
    /// 0.0 under lockstep, which has no clock). For barrier methods this
    /// is Σ_t max_i dur, for async methods max_i Σ_t dur — the straggler
    /// tax is exactly the gap between the two
    pub virtual_makespan: f64,
    /// fraction of aggregate client-time not spent computing
    /// (1 − Σ compute / (n · makespan)): barrier waiting plus end-of-run
    /// tail idling. 0.0 under lockstep and under uniform rates
    pub idle_frac: f64,
    /// local steps completed per client (event mode; equal to `steps` for
    /// every client today — the field exists so late-joiner/participation
    /// churn runs can report partial progress)
    pub client_steps: Vec<u64>,
    /// staleness distribution percentiles over every applied flooded
    /// message (apply iteration − origin iteration; SeedFlood only).
    /// Under lockstep these accompany `max_staleness`; under `stragglers`
    /// rates they are the headline robustness metric
    pub staleness_p50: f64,
    pub staleness_p90: f64,
    pub staleness_p99: f64,
    pub wall_secs: f64,
    /// phase name -> total ms (Table 4 breakdown)
    pub phase_ms: Vec<(String, f64)>,
}

/// Exact percentile of a histogram of integer-valued samples
/// (`hist[v]` = count of samples with value `v`): the smallest value at
/// or below which at least `p`% of the mass lies. 0.0 on an empty
/// histogram.
pub fn hist_percentile(hist: &[u64], p: f64) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (v, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= target {
            return v as f64;
        }
    }
    (hist.len() - 1) as f64
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("task", Json::str(&self.task)),
            ("model", Json::str(&self.model)),
            ("topology", Json::str(&self.topology)),
            ("clients", Json::num(self.clients as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("netcond", Json::str(&self.netcond)),
            ("gmp", Json::num(self.gmp)),
            ("final_loss", Json::num(self.final_loss)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("per_edge_bytes", Json::num(self.per_edge_bytes)),
            ("dropped_messages", Json::num(self.dropped_messages as f64)),
            ("delivery_ratio", Json::num(self.delivery_ratio)),
            ("flood_duplicates", Json::num(self.flood_duplicates as f64)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            ("repair_bytes", Json::num(self.repair_bytes as f64)),
            ("repair_messages", Json::num(self.repair_messages as f64)),
            ("repair_gap_misses", Json::num(self.repair_gap_misses as f64)),
            ("flood_retained", Json::num(self.flood_retained as f64)),
            ("time_model", Json::str(&self.time_model)),
            ("rates", Json::str(&self.rates)),
            ("virtual_makespan", Json::num(self.virtual_makespan)),
            ("idle_frac", Json::num(self.idle_frac)),
            (
                "client_steps",
                Json::Arr(self.client_steps.iter().map(|&s| Json::num(s as f64)).collect()),
            ),
            ("staleness_p50", Json::num(self.staleness_p50)),
            ("staleness_p90", Json::num(self.staleness_p90)),
            ("staleness_p99", Json::num(self.staleness_p99)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("train_losses", Json::arr_f64(&self.train_losses)),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                                ("accuracy", Json::num(e.accuracy)),
                                ("total_bytes", Json::num(e.total_bytes as f64)),
                                ("per_edge_bytes", Json::num(e.per_edge_bytes)),
                                ("consensus_error", Json::num(e.consensus_error)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "phase_ms",
                Json::Arr(
                    self.phase_ms
                        .iter()
                        .map(|(k, v)| {
                            Json::obj(vec![("phase", Json::str(k)), ("ms", Json::num(*v))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let mut r = RunRecord {
            method: "SeedFlood".into(),
            task: "sst2".into(),
            netcond: "lossy-ring".into(),
            gmp: 0.84,
            total_bytes: 400_000,
            delivery_ratio: 0.93,
            dropped_messages: 112,
            max_staleness: 3,
            repair_bytes: 1234,
            flood_retained: 96,
            time_model: "event".into(),
            rates: "stragglers:0.25,4".into(),
            virtual_makespan: 481.5,
            idle_frac: 0.32,
            client_steps: vec![120, 120, 30],
            staleness_p50: 1.0,
            staleness_p99: 17.0,
            ..Default::default()
        };
        r.evals.push(EvalPoint {
            step: 100,
            loss: 0.5,
            accuracy: 0.8,
            total_bytes: 1000,
            per_edge_bytes: 125.0,
            consensus_error: 0.0,
        });
        r.phase_ms.push(("ge".into(), 914.0));
        let j = r.to_json();
        let txt = j.to_string_pretty();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("gmp").unwrap().as_f64().unwrap(), 0.84);
        assert_eq!(back.get("netcond").unwrap().as_str().unwrap(), "lossy-ring");
        assert_eq!(back.get("delivery_ratio").unwrap().as_f64().unwrap(), 0.93);
        assert_eq!(back.get("max_staleness").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(back.get("repair_bytes").unwrap().as_f64().unwrap(), 1234.0);
        assert_eq!(back.get("flood_retained").unwrap().as_f64().unwrap(), 96.0);
        assert_eq!(back.get("time_model").unwrap().as_str().unwrap(), "event");
        assert_eq!(back.get("rates").unwrap().as_str().unwrap(), "stragglers:0.25,4");
        assert_eq!(back.get("virtual_makespan").unwrap().as_f64().unwrap(), 481.5);
        assert_eq!(back.get("idle_frac").unwrap().as_f64().unwrap(), 0.32);
        assert_eq!(back.get("client_steps").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(back.get("staleness_p99").unwrap().as_f64().unwrap(), 17.0);
        assert_eq!(
            back.get("evals").unwrap().as_arr().unwrap()[0]
                .get("accuracy")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.8
        );
    }

    #[test]
    fn hist_percentile_exact_on_integer_buckets() {
        // 10 samples: value 0 ×5, value 2 ×4, value 7 ×1
        let mut hist = vec![0u64; 8];
        hist[0] = 5;
        hist[2] = 4;
        hist[7] = 1;
        assert_eq!(hist_percentile(&hist, 50.0), 0.0);
        assert_eq!(hist_percentile(&hist, 90.0), 2.0);
        assert_eq!(hist_percentile(&hist, 99.0), 7.0);
        assert_eq!(hist_percentile(&hist, 100.0), 7.0);
        assert_eq!(hist_percentile(&[], 50.0), 0.0);
        assert_eq!(hist_percentile(&[0, 0], 50.0), 0.0);
        // a single sample is every percentile
        assert_eq!(hist_percentile(&[0, 0, 0, 1], 1.0), 3.0);
    }
}
