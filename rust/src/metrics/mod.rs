//! Training metrics: loss curves, GMP, communication cost, consensus
//! error, per-phase wall-clock — everything the paper's tables/figures
//! report, serialized to `results/*.json`.

use crate::util::json::Json;

/// One evaluation point during / after training.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    /// total bytes transmitted network-wide up to this step
    pub total_bytes: u64,
    /// bytes per directed edge (paper's per-edge cost convention)
    pub per_edge_bytes: f64,
    /// mean squared distance of client models from their average
    pub consensus_error: f64,
}

/// Full record of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub method: String,
    pub task: String,
    pub model: String,
    pub topology: String,
    pub clients: usize,
    pub steps: usize,
    /// RNG seed the run was configured with (init θ⁰, samplers, ZO
    /// probes) — what distinguishes the runs a sweep aggregates over
    pub seed: u64,
    /// configured SubCGE subspace rank r (0 in records saved before
    /// ISSUE 5 = unrecorded)
    pub rank: usize,
    /// configured SubCGE basis refresh period τ (0 = unrecorded)
    pub refresh: usize,
    /// configured flooding steps per iteration, as given (0 = network
    /// diameter, the paper default)
    pub flood_steps: usize,
    /// netcond fault scenario (preset name or spec string; "" = reliable)
    pub netcond: String,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    /// final Global Model Performance (accuracy of averaged model on test)
    pub gmp: f64,
    pub final_loss: f64,
    pub total_bytes: u64,
    pub per_edge_bytes: f64,
    /// messages killed by fault injection (their bytes stay counted)
    pub dropped_messages: u64,
    /// delivered / transmitted messages (1.0 on the reliable network)
    pub delivery_ratio: f64,
    /// duplicate flood receipts filtered by the dedup set (SeedFlood only;
    /// includes the deliberate duplicate traffic of netcond repairs)
    pub flood_duplicates: u64,
    /// worst (apply iteration − origin iteration) over all flooded
    /// messages (SeedFlood only; 0 = everything applied same-iteration)
    pub max_staleness: u64,
    /// bytes of repair traffic: gap-request summaries + gap-fills, or
    /// legacy re-flood broadcasts (subset of `total_bytes`; 0 when no
    /// repair ever triggered)
    pub repair_bytes: u64,
    /// transmissions attributable to repair (same attribution rules)
    pub repair_messages: u64,
    /// gap-fill responses whose oldest requested step was already evicted
    /// from the responder's retention window — history that could not be
    /// replayed. Persistently nonzero ⇒ `flood_retain` is too small for
    /// the scenario's outage lengths (silent-loss warning)
    pub repair_gap_misses: u64,
    /// worst per-client memory retained by the flooding layer at run end:
    /// repair-window entries + out-of-order dedup tail entries — the
    /// O(n + window) bound (SeedFlood only)
    pub flood_retained: u64,
    /// worst per-client dedup-filter footprint at run end, in bytes
    /// (allocation capacities, `FloodDedup::mem_bytes`) — the metric the
    /// origin-sparse representation exists to keep flat where the dense
    /// table was O(n) per client / O(n²) simulation-wide (SeedFlood only)
    pub flood_dedup_bytes: u64,
    /// high-water mark of wire bytes simultaneously in flight on the
    /// network over the whole run (`Accounting::peak_in_flight_bytes`) —
    /// the other half of the large-n memory story
    pub peak_in_flight_bytes: u64,
    /// which execution engine drove the loop: "lockstep" or "event"
    pub time_model: String,
    /// the client speed-model spec ("uniform" on the lockstep clock)
    pub rates: String,
    /// total virtual time of the run in nominal-step units (event mode;
    /// 0.0 under lockstep, which has no clock). For barrier methods this
    /// is Σ_t max_i dur, for async methods max_i Σ_t dur — the straggler
    /// tax is exactly the gap between the two
    pub virtual_makespan: f64,
    /// fraction of aggregate client-time not spent computing
    /// (1 − Σ compute / (n · makespan)): barrier waiting plus end-of-run
    /// tail idling. 0.0 under lockstep and under uniform rates
    pub idle_frac: f64,
    /// local steps completed per client (event mode; equal to `steps` for
    /// every client today — the field exists so late-joiner/participation
    /// churn runs can report partial progress)
    pub client_steps: Vec<u64>,
    /// staleness distribution percentiles over every applied flooded
    /// message (apply iteration − origin iteration; SeedFlood only).
    /// Under lockstep these accompany `max_staleness`; under `stragglers`
    /// rates they are the headline robustness metric
    pub staleness_p50: f64,
    pub staleness_p90: f64,
    pub staleness_p99: f64,
    pub wall_secs: f64,
    /// phase name -> total ms (Table 4 breakdown)
    pub phase_ms: Vec<(String, f64)>,
}

/// Exact percentile of a histogram of integer-valued samples
/// (`hist[v]` = count of samples with value `v`): the smallest value at
/// or below which at least `p`% of the mass lies. 0.0 on an empty
/// histogram.
pub fn hist_percentile(hist: &[u64], p: f64) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (v, &c) in hist.iter().enumerate() {
        seen += c;
        if seen >= target {
            return v as f64;
        }
    }
    (hist.len() - 1) as f64
}

impl EvalPoint {
    pub fn from_json(j: &Json) -> anyhow::Result<EvalPoint> {
        Ok(EvalPoint {
            step: j.get("step")?.as_usize()?,
            loss: j.get("loss")?.as_f64()?,
            accuracy: j.get("accuracy")?.as_f64()?,
            total_bytes: j.get("total_bytes")?.as_f64()? as u64,
            per_edge_bytes: j.get("per_edge_bytes")?.as_f64()?,
            consensus_error: j.get("consensus_error")?.as_f64()?,
        })
    }
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("task", Json::str(&self.task)),
            ("model", Json::str(&self.model)),
            ("topology", Json::str(&self.topology)),
            ("clients", Json::num(self.clients as f64)),
            ("steps", Json::num(self.steps as f64)),
            // JSON numbers are f64: seeds round-trip exactly up to 2^53
            ("seed", Json::num(self.seed as f64)),
            ("rank", Json::num(self.rank as f64)),
            ("refresh", Json::num(self.refresh as f64)),
            ("flood_steps", Json::num(self.flood_steps as f64)),
            ("netcond", Json::str(&self.netcond)),
            ("gmp", Json::num(self.gmp)),
            ("final_loss", Json::num(self.final_loss)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("per_edge_bytes", Json::num(self.per_edge_bytes)),
            ("dropped_messages", Json::num(self.dropped_messages as f64)),
            ("delivery_ratio", Json::num(self.delivery_ratio)),
            ("flood_duplicates", Json::num(self.flood_duplicates as f64)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            ("repair_bytes", Json::num(self.repair_bytes as f64)),
            ("repair_messages", Json::num(self.repair_messages as f64)),
            ("repair_gap_misses", Json::num(self.repair_gap_misses as f64)),
            ("flood_retained", Json::num(self.flood_retained as f64)),
            ("flood_dedup_bytes", Json::num(self.flood_dedup_bytes as f64)),
            ("peak_in_flight_bytes", Json::num(self.peak_in_flight_bytes as f64)),
            ("time_model", Json::str(&self.time_model)),
            ("rates", Json::str(&self.rates)),
            ("virtual_makespan", Json::num(self.virtual_makespan)),
            ("idle_frac", Json::num(self.idle_frac)),
            (
                "client_steps",
                Json::Arr(self.client_steps.iter().map(|&s| Json::num(s as f64)).collect()),
            ),
            ("staleness_p50", Json::num(self.staleness_p50)),
            ("staleness_p90", Json::num(self.staleness_p90)),
            ("staleness_p99", Json::num(self.staleness_p99)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("train_losses", Json::arr_f64(&self.train_losses)),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                                ("accuracy", Json::num(e.accuracy)),
                                ("total_bytes", Json::num(e.total_bytes as f64)),
                                ("per_edge_bytes", Json::num(e.per_edge_bytes)),
                                ("consensus_error", Json::num(e.consensus_error)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "phase_ms",
                Json::Arr(
                    self.phase_ms
                        .iter()
                        .map(|(k, v)| {
                            Json::obj(vec![("phase", Json::str(k)), ("ms", Json::num(*v))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a record saved by [`Self::to_json`] — the single parsing
    /// site shared by `seedflood report` and the sweep driver's resume
    /// path (this used to live inline in `experiments::report`).
    ///
    /// Fields added after the seed release are optional, with the same
    /// defaults the writers of that era implied: netcond fields default
    /// to the reliable network (ISSUE 2), time-model fields to a lockstep
    /// run (ISSUE 4), and the provenance fields `seed`/`rank`/`refresh`/
    /// `flood_steps` to 0 = unrecorded (ISSUE 5). Everything
    /// [`Self::to_json`] writes is parsed back, so
    /// `from_json(&r.to_json())` reproduces `r` exactly
    /// (rust/tests/properties.rs).
    pub fn from_json(r: &Json) -> anyhow::Result<RunRecord> {
        let opt_f64 = |k: &str, d: f64| r.get(k).and_then(|v| v.as_f64()).unwrap_or(d);
        let opt_u64 = |k: &str| opt_f64(k, 0.0) as u64;
        let opt_str = |k: &str, d: &str| {
            r.get(k).and_then(|v| v.as_str()).unwrap_or(d).to_string()
        };
        let f64_arr = |k: &str| -> anyhow::Result<Vec<f64>> {
            match r.get(k) {
                Ok(v) => v.as_arr()?.iter().map(|x| x.as_f64()).collect(),
                Err(_) => Ok(vec![]),
            }
        };
        let evals = match r.get("evals") {
            Ok(v) => v
                .as_arr()?
                .iter()
                .map(EvalPoint::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
            Err(_) => vec![],
        };
        let phase_ms = match r.get("phase_ms") {
            Ok(v) => v
                .as_arr()?
                .iter()
                .map(|p| Ok((p.get("phase")?.as_str()?.to_string(), p.get("ms")?.as_f64()?)))
                .collect::<anyhow::Result<Vec<_>>>()?,
            Err(_) => vec![],
        };
        Ok(RunRecord {
            method: r.get("method")?.as_str()?.to_string(),
            task: r.get("task")?.as_str()?.to_string(),
            model: r.get("model")?.as_str()?.to_string(),
            topology: r.get("topology")?.as_str()?.to_string(),
            clients: r.get("clients")?.as_usize()?,
            steps: r.get("steps")?.as_usize()?,
            seed: opt_u64("seed"),
            rank: opt_f64("rank", 0.0) as usize,
            refresh: opt_f64("refresh", 0.0) as usize,
            flood_steps: opt_f64("flood_steps", 0.0) as usize,
            netcond: opt_str("netcond", ""),
            train_losses: f64_arr("train_losses")?,
            evals,
            gmp: r.get("gmp")?.as_f64()?,
            final_loss: r.get("final_loss")?.as_f64()?,
            total_bytes: r.get("total_bytes")?.as_f64()? as u64,
            per_edge_bytes: r.get("per_edge_bytes")?.as_f64()?,
            dropped_messages: opt_u64("dropped_messages"),
            delivery_ratio: opt_f64("delivery_ratio", 1.0),
            flood_duplicates: opt_u64("flood_duplicates"),
            max_staleness: opt_u64("max_staleness"),
            repair_bytes: opt_u64("repair_bytes"),
            repair_messages: opt_u64("repair_messages"),
            repair_gap_misses: opt_u64("repair_gap_misses"),
            flood_retained: opt_u64("flood_retained"),
            flood_dedup_bytes: opt_u64("flood_dedup_bytes"),
            peak_in_flight_bytes: opt_u64("peak_in_flight_bytes"),
            time_model: opt_str("time_model", "lockstep"),
            rates: opt_str("rates", "uniform"),
            virtual_makespan: opt_f64("virtual_makespan", 0.0),
            idle_frac: opt_f64("idle_frac", 0.0),
            client_steps: f64_arr("client_steps")?.iter().map(|&s| s as u64).collect(),
            staleness_p50: opt_f64("staleness_p50", 0.0),
            staleness_p90: opt_f64("staleness_p90", 0.0),
            staleness_p99: opt_f64("staleness_p99", 0.0),
            wall_secs: r.get("wall_secs")?.as_f64()?,
            phase_ms,
        })
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let mut r = RunRecord {
            method: "SeedFlood".into(),
            task: "sst2".into(),
            netcond: "lossy-ring".into(),
            gmp: 0.84,
            total_bytes: 400_000,
            delivery_ratio: 0.93,
            dropped_messages: 112,
            max_staleness: 3,
            repair_bytes: 1234,
            flood_retained: 96,
            flood_dedup_bytes: 5888,
            peak_in_flight_bytes: 40_960,
            time_model: "event".into(),
            rates: "stragglers:0.25,4".into(),
            virtual_makespan: 481.5,
            idle_frac: 0.32,
            client_steps: vec![120, 120, 30],
            staleness_p50: 1.0,
            staleness_p99: 17.0,
            ..Default::default()
        };
        r.evals.push(EvalPoint {
            step: 100,
            loss: 0.5,
            accuracy: 0.8,
            total_bytes: 1000,
            per_edge_bytes: 125.0,
            consensus_error: 0.0,
        });
        r.phase_ms.push(("ge".into(), 914.0));
        let j = r.to_json();
        let txt = j.to_string_pretty();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("gmp").unwrap().as_f64().unwrap(), 0.84);
        assert_eq!(back.get("netcond").unwrap().as_str().unwrap(), "lossy-ring");
        assert_eq!(back.get("delivery_ratio").unwrap().as_f64().unwrap(), 0.93);
        assert_eq!(back.get("max_staleness").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(back.get("repair_bytes").unwrap().as_f64().unwrap(), 1234.0);
        assert_eq!(back.get("flood_retained").unwrap().as_f64().unwrap(), 96.0);
        assert_eq!(back.get("flood_dedup_bytes").unwrap().as_f64().unwrap(), 5888.0);
        assert_eq!(back.get("peak_in_flight_bytes").unwrap().as_f64().unwrap(), 40960.0);
        assert_eq!(back.get("time_model").unwrap().as_str().unwrap(), "event");
        assert_eq!(back.get("rates").unwrap().as_str().unwrap(), "stragglers:0.25,4");
        assert_eq!(back.get("virtual_makespan").unwrap().as_f64().unwrap(), 481.5);
        assert_eq!(back.get("idle_frac").unwrap().as_f64().unwrap(), 0.32);
        assert_eq!(back.get("client_steps").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(back.get("staleness_p99").unwrap().as_f64().unwrap(), 17.0);
        assert_eq!(
            back.get("evals").unwrap().as_arr().unwrap()[0]
                .get("accuracy")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.8
        );
    }

    #[test]
    fn from_json_parses_what_to_json_writes() {
        let mut r = RunRecord {
            method: "SubCGE".into(),
            task: "rte".into(),
            model: "synthetic".into(),
            topology: "ring".into(),
            clients: 8,
            steps: 120,
            seed: 7,
            rank: 64,
            refresh: 500,
            flood_steps: 4,
            gmp: 0.71,
            train_losses: vec![1.5, 1.2],
            client_steps: vec![120, 120],
            phase_ms: vec![("ge".into(), 12.5)],
            ..Default::default()
        };
        r.evals.push(EvalPoint {
            step: 60,
            loss: 1.1,
            accuracy: 0.6,
            total_bytes: 2048,
            per_edge_bytes: 128.0,
            consensus_error: 1e-9,
        });
        let back = RunRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back.to_json(), r.to_json());
        assert_eq!((back.seed, back.rank, back.refresh, back.flood_steps), (7, 64, 500, 4));
        assert_eq!(back.evals.len(), 1);
        assert_eq!(back.train_losses, vec![1.5, 1.2]);
        assert_eq!(back.phase_ms, vec![("ge".into(), 12.5)]);
    }

    #[test]
    fn from_json_defaults_fields_missing_from_old_records() {
        // a record saved before ISSUE 2/4/5: only the seed-era fields
        let old = r#"{
          "method": "SeedFlood", "task": "sst2", "model": "tiny",
          "topology": "ring", "clients": 16, "steps": 400,
          "gmp": 0.8, "final_loss": 0.4, "total_bytes": 1000,
          "per_edge_bytes": 12.5, "wall_secs": 3.5
        }"#;
        let r = RunRecord::from_json(&Json::parse(old).unwrap()).unwrap();
        assert_eq!((r.seed, r.rank, r.refresh, r.flood_steps), (0, 0, 0, 0));
        assert_eq!(r.netcond, "");
        assert_eq!(r.delivery_ratio, 1.0);
        assert_eq!(r.time_model, "lockstep");
        assert_eq!(r.rates, "uniform");
        assert!(r.evals.is_empty() && r.train_losses.is_empty());
        // core fields stay strict: a record missing them is an error
        assert!(RunRecord::from_json(&Json::parse(r#"{"method": "x"}"#).unwrap()).is_err());
    }

    #[test]
    fn hist_percentile_exact_on_integer_buckets() {
        // 10 samples: value 0 ×5, value 2 ×4, value 7 ×1
        let mut hist = vec![0u64; 8];
        hist[0] = 5;
        hist[2] = 4;
        hist[7] = 1;
        assert_eq!(hist_percentile(&hist, 50.0), 0.0);
        assert_eq!(hist_percentile(&hist, 90.0), 2.0);
        assert_eq!(hist_percentile(&hist, 99.0), 7.0);
        assert_eq!(hist_percentile(&hist, 100.0), 7.0);
        assert_eq!(hist_percentile(&[], 50.0), 0.0);
        assert_eq!(hist_percentile(&[0, 0], 50.0), 0.0);
        // a single sample is every percentile
        assert_eq!(hist_percentile(&[0, 0, 0, 1], 1.0), 3.0);
    }
}
