//! Training metrics: loss curves, GMP, communication cost, consensus
//! error, per-phase wall-clock — everything the paper's tables/figures
//! report, serialized to `results/*.json`.

use crate::util::json::Json;

/// One evaluation point during / after training.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    /// total bytes transmitted network-wide up to this step
    pub total_bytes: u64,
    /// bytes per directed edge (paper's per-edge cost convention)
    pub per_edge_bytes: f64,
    /// mean squared distance of client models from their average
    pub consensus_error: f64,
}

/// Full record of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub method: String,
    pub task: String,
    pub model: String,
    pub topology: String,
    pub clients: usize,
    pub steps: usize,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    /// final Global Model Performance (accuracy of averaged model on test)
    pub gmp: f64,
    pub final_loss: f64,
    pub total_bytes: u64,
    pub per_edge_bytes: f64,
    pub wall_secs: f64,
    /// phase name -> total ms (Table 4 breakdown)
    pub phase_ms: Vec<(String, f64)>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("task", Json::str(&self.task)),
            ("model", Json::str(&self.model)),
            ("topology", Json::str(&self.topology)),
            ("clients", Json::num(self.clients as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("gmp", Json::num(self.gmp)),
            ("final_loss", Json::num(self.final_loss)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("per_edge_bytes", Json::num(self.per_edge_bytes)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("train_losses", Json::arr_f64(&self.train_losses)),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                                ("accuracy", Json::num(e.accuracy)),
                                ("total_bytes", Json::num(e.total_bytes as f64)),
                                ("per_edge_bytes", Json::num(e.per_edge_bytes)),
                                ("consensus_error", Json::num(e.consensus_error)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "phase_ms",
                Json::Arr(
                    self.phase_ms
                        .iter()
                        .map(|(k, v)| {
                            Json::obj(vec![("phase", Json::str(k)), ("ms", Json::num(*v))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let mut r = RunRecord {
            method: "SeedFlood".into(),
            task: "sst2".into(),
            gmp: 0.84,
            total_bytes: 400_000,
            ..Default::default()
        };
        r.evals.push(EvalPoint {
            step: 100,
            loss: 0.5,
            accuracy: 0.8,
            total_bytes: 1000,
            per_edge_bytes: 125.0,
            consensus_error: 0.0,
        });
        r.phase_ms.push(("ge".into(), 914.0));
        let j = r.to_json();
        let txt = j.to_string_pretty();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("gmp").unwrap().as_f64().unwrap(), 0.84);
        assert_eq!(
            back.get("evals").unwrap().as_arr().unwrap()[0]
                .get("accuracy")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.8
        );
    }
}
