//! Training metrics: loss curves, GMP, communication cost, consensus
//! error, per-phase wall-clock — everything the paper's tables/figures
//! report, serialized to `results/*.json`.

use crate::util::json::Json;

/// One evaluation point during / after training.
#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    /// total bytes transmitted network-wide up to this step
    pub total_bytes: u64,
    /// bytes per directed edge (paper's per-edge cost convention)
    pub per_edge_bytes: f64,
    /// mean squared distance of client models from their average
    pub consensus_error: f64,
}

/// Full record of one experiment run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    pub method: String,
    pub task: String,
    pub model: String,
    pub topology: String,
    pub clients: usize,
    pub steps: usize,
    /// netcond fault scenario (preset name or spec string; "" = reliable)
    pub netcond: String,
    pub train_losses: Vec<f64>,
    pub evals: Vec<EvalPoint>,
    /// final Global Model Performance (accuracy of averaged model on test)
    pub gmp: f64,
    pub final_loss: f64,
    pub total_bytes: u64,
    pub per_edge_bytes: f64,
    /// messages killed by fault injection (their bytes stay counted)
    pub dropped_messages: u64,
    /// delivered / transmitted messages (1.0 on the reliable network)
    pub delivery_ratio: f64,
    /// duplicate flood receipts filtered by the dedup set (SeedFlood only;
    /// includes the deliberate duplicate traffic of netcond repairs)
    pub flood_duplicates: u64,
    /// worst (apply iteration − origin iteration) over all flooded
    /// messages (SeedFlood only; 0 = everything applied same-iteration)
    pub max_staleness: u64,
    /// bytes of repair traffic: gap-request summaries + gap-fills, or
    /// legacy re-flood broadcasts (subset of `total_bytes`; 0 when no
    /// repair ever triggered)
    pub repair_bytes: u64,
    /// transmissions attributable to repair (same attribution rules)
    pub repair_messages: u64,
    /// gap-fill responses whose oldest requested step was already evicted
    /// from the responder's retention window — history that could not be
    /// replayed. Persistently nonzero ⇒ `flood_retain` is too small for
    /// the scenario's outage lengths (silent-loss warning)
    pub repair_gap_misses: u64,
    /// worst per-client memory retained by the flooding layer at run end:
    /// repair-window entries + out-of-order dedup tail entries — the
    /// O(n + window) bound (SeedFlood only)
    pub flood_retained: u64,
    pub wall_secs: f64,
    /// phase name -> total ms (Table 4 breakdown)
    pub phase_ms: Vec<(String, f64)>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::str(&self.method)),
            ("task", Json::str(&self.task)),
            ("model", Json::str(&self.model)),
            ("topology", Json::str(&self.topology)),
            ("clients", Json::num(self.clients as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("netcond", Json::str(&self.netcond)),
            ("gmp", Json::num(self.gmp)),
            ("final_loss", Json::num(self.final_loss)),
            ("total_bytes", Json::num(self.total_bytes as f64)),
            ("per_edge_bytes", Json::num(self.per_edge_bytes)),
            ("dropped_messages", Json::num(self.dropped_messages as f64)),
            ("delivery_ratio", Json::num(self.delivery_ratio)),
            ("flood_duplicates", Json::num(self.flood_duplicates as f64)),
            ("max_staleness", Json::num(self.max_staleness as f64)),
            ("repair_bytes", Json::num(self.repair_bytes as f64)),
            ("repair_messages", Json::num(self.repair_messages as f64)),
            ("repair_gap_misses", Json::num(self.repair_gap_misses as f64)),
            ("flood_retained", Json::num(self.flood_retained as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
            ("train_losses", Json::arr_f64(&self.train_losses)),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                                ("accuracy", Json::num(e.accuracy)),
                                ("total_bytes", Json::num(e.total_bytes as f64)),
                                ("per_edge_bytes", Json::num(e.per_edge_bytes)),
                                ("consensus_error", Json::num(e.consensus_error)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "phase_ms",
                Json::Arr(
                    self.phase_ms
                        .iter()
                        .map(|(k, v)| {
                            Json::obj(vec![("phase", Json::str(k)), ("ms", Json::num(*v))])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let mut r = RunRecord {
            method: "SeedFlood".into(),
            task: "sst2".into(),
            netcond: "lossy-ring".into(),
            gmp: 0.84,
            total_bytes: 400_000,
            delivery_ratio: 0.93,
            dropped_messages: 112,
            max_staleness: 3,
            repair_bytes: 1234,
            flood_retained: 96,
            ..Default::default()
        };
        r.evals.push(EvalPoint {
            step: 100,
            loss: 0.5,
            accuracy: 0.8,
            total_bytes: 1000,
            per_edge_bytes: 125.0,
            consensus_error: 0.0,
        });
        r.phase_ms.push(("ge".into(), 914.0));
        let j = r.to_json();
        let txt = j.to_string_pretty();
        let back = Json::parse(&txt).unwrap();
        assert_eq!(back.get("gmp").unwrap().as_f64().unwrap(), 0.84);
        assert_eq!(back.get("netcond").unwrap().as_str().unwrap(), "lossy-ring");
        assert_eq!(back.get("delivery_ratio").unwrap().as_f64().unwrap(), 0.93);
        assert_eq!(back.get("max_staleness").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(back.get("repair_bytes").unwrap().as_f64().unwrap(), 1234.0);
        assert_eq!(back.get("flood_retained").unwrap().as_f64().unwrap(), 96.0);
        assert_eq!(
            back.get("evals").unwrap().as_arr().unwrap()[0]
                .get("accuracy")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.8
        );
    }
}
