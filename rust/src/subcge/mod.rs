//! SubCGE: Subspace Canonical-basis Gradient Estimation (paper §3.4).
//!
//! Two pieces:
//!
//! * [`SubspaceBasis`] — the globally shared low-rank factors `U_ℓ (n_ℓ×r)`,
//!   `V_ℓ (m_ℓ×r)`, regenerated from `RNG(s_glob + t)` every τ steps
//!   (Alg. 1 step A). All clients derive identical bases from the shared
//!   seed; the simulator stores the basis once and hands every client a
//!   reference (the determinism that justifies this is unit-tested).
//! * [`CoeffAccum`] — per-client coefficient accumulators `A_ℓ (r×r)` into
//!   which flooded seed-scalar messages fold in O(1) each
//!   (`A[i_k, j_k] += coeff_k`, Table 4 "coordinate update"). The batched
//!   update `θ_ℓ ← θ_ℓ − U_ℓ A_ℓ V_ℓᵀ` (Eq. 10) is applied by `flush_*`,
//!   either through the AOT pallas-kernel artifact (hot path) or the
//!   pure-rust fallback (tests, microbenches).
//!
//! The artifact is lowered at the manifest's fixed `r = subcge_rank`;
//! smaller *effective* ranks (`rank_eff`, swept in Fig 6) restrict which
//! coordinates are sampled — mathematically identical to a narrower
//! subspace because unsampled rows/cols of `A` stay zero.

use anyhow::Result;

use crate::model::Manifest;
use crate::net::SeedUpdate;
use crate::rng::Rng;
use crate::runtime::{Arg, Executable, Runtime};
use crate::tensor::{ParamVec, Tensor};
// real bindings with `--features xla`, in-repo stub otherwise (lib.rs)
use crate::xla;

/// The globally shared subspace factors (identical on every client).
pub struct SubspaceBasis {
    /// indices into the ParamVec of the 2D layers, in params2d order
    pub param_indices: Vec<usize>,
    /// U_ℓ: (n_ℓ, r), row-major
    pub us: Vec<Tensor>,
    /// V_ℓ: (m_ℓ, r), row-major
    pub vs: Vec<Tensor>,
    /// artifact rank (full width of U/V/A)
    pub rank: usize,
    /// effective rank — coordinates are sampled from [rank_eff]²
    pub rank_eff: usize,
    /// global seed s_glob shared by all clients
    pub global_seed: u64,
    /// refresh period τ
    pub refresh_period: usize,
    /// bumped on every regenerate — lets device-side caches invalidate
    pub epoch: u64,
}

impl SubspaceBasis {
    pub fn new(
        manifest: &Manifest,
        rank_eff: usize,
        refresh_period: usize,
        global_seed: u64,
    ) -> SubspaceBasis {
        let rank = manifest.config.subcge_rank;
        assert!(rank_eff >= 1 && rank_eff <= rank,
                "rank_eff {rank_eff} not in [1, {rank}]");
        let param_indices = manifest.param2d_indices();
        let shapes: Vec<(usize, usize)> = param_indices
            .iter()
            .map(|&i| (manifest.params[i].shape[0], manifest.params[i].shape[1]))
            .collect();
        let mut s = SubspaceBasis {
            param_indices,
            us: shapes.iter().map(|&(a, _)| Tensor::zeros(&[a, rank])).collect(),
            vs: shapes.iter().map(|&(_, b)| Tensor::zeros(&[b, rank])).collect(),
            rank,
            rank_eff,
            global_seed,
            refresh_period,
            epoch: 0,
        };
        s.regenerate(0);
        s
    }

    pub fn n_layers(&self) -> usize {
        self.param_indices.len()
    }

    /// Whether [`Self::maybe_refresh`] would regenerate at `step` — the
    /// peek that lets callers flush basis-relative pending state *before*
    /// the subspace changes (the event engine's stragglers can hold
    /// accumulated coefficients at a refresh boundary).
    pub fn refresh_due(&self, step: usize) -> bool {
        step % self.refresh_period == 0
    }

    /// Alg. 1 step A: every τ steps re-draw U, V from RNG(s_glob + t).
    /// All clients call this with the same t ⇒ identical subspaces.
    /// Returns true if a refresh happened (pending A's must be flushed
    /// *before* calling — coordinates are basis-relative).
    pub fn maybe_refresh(&mut self, step: usize) -> bool {
        if self.refresh_due(step) {
            self.regenerate(step);
            true
        } else {
            false
        }
    }

    pub fn regenerate(&mut self, step: usize) {
        let mut rng = Rng::new(self.global_seed.wrapping_add(step as u64));
        for l in 0..self.us.len() {
            rng.fill_normal(&mut self.us[l].data);
            rng.fill_normal(&mut self.vs[l].data);
        }
        self.epoch += 1;
    }

    /// Column i of U_ℓ (copied out of the row-major store).
    pub fn u_col(&self, l: usize, i: usize) -> Vec<f32> {
        let (n, r) = self.us[l].dims2();
        (0..n).map(|row| self.us[l].data[row * r + i]).collect()
    }

    pub fn v_col(&self, l: usize, j: usize) -> Vec<f32> {
        let (m, r) = self.vs[l].dims2();
        (0..m).map(|row| self.vs[l].data[row * r + j]).collect()
    }
}

/// Per-client coefficient accumulators (A_ℓ) + queued 1D dense components.
pub struct CoeffAccum {
    pub amats: Vec<Tensor>,
    /// dense 1D part of each pending message: (seed, coeff)
    dense_queue: Vec<(u64, f32)>,
    pub pending: usize,
}

impl CoeffAccum {
    pub fn new(basis: &SubspaceBasis) -> CoeffAccum {
        CoeffAccum {
            amats: (0..basis.n_layers())
                .map(|_| Tensor::zeros(&[basis.rank, basis.rank]))
                .collect(),
            dense_queue: vec![],
            pending: 0,
        }
    }

    /// Fold one flooded message in — O(1) per 2D layer.
    pub fn accumulate(&mut self, basis: &SubspaceBasis, msg: &SeedUpdate) {
        let coords = crate::zo::subcge_coords(msg.seed, basis.n_layers(), basis.rank_eff);
        let r = basis.rank;
        for (l, &(i, j)) in coords.iter().enumerate() {
            self.amats[l].data[i as usize * r + j as usize] += msg.coeff;
        }
        self.dense_queue.push((msg.seed, msg.coeff));
        self.pending += 1;
    }

    /// Apply all accumulated updates through the AOT pallas artifact
    /// (`<cfg>_subcge.hlo.txt`), then clear.
    pub fn flush_with_artifact(
        &mut self,
        basis: &SubspaceBasis,
        params: &mut ParamVec,
        exe: &Executable,
        rt: &Runtime,
    ) -> Result<()> {
        if self.pending == 0 {
            return Ok(());
        }
        {
            let mut args: Vec<Arg> = Vec::with_capacity(4 * basis.n_layers());
            for &pi in &basis.param_indices {
                args.push(Arg::F32(&params.tensors[pi]));
            }
            for u in &basis.us {
                args.push(Arg::F32(u));
            }
            for v in &basis.vs {
                args.push(Arg::F32(v));
            }
            for a in &self.amats {
                args.push(Arg::F32(a));
            }
            let out = exe.run(&args)?;
            rt.count_execution();
            for (k, &pi) in basis.param_indices.iter().enumerate() {
                params.tensors[pi] = out[k].clone();
            }
        }
        self.apply_dense_tail(basis, params);
        self.clear();
        Ok(())
    }

    /// Pure-rust flush (tests / microbench / no-runtime contexts).
    pub fn flush_rust(&mut self, basis: &SubspaceBasis, params: &mut ParamVec) {
        if self.pending == 0 {
            return;
        }
        for (l, &pi) in basis.param_indices.iter().enumerate() {
            apply_uavt(
                &mut params.tensors[pi],
                &basis.us[l],
                &self.amats[l],
                &basis.vs[l],
                basis.rank_eff,
            );
        }
        self.apply_dense_tail(basis, params);
        self.clear();
    }

    /// Reconstruct + apply the queued dense 1D components (tiny fraction
    /// of d; LN scales/biases only). The whole queue is applied in one
    /// sweep over the non-2D tensors via [`crate::zo::apply_dense_multi`]
    /// — bit-identical to the historical per-message full passes (the
    /// per-element f32 operation order is preserved; see that function's
    /// contract), but each tensor is pulled through cache once instead of
    /// `k` times.
    fn apply_dense_tail(&mut self, basis: &SubspaceBasis, params: &mut ParamVec) {
        if self.dense_queue.is_empty() {
            return;
        }
        let is2d: Vec<bool> = (0..params.tensors.len())
            .map(|i| basis.param_indices.contains(&i))
            .collect();
        let mut rngs: Vec<Rng> = self
            .dense_queue
            .iter()
            // sflint: allow(rng-hygiene, reason = "must reproduce the sender's zo::perturb_subcge dense-tail stream bit-for-bit; seed is an already-avalanched probe seed")
            .map(|&(seed, _)| Rng::new(seed ^ 0x1D1D_1D1D))
            .collect();
        let scales: Vec<f32> = self.dense_queue.iter().map(|&(_, coeff)| -coeff).collect();
        crate::zo::apply_dense_multi(
            params
                .tensors
                .iter_mut()
                .enumerate()
                .filter(|(idx, _)| !is2d[*idx])
                .map(|(_, t)| t.data.as_mut_slice()),
            &mut rngs,
            &scales,
        );
    }

    fn clear(&mut self) {
        for a in &mut self.amats {
            a.data.fill(0.0);
        }
        self.dense_queue.clear();
        self.pending = 0;
    }
}

/// Device-resident copy of the basis factors, re-uploaded only when the
/// basis epoch changes. Saves the dominant host→device transfer in the
/// flush (U/V are ~60% of the upload bytes and change only every τ steps;
/// DESIGN.md §Perf / EXPERIMENTS.md §Perf record the before/after).
pub struct DeviceBasisCache {
    epoch: u64,
    us: Vec<xla::PjRtBuffer>,
    vs: Vec<xla::PjRtBuffer>,
}

// SAFETY: device buffers are written once at upload and only read by
// executions afterwards; PJRT buffers may be shared across threads per the
// PJRT C API contract (see runtime::Executable).
unsafe impl Send for DeviceBasisCache {}
// SAFETY: same argument as Send directly above — after upload the cache is
// read-only (epoch and buffers never mutate through `&self`), so sharing
// references across threads cannot race.
unsafe impl Sync for DeviceBasisCache {}

impl DeviceBasisCache {
    pub fn new(basis: &SubspaceBasis, rt: &Runtime) -> Result<DeviceBasisCache> {
        let mut us = Vec::with_capacity(basis.us.len());
        let mut vs = Vec::with_capacity(basis.vs.len());
        for u in &basis.us {
            us.push(rt.upload_f32(&u.data, &u.shape)?);
        }
        for v in &basis.vs {
            vs.push(rt.upload_f32(&v.data, &v.shape)?);
        }
        Ok(DeviceBasisCache { epoch: basis.epoch, us, vs })
    }

    /// Re-upload if the basis refreshed since this cache was built.
    pub fn sync(&mut self, basis: &SubspaceBasis, rt: &Runtime) -> Result<()> {
        if self.epoch != basis.epoch {
            *self = Self::new(basis, rt)?;
        }
        Ok(())
    }
}

impl CoeffAccum {
    /// Buffer-path flush: basis factors stay device-resident; only the 2D
    /// params and the small A matrices cross the PCIe boundary per call.
    pub fn flush_with_artifact_cached(
        &mut self,
        basis: &SubspaceBasis,
        cache: &mut DeviceBasisCache,
        params: &mut ParamVec,
        exe: &Executable,
        rt: &Runtime,
    ) -> Result<()> {
        if self.pending == 0 {
            return Ok(());
        }
        cache.sync(basis, rt)?;
        {
            let n2d = basis.n_layers();
            let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(2 * n2d);
            for &pi in &basis.param_indices {
                let t = &params.tensors[pi];
                owned.push(rt.upload_f32(&t.data, &t.shape)?);
            }
            for a in &self.amats {
                owned.push(rt.upload_f32(&a.data, &a.shape)?);
            }
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(4 * n2d);
            args.extend(owned[..n2d].iter());
            args.extend(cache.us.iter());
            args.extend(cache.vs.iter());
            args.extend(owned[n2d..].iter());
            let out = exe.run_b(&args)?;
            rt.count_execution();
            for (k, &pi) in basis.param_indices.iter().enumerate() {
                params.tensors[pi] = out[k].clone();
            }
        }
        self.apply_dense_tail(basis, params);
        self.clear();
        Ok(())
    }
}

/// θ ← θ − U A Vᵀ restricted to the top-left rank_eff×rank_eff block of A
/// (pure-rust; mirrors the pallas kernel semantics; test oracle/fallback).
pub fn apply_uavt(theta: &mut Tensor, u: &Tensor, a: &Tensor, v: &Tensor, rank_eff: usize) {
    let (n, r) = u.dims2();
    let (m, _) = v.dims2();
    debug_assert_eq!(theta.dims2(), (n, m));
    // T = U A  (n × rank_eff block)
    let mut t = vec![0.0f32; n * r];
    for row in 0..n {
        for i in 0..rank_eff {
            let ui = u.data[row * r + i];
            if ui == 0.0 {
                continue;
            }
            let arow = &a.data[i * r..i * r + rank_eff];
            let trow = &mut t[row * r..row * r + rank_eff];
            for (tj, &aij) in trow.iter_mut().zip(arow.iter()) {
                *tj += ui * aij;
            }
        }
    }
    // θ -= T Vᵀ
    for row in 0..n {
        let trow = &t[row * r..row * r + rank_eff];
        let dst = &mut theta.data[row * m..(row + 1) * m];
        for (col, d) in dst.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            let vrow = &v.data[col * r..col * r + rank_eff];
            for (tj, vj) in trow.iter().zip(vrow.iter()) {
                acc += tj * vj;
            }
            *d -= acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;
    use crate::net::{MsgId, SeedUpdate};

    fn mini_manifest() -> Manifest {
        Manifest::parse(
            r#"{
          "config": {"name":"t","vocab":16,"seq":4,"dim":8,"layers":1,"heads":2,
                     "mlp_ratio":4,"batch":2,"num_classes":2,"lora_rank":2,
                     "subcge_rank":8,"num_params":200},
          "params": [{"name":"w1","shape":[16,8]},
                     {"name":"b1","shape":[8]},
                     {"name":"w2","shape":[8,12]}],
          "lora_params": [],
          "params2d": ["w1","w2"],
          "artifacts": {}
        }"#,
        )
        .unwrap()
    }

    fn mk_params(m: &Manifest) -> ParamVec {
        ParamVec::new(
            m.params.iter().map(|s| s.name.clone()).collect(),
            m.params
                .iter()
                .map(|s| {
                    let mut t = Tensor::zeros(&s.shape);
                    let mut rng = Rng::new(s.shape.len() as u64);
                    rng.fill_normal(&mut t.data);
                    t
                })
                .collect(),
        )
    }

    fn msg(origin: u32, step: u32, seed: u64, coeff: f32) -> SeedUpdate {
        SeedUpdate { id: MsgId { origin, step }, seed, coeff }
    }

    #[test]
    fn bases_identical_across_clients() {
        let m = mini_manifest();
        let a = SubspaceBasis::new(&m, 4, 10, 42);
        let b = SubspaceBasis::new(&m, 4, 10, 42);
        assert_eq!(a.us[0].data, b.us[0].data);
        assert_eq!(a.vs[1].data, b.vs[1].data);
        let c = SubspaceBasis::new(&m, 4, 10, 43);
        assert_ne!(a.us[0].data, c.us[0].data);
    }

    #[test]
    fn refresh_changes_basis_on_period_only() {
        let m = mini_manifest();
        let mut s = SubspaceBasis::new(&m, 4, 10, 42);
        let before = s.us[0].data.clone();
        assert!(!s.maybe_refresh(7));
        assert_eq!(s.us[0].data, before);
        assert!(s.maybe_refresh(10));
        assert_ne!(s.us[0].data, before);
    }

    #[test]
    fn accumulate_then_flush_equals_per_message_rank1() {
        // Eq-10 consistency: batched A-flush == one-by-one rank-1 applies
        let m = mini_manifest();
        let basis = SubspaceBasis::new(&m, 8, 100, 1);
        let mut acc = CoeffAccum::new(&basis);
        let mut p_batch = mk_params(&m);
        let mut p_seq = p_batch.clone();

        let msgs: Vec<SeedUpdate> =
            (0..20).map(|k| msg(0, k, 1000 + k as u64, 0.01 * (k as f32 - 10.0))).collect();
        for mm in &msgs {
            acc.accumulate(&basis, mm);
            crate::zo::perturb_subcge(&mut p_seq, &basis, mm.seed, -mm.coeff);
        }
        acc.flush_rust(&basis, &mut p_batch);
        for (a, b) in p_batch.tensors.iter().zip(p_seq.tensors.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
        assert_eq!(acc.pending, 0);
    }

    #[test]
    fn flush_empty_is_noop() {
        let m = mini_manifest();
        let basis = SubspaceBasis::new(&m, 4, 100, 1);
        let mut acc = CoeffAccum::new(&basis);
        let mut p = mk_params(&m);
        let orig = p.clone();
        acc.flush_rust(&basis, &mut p);
        assert_eq!(p.tensors[0].data, orig.tensors[0].data);
    }

    #[test]
    fn rank_eff_restricts_coordinates() {
        let m = mini_manifest();
        let basis = SubspaceBasis::new(&m, 2, 100, 1);
        let mut acc = CoeffAccum::new(&basis);
        for k in 0..50 {
            acc.accumulate(&basis, &msg(0, k, k as u64 * 31 + 7, 0.1));
        }
        let r = basis.rank;
        for a in &acc.amats {
            for i in 0..r {
                for j in 0..r {
                    if i >= 2 || j >= 2 {
                        assert_eq!(a.data[i * r + j], 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn order_of_accumulation_is_irrelevant() {
        // flooding gives no ordering guarantees; A-folding must commute
        let m = mini_manifest();
        let basis = SubspaceBasis::new(&m, 8, 100, 1);
        let msgs: Vec<SeedUpdate> =
            (0..12).map(|k| msg(k, 0, 77 + k as u64, 0.05 * k as f32)).collect();
        let mut fwd = CoeffAccum::new(&basis);
        let mut rev = CoeffAccum::new(&basis);
        for mm in &msgs {
            fwd.accumulate(&basis, mm);
        }
        for mm in msgs.iter().rev() {
            rev.accumulate(&basis, mm);
        }
        let (mut pa, mut pb) = (mk_params(&m), mk_params(&m));
        fwd.flush_rust(&basis, &mut pa);
        rev.flush_rust(&basis, &mut pb);
        for (a, b) in pa.tensors.iter().zip(pb.tensors.iter()) {
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn apply_uavt_matches_naive() {
        let mut rng = Rng::new(3);
        let (n, mcols, r) = (6, 5, 4);
        let mut theta = Tensor::zeros(&[n, mcols]);
        let mut u = Tensor::zeros(&[n, r]);
        let mut v = Tensor::zeros(&[mcols, r]);
        let mut a = Tensor::zeros(&[r, r]);
        rng.fill_normal(&mut theta.data);
        rng.fill_normal(&mut u.data);
        rng.fill_normal(&mut v.data);
        rng.fill_normal(&mut a.data);
        let mut want = theta.clone();
        for row in 0..n {
            for col in 0..mcols {
                let mut s = 0.0f32;
                for i in 0..r {
                    for j in 0..r {
                        s += u.data[row * r + i] * a.data[i * r + j] * v.data[col * r + j];
                    }
                }
                want.data[row * mcols + col] -= s;
            }
        }
        apply_uavt(&mut theta, &u, &a, &v, r);
        for (x, y) in theta.data.iter().zip(want.data.iter()) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}
