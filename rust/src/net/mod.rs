//! Simulated message-passing network with exact byte accounting.
//!
//! The paper (§2.1) assumes a connected, static, reliable graph; clients
//! exchange messages only with neighbors. This module provides that
//! substrate in-process: per-directed-edge FIFO queues, typed payloads with
//! a defined wire size, and per-edge byte/message counters — the counters
//! are the measurement behind every "Cost" column we reproduce (Fig 1/3,
//! Table 8).
//!
//! Wire-size conventions (documented in EXPERIMENTS.md):
//! * seed–scalar update: origin+step id (8 B) + seed (8 B) + coeff (4 B) = 20 B
//! * dense tensor traffic: 4 B per f32 element (+16 B header)
//! * sparse top-K traffic: 8 B per (index, value) pair (+16 B header)
//! * repair summary: 8 B header + 4 B per origin (contiguous high-water mark)
//! * repair gap-fill: 8 B header + 20 B per update (9 B when quantized)
//!
//! # Fault injection
//!
//! The reliable static graph is only the *default*. Installing a
//! [`NetCond`] ([`Network::install`]) turns on the unreliable-network &
//! churn model: per-edge packet loss and delivery delay, scheduled link
//! down-windows, and node churn, all driven by a dedicated seeded RNG
//! stream so faulty runs stay bit-for-bit reproducible. Without an
//! installed model the network behaves exactly as the pre-netcond
//! simulator (no RNG draws, immediate delivery).
//!
//! Two clocks govern faults: [`Network::set_step`] advances the
//! *schedule* clock (training iterations — link/node windows, repair
//! triggers) and [`Network::tick`] advances the *delivery* clock
//! (communication rounds — delay queues).
//!
//! Who drives those clocks is the execution engine's choice
//! ([`crate::sim::Driver`]): the lockstep loop advances the schedule once
//! per shared iteration and ticks `k` rounds inside each `communicate`;
//! the event-driven engine (`--time-model event`) re-keys both to virtual
//! time — one tick every [`crate::sched::TICKS_PER_ROUND`] virtual ticks
//! and one schedule step per *nominal* iteration — so `delay` keeps its
//! round unit and down-windows their iteration unit under either engine.
//!
//! ```
//! use seedflood::net::{MsgId, Network, Payload, SeedUpdate};
//! use seedflood::topology::Topology;
//!
//! let mut net = Network::new(Topology::ring(4));
//! let update = SeedUpdate { id: MsgId { origin: 0, step: 0 }, seed: 7, coeff: 0.5 };
//! net.send(0, 1, Payload::Seeds(vec![update]));
//! assert_eq!(net.acct.total_bytes, SeedUpdate::WIRE_BYTES);
//! let msgs = net.recv_all(1);
//! assert_eq!(msgs.len(), 1);
//! assert_eq!(msgs[0].from, 0);
//! ```

use std::sync::Arc;

use anyhow::Result;

use crate::netcond::{Event, NetCond};
use crate::rng::Rng;
use crate::tensor::ParamVec;
use crate::topology::Topology;

/// Globally unique id of a zeroth-order update: (origin client, step,
/// local probe index). This is what the flooding dedup set stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MsgId {
    pub origin: u32,
    pub step: u32,
}

/// A seed-reconstructible zeroth-order update (paper §3.1):
/// `m = (s, η·α/n)` — the entire payload of a SeedFlood message.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SeedUpdate {
    pub id: MsgId,
    pub seed: u64,
    pub coeff: f32,
}

impl SeedUpdate {
    pub const WIRE_BYTES: u64 = 20;
    /// Quantized wire format (Zelikman et al. 2023, "just one byte per
    /// gradient", cited in §3.1): origin+step id (8 B) + implicit seed
    /// (derived from id via the shared probe_seed function, 0 B) + 1-byte
    /// µ-law coefficient.
    pub const WIRE_BYTES_QUANTIZED: u64 = 9;

    /// The code an exactly-zero coefficient maps to. The grid is
    /// *mid-tread*: codes are `128 + round(y·127)` with `y` the µ-law
    /// companded value, so code 128 sits exactly on zero and the zero cell
    /// is symmetric — a historical mid-riser grid (offset 127.5) had no
    /// zero code at all, decoding `c = 0.0` to a small positive value and
    /// injecting a systematic drift under `--quantize-msgs`. Code 0 is
    /// unused (255 symmetric levels).
    pub const ZERO_CODE: u8 = 128;

    /// µ-law quantize the coefficient to 8 bits around `scale` (callers
    /// use the learning rate — coefficients are η·α/n, so |c|/scale is
    /// O(α) and well covered by µ-law's dynamic range). Monotone in `c`;
    /// `c = 0.0` maps to [`Self::ZERO_CODE`] and round-trips to exactly
    /// 0.0, with the same dead zone on either side of zero.
    pub fn quantize_coeff(c: f32, scale: f32) -> u8 {
        let x = (c / (scale * 64.0)).clamp(-1.0, 1.0);
        const MU: f32 = 255.0;
        let y = x.signum() * (1.0 + MU * x.abs()).ln() / (1.0 + MU).ln();
        (Self::ZERO_CODE as i32 + (y * 127.0).round() as i32).clamp(1, 255) as u8
    }

    /// Inverse of [`Self::quantize_coeff`]; monotone, with
    /// [`Self::ZERO_CODE`] decoding to exactly 0.0.
    pub fn dequantize_coeff(q: u8, scale: f32) -> f32 {
        const MU: f32 = 255.0;
        let y = (q as f32 - Self::ZERO_CODE as f32) / 127.0;
        let x = y.signum() * ((1.0 + MU).powf(y.abs()) - 1.0) / MU;
        x * scale * 64.0
    }

    /// Round-trip through the 1-byte wire format.
    pub fn quantized(self, scale: f32) -> SeedUpdate {
        SeedUpdate {
            coeff: Self::dequantize_coeff(Self::quantize_coeff(self.coeff, scale), scale),
            ..self
        }
    }
}

/// Typed network payloads covering every method in the paper's comparison.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Flooded batch of seed-scalar updates (SeedFlood / DZSGD-seeded).
    Seeds(Vec<SeedUpdate>),
    /// Same but counted at the 1-byte-quantized wire size (the Zelikman
    /// et al. format; values are already dequantized at this layer).
    SeedsQuantized(Vec<SeedUpdate>),
    /// Full dense model / model-delta (DSGD, DZSGD; Arc: zero-copy fan-out).
    Dense(Arc<ParamVec>),
    /// Sparse top-K compressed delta (ChocoSGD): per-tensor (index, value).
    Sparse(Arc<Vec<Vec<(u32, f32)>>>),
    /// Gap-request repair, step 1: per-origin contiguous high-water marks
    /// (origin-indexed; everything below `summary[o]` seen from origin
    /// `o`). O(n) bytes, broadcast by a recovering client so neighbors can
    /// answer with only what it missed. Counted into
    /// [`Accounting::repair_bytes`].
    Summary(Arc<Vec<u32>>),
    /// Gap-request repair, step 2: the retained messages a received
    /// [`Payload::Summary`] showed missing, unicast back to the requester.
    /// O(gap) bytes; `quantized` mirrors the run's seed wire format so
    /// repair traffic is costed like the flood traffic it replaces.
    /// Counted into [`Accounting::repair_bytes`].
    GapFill { msgs: Vec<SeedUpdate>, quantized: bool },
}

impl Payload {
    /// Framing header modeled for the repair payloads (type tag + length).
    pub const REPAIR_HEADER_BYTES: u64 = 8;

    /// Logical bytes on the wire (the paper's communication-cost metric).
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Seeds(v) => v.len() as u64 * SeedUpdate::WIRE_BYTES,
            Payload::SeedsQuantized(v) => {
                v.len() as u64 * SeedUpdate::WIRE_BYTES_QUANTIZED
            }
            Payload::Dense(p) => 16 + 4 * p.num_elements() as u64,
            Payload::Sparse(t) => {
                16 + 8 * t.iter().map(|v| v.len() as u64).sum::<u64>()
            }
            Payload::Summary(h) => Self::REPAIR_HEADER_BYTES + 4 * h.len() as u64,
            Payload::GapFill { msgs, quantized } => {
                let per_msg = if *quantized {
                    SeedUpdate::WIRE_BYTES_QUANTIZED
                } else {
                    SeedUpdate::WIRE_BYTES
                };
                Self::REPAIR_HEADER_BYTES + msgs.len() as u64 * per_msg
            }
        }
    }

    /// Whether this payload is repair traffic (gap-request protocol);
    /// [`Network::send`] attributes its bytes to
    /// [`Accounting::repair_bytes`].
    pub fn is_repair(&self) -> bool {
        matches!(self, Payload::Summary(_) | Payload::GapFill { .. })
    }
}

#[derive(Clone, Debug)]
pub struct Message {
    pub from: usize,
    pub payload: Payload,
}

/// Per-network traffic counters.
#[derive(Clone, Debug, Default)]
pub struct Accounting {
    /// bytes sent over each directed edge, indexed by flat edge id
    // sflint: allow(accounting-conservation, reason = "aggregated into RunRecord::per_edge_bytes by Network::per_edge_bytes; total_bytes carries the serialized sum")
    pub edge_bytes: Vec<u64>,
    pub total_bytes: u64,
    // sflint: allow(accounting-conservation, reason = "denominator of Accounting::delivery_ratio, which sim stores as RunRecord::delivery_ratio")
    pub total_messages: u64,
    /// messages actually handed to a receiver by [`Network::recv_all`]
    // sflint: allow(accounting-conservation, reason = "numerator of Accounting::delivery_ratio, which sim stores as RunRecord::delivery_ratio")
    pub delivered_messages: u64,
    /// messages killed by fault injection (loss, down links, down nodes);
    /// their bytes stay counted — transmission is what costs
    pub dropped_messages: u64,
    /// bytes attributable to repair: gap-request summaries and gap-fills
    /// (counted by [`Network::send`] via [`Payload::is_repair`]) plus
    /// legacy re-flood broadcasts (attributed by the flooding layer,
    /// `flood::FloodState::send_round`). A subset of `total_bytes`.
    pub repair_bytes: u64,
    /// transmissions attributable to repair (same attribution rules)
    pub repair_messages: u64,
    /// wire bytes currently queued on edges (delayed, or buffered for an
    /// offline receiver) — the payload-memory gauge behind
    /// [`Self::peak_in_flight_bytes`]. Zero whenever the network is
    /// drained.
    // sflint: allow(accounting-conservation, reason = "transient gauge, asserted zero on drain by Network::debug_check_conservation; peak_in_flight_bytes is its serialized summary")
    pub in_flight_bytes: u64,
    /// high-water mark of [`Self::in_flight_bytes`] over the run: the
    /// network-side half of the simulation's memory story (the dedup-side
    /// half is `RunRecord::flood_dedup_bytes`) — at 100k clients the
    /// in-flight payload volume, not the graph, is what bounds a round
    pub peak_in_flight_bytes: u64,
}

impl Accounting {
    /// Delivered fraction of all transmissions. Messages still in flight
    /// (delayed, or buffered for an offline receiver) count against the
    /// ratio until they are received; on the reliable default path every
    /// drained run reports exactly 1.0.
    pub fn delivery_ratio(&self) -> f64 {
        if self.total_messages == 0 {
            return 1.0;
        }
        self.delivered_messages as f64 / self.total_messages as f64
    }
}

/// Compiled per-edge fault state (from an installed [`NetCond`]).
struct CondState {
    /// iid loss probability per flat directed edge
    loss: Vec<f64>,
    /// delivery delay in rounds per flat directed edge
    delay: Vec<u64>,
    /// schedule-evaluated: link currently down, per flat directed edge
    link_down: Vec<bool>,
    /// schedule-evaluated: node currently offline
    node_down: Vec<bool>,
    /// repair trigger for the current step (recovery or anti-entropy)
    repair_due: Vec<bool>,
    /// previous step's per-node impairment, for recovery-edge detection
    impaired_prev: Vec<bool>,
    /// reusable scratch for [`Network::set_step`]'s impairment pass —
    /// computed here each step, then swapped into `impaired_prev` (no
    /// per-iteration allocation)
    impaired_scratch: Vec<bool>,
    events: Vec<Event>,
    repair_every: usize,
    /// dedicated fault stream — advanced only on the sequential
    /// communication path, never by worker threads, so faulty runs keep
    /// the engine's `--threads` determinism contract
    rng: Rng,
}

/// Sentinel for "no node" in [`MsgPool`]'s intrusive lists.
const NIL: u32 = u32::MAX;

/// One slab slot of a per-edge FIFO (see [`MsgPool`]).
struct MsgNode {
    /// delivery round (receivable once the clock reaches it)
    at: u64,
    /// next node in the same edge's FIFO, or [`NIL`]
    next: u32,
    /// `None` while the slot sits on the free list
    msg: Option<Message>,
}

/// Pooled per-edge FIFOs: one contiguous message slab plus a free list,
/// with an intrusive singly-linked list per directed edge. Replaces one
/// heap-allocated `VecDeque` per edge — at n = 100k that was hundreds of
/// thousands of resident buffers; here idle edges cost 12 bytes of
/// head/tail/len and the slab's capacity tracks the *peak in-flight*
/// message count, not the edge count.
struct MsgPool {
    nodes: Vec<MsgNode>,
    free: Vec<u32>,
    head: Vec<u32>,
    tail: Vec<u32>,
    len: Vec<u32>,
}

impl MsgPool {
    fn new(edges: usize) -> MsgPool {
        MsgPool {
            nodes: vec![],
            free: vec![],
            head: vec![NIL; edges],
            tail: vec![NIL; edges],
            len: vec![0; edges],
        }
    }

    fn push(&mut self, eid: usize, at: u64, msg: Message) {
        let slot = match self.free.pop() {
            Some(s) => {
                self.nodes[s as usize] = MsgNode { at, next: NIL, msg: Some(msg) };
                s
            }
            None => {
                self.nodes.push(MsgNode { at, next: NIL, msg: Some(msg) });
                (self.nodes.len() - 1) as u32
            }
        };
        if self.tail[eid] == NIL {
            self.head[eid] = slot;
        } else {
            self.nodes[self.tail[eid] as usize].next = slot;
        }
        self.tail[eid] = slot;
        self.len[eid] += 1;
    }

    /// Pop the edge's front message if it is due at `now`. FIFO: per-edge
    /// delay is constant, so the front is always the earliest arrival.
    fn pop_due(&mut self, eid: usize, now: u64) -> Option<Message> {
        let h = self.head[eid];
        if h == NIL || self.nodes[h as usize].at > now {
            return None;
        }
        let node = &mut self.nodes[h as usize];
        let msg = node.msg.take();
        self.head[eid] = node.next;
        if self.head[eid] == NIL {
            self.tail[eid] = NIL;
        }
        self.len[eid] -= 1;
        self.free.push(h);
        msg
    }

    /// Drop everything queued on `eid`; returns (messages, wire bytes)
    /// killed. Payloads are released immediately, not at slot reuse.
    fn purge(&mut self, eid: usize) -> (usize, u64) {
        let mut h = self.head[eid];
        let mut killed = 0;
        let mut bytes = 0u64;
        while h != NIL {
            let node = &mut self.nodes[h as usize];
            if let Some(msg) = node.msg.take() {
                bytes += msg.payload.wire_bytes();
            }
            self.free.push(h);
            h = node.next;
            killed += 1;
        }
        self.head[eid] = NIL;
        self.tail[eid] = NIL;
        self.len[eid] = 0;
        (killed, bytes)
    }

    fn queued(&self, eid: usize) -> usize {
        self.len[eid] as usize
    }
}

/// The simulated network over a [`Topology`], in CSR edge layout.
///
/// Both edge directions live in flat offset arrays: `out` holds the
/// (dst, eid) rows of every source concatenated (eid = position in `out`,
/// assigned src-ascending then dst-ascending — the historical id order),
/// and `inc` the (src, eid) rows of every destination, src ascending.
/// Edge-id lookup is a binary search of the source's row (rows are
/// dst-sorted), replacing the former `HashMap<(usize, usize), usize>`;
/// message queues live in one pooled slab ([`MsgPool`]) instead of a
/// `VecDeque` per directed edge. Construction and memory are O(n + m)
/// flat arrays with no per-edge heap allocation — the layout that keeps
/// 100k-client graphs cheap — while [`Self::recv_all`]'s ascending-source
/// drain order and [`Self::send`]'s RNG draw order stay bit-for-bit
/// identical to the previous implementation (determinism contract, see
/// `recv_all_orders_sources_ascending` and rust/tests/properties.rs).
pub struct Network {
    topo: Topology,
    /// CSR out-edges: flat (dst, eid) pairs; row of `src` is
    /// `out[out_off[src]..out_off[src+1]]`, dst ascending, eid = index
    out: Vec<(usize, usize)>,
    out_off: Vec<usize>,
    /// CSR in-edges: flat (src, eid) pairs; row of `dst` is
    /// `inc[in_off[dst]..in_off[dst+1]]`, src ascending — keeps recv_all's
    /// message order identical to the historical 0..n scan
    inc: Vec<(usize, usize)>,
    in_off: Vec<usize>,
    /// pooled per-edge FIFOs; entries are (deliver-at round, message)
    pool: MsgPool,
    pub acct: Accounting,
    /// delivery clock, in communication rounds (see [`Self::tick`])
    now: u64,
    /// messages currently queued on some edge (see [`Self::in_flight`])
    in_flight: usize,
    /// fault injection, absent by default (see [`Self::install`])
    cond: Option<CondState>,
}

/// Directed-edge id lookup in the CSR out table: binary search of the
/// dst-sorted row of `src`. Free function so [`Network::set_step`] can use
/// it while holding a mutable borrow of the fault state.
fn edge_id_in(out: &[(usize, usize)], out_off: &[usize], src: usize, dst: usize) -> Option<usize> {
    if src >= out_off.len() - 1 {
        return None;
    }
    let row = &out[out_off[src]..out_off[src + 1]];
    row.binary_search_by_key(&dst, |&(d, _)| d).ok().map(|p| out_off[src] + p)
}

impl Network {
    pub fn new(topo: Topology) -> Network {
        let n = topo.n;
        let m2: usize = (0..n).map(|i| topo.neighbors(i).len()).sum();
        let mut out = Vec::with_capacity(m2);
        let mut out_off = Vec::with_capacity(n + 1);
        out_off.push(0);
        for src in 0..n {
            for &dst in topo.neighbors(src) {
                let eid = out.len();
                out.push((dst, eid));
            }
            out_off.push(out.len());
        }
        // reverse CSR: count in-degrees, prefix-sum, fill — iterating
        // sources in ascending order makes each row src-ascending for free
        let mut in_off = vec![0usize; n + 1];
        for &(dst, _) in &out {
            in_off[dst + 1] += 1;
        }
        for i in 0..n {
            in_off[i + 1] += in_off[i];
        }
        let mut cursor = in_off.clone();
        let mut inc = vec![(0usize, 0usize); m2];
        for src in 0..n {
            for k in out_off[src]..out_off[src + 1] {
                let (dst, eid) = out[k];
                inc[cursor[dst]] = (src, eid);
                cursor[dst] += 1;
            }
        }
        Network {
            pool: MsgPool::new(m2),
            out,
            out_off,
            inc,
            in_off,
            acct: Accounting {
                edge_bytes: vec![0; m2],
                ..Default::default()
            },
            now: 0,
            in_flight: 0,
            cond: None,
            topo,
        }
    }

    /// Compile and install a fault model. Validates the model against this
    /// network's topology. Call before the first send; the schedule starts
    /// fully up — drive it with [`Self::set_step`].
    pub fn install(&mut self, cond: &NetCond) -> Result<()> {
        cond.validate(&self.topo)?;
        let ne = self.out.len();
        let n = self.topo.n;
        let mut loss = vec![cond.loss; ne];
        let mut delay = vec![cond.delay; ne];
        for &(a, b, p) in &cond.edge_loss {
            for (x, y) in [(a, b), (b, a)] {
                if let Some(e) = edge_id_in(&self.out, &self.out_off, x, y) {
                    loss[e] = p;
                }
            }
        }
        for &(a, b, k) in &cond.edge_delay {
            for (x, y) in [(a, b), (b, a)] {
                if let Some(e) = edge_id_in(&self.out, &self.out_off, x, y) {
                    delay[e] = k;
                }
            }
        }
        self.cond = Some(CondState {
            loss,
            delay,
            link_down: vec![false; ne],
            node_down: vec![false; n],
            repair_due: vec![false; n],
            impaired_prev: vec![false; n],
            impaired_scratch: vec![false; n],
            events: cond.events.clone(),
            repair_every: cond.repair_every,
            rng: Rng::new(cond.seed),
        });
        Ok(())
    }

    /// Advance the fault schedule to training iteration `t`: evaluate the
    /// link/node down-windows and compute the per-client repair triggers
    /// (down→up recovery edges, plus the periodic anti-entropy heartbeat).
    /// No-op without an installed fault model.
    pub fn set_step(&mut self, t: usize) {
        let Some(c) = self.cond.as_mut() else { return };
        for v in c.link_down.iter_mut() {
            *v = false;
        }
        for v in c.node_down.iter_mut() {
            *v = false;
        }
        for ev in &c.events {
            match *ev {
                Event::Node { id, from, until } => {
                    if t >= from && t < until {
                        c.node_down[id] = true;
                    }
                }
                Event::Link { a, b, from, until } => {
                    if t >= from && t < until {
                        for (x, y) in [(a, b), (b, a)] {
                            if let Some(e) = edge_id_in(&self.out, &self.out_off, x, y) {
                                c.link_down[e] = true;
                            }
                        }
                    }
                }
            }
        }
        // links don't buffer: everything in flight on a down link dies the
        // moment the schedule marks it down, independent of when (or
        // whether) the receiver polls — unlike node churn, where in-flight
        // traffic stays buffered on the in-edges until the node rejoins
        for (eid, down) in c.link_down.iter().enumerate() {
            if *down && self.pool.queued(eid) > 0 {
                let (purged, purged_bytes) = self.pool.purge(eid);
                self.acct.dropped_messages += purged as u64;
                self.in_flight -= purged;
                self.acct.in_flight_bytes -= purged_bytes;
            }
        }
        // per-node impairment — exactly the local knowledge a real client
        // has: itself offline, a neighbor offline, or an incident link
        // down. Computed into the reusable scratch (no per-step alloc),
        // then swapped into impaired_prev.
        let n = self.topo.n;
        for (i, imp) in c.impaired_scratch.iter_mut().enumerate() {
            *imp = c.node_down[i]
                || self.out[self.out_off[i]..self.out_off[i + 1]]
                    .iter()
                    .any(|&(dst, eid)| c.node_down[dst] || c.link_down[eid]);
        }
        let periodic = c.repair_every > 0 && t > 0 && t % c.repair_every == 0;
        for i in 0..n {
            c.repair_due[i] = (c.impaired_prev[i] && !c.impaired_scratch[i]) || periodic;
        }
        std::mem::swap(&mut c.impaired_prev, &mut c.impaired_scratch);
        self.debug_check_conservation();
    }

    /// Advance the delivery clock one communication round (delayed
    /// messages become receivable once the clock passes their arrival).
    pub fn tick(&mut self) {
        self.now += 1;
    }

    /// Current delivery-clock round (number of [`Self::tick`]s so far) —
    /// diagnostic only; delivery decisions always compare against the
    /// live clock inside [`Self::recv_all`].
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Whether client `i` is currently online (always true without a
    /// fault model). Offline clients neither transmit nor receive; the
    /// protocol layer also skips their send rounds so outboxes persist.
    pub fn is_online(&self, i: usize) -> bool {
        match &self.cond {
            Some(c) => !c.node_down[i],
            None => true,
        }
    }

    /// Whether client `i` should run its repair protocol this iteration
    /// (set by [`Self::set_step`]: an incident link/node just recovered,
    /// or the anti-entropy period elapsed). What "repair" means is the
    /// flooding layer's choice — see [`crate::flood::RepairMode`].
    pub fn should_repair(&self, i: usize) -> bool {
        match &self.cond {
            Some(c) => c.repair_due[i],
            None => false,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn n(&self) -> usize {
        self.topo.n
    }

    /// Out-edges of `src` as (dst, flat edge id), dst ascending — a slice
    /// of the CSR table.
    pub fn out_edges(&self, src: usize) -> &[(usize, usize)] {
        &self.out[self.out_off[src]..self.out_off[src + 1]]
    }

    fn edge_id(&self, src: usize, dst: usize) -> Option<usize> {
        edge_id_in(&self.out, &self.out_off, src, dst)
    }

    /// Send to one neighbor. Panics if (src,dst) is not an edge — the
    /// decentralized constraint is enforced structurally.
    ///
    /// Fault semantics: an offline *sender* transmits nothing (no cost);
    /// everything else is counted as transmitted, then possibly killed by
    /// a down link, an offline receiver, or a seeded loss draw — dropped
    /// bytes stay in the accounting because transmission is what costs.
    pub fn send(&mut self, src: usize, dst: usize, payload: Payload) {
        let eid = self
            .edge_id(src, dst)
            .unwrap_or_else(|| panic!("({src},{dst}) is not an edge of {}", self.topo.kind));
        self.send_on_edge(src, dst, eid, payload);
    }

    /// [`Self::send`] with the edge id already in hand (the broadcast fast
    /// path — no per-neighbor binary search).
    fn send_on_edge(&mut self, src: usize, dst: usize, eid: usize, payload: Payload) {
        if let Some(c) = self.cond.as_ref() {
            if c.node_down[src] {
                return;
            }
        }
        let bytes = payload.wire_bytes();
        self.acct.edge_bytes[eid] += bytes;
        self.acct.total_bytes += bytes;
        self.acct.total_messages += 1;
        if payload.is_repair() {
            self.acct.repair_bytes += bytes;
            self.acct.repair_messages += 1;
        }
        let deliver_at = match self.cond.as_mut() {
            Some(c) => {
                if c.node_down[dst] || c.link_down[eid] {
                    self.acct.dropped_messages += 1;
                    self.debug_check_conservation();
                    return;
                }
                if c.loss[eid] > 0.0 && c.rng.next_f64() < c.loss[eid] {
                    self.acct.dropped_messages += 1;
                    self.debug_check_conservation();
                    return;
                }
                self.now + c.delay[eid]
            }
            None => self.now,
        };
        self.in_flight += 1;
        self.acct.in_flight_bytes += bytes;
        self.acct.peak_in_flight_bytes =
            self.acct.peak_in_flight_bytes.max(self.acct.in_flight_bytes);
        self.pool.push(eid, deliver_at, Message { from: src, payload });
        self.debug_check_conservation();
    }

    /// Send the same payload to every neighbor of `src` (clone-per-edge is
    /// cheap: payloads are Arc or small vectors). Iterates the CSR row in
    /// place — no neighbor-list clone on this per-client-per-round path.
    pub fn broadcast(&mut self, src: usize, payload: &Payload) {
        for k in self.out_off[src]..self.out_off[src + 1] {
            let (dst, eid) = self.out[k];
            self.send_on_edge(src, dst, eid, payload.clone());
        }
    }

    /// Drain every *due* queued message destined for `dst` — O(in-degree)
    /// via the reverse CSR table, sources in ascending order. Messages
    /// whose delivery round is still in the future stay queued (per-edge
    /// delay is constant, so FIFO order is preserved).
    ///
    /// Faults: an offline receiver drains nothing — its in-flight traffic
    /// stays buffered until it rejoins (nodes buffer). Down *links* never
    /// hold traffic at all: sends onto them are dropped and anything
    /// already in flight is purged when the schedule marks the link down
    /// ([`Self::set_step`]), so a link queue reaching this point is live.
    pub fn recv_all(&mut self, dst: usize) -> Vec<Message> {
        if let Some(c) = self.cond.as_ref() {
            if c.node_down[dst] {
                return vec![];
            }
        }
        let mut out = vec![];
        for k in self.in_off[dst]..self.in_off[dst + 1] {
            let eid = self.inc[k].1;
            while let Some(msg) = self.pool.pop_due(eid, self.now) {
                out.push(msg);
            }
        }
        self.acct.delivered_messages += out.len() as u64;
        self.in_flight -= out.len();
        let delivered_bytes: u64 = out.iter().map(|m| m.payload.wire_bytes()).sum();
        self.acct.in_flight_bytes -= delivered_bytes;
        self.debug_check_conservation();
        out
    }

    /// Messages currently queued on some edge (delayed, or buffered for a
    /// churned-out receiver). The event driver uses this to prove a
    /// delivery round cannot do anything and skip its scans.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Debug-build conservation invariant — the dynamic complement of
    /// sflint's accounting-conservation rule: every transmission ever
    /// counted is delivered, dropped, or still in flight, and a drained
    /// network holds zero in-flight payload bytes (one-directional
    /// because zero-byte payloads exist). Called after every ledger
    /// mutation; `cargo test` builds with debug_assertions enabled, so
    /// the whole suite exercises it.
    #[inline]
    fn debug_check_conservation(&self) {
        debug_assert_eq!(
            self.acct.total_messages,
            self.acct.delivered_messages + self.acct.dropped_messages + self.in_flight as u64,
            "message ledger out of balance: total != delivered + dropped + in-flight"
        );
        debug_assert!(
            self.in_flight > 0 || self.acct.in_flight_bytes == 0,
            "in-flight byte gauge nonzero on a drained network"
        );
    }

    /// Paper convention: "total transmitted volume over the training per
    /// edge", counted one-directionally — total bytes / directed edges.
    pub fn per_edge_bytes(&self) -> f64 {
        let edges = self.acct.edge_bytes.len().max(1);
        self.acct.total_bytes as f64 / edges as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn seed_payload(n: usize) -> Payload {
        Payload::Seeds(
            (0..n)
                .map(|i| SeedUpdate {
                    id: MsgId { origin: 0, step: i as u32 },
                    seed: i as u64,
                    coeff: 1.0,
                })
                .collect(),
        )
    }

    #[test]
    fn send_recv_roundtrip() {
        let mut net = Network::new(Topology::ring(4));
        net.send(0, 1, seed_payload(3));
        let msgs = net.recv_all(1);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].from, 0);
        match &msgs[0].payload {
            Payload::Seeds(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        // queue drained
        assert!(net.recv_all(1).is_empty());
        assert_eq!(net.acct.delivered_messages, 1);
        // and nothing left on the payload-memory gauge
        assert_eq!(net.acct.in_flight_bytes, 0);
    }

    #[test]
    fn in_flight_gauge_tracks_queued_payload_bytes() {
        let mut net = Network::new(Topology::ring(4));
        net.install(&crate::netcond::NetCond::parse("delay=2;seed=1").unwrap()).unwrap();
        net.send(0, 1, seed_payload(3));
        let queued = net.acct.in_flight_bytes;
        assert_eq!(queued, seed_payload(3).wire_bytes());
        assert_eq!(net.acct.peak_in_flight_bytes, queued);
        // the payload waits out its delay on the edge: the gauge holds
        assert!(net.recv_all(1).is_empty());
        assert_eq!(net.acct.in_flight_bytes, queued);
        net.tick();
        net.tick();
        assert_eq!(net.recv_all(1).len(), 1);
        // drained: the gauge returns to zero, the high-water mark stays
        assert_eq!(net.acct.in_flight_bytes, 0);
        assert_eq!(net.acct.peak_in_flight_bytes, queued);
        assert_eq!(net.acct.delivery_ratio(), 1.0);
    }

    #[test]
    #[should_panic(expected = "not an edge")]
    fn non_edge_send_panics() {
        let mut net = Network::new(Topology::ring(6));
        net.send(0, 3, seed_payload(1)); // 0-3 not adjacent on a 6-ring
    }

    #[test]
    fn byte_accounting_seed() {
        let mut net = Network::new(Topology::ring(4));
        net.send(0, 1, seed_payload(5));
        assert_eq!(net.acct.total_bytes, 5 * SeedUpdate::WIRE_BYTES);
        assert_eq!(net.acct.total_messages, 1);
    }

    #[test]
    fn quantized_coeff_roundtrip_accuracy() {
        // 1-byte µ-law must preserve sign and ~1% relative accuracy over
        // the dynamic range the flooding coefficients actually occupy
        let scale = 1e-3f32;
        for &c in &[0.0f32, 1e-5, -1e-5, 3e-4, -3e-4, 2e-3, -2e-3, 0.05, -0.05] {
            let q = SeedUpdate::quantize_coeff(c, scale);
            let back = SeedUpdate::dequantize_coeff(q, scale);
            assert_eq!(back.signum(), if c == 0.0 { back.signum() } else { c.signum() });
            if c.abs() > 1e-5 && c.abs() < scale * 64.0 {
                assert!((back - c).abs() < 0.1 * c.abs() + 2e-4 * scale * 64.0,
                        "c={c} back={back}");
            }
        }
    }

    #[test]
    fn quantized_wire_size_smaller() {
        let msgs: Vec<SeedUpdate> = (0..10)
            .map(|i| SeedUpdate {
                id: MsgId { origin: 0, step: i },
                seed: i as u64,
                coeff: 1e-4,
            })
            .collect();
        let full = Payload::Seeds(msgs.clone()).wire_bytes();
        let quant = Payload::SeedsQuantized(msgs).wire_bytes();
        assert_eq!(full, 200);
        assert_eq!(quant, 90);
    }

    #[test]
    fn quantize_zero_roundtrips_exactly() {
        // regression: c = 0.0 used to decode to a small positive value
        // (the 127.5 midpoint has no exact-zero code), injecting a
        // systematic drift under --quantize-msgs
        for scale in [1e-5f32, 1e-3, 1.0] {
            let q = SeedUpdate::quantize_coeff(0.0, scale);
            assert_eq!(SeedUpdate::dequantize_coeff(q, scale), 0.0, "scale {scale}");
        }
        // the zero code does not break decode monotonicity around zero,
        // and the mid-tread grid is symmetric: ±c map to mirrored codes,
        // so near-zero noise carries no systematic sign bias
        let scale = 1e-3;
        assert!(SeedUpdate::dequantize_coeff(127, scale) < 0.0);
        assert!(SeedUpdate::dequantize_coeff(129, scale) > 0.0);
        for k in 1..=127u8 {
            assert_eq!(
                SeedUpdate::dequantize_coeff(128 + k, scale),
                -SeedUpdate::dequantize_coeff(128 - k, scale),
                "code {k}"
            );
        }
        for c in [1e-12f32, 3e-4, 0.02] {
            assert_eq!(
                SeedUpdate::quantize_coeff(c, scale) as i32 - 128,
                128 - SeedUpdate::quantize_coeff(-c, scale) as i32,
                "c={c}"
            );
        }
    }

    #[test]
    fn repair_payload_wire_sizes_and_accounting() {
        let mut net = Network::new(Topology::ring(4));
        let summary = Payload::Summary(Arc::new(vec![5, 0, 3, 1]));
        assert_eq!(summary.wire_bytes(), 8 + 4 * 4);
        let msgs: Vec<SeedUpdate> = (0..3)
            .map(|i| SeedUpdate {
                id: MsgId { origin: 0, step: i },
                seed: i as u64,
                coeff: 1.0,
            })
            .collect();
        let gap = Payload::GapFill { msgs: msgs.clone(), quantized: false };
        assert_eq!(gap.wire_bytes(), 8 + 3 * SeedUpdate::WIRE_BYTES);
        // quantized runs cost their repair traffic at the quantized rate
        let gap_q = Payload::GapFill { msgs, quantized: true };
        assert_eq!(gap_q.wire_bytes(), 8 + 3 * SeedUpdate::WIRE_BYTES_QUANTIZED);
        net.send(0, 1, summary);
        net.send(1, 0, gap);
        net.send(0, 1, seed_payload(2)); // normal traffic is not repair
        assert_eq!(net.acct.repair_bytes, 24 + 68);
        assert_eq!(net.acct.repair_messages, 2);
        assert_eq!(net.acct.total_bytes, 24 + 68 + 40);
    }

    #[test]
    fn byte_accounting_dense_and_sparse() {
        let mut net = Network::new(Topology::ring(4));
        let p = Arc::new(ParamVec::new(
            vec!["w".into()],
            vec![Tensor::zeros(&[10, 10])],
        ));
        net.send(0, 1, Payload::Dense(p));
        assert_eq!(net.acct.total_bytes, 16 + 400);
        let sparse = Arc::new(vec![vec![(0u32, 1.0f32); 7]]);
        net.send(1, 2, Payload::Sparse(sparse));
        assert_eq!(net.acct.total_bytes, 16 + 400 + 16 + 56);
    }

    #[test]
    fn broadcast_hits_all_neighbors() {
        let mut net = Network::new(Topology::star(5));
        net.broadcast(0, &seed_payload(1));
        for i in 1..5 {
            assert_eq!(net.recv_all(i).len(), 1);
        }
        assert_eq!(net.acct.total_messages, 4);
    }

    #[test]
    fn recv_all_orders_sources_ascending() {
        // the reverse-adjacency fast path must keep the historical
        // ascending-source drain order (engine determinism contract)
        let mut net = Network::new(Topology::star(5));
        for src in [3usize, 1, 4, 2] {
            net.send(src, 0, seed_payload(src));
        }
        let froms: Vec<usize> = net.recv_all(0).iter().map(|m| m.from).collect();
        assert_eq!(froms, vec![1, 2, 3, 4]);
    }

    #[test]
    fn out_edges_match_neighbors() {
        let net = Network::new(Topology::meshgrid(9));
        for src in 0..9 {
            let dsts: Vec<usize> = net.out_edges(src).iter().map(|&(d, _)| d).collect();
            assert_eq!(dsts, net.topology().neighbors(src).to_vec());
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut net = Network::new(Topology::ring(3));
        for k in 0..5 {
            net.send(0, 1, seed_payload(k + 1));
        }
        let msgs = net.recv_all(1);
        let lens: Vec<usize> = msgs
            .iter()
            .map(|m| match &m.payload {
                Payload::Seeds(v) => v.len(),
                _ => 0,
            })
            .collect();
        assert_eq!(lens, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn offline_receiver_blackholes_new_sends() {
        let mut net = Network::new(Topology::ring(4));
        net.install(&NetCond {
            events: vec![Event::Node { id: 1, from: 0, until: 10 }],
            ..Default::default()
        })
        .unwrap();
        net.set_step(0);
        net.send(0, 1, seed_payload(1));
        assert!(net.recv_all(1).is_empty());
        // still counted as transmitted, and counted as dropped
        assert_eq!(net.acct.total_messages, 1);
        assert_eq!(net.acct.dropped_messages, 1);
        // ...while the offline *sender* costs nothing
        net.send(1, 2, seed_payload(1));
        assert_eq!(net.acct.total_messages, 1);
    }

    #[test]
    fn seeded_loss_loses_some_deterministically() {
        let run = || {
            let mut net = Network::new(Topology::ring(4));
            net.install(&NetCond { loss: 0.5, ..Default::default() }).unwrap();
            for _ in 0..200 {
                net.send(0, 1, seed_payload(1));
            }
            net.recv_all(1).len()
        };
        let got = run();
        assert!(got > 50 && got < 150, "got {got}");
        // dedicated seeded stream → bit-for-bit reproducible loss pattern
        assert_eq!(got, run());
    }

    #[test]
    fn link_down_window_drops_then_recovers() {
        let mut net = Network::new(Topology::ring(4));
        net.install(&NetCond {
            events: vec![Event::Link { a: 0, b: 1, from: 2, until: 4 }],
            ..Default::default()
        })
        .unwrap();
        net.set_step(2);
        net.send(0, 1, seed_payload(1)); // down window: dropped both ways
        net.send(1, 0, seed_payload(1));
        assert!(net.recv_all(1).is_empty());
        assert!(net.recv_all(0).is_empty());
        assert_eq!(net.acct.dropped_messages, 2);
        // other links unaffected
        net.send(1, 2, seed_payload(1));
        assert_eq!(net.recv_all(2).len(), 1);
        net.set_step(4); // window closed: both endpoints see a recovery
        assert!(net.should_repair(0) && net.should_repair(1));
        assert!(!net.should_repair(3));
        net.send(0, 1, seed_payload(1));
        assert_eq!(net.recv_all(1).len(), 1);
    }

    #[test]
    fn in_flight_message_dies_when_link_cut_mid_flight() {
        // links don't buffer: an in-flight delayed message is purged the
        // moment the schedule cuts the link — independent of when (or
        // whether) the receiver polls during the outage, so an overlapping
        // receiver churn window cannot resurrect it afterwards
        let mut net = Network::new(Topology::ring(4));
        net.install(&NetCond {
            delay: 2,
            events: vec![
                Event::Link { a: 0, b: 1, from: 1, until: 3 },
                Event::Node { id: 1, from: 1, until: 4 },
            ],
            ..Default::default()
        })
        .unwrap();
        net.set_step(0);
        net.send(0, 1, seed_payload(1)); // link up at send, due at round 2
        net.tick();
        net.tick();
        net.set_step(1); // link cut with the packet in flight → purged
        assert_eq!(net.acct.dropped_messages, 1);
        net.set_step(4); // link and receiver both back up — packet is gone
        assert!(net.recv_all(1).is_empty());
    }

    #[test]
    fn delay_defers_delivery_until_tick() {
        let mut net = Network::new(Topology::ring(4));
        net.install(&NetCond { delay: 2, ..Default::default() }).unwrap();
        net.send(0, 1, seed_payload(1));
        assert!(net.recv_all(1).is_empty());
        assert_eq!(net.in_flight(), 1, "delayed message is in flight");
        net.tick();
        assert!(net.recv_all(1).is_empty());
        net.tick();
        assert_eq!(net.recv_all(1).len(), 1);
        assert_eq!(net.in_flight(), 0, "delivery must drain the in-flight count");
    }

    #[test]
    fn in_flight_tracks_queues_through_drops_and_purges() {
        let mut net = Network::new(Topology::ring(4));
        assert_eq!(net.in_flight(), 0);
        net.send(0, 1, seed_payload(1));
        assert_eq!(net.in_flight(), 1);
        net.recv_all(1);
        assert_eq!(net.in_flight(), 0);
        // a loss-dropped send never enters a queue
        net.install(&NetCond {
            delay: 1,
            events: vec![Event::Link { a: 0, b: 1, from: 1, until: 2 }],
            ..Default::default()
        })
        .unwrap();
        net.set_step(0);
        net.send(0, 1, seed_payload(1)); // queued, due next round
        assert_eq!(net.in_flight(), 1);
        net.set_step(1); // link cut: the in-flight message is purged
        assert_eq!(net.in_flight(), 0);
        net.send(0, 1, seed_payload(1)); // down link: dropped at send
        assert_eq!(net.in_flight(), 0);
    }

    #[test]
    fn node_recovery_triggers_neighbor_repair_and_buffered_delivery() {
        let mut net = Network::new(Topology::ring(4));
        net.install(&NetCond {
            delay: 1,
            events: vec![Event::Node { id: 2, from: 1, until: 3 }],
            ..Default::default()
        })
        .unwrap();
        net.set_step(0);
        net.send(1, 2, seed_payload(1)); // in flight when 2 goes down
        net.tick();
        net.set_step(1);
        assert!(!net.is_online(2));
        assert!(net.recv_all(2).is_empty()); // buffered, not dropped
        net.set_step(3); // node 2 rejoins
        assert!(net.is_online(2));
        // the node itself and its ring neighbors all see the recovery
        assert!(net.should_repair(2) && net.should_repair(1) && net.should_repair(3));
        assert!(!net.should_repair(0));
        assert_eq!(net.recv_all(2).len(), 1); // buffered message delivered
    }

    #[test]
    fn periodic_repair_heartbeat() {
        let mut net = Network::new(Topology::ring(4));
        net.install(&NetCond { loss: 0.1, repair_every: 3, ..Default::default() })
            .unwrap();
        for (t, due) in [(0, false), (1, false), (2, false), (3, true), (4, false), (6, true)] {
            net.set_step(t);
            assert_eq!(net.should_repair(0), due, "step {t}");
        }
    }

    #[test]
    fn zero_cond_behaves_like_no_cond() {
        let run = |install: bool| {
            let mut net = Network::new(Topology::ring(4));
            if install {
                net.install(&NetCond { loss: 0.0, ..Default::default() }).unwrap();
            }
            for t in 0..5 {
                net.set_step(t);
                net.tick();
                net.send(0, 1, seed_payload(t + 1));
            }
            let got = net.recv_all(1).len();
            (got, net.acct.total_bytes, net.acct.dropped_messages)
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn per_edge_bytes_convention() {
        let mut net = Network::new(Topology::ring(4)); // 8 directed edges
        net.send(0, 1, seed_payload(2)); // 40 bytes
        assert_eq!(net.per_edge_bytes(), 40.0 / 8.0);
    }
}
